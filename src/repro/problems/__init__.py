"""Branching problems (plug-ins for the paper's Algorithm 1 / 2 structure)."""

from repro.problems.sequential import (
    SeqStats,
    reduce_instance,
    branch_once,
    solve_sequential,
    expand_frontier,
)

__all__ = [
    "SeqStats",
    "reduce_instance",
    "branch_once",
    "solve_sequential",
    "expand_frontier",
]
