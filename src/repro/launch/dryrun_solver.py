import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Dry-run of the PAPER'S OWN technique at production scale: the SPMD
superstep engine lowered with one worker per device on a 512-chip mesh.

Reports the same roofline terms as the LM cells, for the baseline engine
(3-int status rows, unconditional record all-gather — the straight port of
the protocol), the optimized control plane (bit-packed 1-int status + pmin
bound, data plane skipped on match-free rounds) and the sparse data plane
(masked-psum transfer: payload rows carry only matched records) — §Perf
cell C of EXPERIMENTS.md.  ``--chunked`` lowers the K-round device-resident
runner instead of a single superstep (the shape the production launcher
runs: one host sync per chunk).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun_solver [--n 1024] [--out f.json]
"""

import argparse
import json

import jax
import jax.numpy as jnp

from repro.core.superstep import (
    build_chunk_fn,
    build_superstep_fn,
    make_worker_state,
)
from repro.graphs.bitgraph import n_words
from repro.graphs.generators import erdos_renyi
from repro.launch.analysis import collective_bytes, roofline
from repro.launch.mesh import make_mesh_compat
from repro.problems.base import make_data
from repro.problems.registry import get_problem


def lower_engine(n: int, workers: int, *, packed_status, skip_empty_transfer,
                 transfer_impl="gather", steps_per_round=32, lanes=1,
                 codec_pad=0, chunked=False, chunk_rounds=16,
                 problem="vertex_cover"):
    mesh = make_mesh_compat((workers,), ("workers",))
    g = erdos_renyi(n, 4.0 / (n - 1), 0)
    spec = get_problem(problem)
    data = make_data(spec, g)
    W = n_words(n)
    cap = 4 * n + 8 * lanes
    kwargs = dict(
        num_workers=workers,
        steps_per_round=steps_per_round,
        lanes=lanes,
        transfer_pad_words=codec_pad,
        packed_status=packed_status,
        skip_empty_transfer=skip_empty_transfer,
        transfer_impl=transfer_impl,
        mesh=mesh,
    )
    if chunked:
        fn = build_chunk_fn(spec, data, chunk_rounds=chunk_rounds, **kwargs)
    else:
        fn = build_superstep_fn(spec, data, **kwargs)
    state = jax.eval_shape(
        lambda: jax.vmap(lambda _: make_worker_state(cap, W, n + 1))(
            jnp.arange(workers)
        )
    )
    lowered = fn.lower(state)
    compiled = lowered.compile()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):  # jax 0.4.x: one dict per device
        cost = cost[0] if cost else {}
    coll = collective_bytes(compiled.as_text())
    mem = compiled.memory_analysis()
    flops = float(cost.get("flops", 0.0))
    rl = roofline(flops, float(cost.get("bytes accessed", 0.0)), coll["total"])
    return {
        "n": n,
        "workers": workers,
        "packed_status": packed_status,
        "skip_empty_transfer": skip_empty_transfer,
        "transfer_impl": transfer_impl,
        "chunked": chunked,
        "flops_per_dev": flops,
        "collectives": {k: v for k, v in coll.items() if k != "counts"},
        "collective_counts": coll["counts"],
        "temp_b": int(getattr(mem, "temp_size_in_bytes", 0)),
        "roofline": rl,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=1024)
    ap.add_argument("--workers", type=int, default=512)
    ap.add_argument("--out", default=None)
    ap.add_argument("--chunked", action="store_true",
                    help="lower the K-round device-resident runner")
    ap.add_argument("--chunk-rounds", type=int, default=16)
    args = ap.parse_args()
    results = []
    for packed, skip, impl, label in [
        (False, False, "gather", "baseline (3-int status, unconditional gather)"),
        (True, False, "gather", "packed status word"),
        (True, True, "gather", "packed + skip-empty-transfer"),
        (True, True, "sparse", "packed + skip-empty + sparse psum transfer"),
    ]:
        r = lower_engine(
            args.n, args.workers, packed_status=packed,
            skip_empty_transfer=skip, transfer_impl=impl,
            chunked=args.chunked, chunk_rounds=args.chunk_rounds,
        )
        r["label"] = label
        results.append(r)
        c = r["collectives"]
        print(
            f"{label:>50s}: coll_total={c['total']/2**10:.1f}KiB "
            f"(ag={c['all-gather']/2**10:.1f} ar={c['all-reduce']/2**10:.1f}) "
            f"counts={r['collective_counts']} temp={r['temp_b']/2**20:.1f}MiB",
            flush=True,
        )
    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)


if __name__ == "__main__":
    main()
