"""The unified result schema every backend returns.

Before this layer each engine had its own result type — ``EngineResult``
(SPMD), ``SimResult`` (both discrete-event simulators), bare tuples
(sequential reference) — so callers special-cased per backend.
:class:`SolveResult` is the one schema: the solution and the universally
meaningful counters are first-class fields, and everything
backend-specific (byte accounting, message histograms, overflow flags)
rides in ``stats`` under stable keys.  :class:`BatchSolveResult` is the
``solve_many`` analogue, preserving submission order.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


@dataclasses.dataclass
class SolveResult:
    """One instance solved by one backend.

    ``best_size`` is in the problem's EXTERNAL objective (``-1`` for an
    unsatisfiable FPT decision); ``rounds`` counts the backend's native
    progress unit (supersteps for spmd, simulator ticks for the two
    discrete-event backends, expanded nodes for sequential).
    """

    problem: str
    backend: str
    best_size: int
    best_sol: Optional[np.ndarray]
    found: bool
    wall_s: float
    rounds: int
    nodes_expanded: int
    tasks_transferred: int
    stats: dict = dataclasses.field(default_factory=dict)

    def to_dict(self) -> dict:
        """JSON-safe view (``best_sol`` as a list of packed u32 words)."""
        d = dataclasses.asdict(self)
        if self.best_sol is not None:
            d["best_sol"] = [int(w) for w in np.asarray(self.best_sol, np.uint32)]
        d["stats"] = _jsonable(self.stats)
        return d


@dataclasses.dataclass
class BatchSolveResult:
    """Per-instance results of one batched solve; ``results[i]`` corresponds
    to ``graphs[i]`` (submission order survives bucketing/compaction).

    ``buckets`` is the packing record — one ``(W, n_max, [indices])`` triple
    per compiled bucket (empty for backends that solve instance-by-
    instance); ``compactions`` counts host-side batch compactions.

    ``lane_stats`` reports plane occupancy: ``chunk_calls`` (compiled chunk
    dispatches), ``lane_chunks`` (chunk_calls × plane width — paid lane
    slots), ``live_lane_chunks`` (slots that held an unfinished instance)
    and their ratio ``occupancy`` — the utilization a continuous-admission
    service raises over fixed batching (empty where not tracked).
    """

    problem: str
    backend: str
    results: list
    wall_s: float
    buckets: list = dataclasses.field(default_factory=list)
    compactions: int = 0
    lane_stats: dict = dataclasses.field(default_factory=dict)

    def __len__(self) -> int:
        return len(self.results)

    def __iter__(self):
        return iter(self.results)


def _jsonable(obj):
    if isinstance(obj, dict):
        return {str(k): _jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_jsonable(v) for v in obj]
    if isinstance(obj, np.generic):
        return obj.item()
    return obj


# -- converters from the legacy per-engine schemas -----------------------------


def from_engine_result(r, *, problem: str, backend: str = "spmd") -> SolveResult:
    """Wrap a :class:`repro.core.engine.EngineResult`."""
    return SolveResult(
        problem=problem,
        backend=backend,
        best_size=r.best_size,
        best_sol=r.best_sol,
        found=r.best_sol is not None,
        wall_s=r.wall_s,
        rounds=r.rounds,
        nodes_expanded=r.nodes_expanded,
        tasks_transferred=r.tasks_transferred,
        stats={
            "overflow": r.overflow,
            "overflow_count": r.overflow_count,
            "control_bytes_per_round": r.control_bytes_per_round,
            "transfer_rounds": r.transfer_rounds,
            "transfer_bytes_total": r.transfer_bytes_total,
            "transfer_bytes_per_round": r.transfer_bytes_per_round,
        },
    )


def from_sim_result(r, *, problem: str, backend: str, wall_s: float) -> SolveResult:
    """Wrap a :class:`repro.core.protocol_sim.SimResult` (both simulators)."""
    s = r.stats
    return SolveResult(
        problem=problem,
        backend=backend,
        best_size=r.best_size,
        best_sol=r.best_sol,
        found=r.best_sol is not None,
        wall_s=wall_s,
        rounds=r.ticks,
        nodes_expanded=s.nodes_expanded,
        tasks_transferred=s.tasks_transferred,
        stats={
            # host explorers keep unbounded Python frontiers: nothing to drop
            "overflow_count": 0,
            "ticks": r.ticks,
            "failed_requests": s.failed_requests,
            "termination_cancelled": s.termination_cancelled,
            "total_bytes": s.total_bytes,
            "center_bytes": s.center_bytes,
            "msg_count": dict(s.msg_count),
            "msg_bytes": dict(s.msg_bytes),
        },
    )


def from_sequential(best, sol, stats, *, problem: str, wall_s: float) -> SolveResult:
    """Wrap the sequential reference's ``(best, sol, SeqStats)`` triple."""
    return SolveResult(
        problem=problem,
        backend="sequential",
        best_size=best,
        best_sol=sol,
        found=sol is not None,
        wall_s=wall_s,
        rounds=stats.nodes,
        nodes_expanded=stats.nodes,
        tasks_transferred=0,
        stats={
            "overflow_count": 0,  # host recursion: no fixed-capacity pool
            "pruned": stats.pruned,
            "solutions": stats.solutions,
            "max_depth": stats.max_depth,
        },
    )
