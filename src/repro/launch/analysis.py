"""Roofline-term extraction from compiled dry-run artifacts.

Hardware model: TPU v5e —
  peak_flops  = 197e12  bf16 FLOP/s per chip
  hbm_bw      = 819e9   B/s per chip
  ici_bw      = 4.5e10  B/s per link (~50 GB/s markets as 45-50; we use 45)

Terms (per device, per step):
  compute    = HLO_FLOPs / peak_flops          (cost_analysis 'flops' is the
                                                per-device partitioned module)
  memory     = HLO_bytes / hbm_bw              (cost_analysis 'bytes accessed')
  collective = collective_bytes / ici_bw

collective_bytes convention (documented in EXPERIMENTS.md): the sum over
collective ops of the RESULT buffer size, weighted 2× for all-reduce (ring
reduce-scatter + all-gather moves ~2× payload per device) and 1× otherwise —
a standard per-device link-traffic estimate for ring algorithms.
"""

from __future__ import annotations

import re

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 45e9

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    b = _DTYPE_BYTES.get(dtype)
    if b is None:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * b


def collective_bytes(hlo_text: str) -> dict:
    """Parse an HLO module dump; returns {op_kind: bytes, 'total': bytes}."""
    out = {k: 0 for k in _COLLECTIVES}
    count = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if "=" not in stripped:
            continue
        lhs, _, rhs = stripped.partition("=")
        kind = None
        for k in _COLLECTIVES:
            # match the op name at the start of the RHS expression
            if re.search(rf"(^|\)|\s){k}(-start|-done)?\(", rhs):
                kind = k
                break
        if kind is None:
            continue
        if f"{kind}-done(" in rhs:
            continue  # the -start op already carries the shape
        # result shape(s) appear on the RHS before the op name
        head = rhs.split(f"{kind}", 1)[0]
        nbytes = sum(_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(head))
        weight = 2 if kind == "all-reduce" else 1
        out[kind] += weight * nbytes
        count[kind] += 1
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    out["counts"] = count
    return out


def roofline(flops: float, bytes_accessed: float, coll_bytes: float) -> dict:
    compute_s = flops / PEAK_FLOPS
    memory_s = bytes_accessed / HBM_BW
    coll_s = coll_bytes / ICI_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s, "collective_s": coll_s}
    dominant = max(terms, key=terms.get)
    bound_s = max(compute_s, memory_s, coll_s)
    return {
        **terms,
        "dominant": dominant.replace("_s", ""),
        "bound_s": bound_s,
        # fraction of the roofline the compute term occupies: 1.0 means the
        # step is perfectly compute-bound (the best a fixed algorithm can do)
        "compute_fraction": compute_s / bound_s if bound_s else 0.0,
    }


def model_flops(cfg, shape) -> float:
    """6·N·D (dense) / 6·N_active·D (MoE) useful-FLOPs estimate; forward-only
    kinds use 2·N·D.  D = tokens processed in the step."""
    n_active = cfg.active_params_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch
