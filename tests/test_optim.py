"""AdamW + schedule + clipping behaviour."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim.adamw import adamw_init, adamw_update, cosine_schedule, global_norm


def test_quadratic_converges():
    params = {"w": jnp.asarray([5.0, -3.0])}
    opt = adamw_init(params)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    for _ in range(300):
        g = jax.grad(loss)(params)
        params, opt, _ = adamw_update(
            params, g, opt, peak_lr=0.05, warmup_steps=10, total_steps=300,
            weight_decay=0.0,
        )
    assert float(loss(params)) < 1e-2


def test_clipping():
    params = {"w": jnp.zeros(4)}
    opt = adamw_init(params)
    huge = {"w": jnp.full(4, 1e9)}
    _, _, stats = adamw_update(params, huge, opt, clip_norm=1.0)
    assert float(stats["grad_norm"]) > 1e8  # reported pre-clip
    # post-clip update magnitude is bounded by lr * O(1)


def test_schedule_shape():
    s0 = cosine_schedule(jnp.int32(0), peak_lr=1.0, warmup_steps=10, total_steps=100)
    s10 = cosine_schedule(jnp.int32(10), peak_lr=1.0, warmup_steps=10, total_steps=100)
    s100 = cosine_schedule(jnp.int32(100), peak_lr=1.0, warmup_steps=10, total_steps=100)
    assert float(s0) == 0.0
    assert abs(float(s10) - 1.0) < 1e-6
    assert 0.0 < float(s100) <= 0.11  # decays to final_frac * peak


def test_moments_dtype_fp32():
    params = {"w": jnp.zeros(3, jnp.bfloat16)}
    opt = adamw_init(params)
    assert opt.m["w"].dtype == jnp.float32
    g = {"w": jnp.ones(3, jnp.bfloat16)}
    p2, opt2, _ = adamw_update(params, g, opt)
    assert p2["w"].dtype == jnp.bfloat16
    assert opt2.v["w"].dtype == jnp.float32


def test_global_norm():
    t = {"a": jnp.asarray([3.0]), "b": jnp.asarray([4.0])}
    assert abs(float(global_norm(t)) - 5.0) < 1e-6
