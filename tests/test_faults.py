"""Self-healing solve plane (``repro.faults``): the recovery contracts.

The fault machinery's promise is that a deterministic fault schedule is
*observable only in the ledgers*: every injected fault is recovered, no
task is lost, and the incumbent/witness the solve lands on is bit-identical
to the fault-free run.  Grouped by tier:

1. **Plans** — seeded schedules are reproducible and JSON round-trip.
2. **Checksums** — every single-bit flip of a checked task record is
   caught (property-tested over flip positions).
3. **Checkpoint I/O retry** — bounded exponential backoff with injectable
   sleep/rng; the injector's io_hook drives the store's retry loop to a
   clean write and books the recovery.
4. **Generation retention** — a corrupted newest generation falls back to
   the retained older one with a loud warning; all-corrupt still raises.
5. **Crash anywhere** — a lane/worker crash at ANY chunk boundary leaves
   solo / fpt / solve_many / service results bit-identical (re-admission
   from tracked placement is a true replay).
6. **Cold-tier corruption** — the spill pump conserves the task multiset
   exactly under injected payload corruption (PR-9's no-drop claim holds
   under faults, not just under pressure).
7. **Quarantine + degradation** — crashed lanes are quarantined, their
   requests re-admitted, and the shed/heal accounting surfaces in stats.
8. **Timeouts** — ``request_timeout_s`` turns a hung request (queued or
   on-lane) into a typed :class:`SolveTimeout`; an awaited async solve can
   never hang.
"""

import asyncio
import json
import time

import numpy as np
import pytest

from repro.api import PlaneCache, SolveConfig, SolverSession, SolveTimeout
from repro.api.service import AsyncSolveService
from repro.checkpoint.solve import SolveCheckpoint
from repro.checkpoint.store import (
    RetryPolicy,
    call_with_retry,
    latest_step,
    save_checkpoint,
)
from repro.core.encoding import (
    PayloadCorruptionError,
    checked_record,
    make_codec,
    strip_record,
    verify_record,
)
from repro.core.spill import FrontierSpiller
from repro.faults import FAULT_KINDS, FaultEvent, FaultInjector, FaultPlan
from repro.graphs.generators import erdos_renyi
from repro.problems.sequential import solve_sequential
from tests._hypothesis_compat import given, settings, strategies as st

# one warm plane cache for the whole module: property examples re-solve the
# same shapes many times and must not recompile each time
_CACHE = PlaneCache()
_BASELINES: dict = {}


def _clock():
    class FakeClock:
        def __init__(self):
            self.t = 0.0

        def __call__(self):
            return self.t

    return FakeClock()


# -- 1. plans ------------------------------------------------------------------


def test_fault_plan_random_is_seed_deterministic():
    a = FaultPlan.random(7, n_events=12, lanes=4)
    b = FaultPlan.random(7, n_events=12, lanes=4)
    assert a == b and len(a.events) == 12
    assert FaultPlan.random(8, n_events=12, lanes=4) != a
    assert sum(a.counts().values()) == 12
    for ev in a.events:
        assert ev.kind in FAULT_KINDS
        if ev.kind == "io_error":
            assert ev.op in ("write", "read")


def test_fault_plan_json_roundtrip_and_sort():
    plan = FaultPlan(
        seed=3,
        events=(
            FaultEvent("io_error", at=5, op="read"),
            FaultEvent("crash", at=1, lane=2),
            FaultEvent("stall", at=1, lane=0, duration=3),
        ),
    )
    # events normalize to (at, kind, lane) order regardless of input order
    assert [e.kind for e in plan.events] == ["crash", "stall", "io_error"]
    back = FaultPlan.from_dict(json.loads(json.dumps(plan.to_dict())))
    assert back == plan


def test_fault_event_validation():
    with pytest.raises(ValueError, match="kind"):
        FaultEvent("meteor", at=0)
    with pytest.raises(ValueError, match="bad fault event"):
        FaultEvent("crash", at=-1)
    with pytest.raises(ValueError, match="bad fault event"):
        FaultEvent("stall", at=0, duration=0)
    with pytest.raises(ValueError, match="io op"):
        FaultEvent("io_error", at=0, op="fsync")


# -- 2. checksums --------------------------------------------------------------


def test_checked_record_roundtrip():
    rec = (np.arange(17, dtype=np.uint64) * 2654435761 % (1 << 32)).astype(
        np.uint32
    )
    ck = checked_record(rec)
    assert ck.size == rec.size + 1
    assert verify_record(ck)
    assert (strip_record(ck) == rec).all()


@settings(max_examples=60, deadline=None)
@given(st.integers(0, 10_000), st.integers(0, 31))
def test_any_single_bit_flip_is_caught(pos, bit):
    """CRC32 detects EVERY single-bit error — including one in the checksum
    word itself — so one redelivery from the intact source always heals a
    transfer/cold corruption."""
    rec = (np.arange(9, dtype=np.uint64) * 2654435761 % (1 << 32)).astype(
        np.uint32
    )
    ck = checked_record(rec)
    bad = ck.copy()
    i = pos % bad.size
    bad[i] = np.uint32(int(bad[i]) ^ (1 << bit))
    assert not verify_record(bad)
    with pytest.raises(PayloadCorruptionError):
        strip_record(bad)


# -- 3. retry/backoff ----------------------------------------------------------


def test_call_with_retry_backs_off_exponentially():
    sleeps = []
    policy = RetryPolicy(
        max_attempts=4, base_s=0.05, sleep=sleeps.append
    )
    calls = []

    def flaky():
        calls.append(1)
        if len(calls) < 3:
            raise OSError("transient")
        return "ok"

    with pytest.warns(RuntimeWarning, match="retrying"):
        assert call_with_retry(flaky, policy, what="unit I/O") == "ok"
    assert len(calls) == 3 and policy.retries == 2
    # exponential with multiplicative jitter in [1, 1.25]: the second delay
    # is 2x the base of the first, so their ratio stays in [2/1.25, 2*1.25]
    assert len(sleeps) == 2
    assert 0.05 <= sleeps[0] <= 0.05 * 1.25
    assert 2 / 1.25 <= sleeps[1] / sleeps[0] <= 2 * 1.25


def test_call_with_retry_exhausts_and_raises():
    policy = RetryPolicy(max_attempts=3, sleep=lambda s: None)

    def broken():
        raise OSError("permanent")

    with pytest.warns(RuntimeWarning):
        with pytest.raises(OSError, match="permanent"):
            call_with_retry(broken, policy)
    assert policy.retries == 2  # attempts beyond the first, all wasted


def test_call_with_retry_passes_corruption_through():
    """Only ``retry_on`` (I/O flakes) retries — corrupt CONTENT is not a
    flake and must fall through to the generation-fallback path at once."""
    policy = RetryPolicy(max_attempts=5, sleep=lambda s: None)
    calls = []

    def corrupt():
        calls.append(1)
        raise ValueError("checksum mismatch")

    with pytest.raises(ValueError):
        call_with_retry(corrupt, policy)
    assert len(calls) == 1 and policy.retries == 0


def test_injector_io_hook_drives_store_retry(tmp_path):
    """An injected write fault makes the first attempt raise; the store's
    backoff loop re-enters (virtual sleep, no waiting), the second attempt
    lands, and the injector books injected == recovered plus the retry."""
    inj = FaultInjector(
        FaultPlan(seed=0, events=(FaultEvent("io_error", at=0, op="write"),))
    )
    tree = {"x": np.arange(6, dtype=np.int32)}
    with pytest.warns(RuntimeWarning, match="checkpoint write"):
        save_checkpoint(
            str(tmp_path), 0, tree,
            retry=inj.retry_policy(), fault_hook=inj.io_hook,
        )
    assert latest_step(str(tmp_path)) == 0
    assert inj.injected["io_error"] == 1
    assert inj.recovered["io_error"] == 1
    assert inj.retries == 1
    assert inj.clock_s > 0  # backoff elapsed on the VIRTUAL clock only
    assert inj.report()["pending"] == 0


# -- 4. generation retention + corruption fallback -----------------------------


def _corrupt(step_dir) -> None:
    p = step_dir / "arrays.npz"
    raw = bytearray(p.read_bytes())
    raw[len(raw) // 2] ^= 0xFF
    p.write_bytes(bytes(raw))


def test_corrupt_generation_falls_back_to_older(tmp_path):
    g = erdos_renyi(24, 0.3, 2)
    cfg = SolveConfig(
        num_workers=4, steps_per_round=2, chunk_rounds=1, checkpoint_every=1
    )
    sess = SolverSession("vertex_cover", config=cfg, cache=_CACHE)
    base = sess.solve(g)
    sess.solve(g, checkpoint_dir=str(tmp_path))
    steps = sorted(
        int(p.name.split("_")[1])
        for p in tmp_path.iterdir()
        if p.name.startswith("step_") and not p.name.endswith(".prev")
    )
    assert len(steps) >= 2

    # newest generation corrupt: resume warns LOUDLY and replays from the
    # older one — landing on the same answer
    _corrupt(tmp_path / f"step_{steps[-1]}")
    with pytest.warns(RuntimeWarning, match="OLDER checkpoint generation"):
        res = SolverSession.resume(str(tmp_path), cache=_CACHE)
    assert res.best_size == base.best_size
    assert (np.asarray(res.best_sol) == np.asarray(base.best_sol)).all()

    # every generation corrupt: fail loudly, not silently from scratch
    for s in steps:
        _corrupt(tmp_path / f"step_{s}")
    with pytest.raises(Exception, match="corrupt|checksum"):
        SolveCheckpoint.load_latest_good(str(tmp_path))


# -- 5. crash anywhere ---------------------------------------------------------


def _solo_case():
    if "solo" not in _BASELINES:
        g = erdos_renyi(30, 0.3, 5)
        cfg = SolveConfig(num_workers=4, steps_per_round=2, chunk_rounds=1)
        sess = SolverSession("vertex_cover", config=cfg, cache=_CACHE)
        _BASELINES["solo"] = (g, sess, sess.solve(g))
    return _BASELINES["solo"]


@settings(max_examples=6, deadline=None)
@given(st.integers(0, 12))
def test_solo_crash_at_any_boundary_is_bit_identical(boundary):
    g, sess, base = _solo_case()
    inj = FaultInjector(
        FaultPlan(seed=0, events=(FaultEvent("crash", at=boundary),))
    )
    r = sess.solve(g, injector=inj)
    assert r.best_size == base.best_size
    assert (np.asarray(r.best_sol) == np.asarray(base.best_sol)).all()
    assert r.rounds == base.rounds
    assert r.stats.overflow_count == 0
    # fired -> recovered; scheduled past the end -> never fired: either way
    # nothing is left half-injected
    assert inj.injected["crash"] == inj.recovered["crash"]


@settings(max_examples=4, deadline=None)
@given(st.integers(0, 10))
def test_fpt_crash_keeps_the_witness(boundary):
    if "fpt" not in _BASELINES:
        g = erdos_renyi(26, 0.3, 4)
        k = solve_sequential(g)[0]
        cfg = SolveConfig(
            num_workers=4, steps_per_round=2, chunk_rounds=1, mode="fpt", k=k
        )
        sess = SolverSession("vertex_cover", config=cfg, cache=_CACHE)
        _BASELINES["fpt"] = (g, sess, sess.solve(g))
    g, sess, base = _BASELINES["fpt"]
    inj = FaultInjector(
        FaultPlan(seed=0, events=(FaultEvent("crash", at=boundary),))
    )
    r = sess.solve(g, injector=inj)
    assert (r.found, r.best_size) == (base.found, base.best_size)
    assert (np.asarray(r.best_sol) == np.asarray(base.best_sol)).all()


@settings(max_examples=4, deadline=None)
@given(st.integers(0, 8), st.integers(0, 3))
def test_solve_many_crash_at_any_boundary_is_bit_identical(boundary, lane):
    if "many" not in _BASELINES:
        gs = [erdos_renyi(26, 0.3, 20 + i) for i in range(2)]
        cfg = SolveConfig(num_workers=4, steps_per_round=2, chunk_rounds=1)
        sess = SolverSession("vertex_cover", config=cfg, cache=_CACHE)
        _BASELINES["many"] = (gs, sess, sess.solve_many(gs))
    gs, sess, base = _BASELINES["many"]
    inj = FaultInjector(
        FaultPlan(
            seed=0, events=(FaultEvent("crash", at=boundary, lane=lane),)
        )
    )
    out = sess.solve_many(gs, injector=inj)
    for got, want in zip(out.results, base.results):
        assert got.best_size == want.best_size
        assert (
            np.asarray(got.best_sol) == np.asarray(want.best_sol)
        ).all()
        assert got.stats.overflow_count == 0
    assert inj.injected["crash"] == inj.recovered["crash"]


@settings(max_examples=4, deadline=None)
@given(st.integers(0, 8), st.integers(0, 3))
def test_service_crash_at_any_boundary_is_bit_identical(boundary, lane):
    if "service" not in _BASELINES:
        gs = [erdos_renyi(26, 0.3, 30 + i) for i in range(3)]
        cfg = SolveConfig(
            num_workers=4, steps_per_round=2, chunk_rounds=1,
            service_lanes=2,
        )
        sess = SolverSession("vertex_cover", config=cfg, cache=_CACHE)
        svc = sess.serve()
        tix = [svc.submit(g) for g in gs]
        svc.drain()
        _BASELINES["service"] = (
            gs, sess, {i: svc.result(t) for i, t in enumerate(tix)}
        )
    gs, sess, want = _BASELINES["service"]
    inj = FaultInjector(
        FaultPlan(
            seed=0, events=(FaultEvent("crash", at=boundary, lane=lane),)
        )
    )
    svc = sess.serve(injector=inj)
    tix = [svc.submit(g) for g in gs]
    svc.drain()
    for i, t in enumerate(tix):
        got = svc.result(t)
        assert got.best_size == want[i].best_size
        assert (
            np.asarray(got.best_sol) == np.asarray(want[i].best_sol)
        ).all()
    assert inj.injected["crash"] == inj.recovered["crash"]
    s = svc.stats()
    assert s["lanes_quarantined"] == inj.injected["crash"]
    assert s["faults_injected"] == inj.faults_injected


# -- 6. cold-tier corruption conserves the task multiset -----------------------


def _pool(P=4, CAP=32, W=1, per_worker=30):
    masks = np.zeros((P, CAP, W), np.uint32)
    sols = np.zeros((P, CAP, W), np.uint32)
    depths = np.zeros((P, CAP), np.int32)
    active = np.zeros((P, CAP), bool)
    for w in range(P):
        for s in range(per_worker):
            masks[w, s] = w * CAP + s + 1
            depths[w, s] = (w * per_worker + s) % 24
            active[w, s] = True
    return masks, sols, depths, active


def _pool_keys(masks, depths, active):
    return sorted(
        (int(masks[w, s, 0]), int(depths[w, s]))
        for w, s in zip(*np.nonzero(active))
    )


def test_pump_host_conserves_multiset_under_injected_corruption():
    events = tuple(
        FaultEvent("cold_corrupt", at=0) for _ in range(3)
    ) + tuple(FaultEvent("transfer_corrupt", at=0) for _ in range(3))
    inj = FaultInjector(FaultPlan(seed=9, events=events))
    sp = FrontierSpiller(
        make_codec("optimized", 12), 4, 32, (0.25, 0.75),
        chunk_rounds=1, steps_per_round=2, lanes=1, donate_k=1,
        injector=inj,
    )
    masks, sols, depths, active = _pool()
    before = _pool_keys(masks, depths, active)
    assert sp.pump_host(masks, sols, depths, active)
    recovered = _pool_keys(masks, depths, active)
    while sp.cold_tasks:
        m2, s2 = np.zeros_like(masks), np.zeros_like(sols)
        d2, a2 = np.zeros_like(depths), np.zeros_like(active)
        assert sp.pump_host(m2, s2, d2, a2)
        recovered += _pool_keys(m2, d2, a2)
    # the multiset survives corruption exactly: no drop, no duplication
    assert sorted(recovered) == before
    assert sp.readmitted_total == sp.spilled_total
    for kind in ("cold_corrupt", "transfer_corrupt"):
        assert inj.injected[kind] >= 1
        assert inj.injected[kind] == inj.recovered[kind]
    assert sp.delivery_retries == inj.retries == inj.faults_injected


def test_saturated_solve_unchanged_by_payload_corruption():
    g = erdos_renyi(40, 0.28, 0)
    cfg = SolveConfig(
        num_workers=4, steps_per_round=2, chunk_rounds=2, capacity=16,
        frontier_spill=True,
    )
    sess = SolverSession("vertex_cover", config=cfg, cache=_CACHE)
    base = sess.solve(g)
    assert base.stats.spilled_tasks > 0
    inj = FaultInjector(
        FaultPlan(
            seed=2,
            events=(
                FaultEvent("transfer_corrupt", at=1),
                FaultEvent("cold_corrupt", at=2),
            ),
        )
    )
    r = sess.solve(g, injector=inj)
    assert r.best_size == base.best_size
    assert (np.asarray(r.best_sol) == np.asarray(base.best_sol)).all()
    assert r.stats.spilled_tasks == base.stats.spilled_tasks
    assert r.stats.readmitted_tasks == base.stats.readmitted_tasks
    assert inj.faults_injected == inj.faults_recovered == 2


# -- 7. quarantine, degradation, rehabilitation --------------------------------


def test_repeated_crashes_quarantine_shed_and_still_complete():
    gs = [erdos_renyi(28, 0.3, 50 + i) for i in range(4)]
    cfg = SolveConfig(
        num_workers=4, steps_per_round=2, chunk_rounds=1, service_lanes=2,
    )
    sess = SolverSession("vertex_cover", config=cfg, cache=_CACHE)
    svc_ref = sess.serve()
    ref_tix = [svc_ref.submit(g) for g in gs]
    svc_ref.drain()
    want = [svc_ref.result(t) for t in ref_tix]

    inj = FaultInjector(
        FaultPlan(
            seed=0,
            events=tuple(
                FaultEvent("crash", at=2 + i, lane=i % 2) for i in range(4)
            ),
        )
    )
    svc = sess.serve(injector=inj)
    tix = [svc.submit(g) for g in gs]
    svc.drain()
    for t, w in zip(tix, want):
        got = svc.result(t)
        assert got.best_size == w.best_size
        assert (np.asarray(got.best_sol) == np.asarray(w.best_sol)).all()
    s = svc.stats()
    assert s["lanes_quarantined"] == 4
    assert s["faults_injected"] == s["faults_recovered"] == 4
    assert s["completed"] == 4
    # degradation healed by drain time: the plane is whole again
    assert s["lanes_shed"] == 0


def test_stall_watchdog_quarantines_and_replays():
    gs = [erdos_renyi(28, 0.3, 60 + i) for i in range(3)]
    cfg = SolveConfig(
        num_workers=4, steps_per_round=2, chunk_rounds=1, service_lanes=2,
        lane_stall_chunks=2,
    )
    sess = SolverSession("vertex_cover", config=cfg, cache=_CACHE)
    svc_ref = sess.serve()
    ref_tix = [svc_ref.submit(g) for g in gs]
    svc_ref.drain()
    want = [svc_ref.result(t) for t in ref_tix]

    inj = FaultInjector(
        FaultPlan(
            seed=0,
            events=(FaultEvent("stall", at=2, lane=1, duration=4),),
        )
    )
    svc = sess.serve(injector=inj, lane_stall_chunks=2)
    tix = [svc.submit(g) for g in gs]
    svc.drain()
    for t, w in zip(tix, want):
        got = svc.result(t)
        assert got.best_size == w.best_size
        assert (np.asarray(got.best_sol) == np.asarray(w.best_sol)).all()
    assert inj.injected["stall"] == inj.recovered["stall"] == 1
    assert svc.stats()["lanes_quarantined"] == 1


# -- 8. timeouts ---------------------------------------------------------------


def test_queued_request_times_out_with_typed_error():
    from repro.api import SolveService

    clk = _clock()
    cfg = SolveConfig(
        num_workers=4, steps_per_round=2, chunk_rounds=1, service_lanes=1,
        admission="fifo", request_timeout_s=5.0,
    )
    svc = SolveService("vertex_cover", cfg, clock=clk, cache=_CACHE)
    hard = svc.submit(erdos_renyi(30, 0.45, 3))
    queued = svc.submit(erdos_renyi(20, 0.3, 4))
    svc.step()  # hard takes the only lane; queued waits
    clk.t = 10.0
    completed = svc.step()  # both over budget: queued swept, hard evicted
    assert queued in completed and hard in completed
    with pytest.raises(SolveTimeout) as ei:
        svc.result(queued)
    assert ei.value.ticket == queued
    assert ei.value.result is None  # never reached a lane: no partial
    assert ei.value.waited_s >= 5.0
    assert "still queued" in str(ei.value)
    with pytest.raises(SolveTimeout) as ei:
        svc.result(hard)
    assert ei.value.result is not None  # was on a lane: anytime partial
    assert "on a lane" in str(ei.value)
    assert svc.stats()["timed_out"] == 2
    assert svc.idle()  # nothing left behind — no hung request survives


def test_on_lane_request_times_out_with_partial_result():
    from repro.api import SolveService

    clk = _clock()
    cfg = SolveConfig(
        num_workers=4, steps_per_round=2, chunk_rounds=1, service_lanes=1,
        request_timeout_s=5.0,
    )
    svc = SolveService("vertex_cover", cfg, clock=clk, cache=_CACHE)
    t = svc.submit(erdos_renyi(34, 0.5, 7))
    svc.step()  # on the lane, within budget
    clk.t = 10.0
    assert t in svc.step()
    with pytest.raises(SolveTimeout) as ei:
        svc.result(t)
    partial = ei.value.result
    assert partial is not None and partial.rounds >= 1  # anytime snapshot
    assert partial.stats.service.wall_deadline_hit is False
    assert partial.stats.service.deadline_hit is False
    assert "on a lane" in str(ei.value)
    assert svc.stats()["timed_out"] == 1


def test_async_awaited_solve_never_hangs():
    from repro.api import SolveService

    async def scenario():
        cfg = SolveConfig(
            num_workers=4, steps_per_round=2, chunk_rounds=1,
            service_lanes=1, request_timeout_s=1e-4,
        )
        svc = SolveService("vertex_cover", cfg, cache=_CACHE)
        async with AsyncSolveService(svc) as asvc:
            # any real chunk takes longer than 0.1ms of wall: the await
            # resolves with the typed timeout instead of hanging forever
            out = await asyncio.gather(
                asvc.solve(erdos_renyi(34, 0.5, 7)), return_exceptions=True
            )
        assert isinstance(out[0], SolveTimeout)

        cfg_ok = cfg.replace(request_timeout_s=3600.0)
        svc_ok = SolveService("vertex_cover", cfg_ok, cache=_CACHE)
        async with AsyncSolveService(svc_ok) as asvc:
            r = await asvc.solve(erdos_renyi(16, 0.3, 1))
        assert r.found

    asyncio.run(scenario())
