"""The batched multi-instance solve plane vs B independent solo solves.

`solve_many` is an amortization, not an approximation: per-instance
`best_size`/`best_sol` (and the deterministic stats) must be bit-identical
to running `engine.solve` once per instance, across padding, bucketing and
host-side batch compaction — and donation must never cross the instance
axis.
"""

import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, strategies as st

from repro.core import engine as E
from repro.core.frontier import Frontier
from repro.core.superstep import (
    WorkerState,
    build_batch_superstep_fn,
)
from repro.graphs.generators import erdos_renyi
from repro.problems.registry import get_problem
from repro.problems.sequential import solve_sequential
from repro.problems.vertex_cover import VCProblem

VC = get_problem("vertex_cover")


def _assert_matches_solo(graphs, batch, **solve_kw):
    for g, b in zip(graphs, batch.results):
        s = E.solve(g, **solve_kw)
        assert s.best_size == b.best_size
        same_sol = (s.best_sol is None and b.best_sol is None) or (
            (s.best_sol == b.best_sol).all()
        )
        assert same_sol
        assert s.rounds == b.rounds
        assert s.nodes_expanded == b.nodes_expanded
        assert s.tasks_transferred == b.tasks_transferred
        assert s.transfer_bytes_total == b.transfer_bytes_total
        assert not b.overflow


@settings(max_examples=5, deadline=None)
@given(st.integers(0, 10_000))
def test_batch_matches_singles_property(seed):
    """B mixed-size random instances, padded onto one plane: bit-identical
    results and stats vs B solo solves (the padding path is always hit —
    sizes differ within the bucket)."""
    rng = np.random.default_rng(seed)
    sizes = rng.integers(10, 27, size=3)
    graphs = [
        erdos_renyi(int(n), 0.3, int(s))
        for n, s in zip(sizes, rng.integers(0, 1000, size=3))
    ]
    kw = dict(num_workers=4, steps_per_round=4)
    batch = E.solve_many(graphs, **kw)
    _assert_matches_solo(graphs, batch, **kw)
    for g, b in zip(graphs, batch.results):
        want, _, _ = solve_sequential(g)
        assert b.best_size == want


def test_mixed_word_buckets_preserve_order():
    """Instances with different packed widths W split into separate buckets;
    results still come back in submission order."""
    graphs = [
        erdos_renyi(40, 0.28, 0),  # W=2
        erdos_renyi(20, 0.3, 1),  # W=1
        erdos_renyi(36, 0.28, 2),  # W=2 (padded to 40 in its bucket)
        erdos_renyi(14, 0.3, 3),  # W=1 (padded to 20)
    ]
    kw = dict(num_workers=4, steps_per_round=8)
    batch = E.solve_many(graphs, **kw)
    assert sorted(W for W, _, _ in batch.buckets) == [1, 2]
    assert sorted(i for _, _, idxs in batch.buckets for i in idxs) == [0, 1, 2, 3]
    _assert_matches_solo(graphs, batch, **kw)


def test_compaction_bit_identical():
    """Early-exit compaction (finished lanes dropped, batch re-packed to a
    smaller executable) must not perturb the surviving instances."""
    graphs = [erdos_renyi(12, 0.3, s) for s in range(6)] + [
        erdos_renyi(30, 0.25, 0),
        erdos_renyi(30, 0.28, 6),
    ]
    kw = dict(num_workers=4, steps_per_round=1, chunk_rounds=1)
    batch = E.solve_many(graphs, compact_threshold=0.5, **kw)
    assert batch.compactions > 0
    _assert_matches_solo(graphs, batch, **kw)


def test_basic_codec_buckets_by_exact_n():
    """codec="basic" pads records by n·W words, so mixed n must split into
    exact-(W, n) buckets — per-instance payload accounting stays identical
    to the solo run."""
    graphs = [erdos_renyi(24, 0.3, 1), erdos_renyi(20, 0.3, 2)]
    kw = dict(num_workers=4, steps_per_round=4, codec="basic")
    batch = E.solve_many(graphs, **kw)
    assert len(batch.buckets) == 2  # same W, different n
    _assert_matches_solo(graphs, batch, **kw)


def test_fpt_mode_per_instance_bounds():
    graphs = [erdos_renyi(24, 0.3, 1), erdos_renyi(20, 0.3, 2)]
    opts = [solve_sequential(g)[0] for g in graphs]
    # per-instance k: first solvable at its optimum, second unsatisfiable
    ks = [opts[0], opts[1] - 1]
    batch = E.solve_many(graphs, num_workers=4, mode="fpt", k=ks)
    assert batch.results[0].best_size != -1
    assert batch.results[0].best_size <= opts[0]
    assert batch.results[1].best_size == -1
    assert batch.results[1].best_sol is None


def _hand_built_batch(masks_spec, P=4, cap=8, W=1, n=16):
    """(B, P, cap) worker state with explicit frontier contents and a
    matching (trivial) batched problem.  masks_spec[b] = list of
    (worker, mask, depth)."""
    B = len(masks_spec)
    masks = np.zeros((B, P, cap, W), np.uint32)
    sols = np.zeros((B, P, cap, W), np.uint32)
    depths = np.zeros((B, P, cap), np.int32)
    active = np.zeros((B, P, cap), bool)
    slot = np.zeros((B, P), np.int64)
    for b, spec in enumerate(masks_spec):
        for w, mask, depth in spec:
            s = slot[b, w]
            masks[b, w, s, 0] = mask
            depths[b, w, s] = depth
            active[b, w, s] = True
            slot[b, w] += 1
    z = jnp.zeros((B, P), jnp.int32)
    state = WorkerState(
        frontier=Frontier(
            masks=jnp.asarray(masks),
            sols=jnp.asarray(sols),
            depths=jnp.asarray(depths),
            active=jnp.asarray(active),
            overflow=jnp.zeros((B, P), bool),
            dropped=jnp.zeros((B, P), jnp.int32),
        ),
        best_val=jnp.full((B, P), 99, jnp.int32),
        local_best_val=jnp.full((B, P), 99, jnp.int32),
        best_sol=jnp.zeros((B, P, W), jnp.uint32),
        nodes_expanded=z,
        tasks_sent=z,
        tasks_recv=z,
        rounds=z,
        transfer_rounds=z,
        payload_words=z,
    )
    v = np.arange(n, dtype=np.int32)
    problems = VCProblem(
        n=jnp.full((B,), n, jnp.int32),
        adj=jnp.zeros((B, n, W), jnp.uint32),
        word_idx=jnp.asarray(v // 32),
        bit_idx=jnp.asarray((v % 32).astype(np.uint32)),
    )
    return state, problems


def test_donation_never_crosses_instance_axis():
    """Instance 0 has idle workers but NO donor; instance 1 has a donor.
    The rebalance must stay inside each instance: instance 0 receives
    nothing even though instance 1's donor has spare tasks."""
    state, problems = _hand_built_batch(
        [
            # pending=1 -> neither idle nor donor; workers 1-3 idle
            [(0, 0xAAAA, 5)],
            # worker 0 donates its shallowest (0x7, depth 1) inside inst 1
            [(0, 0x1, 3), (0, 0x3, 2), (0, 0x7, 1)],
        ]
    )
    fn = build_batch_superstep_fn(VC, problems, steps_per_round=0, lanes=1)
    new, done = fn(state)
    assert not bool(done[0]) and not bool(done[1])

    # instance 0: untouched — no transfer in, no tasks lost
    assert int(np.asarray(new.tasks_recv)[0].sum()) == 0
    assert int(np.asarray(new.tasks_sent)[0].sum()) == 0
    act0 = np.asarray(new.frontier.active)[0]
    assert act0.sum() == 1
    masks0 = np.asarray(new.frontier.masks)[0][act0]
    assert set(masks0[:, 0].tolist()) == {0xAAAA}

    # instance 1: exactly one intra-instance donation (shallowest record)
    assert int(np.asarray(new.tasks_sent)[1].sum()) == 1
    assert int(np.asarray(new.tasks_recv)[1].sum()) == 1
    act1 = np.asarray(new.frontier.active)[1]
    assert act1.sum() == 3  # moved, not duplicated or lost
    masks1 = np.asarray(new.frontier.masks)[1][act1]
    assert sorted(masks1[:, 0].tolist()) == [0x1, 0x3, 0x7]
    recv_worker = np.asarray(new.tasks_recv)[1].argmax()
    assert recv_worker != 0
    got = np.asarray(new.frontier.masks)[1, recv_worker][
        np.asarray(new.frontier.active)[1, recv_worker]
    ]
    assert got[:, 0].tolist() == [0x7]


def test_per_instance_quiescence():
    """An empty instance is done immediately; a live one in the same batch
    keeps its pending work — done is a per-instance vector."""
    state, problems = _hand_built_batch([[], [(0, 0x1, 0), (1, 0x3, 1)]])
    fn = build_batch_superstep_fn(VC, problems, steps_per_round=0, lanes=1)
    _, done = fn(state)
    assert bool(done[0]) and not bool(done[1])
