"""SPMD superstep engine: the TPU adaptation of the semi-centralized strategy.

One superstep =

  1. **explore** — each worker expands up to ``lanes`` of its deepest tasks
     for ``steps_per_round`` rounds (the paper's exploration threads);
  2. **control plane** — each worker contributes THREE integers
     (pending count, shallowest pending depth, local best value) to an
     all-gather: this is the paper's "every message is a single integer"
     budget, and the gathered (P, 3) table is the entire center state;
  3. **replicated center** — every worker deterministically computes the same
     idle→donor matching from the table (`getNextWorkingNode` over RUNNING
     workers; priority = shallowest pending task, or round-robin "random");
  4. **data plane** — matched donors pop their *shallowest* task (Alg. 6) and
     the fixed-size record moves to the idle worker (reference path:
     all-gather + select; see §Perf in EXPERIMENTS.md for the alternatives);
  5. **best-value broadcast** — global best = min over workers (the paper's
     ``bestval_update`` verify-then-broadcast collapses to one pmin).

Failure-free guarantee (paper §3.1): the matcher only pairs an idle worker
with a donor whose ``pending >= 2``, and in BSP the transfer completes inside
the same superstep — a matched idle worker ALWAYS receives a task, no retries.

Termination (paper §3.3): transfers cannot straddle a superstep boundary, so
``psum(pending) == 0`` after the transfer phase is exact quiescence — the
sent/ack counting and timeout safety mechanisms of the MPI implementation are
subsumed by the BSP barrier.

The same function runs under ``jax.vmap(axis_name=...)`` (P virtual workers
on one device — used by tests) and ``jax.shard_map`` (one worker per mesh
device — used by the launcher and the multi-pod dry-run).
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.frontier import (
    BIG_DEPTH,
    Frontier,
    make_frontier,
    pop_deepest,
    pop_shallowest,
    push_many,
    push_one,
)
from repro.problems.vertex_cover import (
    VCProblem,
    branch_once,
    degrees,
    lower_bound,
    popcount,
)


class WorkerState(NamedTuple):
    frontier: Frontier
    best_val: jnp.ndarray  # () int32 -- global best seen (paper: global_bestval)
    local_best_val: jnp.ndarray  # () int32 -- best found by THIS worker
    best_sol: jnp.ndarray  # (W,) uint32 -- the cover achieving local_best_val
    nodes_expanded: jnp.ndarray  # () int32
    tasks_sent: jnp.ndarray  # () int32
    tasks_recv: jnp.ndarray  # () int32
    rounds: jnp.ndarray  # () int32


def make_worker_state(capacity: int, W: int, initial_best: int) -> WorkerState:
    z = jnp.int32(0)
    return WorkerState(
        frontier=make_frontier(capacity, W),
        best_val=jnp.int32(initial_best),
        local_best_val=jnp.int32(initial_best),
        best_sol=jnp.zeros((W,), jnp.uint32),
        nodes_expanded=z,
        tasks_sent=z,
        tasks_recv=z,
        rounds=z,
    )


# -- phase 1: exploration ------------------------------------------------------


def _explore_one_round(problem: VCProblem, state: WorkerState, lanes: int):
    """Pop up to ``lanes`` deepest tasks, expand each, push children."""
    f, masks, sols, depths, valid = pop_deepest(state.frontier, lanes)

    sol_sizes = jax.vmap(popcount)(sols)  # (L,)
    degs = jax.vmap(lambda m: degrees(problem, m))(masks)  # (L, n)
    lbs = jax.vmap(lower_bound)(degs)  # (L,)
    not_pruned = valid & (sol_sizes + lbs < state.best_val)

    res = jax.vmap(lambda m, s: branch_once(problem, m, s))(masks, sols)

    # terminal candidates -> best update (paper: handleSolution + bestval)
    term = not_pruned & res.is_terminal & (res.terminal_size < state.best_val)
    term_size = jnp.where(term, res.terminal_size, jnp.int32(1 << 30))
    li = jnp.argmin(term_size)
    found_size = term_size[li]  # 1<<30 when no lane found a terminal
    # local best only improves with terminals THIS worker found (its stored
    # solution must actually achieve local_best_val); the global view may also
    # shrink via the pmin in the communication phase.
    new_sol = jnp.where(
        found_size < state.local_best_val, res.terminal_sol[li], state.best_sol
    )
    new_local = jnp.minimum(state.local_best_val, found_size)
    new_best = jnp.minimum(state.best_val, found_size)

    # children push: [left_0..left_L, right_0..right_L], pruned-at-birth if
    # their partial solution already >= best (host reference does the same).
    expandable = not_pruned & ~res.is_terminal
    cdepth = depths + 1
    lvalid = expandable & (jax.vmap(popcount)(res.left_sol) < new_best)
    rvalid = expandable & (jax.vmap(popcount)(res.right_sol) < new_best)
    all_masks = jnp.concatenate([res.left_mask, res.right_mask], axis=0)
    all_sols = jnp.concatenate([res.left_sol, res.right_sol], axis=0)
    all_depths = jnp.concatenate([cdepth, cdepth], axis=0)
    all_valid = jnp.concatenate([lvalid, rvalid], axis=0)
    f = push_many(f, all_masks, all_sols, all_depths, all_valid)

    return state._replace(
        frontier=f,
        best_val=new_best,
        local_best_val=new_local,
        best_sol=new_sol,
        nodes_expanded=state.nodes_expanded + valid.sum().astype(jnp.int32),
    )


def explore_phase(
    problem: VCProblem, state: WorkerState, steps: int, lanes: int
) -> WorkerState:
    def body(_, s):
        return _explore_one_round(problem, s, lanes)

    return jax.lax.fori_loop(0, steps, body, state)


# -- phase 3: the replicated center -------------------------------------------


def match_idle_to_donors(
    pending: jnp.ndarray,  # (P,) int32
    top_depth: jnp.ndarray,  # (P,) int32 (BIG_DEPTH when empty)
    policy_priority: bool,
    round_idx: jnp.ndarray,  # () int32 -- salt for the round-robin policy
):
    """The center's `getNextWorkingNode`, replicated: every worker computes
    the same matching from the same (P,) status vectors.

    Returns (send_to, recv_from): per-worker partner index or -1.
    Donors need pending >= 2 (donate one, keep one — failure-free).
    'priority' ranks donors by shallowest pending depth (heaviest task,
    paper §3.2 metadata policy); 'random' becomes a round-salted round-robin
    (deterministic — required for SPMD replication — but unbiased over time).
    """
    P = pending.shape[0]
    idx = jnp.arange(P, dtype=jnp.int32)
    idle = pending == 0
    donor = pending >= 2

    # rank idle workers 0..n_idle-1 in index order
    idle_rank = jnp.where(idle, jnp.cumsum(idle.astype(jnp.int32)) - 1, -1)

    # order donors: priority -> by (top_depth, idx); round-robin -> by
    # ((idx + salt) mod P, idx) which rotates who donates first each round.
    if policy_priority:
        donor_key = top_depth * P + idx
    else:
        donor_key = (idx + round_idx) % P
    donor_key = jnp.where(donor, donor_key, jnp.int32(1 << 30))
    donor_order = jnp.argsort(donor_key)  # donors first, in key order
    donor_rank = jnp.zeros((P,), jnp.int32).at[donor_order].set(idx)
    donor_rank = jnp.where(donor, donor_rank, -1)

    # donor with rank k serves idle with rank k
    n_idle = idle.sum()
    n_donor = donor.sum()
    n_match = jnp.minimum(n_idle, n_donor)

    # send_to[w] = idle worker with rank donor_rank[w] (if matched)
    idle_by_rank = jnp.zeros((P,), jnp.int32).at[
        jnp.where(idle, idle_rank, P)
    ].set(idx, mode="drop")
    send_to = jnp.where(
        donor & (donor_rank < n_match), idle_by_rank[jnp.clip(donor_rank, 0, P - 1)], -1
    )
    donor_by_rank = jnp.zeros((P,), jnp.int32).at[
        jnp.where(donor, donor_rank, P)
    ].set(idx, mode="drop")
    recv_from = jnp.where(
        idle & (idle_rank < n_match), donor_by_rank[jnp.clip(idle_rank, 0, P - 1)], -1
    )
    return send_to, recv_from


# -- the full superstep ---------------------------------------------------------


def superstep(
    problem: VCProblem,
    state: WorkerState,
    *,
    axis_name: str,
    steps_per_round: int,
    lanes: int,
    policy_priority: bool = True,
    transfer_pad_words: int = 0,
    packed_status: bool = True,
    skip_empty_transfer: bool = True,
):
    """One BSP round for a single worker (replicated via vmap/shard_map).

    ``transfer_pad_words`` emulates the paper's *basic* encoding (§4.3): the
    task record is padded by n·W words of (redundant) adjacency payload so the
    collective moves the same bytes the MPI version would — used by the
    encoding benchmark; 0 = optimized encoding.

    §Perf knobs (EXPERIMENTS.md):
      packed_status       — (pending, top_depth) bit-packed into ONE i32 per
                            worker (+ a scalar pmin for the bound) instead of
                            a 3-int row: the control-plane gather shrinks 3x.
      skip_empty_transfer — the record all-gather runs under a cond that every
                            worker evaluates identically from the replicated
                            table; rounds with no match move ZERO payload.

    Returns (state, done) where done is the exact global quiescence flag.
    """
    W = state.best_sol.shape[0]

    # 1. explore
    state = explore_phase(problem, state, steps_per_round, lanes)

    # 2. control plane through the "center" + 5. best-value broadcast
    pending = state.frontier.pending()
    top_depth = state.frontier.top_priority_depth()
    if packed_status:
        # one i32 per worker: pending (15b) | clamped depth (16b)
        word = (jnp.clip(pending, 0, 0x7FFF) << 16) | jnp.clip(
            top_depth, 0, 0xFFFF
        )
        table_w = jax.lax.all_gather(word, axis_name)  # (P,)
        pend_t = table_w >> 16
        depth_t = table_w & 0xFFFF
        global_best = jax.lax.pmin(
            jnp.minimum(state.local_best_val, state.best_val), axis_name
        )
    else:
        my_status = jnp.stack([pending, top_depth, state.local_best_val])
        table = jax.lax.all_gather(my_status, axis_name)  # (P, 3)
        pend_t, depth_t = table[:, 0], table[:, 1]
        global_best = jnp.minimum(table[:, 2].min(), state.best_val)
    state = state._replace(best_val=global_best)

    # 3. replicated center matching
    me = jax.lax.axis_index(axis_name).astype(jnp.int32)
    send_to, recv_from = match_idle_to_donors(
        pend_t, depth_t, policy_priority, state.rounds
    )
    n_match = (send_to >= 0).sum()

    # 4. data plane: donor pops shallowest; record = (mask, sol, depth[, pad])
    def do_transfer(state):
        i_send = send_to[me] >= 0
        f2, d_mask, d_sol, d_depth, d_valid = pop_shallowest(state.frontier)
        do_send = i_send & d_valid  # guaranteed by pending>=2, but be safe
        new_frontier = jax.tree.map(
            lambda a, b: jnp.where(do_send, a, b), f2, state.frontier
        )
        record = jnp.concatenate(
            [d_mask, d_sol, d_depth[None].astype(jnp.uint32)]
        )
        if transfer_pad_words:
            record = jnp.concatenate(
                [record, jnp.zeros((transfer_pad_words,), jnp.uint32)]
            )
        record = jnp.where(do_send, record, 0)

        # reference path: all-gather the records, select my donor's row
        all_records = jax.lax.all_gather(record, axis_name)  # (P, REC)
        my_src = recv_from[me]
        i_recv = my_src >= 0
        got = all_records[jnp.clip(my_src, 0, all_records.shape[0] - 1)]
        new_frontier = push_one(
            new_frontier,
            got[:W],
            got[W : 2 * W],
            got[2 * W].astype(jnp.int32),
            i_recv,
        )
        return state._replace(
            frontier=new_frontier,
            tasks_sent=state.tasks_sent + do_send.astype(jnp.int32),
            tasks_recv=state.tasks_recv + i_recv.astype(jnp.int32),
        )

    if skip_empty_transfer:
        # n_match derives from the replicated table: every worker takes the
        # same branch, so the collective inside the cond is safe.
        state = jax.lax.cond(n_match > 0, do_transfer, lambda s: s, state)
    else:
        state = do_transfer(state)
    state = state._replace(rounds=state.rounds + 1)

    # exact termination: nothing pending anywhere after the transfer phase
    total_pending = jax.lax.psum(state.frontier.pending(), axis_name)
    done = total_pending == 0
    return state, done


def build_superstep_fn(
    problem: VCProblem,
    *,
    num_workers: int,
    steps_per_round: int,
    lanes: int,
    policy_priority: bool = True,
    transfer_pad_words: int = 0,
    packed_status: bool = True,
    skip_empty_transfer: bool = True,
    mesh=None,
    axis_name: str = "workers",
):
    """Return a jitted ``state -> (state, done)`` over stacked (P, ...) state.

    mesh=None  -> vmap over the leading axis (P virtual workers, one device).
    mesh given -> shard_map over the mesh axis ``axis_name`` (one worker per
                  device; state leading axis must equal mesh size).
    """
    step = functools.partial(
        superstep,
        problem,
        axis_name=axis_name,
        steps_per_round=steps_per_round,
        lanes=lanes,
        policy_priority=policy_priority,
        transfer_pad_words=transfer_pad_words,
        packed_status=packed_status,
        skip_empty_transfer=skip_empty_transfer,
    )
    if mesh is None:
        vstep = jax.vmap(step, axis_name=axis_name)

        def run(state):
            state, done = vstep(state)
            return state, done.all()

        return jax.jit(run)

    from jax.sharding import PartitionSpec as P

    spec = P(axis_name)

    def body(state_block):
        # each shard sees a (1, ...) block: strip, step, restore
        state = jax.tree.map(lambda x: x[0], state_block)
        state, done = step(state)
        return jax.tree.map(lambda x: x[None], state), done

    return jax.jit(
        jax.shard_map(body, mesh=mesh, in_specs=(spec,), out_specs=(spec, P()))
    )
