"""Checkpoint store: atomic, mesh-agnostic save/restore with async writes.

Layout:  <dir>/step_<N>/  arrays.npz  (flattened pytree leaves)
                          manifest.msgpack  (treedef paths, shapes, dtypes,
                                             step, data-pipeline state)

* **atomic**: written to a UNIQUE ``step_<N>.<rand>.tmp`` dir then swapped
  into place under a process-wide lock — a crash mid-write never corrupts
  the latest checkpoint, and concurrent writers of the same step (e.g. an
  async save racing a final blocking save) are last-writer-wins instead of
  colliding on a shared tmp path;
* **mesh-agnostic**: leaves are saved unsharded (device_get) and restored
  with ``jax.device_put(leaf, sharding)`` against whatever mesh the restart
  runs on — re-meshing on restart is how elastic scale-up/down works;
* **async**: ``save_checkpoint(..., blocking=False)`` snapshots to host
  memory synchronously (cheap) and writes on a daemon thread, overlapping
  I/O with the next training steps.
"""

from __future__ import annotations

import os
import shutil
import tempfile
import threading
from typing import Any, Optional

import jax
import msgpack
import numpy as np

_PENDING: list[threading.Thread] = []
# Serializes the final tmp->step_<N> swap across writer threads; the bulk
# np.savez I/O stays outside the lock so async saves still overlap compute.
_SWAP_LOCK = threading.Lock()
# Process umask, read once at import (before writer threads exist — the
# os.umask read is a racy set/restore).
_UMASK = os.umask(0)
os.umask(_UMASK)


def _flatten(tree) -> tuple[list[tuple[str, np.ndarray]], Any]:
    leaves_with_paths = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in leaves_with_paths:
        key = "/".join(str(p) for p in path)
        out.append((key, np.asarray(jax.device_get(leaf))))
    return out, jax.tree.structure(tree)


def save_checkpoint(
    directory: str,
    step: int,
    tree: Any,
    extra: Optional[dict] = None,
    *,
    blocking: bool = True,
) -> str:
    """Snapshot ``tree`` (any pytree of arrays) + ``extra`` metadata."""
    flat, _ = _flatten(tree)
    payload = {k: v for k, v in flat}
    meta = {"step": int(step), "keys": list(payload.keys()), "extra": extra or {}}

    def write():
        os.makedirs(directory, exist_ok=True)
        final = os.path.join(directory, f"step_{step}")
        # Unique tmp dir per writer: concurrent saves of the same step never
        # share a path (the old fixed ``step_<N>.tmp`` raced with itself).
        tmp = tempfile.mkdtemp(
            prefix=f"step_{step}.", suffix=".tmp", dir=directory
        )
        # mkdtemp creates 0700; restore umask-default perms so the renamed
        # step_<N> dir stays readable by other users/services (as the old
        # os.makedirs-based writer left it)
        os.chmod(tmp, 0o777 & ~_UMASK)
        try:
            np.savez(os.path.join(tmp, "arrays.npz"), **payload)
            with open(os.path.join(tmp, "manifest.msgpack"), "wb") as f:
                f.write(msgpack.packb(meta))
            with _SWAP_LOCK:
                if os.path.exists(final):
                    shutil.rmtree(final)
                os.rename(tmp, final)
        except BaseException:
            shutil.rmtree(tmp, ignore_errors=True)
            raise

    if blocking:
        write()
    else:
        t = threading.Thread(target=write, daemon=True)
        t.start()
        _PENDING.append(t)
    return os.path.join(directory, f"step_{step}")


def wait_for_pending() -> None:
    while _PENDING:
        _PENDING.pop().join()


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [
        int(name.split("_", 1)[1])
        for name in os.listdir(directory)
        if name.startswith("step_") and not name.endswith(".tmp")
    ]
    return max(steps) if steps else None


def restore_checkpoint(
    directory: str,
    template: Any,
    step: Optional[int] = None,
    shardings: Any = None,
):
    """Restore into the structure of ``template``.  ``shardings`` (optional)
    mirrors the template with jax.sharding.Sharding leaves — leaves are
    device_put against them (re-meshing happens here).

    Returns (tree, step, extra).
    """
    step = step if step is not None else latest_step(directory)
    if step is None:
        raise FileNotFoundError(f"no checkpoint under {directory}")
    path = os.path.join(directory, f"step_{step}")
    with open(os.path.join(path, "manifest.msgpack"), "rb") as f:
        meta = msgpack.unpackb(f.read())
    arrays = np.load(os.path.join(path, "arrays.npz"))

    leaves_with_paths = jax.tree_util.tree_flatten_with_path(template)[0]
    treedef = jax.tree.structure(template)
    shard_leaves = (
        jax.tree.leaves(shardings, is_leaf=lambda x: hasattr(x, "device_set"))
        if shardings is not None
        else [None] * len(leaves_with_paths)
    )
    restored = []
    for (path_elems, leaf), shard in zip(leaves_with_paths, shard_leaves):
        key = "/".join(str(p) for p in path_elems)
        arr = arrays[key]
        if hasattr(leaf, "dtype"):
            arr = arr.astype(leaf.dtype)
        restored.append(
            jax.device_put(arr, shard) if shard is not None else jax.numpy.asarray(arr)
        )
    return jax.tree.unflatten(treedef, restored), meta["step"], meta["extra"]
