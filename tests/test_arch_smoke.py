"""Per-architecture smoke tests: reduced config, one forward + train step on
CPU, asserting output shapes and no NaNs (assignment requirement §f)."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_smoke_config
from repro.models.registry import get_model
from repro.optim.adamw import adamw_init, adamw_update

B, S = 2, 16


def _batch(cfg, key):
    k1, k2, k3 = jax.random.split(key, 3)
    batch = {
        "tokens": jax.random.randint(k1, (B, S), 0, cfg.vocab),
        "labels": jax.random.randint(k2, (B, S), 0, cfg.vocab),
    }
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(k3, (B, cfg.enc_seq, cfg.d_model))
    if cfg.family == "vlm":
        batch["patch_embeds"] = jax.random.normal(k3, (B, cfg.n_patches, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finite(arch):
    cfg = get_smoke_config(arch)
    model = get_model(cfg)
    params, specs = model.init(jax.random.key(0))
    batch = _batch(cfg, jax.random.key(1))
    logits = model.forward(params, batch)
    expect_S = S + (cfg.n_patches if cfg.family == "vlm" else 0)
    assert logits.shape == (B, expect_S, cfg.vocab)
    assert not bool(jnp.isnan(logits).any())
    # spec tree mirrors params
    assert jax.tree.structure(specs, is_leaf=lambda x: isinstance(x, tuple))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_one_train_step(arch):
    cfg = get_smoke_config(arch)
    model = get_model(cfg)
    params, _ = model.init(jax.random.key(0))
    opt = adamw_init(params)
    batch = _batch(cfg, jax.random.key(1))
    loss, grads = jax.value_and_grad(lambda p: model.loss_fn(p, batch))(params)
    assert jnp.isfinite(loss)
    new_params, opt, stats = adamw_update(params, grads, opt)
    assert jnp.isfinite(stats["grad_norm"])
    # params actually moved
    moved = any(
        bool(jnp.any(a != b))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(new_params))
    )
    assert moved


@pytest.mark.parametrize("arch", ["qwen1_5_0_5b", "rwkv6_3b", "recurrentgemma_9b"])
def test_decode_matches_forward(arch):
    """Teacher-forced decode steps == full forward (the serve path is exact)."""
    cfg = get_smoke_config(arch)
    model = get_model(cfg)
    params, _ = model.init(jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (B, 12), 0, cfg.vocab)
    full = model.forward(params, {"tokens": toks})
    cache, _ = model.init_decode_cache(B, 16)
    outs = []
    for t in range(12):
        lg, cache = model.decode_fn(params, cache, toks[:, t : t + 1])
        outs.append(lg)
    dec = jnp.concatenate(outs, axis=1)
    assert float(jnp.abs(dec - full).max()) < 2e-4
