"""Max-clique plugin: a native candidate-set brancher on the generic plane.

Task state (paper-optimized encoding, unchanged layout): ``mask`` is the
candidate set P (vertices adjacent to everything already picked), ``sol`` is
the clique R being grown.  One expansion branches on a maximum-degree
candidate u — either u joins (candidates shrink to P ∩ N(u)) or u is
discarded — and a task is terminal when P is empty.

The engine minimizes, so the internal objective is ``-|R|``; the admissible
bound ``-(|R| + |P|)`` (every candidate could, at best, join) prunes both
popped tasks and freshly-born children.  ``external_value`` flips the sign
back for reporting.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.problems import sequential
from repro.problems.base import (
    BranchingProblem,
    BranchStep,
    ProblemData,
    degrees,
    popcount,
    single_bit,
)


def branch_once(data: ProblemData, mask, sol) -> BranchStep:
    """Branch on a maximum-degree candidate (degree within P, ties lowest)."""
    W = data.adj.shape[1]
    deg = degrees(data, mask)
    u = jnp.argmax(deg).astype(jnp.int32)
    u_bit = single_bit(u, W)
    nb = data.adj[u] & mask
    return BranchStep(
        left_mask=nb,  # u joins: only its neighbours stay candidates
        left_sol=sol | u_bit,
        right_mask=mask & ~u_bit,  # u discarded
        right_sol=sol,
        is_terminal=popcount(mask) == 0,
        terminal_sol=sol,
        terminal_value=-popcount(sol),
    )


def bound(data: ProblemData, mask, sol) -> jnp.ndarray:
    """-(|R| + |P|): no completion can beat adding every candidate."""
    return -(popcount(sol) + popcount(mask))


def host_bound(g, mask, sol_mask) -> int:
    """Host twin of :func:`bound`: -(|R| + |P|) over packed host bitsets."""
    from repro.graphs.bitgraph import popcount_rows

    return -int(popcount_rows(sol_mask) + popcount_rows(mask))


def host_terminal_value(g, mask, sol_mask) -> int:
    from repro.graphs.bitgraph import popcount_rows

    return -int(popcount_rows(sol_mask))


SPEC = BranchingProblem(
    name="max_clique",
    objective="maximize |clique|",
    branch_once=branch_once,
    task_bound=bound,
    child_bound=bound,
    bnb_bound=lambda g: 1,  # just worse than the empty clique (value 0)
    external_value=lambda v: -v,
    fpt_target=lambda k: -k,
    branch_once_host=sequential.branch_once_clique,
    sequential=sequential.solve_sequential_max_clique,
    verify=sequential.verify_clique,
    host_task_bound=host_bound,
    host_child_bound=host_bound,
    host_terminal_value=host_terminal_value,
)
