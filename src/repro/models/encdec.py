"""Whisper-style encoder-decoder backbone.

Per the assignment, the conv/mel frontend is a STUB: ``input_specs`` feeds
precomputed frame embeddings (B, enc_seq, d_model) directly to the encoder
(bidirectional attention + sinusoidal positions).  The decoder is a standard
causal stack with cross-attention into the encoder output; decode carries a
self-attention KV cache plus the (precomputed once) cross-attention K/V.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.sharding import constrain, gather_params, spec_tree_of


def _sinusoid(S, d):
    pos = jnp.arange(S)[:, None].astype(jnp.float32)
    dim = jnp.arange(d // 2)[None, :].astype(jnp.float32)
    ang = pos / (10_000 ** (2 * dim / d))
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _xattn_init(key, cfg: ModelConfig):
    """Cross-attention: q from decoder, kv from encoder stream."""
    return L.attention_init(key, cfg)


def _enc_block_init(key, cfg):
    k1, k2 = jax.random.split(key)
    p, s = {}, {}
    p["ln1"], s["ln1"] = L.rmsnorm_init(cfg.d_model)
    p["attn"], s["attn"] = L.attention_init(k1, cfg)
    p["ln2"], s["ln2"] = L.rmsnorm_init(cfg.d_model)
    p["mlp"], s["mlp"] = L.gelu_mlp_init(k2, cfg)
    return p, s


def _dec_block_init(key, cfg):
    k1, k2, k3 = jax.random.split(key, 3)
    p, s = {}, {}
    p["ln1"], s["ln1"] = L.rmsnorm_init(cfg.d_model)
    p["attn"], s["attn"] = L.attention_init(k1, cfg)
    p["lnx"], s["lnx"] = L.rmsnorm_init(cfg.d_model)
    p["xattn"], s["xattn"] = _xattn_init(k2, cfg)
    p["ln2"], s["ln2"] = L.rmsnorm_init(cfg.d_model)
    p["mlp"], s["mlp"] = L.gelu_mlp_init(k3, cfg)
    return p, s


def init_lm(key, cfg: ModelConfig):
    dt = jnp.dtype(cfg.dtype)
    k_emb, k_enc, k_dec, k_out = jax.random.split(key, 4)
    ekeys = jax.random.split(k_enc, cfg.n_enc_layers)
    dkeys = jax.random.split(k_dec, cfg.n_layers)
    enc_p = jax.vmap(lambda k: _enc_block_init(k, cfg)[0])(ekeys)
    _, enc_s = _enc_block_init(ekeys[0], cfg)
    dec_p = jax.vmap(lambda k: _dec_block_init(k, cfg)[0])(dkeys)
    _, dec_s = _dec_block_init(dkeys[0], cfg)
    stack = lambda s: jax.tree.map(
        lambda ax: ("layers",) + ax, s, is_leaf=lambda x: isinstance(x, tuple)
    )
    params = {
        "embed": (
            jax.random.normal(k_emb, (cfg.vocab, cfg.d_model), jnp.float32)
            * cfg.d_model**-0.5
        ).astype(dt),
        "enc_blocks": enc_p,
        "enc_ln": L.rmsnorm_init(cfg.d_model)[0],
        "dec_blocks": dec_p,
        "ln_f": L.rmsnorm_init(cfg.d_model)[0],
        "unembed": (
            jax.random.normal(k_out, (cfg.d_model, cfg.vocab), jnp.float32)
            * cfg.d_model**-0.5
        ).astype(dt),
    }
    specs = {
        "embed": ("vocab", "embed"),
        "enc_blocks": stack(enc_s),
        "enc_ln": ("embed",),
        "dec_blocks": stack(dec_s),
        "ln_f": ("embed",),
        "unembed": ("embed", "vocab"),
    }
    return params, specs


def encode(params, cfg: ModelConfig, frames, *, rules=None):
    """frames (B, enc_seq, d) -> encoder output (B, enc_seq, d)."""
    x = frames.astype(jnp.dtype(cfg.dtype))
    x = x + _sinusoid(x.shape[1], cfg.d_model).astype(x.dtype)
    x = constrain(x, ("batch", "seq", None), rules)
    positions = jnp.arange(x.shape[1])

    def blk(bp, x):
        bp = gather_params(bp, _blk_specs(cfg, "enc"), rules)
        h, _ = L.attention_apply(
            cfg, bp["attn"], L.rmsnorm(x, bp["ln1"], cfg.norm_eps),
            positions, causal=False,
        )
        x = constrain(x + h, ("batch", "seq", None), rules)
        m = L.gelu_mlp_apply(bp["mlp"], L.rmsnorm(x, bp["ln2"], cfg.norm_eps))
        return constrain(x + m, ("batch", "seq", None), rules)

    blk = jax.checkpoint(
        blk, policy=L.remat_policy(),
        prevent_cse=False,
    )
    x, _ = jax.lax.scan(
        lambda x, bp: (blk(bp, x), None), x, params["enc_blocks"],
        unroll=L.scan_unroll(),
    )
    return L.rmsnorm(x, params["enc_ln"], cfg.norm_eps)


def _cross_attend(cfg, xp, y, enc_kv):
    """y (B, S, d) queries against precomputed encoder K/V."""
    B, S, d = y.shape
    H, KV, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    q = (y @ xp["wq"]).reshape(B, S, H, Dh)
    k, v = enc_kv  # (B, Se, KV, Dh)
    G = H // KV
    qh = q.transpose(0, 2, 1, 3).reshape(B, KV, G, S, Dh) * (Dh**-0.5)
    kh = k.transpose(0, 2, 1, 3)
    vh = v.transpose(0, 2, 1, 3)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qh, kh.astype(qh.dtype))
    p = jax.nn.softmax(s.astype(jnp.float32), -1).astype(qh.dtype)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", p, vh.astype(qh.dtype))
    o = o.reshape(B, H, S, Dh).transpose(0, 2, 1, 3).reshape(B, S, H * Dh)
    return o @ xp["wo"]


def _enc_kv(cfg, xp, enc_out):
    B, Se, d = enc_out.shape
    KV, Dh = cfg.n_kv_heads, cfg.d_head
    k = (enc_out @ xp["wk"]).reshape(B, Se, KV, Dh)
    v = (enc_out @ xp["wv"]).reshape(B, Se, KV, Dh)
    return k, v


_SPEC_CACHE: dict = {}


def _blk_specs(cfg, which):
    key = (cfg.name, which)
    if key not in _SPEC_CACHE:
        init = _enc_block_init if which == "enc" else _dec_block_init
        _SPEC_CACHE[key] = spec_tree_of(lambda: init(jax.random.key(0), cfg))
    return _SPEC_CACHE[key]


def _dec_block(cfg, bp, x, positions, enc_out, rules, cache=None):
    bp = gather_params(bp, _blk_specs(cfg, "dec"), rules)  # JIT-FSDP regather
    h, new_kv = L.attention_apply(
        cfg, bp["attn"], L.rmsnorm(x, bp["ln1"], cfg.norm_eps),
        positions, causal=True,
        cache=None if cache is None else (cache["k"], cache["v"], cache["len"]),
    )
    x = constrain(x + h, ("batch", "seq", None), rules)
    if cache is not None and "xk" in cache:
        xkv = (cache["xk"], cache["xv"])
    else:
        xkv = _enc_kv(cfg, bp["xattn"], enc_out)
    cx = _cross_attend(cfg, bp["xattn"], L.rmsnorm(x, bp["lnx"], cfg.norm_eps), xkv)
    x = constrain(x + cx, ("batch", "seq", None), rules)
    m = L.gelu_mlp_apply(bp["mlp"], L.rmsnorm(x, bp["ln2"], cfg.norm_eps))
    x = constrain(x + m, ("batch", "seq", None), rules)
    return x, new_kv, xkv


def forward(params, cfg: ModelConfig, tokens, *, frames=None, rules=None, **_):
    """Teacher-forced decoder over encoded frames.  tokens (B, S)."""
    assert frames is not None, "encdec forward needs frames"
    enc_out = encode(params, cfg, frames, rules=rules)
    x = params["embed"][tokens].astype(jnp.dtype(cfg.dtype))
    x = constrain(x, ("batch", "seq", None), rules)
    positions = jnp.arange(x.shape[1])

    def blk(bp, x):
        out, _, _ = _dec_block(cfg, bp, x, positions, enc_out, rules)
        return out

    blk = jax.checkpoint(
        blk, policy=L.remat_policy(),
        prevent_cse=False,
    )
    x, _ = jax.lax.scan(
        lambda x, bp: (blk(bp, x), None), x, params["dec_blocks"],
        unroll=L.scan_unroll(),
    )
    x = L.rmsnorm(x, params["ln_f"], cfg.norm_eps)
    logits = x @ params["unembed"]
    return constrain(logits, ("batch", "seq", "vocab"), rules), jnp.float32(0)


def loss_fn(params, cfg, batch, *, rules=None, **kw):
    logits, _ = forward(
        params, cfg, batch["tokens"], frames=batch["frames"], rules=rules, **kw
    )
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    gold = jnp.take_along_axis(
        logits.astype(jnp.float32), batch["labels"][..., None], axis=-1
    )[..., 0]
    return (lse - gold).mean()


def init_decode_cache(cfg: ModelConfig, batch: int, max_len: int):
    """Self-attn KV cache + slots for the precomputed cross K/V."""
    KV, Dh, Ld = cfg.n_kv_heads, cfg.d_head, cfg.n_layers
    dt = jnp.dtype(cfg.dtype)
    kv_spec = ("layers", "batch", "seq_kv", "kv", None)
    return {
        "k": jnp.zeros((Ld, batch, max_len, KV, Dh), dt),
        "v": jnp.zeros((Ld, batch, max_len, KV, Dh), dt),
        "xk": jnp.zeros((Ld, batch, cfg.enc_seq, KV, Dh), dt),
        "xv": jnp.zeros((Ld, batch, cfg.enc_seq, KV, Dh), dt),
        "primed": jnp.bool_(False),
        "len": jnp.int32(0),
    }, {
        "k": kv_spec,
        "v": kv_spec,
        "xk": kv_spec,
        "xv": kv_spec,
        "primed": (),
        "len": (),
    }


def prime_cross_cache(params, cfg, cache, frames, *, rules=None):
    """Run the encoder once and precompute every layer's cross K/V."""
    enc_out = encode(params, cfg, frames, rules=rules)

    def per_layer(bp):
        k, v = _enc_kv(cfg, bp["xattn"], enc_out)
        return k, v

    xk, xv = jax.vmap(per_layer)(params["dec_blocks"])
    return {**cache, "xk": xk, "xv": xv, "primed": jnp.bool_(True)}


def decode_fn(params, cfg: ModelConfig, cache, tokens, *, rules=None):
    x = params["embed"][tokens].astype(jnp.dtype(cfg.dtype))
    pos = cache["len"]
    positions = jnp.full((1,), pos, jnp.int32)

    def scan_body(x, inp):
        bp, k_l, v_l, xk_l, xv_l = inp
        lcache = {"k": k_l, "v": v_l, "xk": xk_l, "xv": xv_l, "len": pos}
        x, new_kv, _ = _dec_block(
            cfg, bp, x, positions, None, rules, cache=lcache
        )
        return x, (new_kv[0], new_kv[1])

    x, (nk, nv) = jax.lax.scan(
        scan_body,
        x,
        (params["dec_blocks"], cache["k"], cache["v"], cache["xk"], cache["xv"]),
        unroll=L.scan_unroll(),
    )
    x = L.rmsnorm(x, params["ln_f"], cfg.norm_eps)
    logits = x @ params["unembed"]
    return logits, {**cache, "k": nk, "v": nv, "len": cache["len"] + 1}
