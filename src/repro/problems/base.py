"""The ``BranchingProblem`` plugin protocol: the framework/problem split.

The paper's pitch (and GemPBA's) is that a sequential branching algorithm
becomes a massively parallel one by changing only a few lines: the
coordination machinery — supersteps, the replicated center, the data plane,
batching, serving — is problem-generic, and a *problem* is a small plugin.
This module defines that contract; :mod:`repro.core` depends only on it
(never on a concrete problem), and :mod:`repro.problems.registry` maps names
to plugins.

A problem supplies:

* **packed-state layout** — every task is ``(mask, sol, depth)`` over packed
  ``uint32[W]`` bitsets of the ORIGINAL vertex set (the paper's optimized
  encoding, §4.3).  The per-instance device tensors live in a shared
  :class:`ProblemData` pytree; ``host_adj`` defines which adjacency view the
  branching runs on (e.g. MIS branches on the complement graph).
* **device fns** — ``branch_once`` (one node expansion -> a
  :class:`BranchStep`), ``task_bound``/``child_bound`` (admissible bounds for
  pruning).  All jit/vmap-compatible, all over ``(data, mask, sol)``.
* **objective adapter** — the engine always MINIMIZES an int32 *internal*
  value; maximization problems negate (``external_value`` converts back).
  ``bnb_bound(g)`` is the "worse than any real solution" seed;
  ``fpt_target(k)`` the internal decision threshold.
* **host plumbing** — ``branch_once_host`` drives the §3.5 startup split,
  ``sequential`` is the ground-truth reference, ``verify`` checks solutions.
* **codec record layout** — ``record_fields`` names the words a task record
  carries on the wire (see :mod:`repro.core.encoding`).

See ``problems/mis.py`` for the whole contract implemented in ~40 lines
(README "Adding a new problem").
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.graphs.bitgraph import mask_full

WORD_BITS = 32


class ProblemData(NamedTuple):
    """Static per-instance device tensors (replicated on every worker).

    ``adj`` is the BRANCHING graph's packed adjacency — the problem's
    ``host_adj`` decides what that is (original graph, complement, ...).
    Batched instances add a leading axis on ``n``/``adj`` only
    (:data:`DATA_IN_AXES`); ``word_idx``/``bit_idx`` are shared bit maps.
    """

    n: jnp.ndarray  # () int32 -- number of (real, unpadded) vertices
    adj: jnp.ndarray  # (n, W) uint32 packed adjacency
    word_idx: jnp.ndarray  # (n,) int32 -- v // 32
    bit_idx: jnp.ndarray  # (n,) uint32 -- v % 32


# vmap axis spec for batched ProblemData: per-instance n/adj, shared bit maps
DATA_IN_AXES = ProblemData(n=0, adj=0, word_idx=None, bit_idx=None)


class BranchStep(NamedTuple):
    """One node expansion: two children plus terminal detection.

    ``terminal_value`` is the INTERNAL objective value (minimization sense)
    of the completed solution when ``is_terminal``.
    """

    left_mask: jnp.ndarray
    left_sol: jnp.ndarray
    right_mask: jnp.ndarray
    right_sol: jnp.ndarray
    is_terminal: jnp.ndarray  # () bool
    terminal_sol: jnp.ndarray  # (W,) uint32
    terminal_value: jnp.ndarray  # () int32


class ExpandResult(NamedTuple):
    """One-pass batched expansion of L popped tasks (the fused hot path).

    Everything :func:`~repro.core.superstep._explore_one_round` needs from a
    task batch in one call: the pre-expansion bound (== ``task_bound`` per
    lane), the batched :class:`BranchStep` (every field gains a leading lane
    axis), and the two children's birth-time bounds (== ``child_bound`` on
    the left/right child per lane).  Child bounds are only consumed for
    non-terminal, non-pruned lanes, so a fused implementation may return
    arbitrary values on lanes where ``step.is_terminal`` holds.
    """

    bound: jnp.ndarray  # (L,) int32 -- task_bound per lane
    step: BranchStep  # batched: every field has a leading (L,) axis
    left_bound: jnp.ndarray  # (L,) int32 -- child_bound of the left child
    right_bound: jnp.ndarray  # (L,) int32 -- child_bound of the right child


# -- packed-bitset primitives (problem-agnostic device ops) --------------------


def popcount(words: jnp.ndarray) -> jnp.ndarray:
    """Popcount summed over the trailing word axis -> int32."""
    return jax.lax.population_count(words).astype(jnp.int32).sum(axis=-1)


def unpack_bits(words: jnp.ndarray, n: int) -> jnp.ndarray:
    """(..., W) uint32 -> (..., n) bool."""
    shifts = jnp.arange(WORD_BITS, dtype=jnp.uint32)
    bits = (words[..., :, None] >> shifts) & jnp.uint32(1)
    return bits.reshape(*words.shape[:-1], -1)[..., :n].astype(bool)


def pack_bits(bits: jnp.ndarray, W: int) -> jnp.ndarray:
    """(..., n) bool -> (..., W) uint32 (LSB-first)."""
    n = bits.shape[-1]
    pad = W * WORD_BITS - n
    if pad:
        bits = jnp.concatenate(
            [bits, jnp.zeros((*bits.shape[:-1], pad), dtype=bool)], axis=-1
        )
    b = bits.reshape(*bits.shape[:-1], W, WORD_BITS).astype(jnp.uint32)
    weights = jnp.uint32(1) << jnp.arange(WORD_BITS, dtype=jnp.uint32)
    return (b * weights).sum(axis=-1).astype(jnp.uint32)


def single_bit(v: jnp.ndarray, W: int) -> jnp.ndarray:
    """Packed mask with only bit ``v`` set (v: () int32)."""
    word = v // WORD_BITS
    bit = (v % WORD_BITS).astype(jnp.uint32)
    return jnp.where(
        jnp.arange(W) == word, jnp.uint32(1) << bit, jnp.uint32(0)
    ).astype(jnp.uint32)


def in_mask(data: ProblemData, mask: jnp.ndarray) -> jnp.ndarray:
    """(n,) bool: vertex v inside the packed mask."""
    return ((mask[data.word_idx] >> data.bit_idx) & 1).astype(bool)


def degrees(data: ProblemData, mask: jnp.ndarray) -> jnp.ndarray:
    """Induced-subgraph degrees on the branching graph; -1 outside the mask.

    This is the branching hot spot the Pallas kernel accelerates (one AND +
    popcount per adjacency row per task).
    """
    deg = popcount(data.adj & mask[None, :])
    return jnp.where(in_mask(data, mask), deg, jnp.int32(-1))


def degrees_batch(data: ProblemData, masks: jnp.ndarray) -> jnp.ndarray:
    """(L, W) task masks -> (L, n) degrees, kernel-accelerated when native.

    The fused ``expand_tasks`` implementations route their whole lane batch
    through ONE degrees computation; on a TPU runtime this dispatches to the
    Pallas ``bitset_ops`` kernel (native Mosaic), elsewhere it stays on the
    identical jnp math (same values bit-for-bit — the kernel suite asserts
    equality).  Imported lazily so the reference explore path never touches
    :mod:`repro.kernels` (arch-guarded: CPU-only installs stay Pallas-free).
    """
    from repro.kernels.bitset_ops.ops import degrees_auto

    return degrees_auto(data.adj, masks)


def expand_stats_batch(data: ProblemData, masks: jnp.ndarray, sols: jnp.ndarray):
    """(L, W) masks/sols -> (deg (L, n), pc_mask (L,), pc_sol (L,)).

    The fused expand panel (degrees + both popcounts) in one pass; Pallas
    ``batched_expand_stats`` when the runtime lowers it natively, identical
    jnp math elsewhere.  Lazy import, same arch rule as
    :func:`degrees_batch`.
    """
    from repro.kernels.bitset_ops.ops import expand_stats_auto

    return expand_stats_auto(data.adj, masks, sols)


def edge_count(deg: jnp.ndarray) -> jnp.ndarray:
    return jnp.maximum(deg, 0).sum() // 2


# -- the plugin contract --------------------------------------------------------

# Default on-the-wire task record: the frontier's native (mask, sol, depth)
# row.  Widths are symbolic: "W" -> packed words, "n*W" -> adjacency payload,
# int -> literal word count.  Resolved by repro.core.encoding, which is the
# single consumer: a problem's schema MUST start with this native triple
# (the frontier owns those fields); any fields after it ride as zero-filled
# extra payload words that the codecs and the SPMD data plane (via the
# codec's pad_words) actually carry, so wire-byte accounting stays exact.
RECORD_FIELDS = (("mask", "W"), ("sol", "W"), ("depth", 1))


@dataclasses.dataclass(frozen=True)
class BranchingProblem:
    """A branching problem plugged into the generic solve plane.

    Device callables are pure jnp functions over ``(data, mask, sol)``; the
    engine vmaps them across lanes and instances.  Host callables operate on
    :class:`~repro.graphs.bitgraph.BitGraph` instances.
    """

    name: str
    objective: str  # human-readable, e.g. "minimize |cover|"

    # device: one expansion; admissible internal-value bounds for pruning.
    # task_bound gates expansion of a popped task (may be expensive);
    # child_bound gates pushing a freshly-created child (must be cheap).
    branch_once: Callable[[ProblemData, Any, Any], BranchStep]
    task_bound: Callable[[ProblemData, Any, Any], Any]
    child_bound: Callable[[ProblemData, Any, Any], Any]

    # objective adapter (engine minimizes internal int32 values)
    bnb_bound: Callable[[Any], int]  # internal value worse than any solution

    # optional fused hot path: (data, masks (L, W), sols (L, W)) ->
    # ExpandResult computing bound + branch + child bounds in ONE pass over
    # the lane batch (shared popcounts/degrees, batched kernels).  Must be
    # bit-identical to the composed per-task callables on every lane the
    # engine consumes; None -> the engine composes the three callables
    # (:func:`compose_expand_tasks`), so third-party plugins need not
    # provide one to run under ``explore_impl="fused"``.
    expand_tasks: Optional[Callable[[ProblemData, Any, Any], ExpandResult]] = None
    external_value: Callable[[int], int] = staticmethod(lambda v: v)
    fpt_target: Callable[[int], int] = staticmethod(lambda k: k)

    # host plumbing
    host_adj: Callable[[Any], np.ndarray] = staticmethod(lambda g: g.adj)
    host_view: Callable[[Any], Any] = staticmethod(lambda g: g)
    # (view, mask, sol) -> (children, terminal) for the startup BFS split
    branch_once_host: Optional[Callable] = None
    sequential: Optional[Callable] = None  # ground-truth reference solver
    verify: Optional[Callable] = None  # (g, sol_mask) -> bool

    # host-side twins of task_bound/child_bound plus the terminal objective,
    # all in the INTERNAL (minimization) sense over (view, mask, sol_mask) —
    # these are what make a problem runnable on the discrete-event simulator
    # backends (protocol_sim / centralized), which explore on the host.
    host_task_bound: Optional[Callable] = None  # admissible pre-expansion bound
    host_child_bound: Optional[Callable] = None  # cheap bound at task birth
    host_terminal_value: Optional[Callable] = None  # internal value of a leaf

    # codec record layout (see repro.core.encoding)
    record_fields: tuple = RECORD_FIELDS


def compose_expand_tasks(problem: BranchingProblem) -> Callable:
    """The default batched expansion: the three per-task callables, vmapped.

    This is exactly what the reference explore path computes per round —
    ``task_bound`` on the popped batch, ``branch_once``, then ``child_bound``
    on both children — packaged behind the :class:`ExpandResult` signature.
    Problems without a hand-fused ``expand_tasks`` run on this under
    ``explore_impl="fused"`` and are trivially bit-identical to the
    reference path (property-tested in ``tests/test_explore_fused.py``).
    """

    def expand(data: ProblemData, masks, sols) -> ExpandResult:
        bound = jax.vmap(lambda m, s: problem.task_bound(data, m, s))(masks, sols)
        step = jax.vmap(lambda m, s: problem.branch_once(data, m, s))(masks, sols)
        left = jax.vmap(lambda m, s: problem.child_bound(data, m, s))(
            step.left_mask, step.left_sol
        )
        right = jax.vmap(lambda m, s: problem.child_bound(data, m, s))(
            step.right_mask, step.right_sol
        )
        return ExpandResult(bound=bound, step=step, left_bound=left, right_bound=right)

    return expand


def resolve_expand(problem: BranchingProblem) -> Callable:
    """The fused plane's batched expansion for ``problem``: its hand-fused
    ``expand_tasks`` when it ships one, else the composed default."""
    if problem.expand_tasks is not None:
        return problem.expand_tasks
    return compose_expand_tasks(problem)


def require_host_bounds(problem: BranchingProblem) -> BranchingProblem:
    """Assert a problem carries the host-side exploration callables the
    simulator backends need; raises a ``ValueError`` naming what's missing
    (the same fail-helpfully pattern as the registries)."""
    missing = [
        field
        for field in (
            "branch_once_host",
            "host_task_bound",
            "host_child_bound",
            "host_terminal_value",
        )
        if getattr(problem, field) is None
    ]
    if missing:
        raise ValueError(
            f"problem {problem.name!r} cannot run on a host simulator "
            f"backend: missing {', '.join(missing)} (see BranchingProblem)"
        )
    return problem


def initial_bound(problem: BranchingProblem, g, mode: str, k) -> int:
    """The engine's seed internal best: "worse than any acceptable solution".

    bnb: the problem's worst-case bound.  fpt: one worse than the decision
    target, so the bound prunes everything that cannot reach ``k`` and
    ``best < initial`` means the decision succeeded.
    """
    if mode == "fpt":
        if k is None:
            raise ValueError("fpt mode requires k")
        return int(problem.fpt_target(k)) + 1
    return int(problem.bnb_bound(g))


def make_data(problem: BranchingProblem, g) -> ProblemData:
    """Per-instance device tensors from a host graph (solo solve path)."""
    adj = np.asarray(problem.host_adj(g), dtype=np.uint32)
    v = np.arange(adj.shape[0], dtype=np.int32)
    return ProblemData(
        n=jnp.int32(g.n),
        adj=jnp.asarray(adj),
        word_idx=jnp.asarray(v // WORD_BITS),
        bit_idx=jnp.asarray((v % WORD_BITS).astype(np.uint32)),
    )


def make_batch_data(
    problem: BranchingProblem, graphs, n_max: int, W: int
) -> ProblemData:
    """Pack B same-width instances into padded (B, n_max, W) device tensors.

    Padding rows are zero (isolated, never-in-mask vertices), so they change
    no branching decision for any problem whose initial mask covers only the
    real vertices — the batched trace stays bit-identical to the solo one.
    """
    B = len(graphs)
    adj = np.zeros((B, n_max, W), np.uint32)
    for b, g in enumerate(graphs):
        adj[b, : g.n, :] = np.asarray(problem.host_adj(g), np.uint32)
    v = np.arange(n_max, dtype=np.int32)
    return ProblemData(
        n=jnp.asarray(np.array([g.n for g in graphs], np.int32)),
        adj=jnp.asarray(adj),
        word_idx=jnp.asarray(v // WORD_BITS),
        bit_idx=jnp.asarray((v % WORD_BITS).astype(np.uint32)),
    )


def slice_instances(data: ProblemData, sel) -> ProblemData:
    """Select instances along the batch axis (host-side compaction)."""
    return data._replace(n=data.n[sel], adj=data.adj[sel])


def make_blank_batch_data(num_lanes: int, n_max: int, W: int) -> ProblemData:
    """An all-vacant batched :class:`ProblemData` for a live plane: zero
    adjacency and n=0 per lane (inert under the frozen-lane select —
    admission overwrites a lane's slice via :func:`write_instance`)."""
    v = np.arange(n_max, dtype=np.int32)
    return ProblemData(
        n=jnp.zeros((num_lanes,), jnp.int32),
        adj=jnp.zeros((num_lanes, n_max, W), jnp.uint32),
        word_idx=jnp.asarray(v // WORD_BITS),
        bit_idx=jnp.asarray((v % WORD_BITS).astype(np.uint32)),
    )


# jitted lane write (one executable per (B, n_max, W) shape — live-plane
# admission calls this once per swap-in, where eager scatters add up)
@jax.jit
def _write_lane_dev(n, adj, lane, n_val, adj_block):
    return n.at[lane].set(n_val), adj.at[lane].set(adj_block)


def write_instance(
    data: ProblemData, lane: int, problem: BranchingProblem, g
) -> ProblemData:
    """Write one instance into lane ``lane`` of a batched ``data`` (live-
    plane admission).  Rows past ``g.n`` are zeroed (isolated, never-in-mask
    vertices — exactly :func:`make_batch_data`'s padding rule, so the
    admitted instance's trace is bit-identical to its solo solve).  Pure
    data writes: shapes are unchanged, the compiled plane is reused as-is.
    """
    n_max, W = data.adj.shape[1], data.adj.shape[2]
    if g.n > n_max or g.W > W:
        raise ValueError(
            f"instance (n={g.n}, W={g.W}) exceeds the live plane's "
            f"(n_max={n_max}, W={W}) packing"
        )
    adj = np.zeros((n_max, W), np.uint32)
    adj[: g.n, : g.W] = np.asarray(problem.host_adj(g), np.uint32)
    new_n, new_adj = _write_lane_dev(
        data.n, data.adj, jnp.int32(lane), jnp.int32(g.n), jnp.asarray(adj)
    )
    return data._replace(n=new_n, adj=new_adj)


def expand_frontier(
    problem: BranchingProblem,
    g,
    num_tasks: int,
    max_nodes: int = 10_000,
):
    """Startup-phase breadth-first split (paper §3.5), problem-generic:
    expand the root until at least ``num_tasks`` open tasks exist.  Returns
    ``[(mask, sol_mask, depth)]``.

    Terminal nodes encountered during the split are kept (they carry
    candidate solutions and must not be lost).  The traversal order matches
    the pre-plugin vertex-cover implementation exactly: pop the shallowest
    open task, append children in the plugin's order.
    """
    view = problem.host_view(g)
    frontier = [(mask_full(g.n), np.zeros(g.W, dtype=np.uint32), 0)]
    terminals = []
    nodes = 0
    while (
        len(frontier) + len(terminals) < num_tasks
        and frontier
        and nodes < max_nodes
    ):
        # expand the shallowest open task (BFS == equitable split)
        idx = min(range(len(frontier)), key=lambda i: frontier[i][2])
        mask, sol_mask, depth = frontier.pop(idx)
        nodes += 1
        children, terminal = problem.branch_once_host(view, mask, sol_mask)
        if terminal is not None:
            terminals.append((terminal[0], terminal[1], depth))
            continue
        for cmask, csol in children:
            frontier.append((cmask, csol, depth + 1))
    return frontier + terminals
