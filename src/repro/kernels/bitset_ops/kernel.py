"""Pallas TPU kernels: batched bitset degrees + fused expand stats (the B&B
compute hot spot).

TPU-native rethink of the GPU bitset tricks (no warp ballots / popc
intrinsics assumed): the adjacency bitset matrix ``(n, W)`` lives wholly in
VMEM (n ≤ 2048 ⇒ ≤ 512 KiB), a grid over task blocks streams packed task
masks through the VPU, and popcount is a SWAR reduction (shift/mask adds) so
it vectorizes over the (8, 128) VREG tile regardless of Mosaic popcount
support.  Degrees come out as an ``(T, n)`` int32 panel: one AND + popcount
per (task, vertex, word) triple, reduced over words with a fori_loop so the
VMEM working set stays at ``BT × n`` instead of ``BT × n × W``.

Grid:  (ceil(T / BT),)
  masks block  (BT, W)   VMEM
  adj          (n, W)    VMEM (whole matrix, every grid step)
  out block    (BT, n)   VMEM

``batched_expand_stats`` is the fused exploration plane's kernel: the same
degrees panel PLUS the per-task popcounts of the candidate mask and the
partial solution, all in one VMEM pass over the packed words — the exact
quantities a fused ``expand_tasks`` needs for bound / pivot / child-prune
(degrees feed the argmax pivot; popcounts feed the bounds), so the hot path
reads each task word once instead of once per bound.
"""

from __future__ import annotations

import functools
import os
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

WORD_BITS = 32

_INTERPRET_ENV = "REPRO_PALLAS_INTERPRET"


def default_interpret() -> bool:
    """True when the Pallas kernels should run in interpret mode.

    Native Mosaic lowering only exists on TPU; everywhere else the kernels
    run under the (slow, Python-level) interpreter, which is only good for
    validation.  ``REPRO_PALLAS_INTERPRET=0|1`` forces either mode — e.g.
    ``=1`` to debug a kernel on TPU, ``=0`` to assert a runtime really
    lowers natively.  Every kernel entry point defaulting to
    ``interpret=None`` resolves through here, so nothing silently pays the
    interpreter on TPU.
    """
    env = os.environ.get(_INTERPRET_ENV, "").strip()
    if env:  # empty/unset -> backend detection
        return env.lower() not in ("0", "false", "no", "off")
    return jax.default_backend() != "tpu"


def kernels_native() -> bool:
    """True when the Pallas kernels lower natively (worth using in hot
    paths); the complement of :func:`default_interpret`."""
    return not default_interpret()


def _swar_popcount_u32(x: jnp.ndarray) -> jnp.ndarray:
    """Branch-free SWAR popcount on uint32 (VPU shift/mask adds)."""
    x = x - ((x >> 1) & jnp.uint32(0x55555555))
    x = (x & jnp.uint32(0x33333333)) + ((x >> 2) & jnp.uint32(0x33333333))
    x = (x + (x >> 4)) & jnp.uint32(0x0F0F0F0F)
    return ((x * jnp.uint32(0x01010101)) >> 24).astype(jnp.int32)


def _degrees_kernel(masks_ref, adj_ref, out_ref, *, n: int, W: int):
    BT = masks_ref.shape[0]
    masks = masks_ref[...]  # (BT, W) uint32

    def word_step(w, acc):
        mw = masks[:, w]  # (BT,)
        aw = adj_ref[:, w]  # (n,)
        inter = mw[:, None] & aw[None, :]  # (BT, n)
        return acc + _swar_popcount_u32(inter)

    deg = jax.lax.fori_loop(
        0, W, word_step, jnp.zeros((BT, n), jnp.int32)
    )

    # mask out vertices not in the task: bit v of masks word v//32
    v = jax.lax.broadcasted_iota(jnp.int32, (BT, n), 1)
    word_idx = v // WORD_BITS
    bit_idx = (v % WORD_BITS).astype(jnp.uint32)
    mask_words = jnp.take_along_axis(masks, word_idx.astype(jnp.int32), axis=1)
    inside = ((mask_words >> bit_idx) & 1).astype(bool)
    out_ref[...] = jnp.where(inside, deg, jnp.int32(-1))


def _expand_stats_kernel(
    masks_ref, sols_ref, adj_ref, deg_ref, pc_ref, *, n: int, W: int
):
    """Fused panel: degrees (BT, n) + [pc_mask, pc_sol] (BT, 2) per block."""
    BT = masks_ref.shape[0]
    masks = masks_ref[...]  # (BT, W) uint32
    sols = sols_ref[...]  # (BT, W) uint32

    def word_step(w, carry):
        deg, pcm, pcs = carry
        mw = masks[:, w]  # (BT,)
        sw = sols[:, w]  # (BT,)
        aw = adj_ref[:, w]  # (n,)
        inter = mw[:, None] & aw[None, :]  # (BT, n)
        # popcount accumulators stay 2-D (BT, 1): TPU vregs want a lane axis
        return (
            deg + _swar_popcount_u32(inter),
            pcm + _swar_popcount_u32(mw[:, None]),
            pcs + _swar_popcount_u32(sw[:, None]),
        )

    deg, pc_mask, pc_sol = jax.lax.fori_loop(
        0,
        W,
        word_step,
        (
            jnp.zeros((BT, n), jnp.int32),
            jnp.zeros((BT, 1), jnp.int32),
            jnp.zeros((BT, 1), jnp.int32),
        ),
    )

    # mask out vertices not in the task: bit v of masks word v//32
    v = jax.lax.broadcasted_iota(jnp.int32, (BT, n), 1)
    word_idx = v // WORD_BITS
    bit_idx = (v % WORD_BITS).astype(jnp.uint32)
    mask_words = jnp.take_along_axis(masks, word_idx.astype(jnp.int32), axis=1)
    inside = ((mask_words >> bit_idx) & 1).astype(bool)
    deg_ref[...] = jnp.where(inside, deg, jnp.int32(-1))
    pc_ref[...] = jnp.concatenate([pc_mask, pc_sol], axis=1)


@functools.partial(jax.jit, static_argnames=("block_tasks", "interpret"))
def batched_expand_stats(
    adj: jnp.ndarray,
    masks: jnp.ndarray,
    sols: jnp.ndarray,
    *,
    block_tasks: int = 8,
    interpret: Optional[bool] = None,
):
    """adj (n, W), masks/sols (T, W) uint32 -> (deg (T, n) int32,
    pc (T, 2) int32) where pc[:, 0] = popcount(mask), pc[:, 1] =
    popcount(sol) — the fused expand hot-path panel in one kernel pass.

    ``interpret=None`` resolves via :func:`default_interpret` (native on
    TPU, interpret elsewhere); an explicit bool pins the mode.
    """
    if interpret is None:
        interpret = default_interpret()
    n, W = adj.shape
    T = masks.shape[0]
    BT = min(block_tasks, T)
    grid = (pl.cdiv(T, BT),)
    return pl.pallas_call(
        functools.partial(_expand_stats_kernel, n=n, W=W),
        grid=grid,
        in_specs=[
            pl.BlockSpec((BT, W), lambda i: (i, 0)),  # task masks block
            pl.BlockSpec((BT, W), lambda i: (i, 0)),  # task sols block
            pl.BlockSpec((n, W), lambda i: (0, 0)),  # whole adjacency
        ],
        out_specs=[
            pl.BlockSpec((BT, n), lambda i: (i, 0)),
            pl.BlockSpec((BT, 2), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((T, n), jnp.int32),
            jax.ShapeDtypeStruct((T, 2), jnp.int32),
        ],
        interpret=interpret,
    )(masks, sols, adj)


@functools.partial(jax.jit, static_argnames=("block_tasks", "interpret"))
def batched_degrees(
    adj: jnp.ndarray,
    masks: jnp.ndarray,
    *,
    block_tasks: int = 8,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """adj (n, W) uint32, masks (T, W) uint32 -> (T, n) int32 degrees.

    ``interpret=None`` resolves via :func:`default_interpret` (native on
    TPU, interpret elsewhere); an explicit bool pins the mode.
    """
    if interpret is None:
        interpret = default_interpret()
    n, W = adj.shape
    T = masks.shape[0]
    BT = min(block_tasks, T)
    grid = (pl.cdiv(T, BT),)
    return pl.pallas_call(
        functools.partial(_degrees_kernel, n=n, W=W),
        grid=grid,
        in_specs=[
            pl.BlockSpec((BT, W), lambda i: (i, 0)),  # task masks block
            pl.BlockSpec((n, W), lambda i: (0, 0)),  # whole adjacency
        ],
        out_specs=pl.BlockSpec((BT, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((T, n), jnp.int32),
        interpret=interpret,
    )(masks, adj)
