"""Serving driver: the asyncio front end of the continuous-batching solve
service.

Drives a synthetic Poisson request stream (Erdős–Rényi instances) through
:class:`repro.api.AsyncSolveService`: every request is submitted the moment
it "arrives", admission fills lanes freed by finished instances on the ONE
live compiled plane per (problem, W), and per-request results stream back
as their lanes retire.  Prints end-to-end latency percentiles (p50/p99,
arrival → result) and steady-state throughput — the serving view of the
paper's quasi-equitable load sharing.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --smoke
  PYTHONPATH=src python -m repro.launch.serve --problem max_clique \
      --requests 32 --lanes 8 --rate 4.0 --n 24

(The old batched LM-decode demo lives in ``examples/serve_lm.py``.)
"""

from __future__ import annotations

import argparse
import asyncio
import json
import time

import numpy as np


def build_requests(args, rng) -> list:
    """The synthetic arrival trace: (arrival_s, graph) pairs.  Sizes are
    drawn uniformly from [n_min, n], all packing into one W=1 plane by
    default; arrival gaps are exponential at ``rate`` req/s (0 = a burst)."""
    from repro.graphs.generators import erdos_renyi

    reqs = []
    t = 0.0
    for i in range(args.requests):
        n = int(rng.integers(args.n_min, args.n + 1))
        g = erdos_renyi(n, args.density, seed=int(rng.integers(1 << 30)))
        if args.rate > 0:
            t += float(rng.exponential(1.0 / args.rate))
        reqs.append((t, g))
    return reqs


async def run_service(args, reqs) -> dict:
    from repro.api import AsyncSolveService, SolveConfig, SolveService

    cfg = SolveConfig(
        num_workers=args.workers,
        steps_per_round=args.steps_per_round,
        chunk_rounds=args.chunk_rounds,
        service_lanes=args.lanes,
        admission=args.admission,
        checkpoint_dir=args.checkpoint_dir,
        checkpoint_every=args.checkpoint_every,
    )
    leftover = []
    if args.resume:
        # restore live lanes + pending queue from a service checkpoint;
        # its tickets finish alongside the fresh synthetic stream
        service = SolveService.restore(args.resume)
        leftover = service.tickets()
        print(f"[serve] restored {args.resume}: {len(leftover)} "
              f"in-flight/queued tickets resume")
    else:
        service = SolveService(args.problem, cfg)
    latencies = []
    t0 = time.perf_counter()

    async def one(arrival_s, g):
        # hold the request until its Poisson arrival, then submit
        now = time.perf_counter() - t0
        if arrival_s > now:
            await asyncio.sleep(arrival_s - now)
        submit = time.perf_counter()
        r = await svc.solve(g, deadline=args.deadline)
        latencies.append(time.perf_counter() - submit)
        return r

    async with AsyncSolveService(service) as svc:
        results = await asyncio.gather(*(one(a, g) for a, g in reqs))
    # the restored checkpoint's own tickets may still be in flight; finish
    # them so a killed-and-restarted service completes everything admitted
    resumed_results = {}
    if leftover:
        service.drain()
        resumed_results = {t: service.result(t) for t in leftover}
    wall = time.perf_counter() - t0

    lat = np.array(sorted(latencies))
    stats = service.stats()
    return {
        "resumed_tickets": len(resumed_results),
        "resumed_best_sizes": [
            resumed_results[t].best_size for t in sorted(resumed_results)
        ],
        "requests": len(reqs),
        "wall_s": wall,
        "instances_per_s": len(reqs) / wall,
        "latency_p50_s": float(np.percentile(lat, 50)),
        "latency_p99_s": float(np.percentile(lat, 99)),
        "occupancy": stats["occupancy"],
        "evicted": stats["evicted"],
        "best_sizes": [r.best_size for r in results],
        "cache": service.cache_stats(),
    }


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--problem", default="max_clique")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--lanes", type=int, default=8,
                    help="service lanes per live plane")
    ap.add_argument("--workers", type=int, default=8)
    ap.add_argument("--steps-per-round", type=int, default=16)
    ap.add_argument("--chunk-rounds", type=int, default=8)
    ap.add_argument("--n", type=int, default=26, help="max instance size")
    ap.add_argument("--n-min", type=int, default=14)
    ap.add_argument("--density", type=float, default=0.5)
    ap.add_argument("--rate", type=float, default=0.0,
                    help="Poisson arrival rate, req/s (0 = burst)")
    ap.add_argument("--deadline", type=int, default=None,
                    help="superstep budget per request (anytime eviction)")
    ap.add_argument("--admission", choices=("fifo", "priority"),
                    default="priority")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--checkpoint-dir", default=None, metavar="DIR",
                    help="auto-checkpoint the live service (lanes + queue) "
                         "every --checkpoint-every steps")
    ap.add_argument("--checkpoint-every", type=int, default=8)
    ap.add_argument("--resume", default=None, metavar="DIR",
                    help="restore a service checkpoint first; its in-flight "
                         "and queued tickets finish alongside the new stream")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny settings for CI")
    ap.add_argument("--json", action="store_true",
                    help="print the full stats dict as JSON")
    args = ap.parse_args()
    if args.smoke:
        args.requests = min(args.requests, 12)
        args.n = min(args.n, 20)
        args.workers = min(args.workers, 4)
        args.lanes = min(args.lanes, 4)
        args.steps_per_round = min(args.steps_per_round, 8)

    rng = np.random.default_rng(args.seed)
    reqs = build_requests(args, rng)
    out = asyncio.run(run_service(args, reqs))
    if args.json:
        print(json.dumps(out, indent=2))
    else:
        print(
            f"[serve] {out['requests']} requests in {out['wall_s']:.2f}s "
            f"({out['instances_per_s']:.2f} inst/s), latency p50 "
            f"{out['latency_p50_s']*1e3:.0f}ms p99 "
            f"{out['latency_p99_s']*1e3:.0f}ms, plane occupancy "
            f"{out['occupancy']:.2f}, evicted {out['evicted']}"
            + (f", resumed {out['resumed_tickets']} checkpointed tickets"
               if out["resumed_tickets"] else "")
        )
        print(f"[serve] cache: {out['cache']}")


if __name__ == "__main__":
    main()
