"""Sequential minimum-vertex-cover branching solver (paper Algorithm 8).

Branch rule: pick a maximum-degree vertex u; either u is in the cover
(recurse on G-u, S+{u}) or all of N(u) is (recurse on G-N(u)-u, S+N(u)).
Reduction rules 1-3 (Chen-Kanj-Jia, paper §4.1) are applied to fixpoint at
every node.  Pruning uses |S| + ceil(E / maxdeg) >= |best| (each cover vertex
covers at most maxdeg remaining edges).

This module is the *ground truth* for every parallel component, and also
provides the shared single-node expansion (`branch_once`) used by the host
startup phase and by the discrete-event protocol simulator.

Tasks are (mask, sol_mask) pairs of packed uint32 bitsets over the ORIGINAL
vertex set — exactly the paper's optimized encoding (§4.3): the graph itself
is never re-serialized, only the surviving-vertex mask travels.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.graphs.bitgraph import BitGraph, mask_full, popcount_rows, single_bit


@dataclasses.dataclass
class SeqStats:
    nodes: int = 0
    pruned: int = 0
    solutions: int = 0
    max_depth: int = 0


def _first_bit(words: np.ndarray) -> int:
    """Index of the lowest set bit; -1 if empty."""
    for wi, w in enumerate(words.tolist()):
        if w:
            return wi * 32 + (w & -w).bit_length() - 1
    return -1


def reduce_instance(
    g: BitGraph, mask: np.ndarray, sol_mask: np.ndarray
) -> tuple[np.ndarray, np.ndarray]:
    """Apply rules 1-3 iteratively until the instance stops changing.

    Rule 1: drop isolated vertices.
    Rule 2: for a degree-1 vertex u with neighbor v, add v to S, drop u, v.
    Rule 3: for a degree-2 vertex u with adjacent neighbors v, w, add v and w
            to S, drop u, v, w.
    """
    mask = mask.copy()
    sol_mask = sol_mask.copy()
    changed = True
    while changed:
        changed = False
        deg = g.degrees(mask)
        inside = deg >= 0
        # Rule 1 (batch-safe: removals never conflict)
        iso = inside & (deg == 0)
        if iso.any():
            from repro.graphs.bitgraph import pack_masks

            mask &= ~pack_masks(iso)
            changed = True
            continue
        # Rule 2 (one vertex per sweep; batching can over-add on isolated edges)
        ones = np.nonzero(inside & (deg == 1))[0]
        if len(ones):
            u = int(ones[0])
            nb = g.adj[u] & mask
            sol_mask |= nb
            mask &= ~(nb | single_bit(u, g.W))
            changed = True
            continue
        # Rule 3
        twos = np.nonzero(inside & (deg == 2))[0]
        for u in twos:
            nb = g.adj[int(u)] & mask
            v = _first_bit(nb)
            rest = nb & ~single_bit(v, g.W)
            w = _first_bit(rest)
            if g.adj[v][w // 32] & np.uint32(1 << (w % 32)):  # v-w edge exists
                sol_mask |= nb
                mask &= ~(nb | single_bit(int(u), g.W))
                changed = True
                break
    return mask, sol_mask


def lower_bound(g: BitGraph, mask: np.ndarray) -> int:
    """ceil(E / maxdeg): every cover vertex covers <= maxdeg edges."""
    deg = g.degrees(mask)
    maxdeg = int(deg.max(initial=-1))
    if maxdeg <= 0:
        return 0
    E = int(deg[deg > 0].sum()) // 2
    return -(-E // maxdeg)


def branch_once(
    g: BitGraph, mask: np.ndarray, sol_mask: np.ndarray
) -> tuple[list[tuple[np.ndarray, np.ndarray]], tuple[np.ndarray, np.ndarray] | None]:
    """One node expansion *after reduction*: returns (children, terminal).

    ``terminal`` is the (mask, sol_mask) if the reduced instance has no edges
    (i.e. sol_mask is a full cover of the original graph), else None.
    ``children`` is the pair of branch sub-instances (paper Alg. 8 lines 8-11),
    in heuristic order (include-u first).
    """
    mask, sol_mask = reduce_instance(g, mask, sol_mask)
    deg = g.degrees(mask)
    maxdeg = int(deg.max(initial=-1))
    if maxdeg <= 0:
        return [], (mask, sol_mask)
    u = int(np.argmax(deg))
    u_bit = single_bit(u, g.W)
    nb = g.adj[u] & mask
    left = (mask & ~u_bit, sol_mask | u_bit)  # u in the cover
    right = (mask & ~(nb | u_bit), sol_mask | nb)  # N(u) in the cover
    return [left, right], None


def solve_sequential(
    g: BitGraph,
    mode: str = "bnb",
    k: int | None = None,
    initial_best: int | None = None,
    node_limit: int | None = None,
) -> tuple[int, np.ndarray | None, SeqStats]:
    """Exact sequential solve.  Returns (best_size, best_sol_mask, stats).

    mode='bnb'  : minimize |S| (branch and bound).
    mode='fpt'  : decision "is there a cover of size <= k"; stops at first hit
                  (returns that solution) -- paper §2.1 FPT variant.
    """
    if mode == "fpt" and k is None:
        raise ValueError("fpt mode requires k")
    stats = SeqStats()
    best_size = initial_best if initial_best is not None else g.n + 1
    if mode == "fpt":
        best_size = min(best_size, k + 1)
    best_sol: np.ndarray | None = None
    stack = [(mask_full(g.n), np.zeros(g.W, dtype=np.uint32), 0)]
    while stack:
        if node_limit is not None and stats.nodes >= node_limit:
            break
        mask, sol_mask, depth = stack.pop()
        stats.nodes += 1
        stats.max_depth = max(stats.max_depth, depth)
        sol_size = int(popcount_rows(sol_mask))
        if sol_size + lower_bound(g, mask) >= best_size:
            stats.pruned += 1
            continue
        children, terminal = branch_once(g, mask, sol_mask)
        if terminal is not None:
            _, tsol = terminal
            tsize = int(popcount_rows(tsol))
            if tsize < best_size:
                best_size = tsize
                best_sol = tsol
                stats.solutions += 1
                if mode == "fpt" and best_size <= k:
                    break
            continue
        # push right first so left (include-u, the heuristic-promising child)
        # is explored first -- matches the leftmost-first priority of §3.4
        for child in reversed(children):
            cmask, csol = child
            if int(popcount_rows(csol)) < best_size:
                stack.append((cmask, csol, depth + 1))
            else:
                stats.pruned += 1
    if mode == "fpt":
        found = best_size <= k
        return (best_size if found else -1), (best_sol if found else None), stats
    return best_size, best_sol, stats


def expand_frontier(
    g: BitGraph,
    num_tasks: int,
    max_nodes: int = 10_000,
) -> list[tuple[np.ndarray, np.ndarray, int]]:
    """Startup-phase breadth-first split (paper §3.5): expand the root until at
    least ``num_tasks`` open tasks exist.  Returns [(mask, sol_mask, depth)].

    Terminal nodes encountered during the split are kept in the list (they
    carry candidate solutions and must not be lost).
    """
    frontier = [(mask_full(g.n), np.zeros(g.W, dtype=np.uint32), 0)]
    terminals: list[tuple[np.ndarray, np.ndarray, int]] = []
    nodes = 0
    while len(frontier) + len(terminals) < num_tasks and frontier and nodes < max_nodes:
        # expand the shallowest open task (BFS == equitable split)
        idx = min(range(len(frontier)), key=lambda i: frontier[i][2])
        mask, sol_mask, depth = frontier.pop(idx)
        nodes += 1
        children, terminal = branch_once(g, mask, sol_mask)
        if terminal is not None:
            terminals.append((terminal[0], terminal[1], depth))
            continue
        for cmask, csol in children:
            frontier.append((cmask, csol, depth + 1))
    return frontier + terminals


# -- max-clique / maximum-independent-set references ---------------------------
#
# Ground truth for the `max_clique` and `mis` plugins, mirroring the device
# brancher: tasks are (candidate-set P, clique R) packed-bitset pairs; branch
# on a maximum-degree candidate u — either u joins the clique (candidates
# shrink to P ∩ N(u)) or u is discarded.  Bound: |R| + |P| (every remaining
# candidate could, at best, join).  MIS is max-clique on the complement.


def branch_once_clique(
    g: BitGraph, mask: np.ndarray, sol_mask: np.ndarray
) -> tuple[list[tuple[np.ndarray, np.ndarray]], tuple[np.ndarray, np.ndarray] | None]:
    """One candidate-set expansion on the (branching) graph ``g``.

    ``mask`` = candidates P, ``sol_mask`` = current clique R.  Terminal when
    no candidates remain (R is maximal along this path).  Children come
    include-u first, matching the device brancher's order.
    """
    deg = g.degrees(mask)
    if not (deg >= 0).any():  # P empty
        return [], (mask, sol_mask)
    u = int(np.argmax(deg))  # max degree within P, ties -> lowest index
    u_bit = single_bit(u, g.W)
    nb = g.adj[u] & mask
    left = (nb, sol_mask | u_bit)  # u joins: candidates must be neighbours
    right = (mask & ~u_bit, sol_mask)  # u discarded
    return [left, right], None


def solve_sequential_max_clique(
    g: BitGraph,
    mode: str = "bnb",
    k: int | None = None,
    node_limit: int | None = None,
) -> tuple[int, np.ndarray | None, SeqStats]:
    """Exact maximum clique.  Returns (best_size, best_sol_mask, stats).

    mode='bnb' : maximize |R|.
    mode='fpt' : decision "is there a clique of size >= k"; stops at the
                 first hit, returns (-1, None, stats) when unsatisfiable.
    """
    if mode == "fpt" and k is None:
        raise ValueError("fpt mode requires k")
    stats = SeqStats()
    best_size = 0
    best_sol = np.zeros(g.W, dtype=np.uint32)  # the empty clique
    floor = (k - 1) if mode == "fpt" else 0  # prune below the decision target
    stack = [(mask_full(g.n), np.zeros(g.W, dtype=np.uint32), 0)]
    while stack:
        if node_limit is not None and stats.nodes >= node_limit:
            break
        mask, sol_mask, depth = stack.pop()
        stats.nodes += 1
        stats.max_depth = max(stats.max_depth, depth)
        r = int(popcount_rows(sol_mask))
        if r + int(popcount_rows(mask)) <= max(best_size, floor):
            stats.pruned += 1
            continue
        children, terminal = branch_once_clique(g, mask, sol_mask)
        if terminal is not None:
            if r > best_size:
                best_size, best_sol = r, sol_mask
                stats.solutions += 1
                if mode == "fpt" and best_size >= k:
                    break
            continue
        # push right first so left (include-u, the promising child) pops first
        for cmask, csol in reversed(children):
            stack.append((cmask, csol, depth + 1))
    if mode == "fpt":
        found = best_size >= k
        return (best_size if found else -1), (best_sol if found else None), stats
    return best_size, best_sol, stats


def solve_sequential_mis(
    g: BitGraph,
    mode: str = "bnb",
    k: int | None = None,
    node_limit: int | None = None,
) -> tuple[int, np.ndarray | None, SeqStats]:
    """Exact maximum independent set = max clique on the complement graph.
    The returned mask is the independent set in the ORIGINAL graph."""
    from repro.graphs.bitgraph import complement

    return solve_sequential_max_clique(
        complement(g), mode=mode, k=k, node_limit=node_limit
    )


def verify_clique(g: BitGraph, sol_mask: np.ndarray) -> bool:
    """True iff every pair of vertices in sol_mask is adjacent in g."""
    from repro.graphs.bitgraph import unpack_mask

    sel = np.flatnonzero(unpack_mask(sol_mask, g.n))
    dense = g.to_dense()
    return all(dense[u, v] for i, u in enumerate(sel) for v in sel[i + 1 :])


def verify_independent_set(g: BitGraph, sol_mask: np.ndarray) -> bool:
    """True iff no edge of g has both endpoints in sol_mask."""
    from repro.graphs.bitgraph import unpack_mask

    sel = unpack_mask(sol_mask, g.n)
    dense = g.to_dense()
    return not (dense & sel[:, None] & sel[None, :]).any()


def verify_cover(g: BitGraph, sol_mask: np.ndarray) -> bool:
    """True iff sol_mask covers every edge of g."""
    from repro.graphs.bitgraph import unpack_mask

    in_cover = unpack_mask(sol_mask, g.n)
    dense = g.to_dense()
    uncovered = dense & ~in_cover[:, None] & ~in_cover[None, :]
    return not uncovered.any()


def brute_force_mvc(g: BitGraph) -> int:
    """Exponential brute force over all subsets -- only for tiny test graphs."""
    assert g.n <= 16
    dense = g.to_dense()
    us, vs = np.nonzero(np.triu(dense, 1))
    best = g.n
    for bits in range(1 << g.n):
        size = bin(bits).count("1")
        if size >= best:
            continue
        sel = np.array([(bits >> i) & 1 for i in range(g.n)], dtype=bool)
        if np.all(sel[us] | sel[vs]):
            best = size
    return best
