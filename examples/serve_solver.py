"""Continuous-batching solver service quickstart: submit -> ticket ->
streamed results.

A SolveService keeps ONE live compiled plane per (problem, W): submitted
instances queue, the scheduler admits them into vacant lanes, and each
step() retires finished lanes — streaming those results out while the
other lanes keep solving and freed lanes re-admit from the queue (zero
re-compilation; swap-in is pure data).

  PYTHONPATH=src python examples/serve_solver.py
"""

import sys

sys.path.insert(0, "src")

from repro.api import SolveConfig, SolverSession
from repro.graphs.generators import erdos_renyi


def main():
    session = SolverSession(
        problem="max_clique",
        config=SolveConfig(num_workers=4, steps_per_round=8, service_lanes=4),
    )
    svc = session.serve()

    # submit a burst twice the lane count: the second half admits into
    # lanes freed by the first as they finish, not in a second batch
    tickets = [
        svc.submit(erdos_renyi(n, 0.5, seed=i), priority=n)
        for i, n in enumerate([18, 24, 14, 22, 16, 20, 12, 26])
    ]
    print("queued:", svc.status())

    while not svc.idle():
        for t in svc.step():  # tickets whose lane retired this step
            r = svc.result(t)  # pops; KeyError before the lane retires
            print(f"ticket {t}: best={r.best_size} rounds={r.rounds} "
                  f"lane={r.stats.service.lane}")

    stats = svc.stats()
    print(f"occupancy={stats['occupancy']:.2f} over "
          f"{stats['chunk_calls']} chunks; cache: {svc.cache_stats()}")
    assert all(t not in svc._results for t in tickets)


if __name__ == "__main__":
    main()
