"""Training driver: real steps on the local device(s), fault-tolerant.

This is the end-to-end path the quickstart uses (CPU-scale configs); on a
real pod the SAME functions run under the production mesh — the launcher
only changes ``--mesh``.  Fault tolerance contract:

* checkpoint every ``--ckpt-every`` steps (async write, atomic rename),
  saving params + optimizer + data-pipeline cursor;
* ``--resume`` restores the latest checkpoint — the deterministic pipeline
  (counter-mode PRNG keyed by step) regenerates identical batches, so the
  loss curve continues exactly;
* the checkpoint is mesh-agnostic: leaves are saved unsharded and re-placed
  against whatever mesh the restart runs with (elastic re-meshing).

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b --smoke \
      --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.store import latest_step, restore_checkpoint, save_checkpoint
from repro.configs.registry import get_config, get_smoke_config
from repro.data.pipeline import SyntheticTokens
from repro.models.registry import get_model
from repro.optim.adamw import adamw_init, adamw_update


def train_loop(
    cfg,
    *,
    steps: int,
    batch: int,
    seq: int,
    ckpt_dir: str | None = None,
    ckpt_every: int = 20,
    resume: bool = False,
    peak_lr: float = 3e-4,
    seed: int = 0,
    log_every: int = 10,
):
    model = get_model(cfg)
    pipe = SyntheticTokens(
        vocab=cfg.vocab, seq_len=seq + 1, global_batch=batch, seed=seed
    )
    key = jax.random.key(seed)
    params, _ = model.init(key)
    opt = adamw_init(params)
    start = 0

    if resume and ckpt_dir and latest_step(ckpt_dir) is not None:
        (params, opt), start, extra = restore_checkpoint(
            ckpt_dir, (params, opt)
        )
        pipe.restore(extra["data"])
        print(f"[train] resumed from step {start}")

    @jax.jit
    def step_fn(params, opt, batch):
        loss, grads = jax.value_and_grad(lambda p: model.loss_fn(p, batch))(
            params
        )
        params, opt, stats = adamw_update(
            params, grads, opt, peak_lr=peak_lr, total_steps=max(steps, 1)
        )
        return params, opt, loss, stats["grad_norm"]

    losses = []
    t0 = time.perf_counter()
    for it in range(start, steps):
        b = pipe.next_batch()
        if cfg.family == "encdec":
            rng = np.random.default_rng(seed * 100_003 + it)
            b["frames"] = jnp.asarray(
                rng.standard_normal((batch, cfg.enc_seq, cfg.d_model), np.float32)
            )
        if cfg.family == "vlm":
            rng = np.random.default_rng(seed * 100_019 + it)
            b["patch_embeds"] = jnp.asarray(
                rng.standard_normal((batch, cfg.n_patches, cfg.d_model), np.float32)
            )
        params, opt, loss, gnorm = step_fn(params, opt, b)
        losses.append(float(loss))
        if it % log_every == 0 or it == steps - 1:
            dt = time.perf_counter() - t0
            print(
                f"[train] step {it:5d} loss {float(loss):7.4f} "
                f"gnorm {float(gnorm):6.2f} ({dt:.1f}s)",
                flush=True,
            )
        if ckpt_dir and (it + 1) % ckpt_every == 0:
            save_checkpoint(
                ckpt_dir,
                it + 1,
                (params, opt),
                extra={"data": pipe.state()},
                blocking=False,
            )
    if ckpt_dir:
        save_checkpoint(
            ckpt_dir, steps, (params, opt), extra={"data": pipe.state()}
        )
    return params, opt, losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", help="reduced config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    _, _, losses = train_loop(
        cfg,
        steps=args.steps,
        batch=args.batch,
        seq=args.seq,
        ckpt_dir=args.ckpt_dir,
        ckpt_every=args.ckpt_every,
        resume=args.resume,
        peak_lr=args.lr,
        seed=args.seed,
    )
    k = max(len(losses) // 10, 1)
    print(
        f"[train] first-{k} mean loss {sum(losses[:k])/k:.4f} -> "
        f"last-{k} mean loss {sum(losses[-k:])/k:.4f}"
    )


if __name__ == "__main__":
    main()
