"""Quickstart: the paper's solver + the LM substrate in two minutes on CPU.

  PYTHONPATH=src python examples/quickstart.py
"""

import sys

sys.path.insert(0, "src")

import jax

from repro.core.engine import solve
from repro.core.protocol_sim import run_protocol_sim
from repro.graphs.generators import erdos_renyi
from repro.launch.train import train_loop
from repro.configs.registry import get_smoke_config
from repro.problems.sequential import solve_sequential, verify_cover


def main():
    # --- 1. the paper's workload: minimum vertex cover, three engines -----
    g = erdos_renyi(50, 4 / 49, seed=7)
    print(f"graph: n={g.n} m={g.num_edges}")
    best, sol, stats = solve_sequential(g)
    print(f"sequential:        mvc={best} ({stats.nodes} nodes)")

    res = run_protocol_sim(g, num_workers=6)
    print(
        f"semi-centralized:  mvc={res.best_size} "
        f"(async protocol sim, {res.stats.tasks_transferred} transfers, "
        f"{res.stats.failed_requests} failed requests)"
    )

    r = solve(g, num_workers=6, steps_per_round=16)
    ok = r.best_size == best and verify_cover(g, r.best_sol)
    print(
        f"SPMD engine:       mvc={r.best_size} "
        f"({r.rounds} supersteps, {r.tasks_transferred} transfers, "
        f"verified={ok})"
    )

    # --- 2. the LM substrate: a tiny qwen-style model learns --------------
    cfg = get_smoke_config("qwen1_5_0_5b")
    print(f"\ntraining {cfg.name} (d={cfg.d_model}, L={cfg.n_layers}) ...")
    _, _, losses = train_loop(cfg, steps=60, batch=8, seq=64, log_every=20)
    first, last = sum(losses[:6]) / 6, sum(losses[-6:]) / 6
    print(f"loss {first:.3f} -> {last:.3f} ({'OK' if last < first else 'FLAT'})")


if __name__ == "__main__":
    main()
