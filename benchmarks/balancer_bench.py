"""Beyond-paper: the semi-centralized request balancer on a hot-shard decode
trace — makespan and idle-slot reduction vs no balancing."""

from __future__ import annotations

import numpy as np

from repro.serving.balancer import simulate


def run(csv=True):
    rng = np.random.default_rng(0)
    rows = []
    for replicas in (4, 8, 16):
        works = list(rng.integers(8, 256, replicas * 8))
        off = simulate(replicas, 8, works, balance=False)
        on = simulate(replicas, 8, works, balance=True)
        rows.append(
            dict(
                replicas=replicas,
                requests=len(works),
                makespan_off=off["rounds"],
                makespan_on=on["rounds"],
                speedup=round(off["rounds"] / on["rounds"], 2),
                idle_off=off["idle_slot_steps"],
                idle_on=on["idle_slot_steps"],
                transfers=on["transfers"],
                control_ints_per_round=on["control_ints_per_round"],
            )
        )
    if csv:
        keys = list(rows[0].keys())
        print(",".join(keys))
        for r in rows:
            print(",".join(str(r[k]) for k in keys))
    return rows


if __name__ == "__main__":
    run()
