"""Property tests for the sparse data plane and multi-task donation.

* gather and sparse transfer implementations must produce IDENTICAL
  ``WorkerState`` pytrees for arbitrary frontiers (the only permitted
  difference is the payload accounting, which is the point of the A/B);
* ``pop_k_shallowest`` conserves tasks: popped + remaining == before, and
  the popped ones are exactly the shallowest.
"""

import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, strategies as st

from repro.core.frontier import make_frontier, pop_k_shallowest, push_many
from repro.core.superstep import build_superstep_fn, make_worker_state
from repro.graphs.bitgraph import n_words
from repro.graphs.generators import erdos_renyi
from repro.problems.base import make_data
from repro.problems.registry import get_problem

VC = get_problem("vertex_cover")

N = 32
W = n_words(N)
P = 6
CAP = 24


def _random_state(seed: int):
    """A (P, ...) stacked WorkerState with a random plausible frontier:
    random subsets of vertices as masks, disjoint partial solutions, random
    depths, a random subset of slots active (some workers possibly idle)."""
    rng = np.random.default_rng(seed)
    state = jax.vmap(lambda _: make_worker_state(CAP, W, N + 1))(jnp.arange(P))
    masks = rng.integers(0, 2**32, size=(P, CAP, W), dtype=np.uint32)
    sols = rng.integers(0, 2**32, size=(P, CAP, W), dtype=np.uint32)
    rem = N % 32
    if rem:
        masks[..., -1] &= np.uint32((1 << rem) - 1)
        sols[..., -1] &= np.uint32((1 << rem) - 1)
    sols &= ~masks  # a vertex is either open or already in the cover
    depths = rng.integers(0, 20, size=(P, CAP)).astype(np.int32)
    active = rng.random((P, CAP)) < rng.random((P, 1))  # skewed per worker
    return state._replace(
        frontier=state.frontier._replace(
            masks=jnp.asarray(masks),
            sols=jnp.asarray(sols),
            depths=jnp.asarray(depths),
            active=jnp.asarray(active),
        )
    )


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000), st.integers(1, 4))
def test_gather_and_sparse_paths_identical(seed, donate_k):
    g = erdos_renyi(N, 0.2, seed % 17)
    data = make_data(VC, g)
    state = _random_state(seed)
    fns = {
        impl: build_superstep_fn(
            VC,
            data,
            num_workers=P,
            steps_per_round=2,
            lanes=1,
            transfer_impl=impl,
            donate_k=donate_k,
        )
        for impl in ("gather", "sparse")
    }
    sg, dg = fns["gather"](state)
    ss, ds = fns["sparse"](state)
    assert bool(dg) == bool(ds)
    for name in sg._fields:
        if name == "payload_words":
            continue  # accounting differs by design (that's the A/B)
        ga, sa = getattr(sg, name), getattr(ss, name)
        for leaf_g, leaf_s in zip(jax.tree.leaves(ga), jax.tree.leaves(sa)):
            assert (np.asarray(leaf_g) == np.asarray(leaf_s)).all(), name
    # sparse payload never exceeds gather payload
    assert int(np.asarray(ss.payload_words)[0]) <= int(
        np.asarray(sg.payload_words)[0]
    )


@settings(max_examples=25, deadline=None)
@given(
    st.lists(st.integers(0, 50), min_size=0, max_size=CAP),
    st.integers(1, 5),
    st.integers(0, 5),
)
def test_pop_k_shallowest_conserves_tasks(depth_vals, k, limit):
    f = make_frontier(CAP, W)
    if depth_vals:
        kk = len(depth_vals)
        masks = jnp.tile(
            jnp.arange(1, kk + 1, dtype=jnp.uint32)[:, None], (1, W)
        )
        f = push_many(
            f,
            masks,
            jnp.zeros((kk, W), jnp.uint32),
            jnp.asarray(depth_vals, jnp.int32),
            jnp.ones((kk,), bool),
        )
    before = int(f.pending())
    f2, masks, sols, depths, valid = pop_k_shallowest(
        f, k, limit=jnp.int32(limit)
    )
    popped = int(np.asarray(valid).sum())
    # conservation: popped + remaining == before
    assert popped + int(f2.pending()) == before
    # the cap honors both the static k and the dynamic limit
    assert popped == min(k, limit, before)
    # the popped ones are exactly the shallowest, shallowest-first
    got = [int(d) for d, v in zip(np.asarray(depths), np.asarray(valid)) if v]
    assert got == sorted(depth_vals)[:popped]
    # remaining multiset is the complement
    rest = sorted(
        int(d)
        for d, a in zip(np.asarray(f2.depths), np.asarray(f2.active))
        if a
    )
    assert rest == sorted(sorted(depth_vals)[popped:])


def test_pop_k_shallowest_no_limit_matches_k():
    f = make_frontier(8, W)
    f = push_many(
        f,
        jnp.ones((3, W), jnp.uint32),
        jnp.zeros((3, W), jnp.uint32),
        jnp.asarray([5, 1, 3], jnp.int32),
        jnp.ones((3,), bool),
    )
    f2, _, _, depths, valid = pop_k_shallowest(f, 2)
    assert [int(d) for d, v in zip(np.asarray(depths), np.asarray(valid)) if v] == [1, 3]
    assert int(f2.pending()) == 1
