"""Pure-jnp oracle for blockwise (flash) attention.

Supports causal masking, sliding-window (local) masking, and GQA (the kernel
folds query-head groups; the oracle broadcasts KV heads).  This is the exact
math the Pallas kernel must reproduce, evaluated with a materialized (S, S)
score matrix — only usable at test sizes.
"""

from __future__ import annotations

import jax.numpy as jnp


def attention_ref(
    q: jnp.ndarray,  # (B, Sq, Hq, D)
    k: jnp.ndarray,  # (B, Sk, Hkv, D)
    v: jnp.ndarray,  # (B, Sk, Hkv, D)
    *,
    causal: bool = True,
    window: int | None = None,  # sliding window size (None = global)
    scale: float | None = None,
) -> jnp.ndarray:
    B, Sq, Hq, D = q.shape
    _, Sk, Hkv, _ = k.shape
    assert Hq % Hkv == 0
    G = Hq // Hkv
    scale = scale if scale is not None else 1.0 / (D**0.5)

    kr = jnp.repeat(k, G, axis=2)  # (B, Sk, Hq, D)
    vr = jnp.repeat(v, G, axis=2)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, kr) * scale  # (B, Hq, Sq, Sk)

    # positions: queries occupy the LAST Sq slots of the Sk timeline (decode:
    # Sq=1 attends to the full cache causally).
    qpos = jnp.arange(Sq) + (Sk - Sq)
    kpos = jnp.arange(Sk)
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window is not None:
        mask &= kpos[None, :] > qpos[:, None] - window
    logits = jnp.where(mask[None, None], logits, -jnp.inf)

    probs = jnp.nan_to_num(jnp.exp(logits - logits.max(-1, keepdims=True)))
    probs = probs / jnp.maximum(probs.sum(-1, keepdims=True), 1e-30)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, vr)
    return out.astype(q.dtype)
