"""Mixture-of-Experts block: top-k routing with sort-based grouped dispatch.

The memory-sane TPU formulation (no (T, E, C) one-hot dispatch tensor):

  1. router logits -> top_k (probs, expert ids) per token;
  2. flatten the T·k assignments and argsort by expert id;
  3. position-within-expert via a searchsorted segment offset; assignments
     beyond the per-expert capacity C = ceil(k·T/E · capacity_factor) drop
     (their tokens fall back to the residual stream only — standard
     "dropped tokens" semantics);
  4. gather tokens into the (E, C, d) expert batch, run the per-expert SwiGLU
     as batched einsums over E (MXU-friendly, sharded over the 'experts'
     logical axis = EP on the model mesh axis);
  5. scatter-add the outputs back weighted by the router probability.

The load-balancing auxiliary loss (Switch-style) is returned to the caller.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.sharding import constrain


def _get_shard_map():
    """shard_map across jax versions (top-level on newer releases,
    ``jax.experimental.shard_map`` on 0.4.x)."""
    fn = getattr(jax, "shard_map", None)
    if fn is not None:
        return fn
    from jax.experimental.shard_map import shard_map

    return shard_map


def moe_init(key, cfg: ModelConfig):
    d, f, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 4)
    scale = d**-0.5
    p = {
        "router": (jax.random.normal(ks[0], (d, E), jnp.float32) * scale).astype(
            jnp.float32
        ),
        "w1": (jax.random.normal(ks[1], (E, d, f), jnp.float32) * scale).astype(dt),
        "w3": (jax.random.normal(ks[2], (E, d, f), jnp.float32) * scale).astype(dt),
        "w2": (
            jax.random.normal(ks[3], (E, f, d), jnp.float32) * f**-0.5
        ).astype(dt),
    }
    # EP: the expert bank shards over the model axis; the per-expert f dim is
    # NOT tensor-parallel (it would duplicate the mesh axis) — fine-grained
    # experts (qwen3: f=1536) are too narrow to split anyway.
    s = {
        "router": ("embed", "experts"),
        "w1": ("experts", "embed", None),
        "w3": ("experts", "embed", None),
        "w2": ("experts", None, "embed"),
    }
    return p, s


def num_groups(rules) -> int:
    """Data-parallel group count = product of the mesh-axis sizes the 'batch'
    rule maps to (1 when running unsharded)."""
    if not rules or not rules.get("batch"):
        return 1
    sizes = rules.get("_sizes") or {}
    g = 1
    for a in rules["batch"]:
        g *= sizes.get(a, 1)
    return g


def moe_apply(cfg: ModelConfig, p, x, rules=None):
    """Dispatch on rules['_moe_impl']: 'gspmd' (baseline, below) or
    'shard_map' (§Perf cell A: explicit per-shard dispatch + psum combine)."""
    if (
        rules
        and rules.get("_moe_impl") == "shard_map"
        and rules.get("_mesh") is not None
        and rules.get("experts")
    ):
        return _moe_shard_map(cfg, p, x, rules)
    return _moe_gspmd(cfg, p, x, rules)


def _moe_gspmd(cfg: ModelConfig, p, x, rules=None):
    """x (B, S, d) -> (out (B, S, d), aux_loss ()).

    Tokens are reshaped to (G, T/G, d) with G = data-shard count so that
    routing, sort and capacity are GROUP-LOCAL (no cross-shard gathers) and
    the only cross-shard movement is the (G, E, C, d) buffer resharding from
    G→data to E→model — which GSPMD lowers to the canonical MoE all-to-all.
    """
    B, S, d = x.shape
    E, K = cfg.n_experts, cfg.top_k
    T = B * S
    G = num_groups(rules)
    while T % G:  # batch not divisible (decode with odd batch): halve groups
        G //= 2
    Tg = T // G
    xt = constrain(x.reshape(G, Tg, d), ("batch", None, None), rules)

    logits = xt.astype(jnp.float32) @ p["router"]  # (G, Tg, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, K)  # (G, Tg, K)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # Switch aux loss: E * sum_e (fraction routed to e) * (mean prob of e)
    counts = jnp.zeros((E,), jnp.float32).at[top_e.reshape(-1)].add(1.0)
    aux = E * jnp.sum((counts / (T * K)) * probs.mean((0, 1)))

    A = Tg * K  # assignments per group
    flat_e = top_e.reshape(G, A)
    flat_t = jnp.tile(
        jnp.repeat(jnp.arange(Tg, dtype=jnp.int32), K)[None], (G, 1)
    )
    flat_p = top_p.reshape(G, A)

    order = jnp.argsort(flat_e, axis=1, stable=True)
    se = jnp.take_along_axis(flat_e, order, axis=1)
    st = jnp.take_along_axis(flat_t, order, axis=1)
    sp = jnp.take_along_axis(flat_p, order, axis=1)

    C = int(max(1, (K * Tg / E) * cfg.capacity_factor))
    seg_start = jax.vmap(
        lambda row: jnp.searchsorted(row, jnp.arange(E, dtype=row.dtype))
    )(se)  # (G, E)
    pos = jnp.arange(A, dtype=jnp.int32)[None] - jnp.take_along_axis(
        seg_start, se, axis=1
    ).astype(jnp.int32)
    keep = pos < C
    slot = jnp.where(keep, se.astype(jnp.int32) * C + pos, E * C)  # OOB -> drop

    gi = jnp.arange(G, dtype=jnp.int32)[:, None]
    buf = jnp.zeros((G, E * C, d), x.dtype).at[gi, slot].set(
        jnp.take_along_axis(xt, st[..., None], axis=1), mode="drop"
    )
    # 2D-sharded expert batch: groups stay on their data shard, the expert
    # dim shards over model — dispatch is LOCAL (xt is replicated over the
    # model axis); only the combine below moves data between shards.
    buf = constrain(
        buf.reshape(G, E, C, d), ("batch", "experts", None, None), rules
    )
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", buf, p["w1"])) * jnp.einsum(
        "gecd,edf->gecf", buf, p["w3"]
    )
    y = jnp.einsum("gecf,efd->gecd", h, p["w2"])
    y = constrain(y, ("batch", "experts", None, None), rules).reshape(G, E * C, d)
    # combine: expert outputs return to their token's shard (baseline lowers
    # this as an all-gather over the model axis; see EXPERIMENTS.md §Perf)
    y = constrain(y, ("batch", None, None), rules)

    contrib = jnp.where(
        keep[..., None],
        y[gi, jnp.clip(slot, 0, E * C - 1)] * sp[..., None].astype(x.dtype),
        0,
    )
    out = jnp.zeros((G, Tg, d), x.dtype).at[gi, st].add(contrib)
    return out.reshape(B, S, d), aux


def _moe_shard_map(cfg: ModelConfig, p, x, rules):
    """§Perf cell A: explicit shard_map MoE.

    GSPMD cannot partition a data-dependent scatter whose written dim is
    sharded — the baseline replicates the (G, E·C, d) buffer per device
    (O(E/k · T · d) bytes moved per layer).  Under shard_map every index op is
    shard-LOCAL: each (data, model) device routes ITS tokens, keeps only the
    assignments that hit ITS experts, and the single cross-shard movement is
    one psum of the (Tg, d) combined output over the model axis — the same
    O(T·d) cost as a dense TP layer.
    """
    import numpy as _np
    from jax.sharding import PartitionSpec as P

    mesh = rules["_mesh"]
    sizes = rules["_sizes"]
    data_axes = tuple(rules.get("batch") or ())
    model_axis = rules["experts"][0]
    B, S, d = x.shape
    E, K = cfg.n_experts, cfg.top_k
    T = B * S
    M = sizes[model_axis]
    G = num_groups(rules)
    while T % G:
        G //= 2
    if E % M or G == 0:
        return _moe_gspmd(cfg, p, x, rules)
    Tg = T // G
    C = int(max(1, -(-K * Tg * cfg.capacity_factor // E)))
    E_loc = E // M
    dt = x.dtype

    def body(xt, router, w1, w3, w2):
        xt = xt.reshape(Tg, d)  # this data-shard's group
        router_full = jax.lax.all_gather(
            router, model_axis, axis=1, tiled=True
        )  # (d, E): tiny
        logits = xt.astype(jnp.float32) @ router_full  # (Tg, E)
        probs = jax.nn.softmax(logits, axis=-1)
        top_p, top_e = jax.lax.top_k(probs, K)
        top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

        counts_loc = jnp.zeros((E,), jnp.float32).at[top_e.reshape(-1)].add(1.0)
        counts = jax.lax.psum(counts_loc, data_axes) if data_axes else counts_loc
        pmean = probs.mean(0)
        if data_axes:
            pmean = jax.lax.pmean(pmean, data_axes)
        aux = E * jnp.sum((counts / (T * K)) * pmean)
        # identical on every model shard by construction; the pmean marks it
        # replicated for the VMA checker (O(1) payload)
        aux = jax.lax.pmean(aux, model_axis)

        A = Tg * K
        flat_e = top_e.reshape(A)
        flat_t = jnp.repeat(jnp.arange(Tg, dtype=jnp.int32), K)
        flat_p = top_p.reshape(A)
        order = jnp.argsort(flat_e, stable=True)
        se, st, sp = flat_e[order], flat_t[order], flat_p[order]
        seg_start = jnp.searchsorted(se, jnp.arange(E, dtype=se.dtype))
        pos = jnp.arange(A, dtype=jnp.int32) - seg_start[se].astype(jnp.int32)
        keep = pos < C

        e0 = (jax.lax.axis_index(model_axis) * E_loc).astype(jnp.int32)
        rel = se.astype(jnp.int32) - e0
        mine = keep & (rel >= 0) & (rel < E_loc)
        slot = jnp.where(mine, rel * C + pos, E_loc * C)  # OOB -> dropped

        buf = jnp.zeros((E_loc * C, d), dt).at[slot].set(xt[st], mode="drop")
        buf3 = buf.reshape(E_loc, C, d)
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf3, w1)) * jnp.einsum(
            "ecd,edf->ecf", buf3, w3
        )
        y = jnp.einsum("ecf,efd->ecd", h, w2).reshape(E_loc * C, d)
        contrib = jnp.where(
            mine[:, None], y[jnp.clip(slot, 0, E_loc * C - 1)] * sp[:, None].astype(dt), 0
        )
        out = jnp.zeros((Tg, d), dt).at[st].add(contrib)
        out = jax.lax.psum(out, model_axis)  # the ONLY big collective
        return out.reshape(1, Tg, d), aux

    xr = x.reshape(G, Tg, d)
    dspec = data_axes if len(data_axes) > 1 else (data_axes[0] if data_axes else None)
    out, aux = _get_shard_map()(
        body,
        mesh=mesh,
        in_specs=(
            P(dspec, None, None),
            P(None, model_axis),
            P(model_axis, None, None),
            P(model_axis, None, None),
            P(model_axis, None, None),
        ),
        out_specs=(P(dspec, None, None), P()),
    )(xr, p["router"], p["w1"], p["w3"], p["w2"])
    return out.reshape(B, S, d), aux
