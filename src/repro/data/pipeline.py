"""Deterministic synthetic token pipeline (shard-aware, checkpointable).

Tokens for (step, shard) are a pure function of (seed, step, shard): a
counter-mode threefry stream — so a restarted/re-sharded job regenerates the
exact same global batch regardless of host count (the fault-tolerance
contract the trainer relies on).  State is a single integer (``step``).

The stream mimics Zipf-ish natural-text marginals (vocab ranks drawn from a
power law) so the CE loss starts near log(vocab_eff) and is learnable —
the quickstart's loss-goes-down check depends on structure, so we inject a
simple bigram pattern: token[t+1] ≡ (token[t] + delta) for a per-sequence
delta with probability ``pattern_p``.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


@dataclasses.dataclass
class SyntheticTokens:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    pattern_p: float = 0.75
    step: int = 0  # checkpointable cursor

    def state(self) -> dict:
        return {"step": self.step}

    def restore(self, state: dict) -> None:
        self.step = int(state["step"])

    def _batch_np(self, step: int, shard: int = 0, num_shards: int = 1):
        """Generate this shard's slice of the global batch for ``step``."""
        assert self.global_batch % num_shards == 0
        b = self.global_batch // num_shards
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, shard])
        )
        # power-law marginals over an effective vocab
        veff = min(self.vocab, 32_768)
        base = rng.zipf(1.3, size=(b, self.seq_len)).astype(np.int64)
        tokens = np.clip(base, 1, veff - 1).astype(np.int32)
        # inject a learnable bigram pattern
        delta = rng.integers(1, 17, size=(b, 1)).astype(np.int32)
        use = rng.random((b, self.seq_len)) < self.pattern_p
        for t in range(1, self.seq_len):
            nxt = (tokens[:, t - 1] + delta[:, 0]) % veff
            tokens[:, t] = np.where(use[:, t], nxt, tokens[:, t])
        return tokens

    def next_batch(self, shard: int = 0, num_shards: int = 1) -> dict:
        tokens = self._batch_np(self.step, shard, num_shards)
        self.step += 1
        inputs = tokens[:, :-1]
        labels = tokens[:, 1:]
        return {
            "tokens": jnp.asarray(np.ascontiguousarray(inputs)),
            "labels": jnp.asarray(np.ascontiguousarray(labels)),
        }


def make_batch_for(cfg: ModelConfig, shape: ShapeConfig, seed: int = 0) -> dict:
    """One concrete (small-host-RAM permitting) batch for cfg × shape —
    used by smoke tests and examples, NOT by the dry-run (which uses
    ShapeDtypeStructs)."""
    pipe = SyntheticTokens(
        vocab=cfg.vocab,
        seq_len=shape.seq_len + 1,
        global_batch=shape.global_batch,
        seed=seed,
    )
    batch = pipe.next_batch()
    if cfg.family == "encdec":
        rng = np.random.default_rng(seed + 1)
        batch["frames"] = jnp.asarray(
            rng.standard_normal(
                (shape.global_batch, cfg.enc_seq, cfg.d_model), np.float32
            ),
            jnp.dtype(cfg.dtype),
        )
    if cfg.family == "vlm":
        rng = np.random.default_rng(seed + 2)
        batch["patch_embeds"] = jnp.asarray(
            rng.standard_normal(
                (shape.global_batch, cfg.n_patches, cfg.d_model), np.float32
            ),
            jnp.dtype(cfg.dtype),
        )
    return batch
