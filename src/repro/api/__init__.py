"""``repro.api`` — the single public surface of the solve system.

One config (:class:`SolveConfig`), one result schema (:class:`SolveResult`
/ :class:`BatchSolveResult`), one façade (:class:`SolverSession`) over all
backends (``spmd``, ``protocol_sim``, ``centralized``, ``sequential``),
with a compiled-plane cache (:class:`PlaneCache`) so warm repeat solves
reuse executables.

Quickstart::

    from repro.api import SolverSession, SolveConfig

    session = SolverSession(problem="vertex_cover",
                            config=SolveConfig(num_workers=8))
    r = session.solve(g)            # SolveResult
    batch = session.solve_many(gs)  # BatchSolveResult
    session.cache_stats()           # warm/cold executable accounting

``__all__`` below is the pinned public API — ``tests/test_arch_guard.py``
snapshots it, so additions/removals are deliberate, reviewed changes.
"""

from repro.api.backends import (
    Backend,
    BACKENDS,
    get_backend,
    known_backends,
)
from repro.api.cache import CacheStats, PlaneCache
from repro.api.config import SolveConfig
from repro.api.result import BatchSolveResult, SolveResult
from repro.api.service import AsyncSolveService, SolveService
from repro.api.session import SolverSession, solve_stream_session

__all__ = [
    "AsyncSolveService",
    "Backend",
    "BACKENDS",
    "BatchSolveResult",
    "CacheStats",
    "PlaneCache",
    "SolveConfig",
    "SolveResult",
    "SolveService",
    "SolverSession",
    "get_backend",
    "known_backends",
    "solve_stream_session",
]
