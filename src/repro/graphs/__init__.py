"""Bitset graph substrate (paper §4.1: adjacency-matrix bitsets).

Graphs are stored as packed ``uint32`` adjacency bitsets of shape ``(n, W)``
with ``W = ceil(n/32)``: bit ``v`` of row ``u`` is set iff ``uv`` is an edge.
This is the representation the paper uses for fast union/intersection in the
reduction rules, and it is also what makes the TPU port natural: every task is
a fixed-shape ``uint32[W]`` vertex mask (the paper's *optimized encoding*).
"""

from repro.graphs.bitgraph import (
    BitGraph,
    pack_masks,
    unpack_mask,
    popcount_rows,
    mask_full,
)
from repro.graphs.generators import (
    erdos_renyi,
    p_hat_like,
    parse_dimacs,
    to_dimacs,
)

__all__ = [
    "BitGraph",
    "pack_masks",
    "unpack_mask",
    "popcount_rows",
    "mask_full",
    "erdos_renyi",
    "p_hat_like",
    "parse_dimacs",
    "to_dimacs",
]
