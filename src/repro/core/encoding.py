"""Task serialization codecs (paper §4.3).

*Basic encoding*: serialize the induced subgraph's full adjacency structure —
O(n·W) words per task.  This is what made the fully-centralized strategy
collapse in the paper's experiments (tasks cross the wire twice).

*Optimized encoding*: each worker loads the ORIGINAL graph at startup; a task
is only the packed bitset of surviving vertices plus the partial-solution
bitset — O(W) words.  The receiver reconstructs the induced subgraph locally.

Both are implemented so the paper's comparison (Fig. 4 / Table 1) can be
reproduced; the SPMD engine transfers fixed-shape records, so the codecs below
also define the exact on-the-wire byte counts used by the communication
accounting in benchmarks and in the roofline collective term.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.graphs.bitgraph import BitGraph, n_words


@dataclasses.dataclass(frozen=True)
class Task:
    """A search-tree node: induced-subgraph mask + partial solution + depth."""

    mask: np.ndarray  # (W,) uint32 -- vertices still in the instance
    sol_mask: np.ndarray  # (W,) uint32 -- vertices already in the cover
    depth: int

    def key(self) -> tuple:
        return (self.mask.tobytes(), self.sol_mask.tobytes(), self.depth)


class OptimizedCodec:
    """n-bit-mask encoding: 2W words + 1 depth word per task."""

    name = "optimized"

    def __init__(self, n: int):
        self.n = n
        self.W = n_words(n)

    @property
    def record_words(self) -> int:
        return 2 * self.W + 1

    @property
    def record_bytes(self) -> int:
        return 4 * self.record_words

    def encode(self, task: Task) -> np.ndarray:
        return np.concatenate(
            [task.mask, task.sol_mask, np.array([task.depth], dtype=np.uint32)]
        ).astype(np.uint32)

    def decode(self, rec: np.ndarray, graph: BitGraph | None = None) -> Task:
        W = self.W
        return Task(
            mask=rec[:W].astype(np.uint32),
            sol_mask=rec[W : 2 * W].astype(np.uint32),
            depth=int(rec[2 * W]),
        )


class BasicCodec:
    """Adjacency-list encoding: the induced subgraph's rows travel with the
    task -- (n+2)·W + 1 words.  The decode does NOT need the original graph
    (that is its only advantage)."""

    name = "basic"

    def __init__(self, n: int):
        self.n = n
        self.W = n_words(n)

    @property
    def record_words(self) -> int:
        return (self.n + 2) * self.W + 1

    @property
    def record_bytes(self) -> int:
        return 4 * self.record_words

    def encode(self, task: Task, graph: BitGraph) -> np.ndarray:
        sub_adj = (graph.adj & task.mask[None, :]).astype(np.uint32)
        # zero out rows outside the mask
        from repro.graphs.bitgraph import unpack_mask

        inside = unpack_mask(task.mask, self.n)
        sub_adj = np.where(inside[:, None], sub_adj, 0).astype(np.uint32)
        return np.concatenate(
            [
                sub_adj.reshape(-1),
                task.mask,
                task.sol_mask,
                np.array([task.depth], dtype=np.uint32),
            ]
        ).astype(np.uint32)

    def decode(self, rec: np.ndarray, graph: BitGraph | None = None) -> Task:
        n, W = self.n, self.W
        off = n * W
        return Task(
            mask=rec[off : off + W].astype(np.uint32),
            sol_mask=rec[off + W : off + 2 * W].astype(np.uint32),
            depth=int(rec[off + 2 * W]),
        )


def make_codec(name: str, n: int):
    if name == "optimized":
        return OptimizedCodec(n)
    if name == "basic":
        return BasicCodec(n)
    raise ValueError(f"unknown codec {name!r}")
