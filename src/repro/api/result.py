"""The unified result schema every backend returns.

Before this layer each engine had its own result type — ``EngineResult``
(SPMD), ``SimResult`` (both discrete-event simulators), bare tuples
(sequential reference) — so callers special-cased per backend.
:class:`SolveResult` is the one schema: the solution and the universally
meaningful counters are first-class fields, and everything
backend-specific rides in ``stats``.

``stats`` used to be an ad-hoc dict whose key set drifted per backend; it
is now the TYPED :class:`SolveStats` dataclass (with the service envelope
as a nested :class:`ServiceStats` and batch-plane occupancy as
:class:`LaneStats` on :class:`BatchSolveResult`).  The field sets are
pinned in ``tests/test_arch_guard.py`` — adding a counter is a deliberate,
reviewed schema change.  Legacy dict-style access (``r.stats["overflow"]``,
``.get``, ``in``) keeps working through a :class:`DeprecationWarning` shim;
read attributes (``r.stats.overflow``) instead.
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Optional

import numpy as np


class _DictAccessShim:
    """Deprecation bridge: the pre-unification dict-style stats access
    (``stats["key"]`` / ``.get`` / ``in`` / ``.keys``) warns once per call
    site and delegates to the dataclass attributes."""

    def _names(self):
        return [f.name for f in dataclasses.fields(self)]

    def _warn(self):
        warnings.warn(
            f"dict-style access to {type(self).__name__} is deprecated and "
            f"will be removed in v1.0; read attributes instead "
            f"(e.g. r.stats.overflow_count, r.stats.service.deadline_hit)",
            DeprecationWarning,
            stacklevel=3,
        )

    def __getitem__(self, key):
        self._warn()
        if key in self._names():
            return getattr(self, key)
        raise KeyError(key)

    def get(self, key, default=None):
        self._warn()
        return getattr(self, key, default) if key in self._names() else default

    def __contains__(self, key):
        self._warn()
        return key in self._names()

    def keys(self):
        self._warn()
        return list(self._names())

    def items(self):
        self._warn()
        return [(name, getattr(self, name)) for name in self._names()]

    def to_dict(self) -> dict:
        """Plain-dict view (JSON-safe, no deprecation warning)."""
        return _jsonable(dataclasses.asdict(self))


@dataclasses.dataclass
class ServiceStats(_DictAccessShim):
    """The service envelope around one completed ticket (spmd service only):
    which lane/plane solved it, queue wait and lane residency (wall
    seconds), and whether its superstep deadline evicted it with an
    anytime result."""

    lane: int = -1
    plane: str = ""
    wait_s: float = 0.0
    residency_s: float = 0.0
    deadline_hit: bool = False
    # the wall-clock twin of deadline_hit: the request's deadline_s elapsed
    # (measured on the service's injected clock) before the solve finished
    wall_deadline_hit: bool = False
    # -- robustness (repro.faults): the self-healing ledger for THIS ticket ---
    # faults that hit the request (lane crash/stall windows), recoveries
    # (re-queue + bit-identical re-admission, cleared stall windows), times
    # its lane was quarantined, and extra payload-delivery attempts spent
    faults_injected: int = 0
    faults_recovered: int = 0
    lanes_quarantined: int = 0
    retries: int = 0

    @classmethod
    def from_dict(cls, d: dict) -> "ServiceStats":
        return cls(**{f.name: d[f.name] for f in dataclasses.fields(cls) if f.name in d})


@dataclasses.dataclass
class SolveStats(_DictAccessShim):
    """Every backend-specific counter, one typed superset schema.

    Fields a backend does not track stay at their zero defaults — the
    groups below document who writes what.  ``service`` is only populated
    for results delivered by a :class:`~repro.api.service.SolveService`.
    """

    # -- SPMD engine (collective-traffic accounting, EXPERIMENTS §Perf) -------
    overflow: bool = False
    overflow_count: int = 0
    control_bytes_per_round: int = 0
    transfer_rounds: int = 0
    transfer_bytes_total: int = 0
    transfer_bytes_per_round: float = 0.0
    # -- durability (spmd checkpoint/resume) ----------------------------------
    checkpoints_written: int = 0
    resumed_from: Optional[str] = None
    # -- hierarchical frontier memory (spmd, cfg.frontier_spill) --------------
    # cold-tier traffic: tasks evicted to the host store, tasks decoded and
    # re-admitted, and the store's peak encoded size in bytes.  With spill
    # enabled, overflow/overflow_count stay 0 (the no-drop guarantee).
    spilled_tasks: int = 0
    readmitted_tasks: int = 0
    cold_bytes_peak: int = 0
    # -- discrete-event simulator backends ------------------------------------
    ticks: int = 0
    failed_requests: int = 0
    termination_cancelled: int = 0
    total_bytes: int = 0
    center_bytes: int = 0
    msg_count: dict = dataclasses.field(default_factory=dict)
    msg_bytes: dict = dataclasses.field(default_factory=dict)
    # -- sequential reference -------------------------------------------------
    pruned: int = 0
    solutions: int = 0
    max_depth: int = 0
    # -- service envelope (None outside SolveService) -------------------------
    service: Optional[ServiceStats] = None

    @classmethod
    def from_dict(cls, d: dict) -> "SolveStats":
        known = {f.name for f in dataclasses.fields(cls)}
        kw = {k: v for k, v in d.items() if k in known and k != "service"}
        service = d.get("service")
        if service is not None:
            kw["service"] = ServiceStats.from_dict(service)
        return cls(**kw)


@dataclasses.dataclass
class LaneStats(_DictAccessShim):
    """Batched-plane occupancy: ``chunk_calls`` (compiled chunk dispatches),
    ``lane_chunks`` (chunk_calls × plane width — paid lane slots),
    ``live_lane_chunks`` (slots that held an unfinished instance) and their
    ratio ``occupancy`` — the utilization a continuous-admission service
    raises over fixed batching (zeros where not tracked)."""

    chunk_calls: int = 0
    lane_chunks: int = 0
    live_lane_chunks: int = 0
    occupancy: float = 0.0


@dataclasses.dataclass
class SolveResult:
    """One instance solved by one backend.

    ``best_size`` is in the problem's EXTERNAL objective (``-1`` for an
    unsatisfiable FPT decision); ``rounds`` counts the backend's native
    progress unit (supersteps for spmd, simulator ticks for the two
    discrete-event backends, expanded nodes for sequential).
    """

    problem: str
    backend: str
    best_size: int
    best_sol: Optional[np.ndarray]
    found: bool
    wall_s: float
    rounds: int
    nodes_expanded: int
    tasks_transferred: int
    stats: SolveStats = dataclasses.field(default_factory=SolveStats)

    def to_dict(self) -> dict:
        """JSON-safe view (``best_sol`` as a list of packed u32 words)."""
        d = dataclasses.asdict(self)
        if self.best_sol is not None:
            d["best_sol"] = [int(w) for w in np.asarray(self.best_sol, np.uint32)]
        d["stats"] = _jsonable(d["stats"])
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "SolveResult":
        """Inverse of :meth:`to_dict` (the service checkpoint round-trip)."""
        sol = d.get("best_sol")
        return cls(
            problem=d["problem"],
            backend=d["backend"],
            best_size=d["best_size"],
            best_sol=None if sol is None else np.asarray(sol, np.uint32),
            found=d["found"],
            wall_s=d["wall_s"],
            rounds=d["rounds"],
            nodes_expanded=d["nodes_expanded"],
            tasks_transferred=d["tasks_transferred"],
            stats=SolveStats.from_dict(d.get("stats") or {}),
        )


@dataclasses.dataclass
class BatchSolveResult:
    """Per-instance results of one batched solve; ``results[i]`` corresponds
    to ``graphs[i]`` (submission order survives bucketing/compaction).

    ``buckets`` is the packing record — one ``(W, n_max, [indices])`` triple
    per compiled bucket (empty for backends that solve instance-by-
    instance); ``compactions`` counts host-side batch compactions;
    ``lane_stats`` is the typed :class:`LaneStats` occupancy record.
    """

    problem: str
    backend: str
    results: list
    wall_s: float
    buckets: list = dataclasses.field(default_factory=list)
    compactions: int = 0
    lane_stats: LaneStats = dataclasses.field(default_factory=LaneStats)

    def __len__(self) -> int:
        return len(self.results)

    def __iter__(self):
        return iter(self.results)


def _jsonable(obj):
    if isinstance(obj, dict):
        return {str(k): _jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_jsonable(v) for v in obj]
    if isinstance(obj, np.generic):
        return obj.item()
    return obj


# -- converters from the legacy per-engine schemas -----------------------------


def from_engine_result(r, *, problem: str, backend: str = "spmd") -> SolveResult:
    """Wrap a :class:`repro.core.engine.EngineResult`."""
    return SolveResult(
        problem=problem,
        backend=backend,
        best_size=r.best_size,
        best_sol=r.best_sol,
        found=r.best_sol is not None,
        wall_s=r.wall_s,
        rounds=r.rounds,
        nodes_expanded=r.nodes_expanded,
        tasks_transferred=r.tasks_transferred,
        stats=SolveStats(
            overflow=r.overflow,
            overflow_count=r.overflow_count,
            control_bytes_per_round=r.control_bytes_per_round,
            transfer_rounds=r.transfer_rounds,
            transfer_bytes_total=r.transfer_bytes_total,
            transfer_bytes_per_round=r.transfer_bytes_per_round,
            checkpoints_written=r.checkpoints_written,
            resumed_from=r.resumed_from,
            spilled_tasks=r.spilled_tasks,
            readmitted_tasks=r.readmitted_tasks,
            cold_bytes_peak=r.cold_bytes_peak,
        ),
    )


def from_sim_result(r, *, problem: str, backend: str, wall_s: float) -> SolveResult:
    """Wrap a :class:`repro.core.protocol_sim.SimResult` (both simulators)."""
    s = r.stats
    return SolveResult(
        problem=problem,
        backend=backend,
        best_size=r.best_size,
        best_sol=r.best_sol,
        found=r.best_sol is not None,
        wall_s=wall_s,
        rounds=r.ticks,
        nodes_expanded=s.nodes_expanded,
        tasks_transferred=s.tasks_transferred,
        stats=SolveStats(
            # host explorers keep unbounded Python frontiers: nothing to drop
            overflow_count=0,
            ticks=r.ticks,
            failed_requests=s.failed_requests,
            termination_cancelled=s.termination_cancelled,
            total_bytes=s.total_bytes,
            center_bytes=s.center_bytes,
            msg_count=dict(s.msg_count),
            msg_bytes=dict(s.msg_bytes),
        ),
    )


def from_sequential(best, sol, stats, *, problem: str, wall_s: float) -> SolveResult:
    """Wrap the sequential reference's ``(best, sol, SeqStats)`` triple."""
    return SolveResult(
        problem=problem,
        backend="sequential",
        best_size=best,
        best_sol=sol,
        found=sol is not None,
        wall_s=wall_s,
        rounds=stats.nodes,
        nodes_expanded=stats.nodes,
        tasks_transferred=0,
        stats=SolveStats(
            overflow_count=0,  # host recursion: no fixed-capacity pool
            pruned=stats.pruned,
            solutions=stats.solutions,
            max_depth=stats.max_depth,
        ),
    )
