"""Kernel micro-bench: wall time of the jnp oracle vs interpret-mode kernels
is NOT meaningful on CPU; instead report the kernels' arithmetic-intensity
characteristics (the roofline inputs a TPU run would see)."""

from __future__ import annotations


def run(csv=True):
    rows = []
    # bitset degrees: T tasks × n vertices × W words
    for n, T in ((512, 64), (1024, 64)):
        W = (n + 31) // 32
        flops = T * n * W * 3  # and + popcount-adds (SWAR ~3 vector ops/word)
        bytes_moved = (n * W + T * W + T * n * 4) * 4
        rows.append(
            dict(kernel="bitset_degrees", shape=f"n{n}xT{T}",
                 vector_ops=flops, bytes=bytes_moved,
                 intensity=round(flops / bytes_moved, 3))
        )
    # flash attention: per (B,H) S×S blockwise
    for S, D in ((4096, 128), (32768, 128)):
        flops = 4 * S * S * D  # qk + pv
        bytes_moved = 3 * S * D * 2 + S * D * 2
        rows.append(
            dict(kernel="flash_attention", shape=f"S{S}xD{D}",
                 vector_ops=flops, bytes=bytes_moved,
                 intensity=round(flops / bytes_moved, 1))
        )
    # wkv6 chunked: per (B,H), T steps, K=V=64, chunk C
    for T, C in ((4096, 32),):
        K = 64
        flops = T * (3 * C * K + 2 * K * K)  # intra scores + state updates
        bytes_moved = T * (4 * K) * 4 + (K * K) * 4
        rows.append(
            dict(kernel="wkv6", shape=f"T{T}xC{C}",
                 vector_ops=flops, bytes=bytes_moved,
                 intensity=round(flops / bytes_moved, 1))
        )
    if csv:
        keys = list(rows[0].keys())
        print(",".join(keys))
        for r in rows:
            print(",".join(str(r[k]) for k in keys))
    return rows


if __name__ == "__main__":
    run()
