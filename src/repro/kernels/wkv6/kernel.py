"""Pallas TPU kernel: chunked WKV6 linear recurrence.

TPU decomposition of a data-dependent-decay RNN (the standard GLA/RWKV
chunking, adapted to MXU/VPU):

* split time into chunks of C; inside a chunk everything is matmuls (MXU):
    A[t, j] = Σ_k r_t[k] · exp(logc_{t-1,k} − logc_{j,k}) · k_j[k]   (j < t)
    A[t, t] = Σ_k r_t[k] · u[k] · k_t[k]                             (bonus)
    o_intra = A_masked @ v
    o_inter = (r ⊙ exp(logc_shift)) @ S_chunk_start
  with logc = cumsum(log d) — every exponent is ≤ 0 (j < t ⇒ the sum of
  negative log-decays), so the chunk math never overflows (this is the
  numerically-safe variant of the k/cumprod trick);
* the (K, V) state is carried across chunks in VMEM scratch — the grid's
  last dimension iterates sequentially on TPU, so the scratch persists:
    S_end = diag(exp(logc_C)) S_start + Σ_j exp(logc_C − logc_j) k_j ⊗ v_j.

Grid: (B·H, T/C).  Per-chunk VMEM: C·K + C·V + C² + K·V floats.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv6_kernel(
    r_ref,  # (1, C, K)
    k_ref,  # (1, C, K)
    v_ref,  # (1, C, V)
    logd_ref,  # (1, C, K)  log-decay (≤ 0)
    u_ref,  # (1, K)
    s0_ref,  # (1, K, V) initial state for this (b, h)
    o_ref,  # (1, C, V)
    sT_ref,  # (1, K, V) final state output
    state,  # VMEM scratch (K, V) carried across chunk iterations
):
    ci = pl.program_id(1)
    C, K = r_ref.shape[1], r_ref.shape[2]

    @pl.when(ci == 0)
    def _init():
        state[...] = s0_ref[0]

    r = r_ref[0].astype(jnp.float32)  # (C, K)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)  # (C, V)
    logd = logd_ref[0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)  # (K,)
    S = state[...]  # (K, V)

    logc = jnp.cumsum(logd, axis=0)  # (C, K) inclusive
    logc_shift = logc - logd  # logc_{t-1}: exclusive cumsum

    # intra-chunk pairwise scores: strictly-lower-triangular part
    #   A[t, j] = Σ_k (r_t ⊙ exp(logc_shift_t))[k] · (k_j ⊙ exp(-logc_j))[k]
    # exp(logc_shift_t - logc_j) ≤ 1 for j < t, but the factored form can
    # overflow via exp(-logc_j); compute the (C, C, K) tensor reduced over K
    # in K-tiles instead (exact, safe): here C is small (≤ 64) so one shot.
    diff = logc_shift[:, None, :] - logc[None, :, :]  # (C, C, K)
    tri = jax.lax.broadcasted_iota(jnp.int32, (C, C), 0) > jax.lax.broadcasted_iota(
        jnp.int32, (C, C), 1
    )
    w = jnp.where(tri[:, :, None], jnp.exp(diff), 0.0)  # masked decay weights
    A = jnp.einsum(
        "tk,tjk,jk->tj", r, w, k, preferred_element_type=jnp.float32
    )
    # diagonal: u-bonus for the current token
    A = A + jnp.where(
        jax.lax.broadcasted_iota(jnp.int32, (C, C), 0)
        == jax.lax.broadcasted_iota(jnp.int32, (C, C), 1),
        (r * u[None, :] * k).sum(axis=1)[:, None],
        0.0,
    )
    o_intra = A @ v  # (C, V)
    o_inter = (r * jnp.exp(logc_shift)) @ S  # (C, V)
    o_ref[0] = (o_intra + o_inter).astype(o_ref.dtype)

    # state update
    decay_all = jnp.exp(logc[-1])  # (K,) prod of chunk decays
    carry_w = jnp.exp(logc[-1][None, :] - logc)  # (C, K) ≤ 1
    S_new = decay_all[:, None] * S + (carry_w * k).T @ v
    state[...] = S_new
    sT_ref[0] = S_new.astype(sT_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6(
    r: jnp.ndarray,  # (B, T, H, K)
    k: jnp.ndarray,
    v: jnp.ndarray,  # (B, T, H, V)
    decay: jnp.ndarray,  # (B, T, H, K) in (0, 1]
    u: jnp.ndarray,  # (H, K)
    initial_state: jnp.ndarray | None = None,  # (B, H, K, V)
    *,
    chunk: int = 32,
    interpret: bool = True,
):
    """Chunked WKV6.  Returns (out (B, T, H, V), final_state (B, H, K, V))."""
    B, T, H, K = r.shape
    V = v.shape[-1]
    assert T % chunk == 0, "pad T to a chunk multiple"
    C = chunk
    BH = B * H

    def fold(x, d):
        return x.transpose(0, 2, 1, 3).reshape(BH, T, d)

    rf, kf, vf = fold(r, K), fold(k, K), fold(v, V)
    logd = jnp.log(jnp.clip(decay.astype(jnp.float32), 1e-30, 1.0))
    df = fold(logd, K)
    uf = jnp.tile(u.astype(jnp.float32), (B, 1))  # (BH, K)
    s0 = (
        initial_state.reshape(BH, K, V).astype(jnp.float32)
        if initial_state is not None
        else jnp.zeros((BH, K, V), jnp.float32)
    )

    grid = (BH, T // C)
    out, sT = pl.pallas_call(
        _wkv6_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, C, K), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, C, K), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, C, V), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, C, K), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, K), lambda b, c: (b, 0)),
            pl.BlockSpec((1, K, V), lambda b, c: (b, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, C, V), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, K, V), lambda b, c: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, T, V), r.dtype),
            jax.ShapeDtypeStruct((BH, K, V), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((K, V), jnp.float32)],
        interpret=interpret,
    )(rf, kf, vf, df, uf, s0)
    return (
        out.reshape(B, H, T, V).transpose(0, 2, 1, 3),
        sT.reshape(B, H, K, V),
    )
