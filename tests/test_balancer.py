"""Semi-centralized serving balancer: the paper's guarantees, restated."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.serving.balancer import (
    BalancerState,
    RequestBatch,
    SolveBatcher,
    rebalance,
    simulate,
    solve_stream,
)


class _FakeGraph:
    """Just enough of a BitGraph for the admission logic (n, W)."""

    def __init__(self, n):
        self.n = n
        self.W = (n + 31) // 32


def test_rebalance_moves_heaviest_to_neediest():
    reps = [
        RequestBatch(4, [], [10, 99, 5]),  # donor with queue
        RequestBatch(4, [], []),  # starving replica
    ]
    state = BalancerState(reps)
    moved = rebalance(state)
    assert moved == 1
    assert 99 in reps[1].queued_work  # heaviest request moved (§3.4 priority)


def test_failure_free_matching():
    """A matched receiver ALWAYS gets a request: donors must have a queue."""
    reps = [RequestBatch(4, [1], []), RequestBatch(4, [], [])]
    state = BalancerState(reps)
    moved = rebalance(state)
    assert moved == 0  # nobody has queued work -> no (failing) match attempted


@settings(max_examples=30, deadline=None)
@given(
    st.lists(st.integers(1, 64), min_size=4, max_size=60),
    st.integers(2, 8),
)
def test_work_conservation(works, replicas):
    """No request is lost or duplicated across rebalancing rounds."""
    reps = [RequestBatch(4, [], []) for _ in range(replicas)]
    reps[0].queued_work = list(works)
    state = BalancerState(reps)
    for _ in range(5):
        rebalance(state)
        total = sorted(
            w for r in reps for w in (r.active_work + r.queued_work)
        )
        assert total == sorted(works)


def test_solve_batcher_buckets_and_fills():
    """Requests bucket by packed width W (the solve plane's packing rule)
    and full planes drain largest-work-first (the balancer's admit order)."""
    b = SolveBatcher(batch_size=2)
    tickets = [b.submit(_FakeGraph(n)) for n in (20, 40, 22, 44, 24)]
    batches = b.ready_batches()
    # W=1 bucket had 3 queued: the largest two (24, 22) form the full plane
    assert [sorted(g.n for g in b.take(batch)) for batch in batches] == [
        [22, 24],
        [40, 44],
    ]
    # the leftover partial plane only drains on flush
    rest = b.flush()
    assert [[g.n for g in b.take(batch)] for batch in rest] == [[20]]
    assert sorted(s for batch in batches + rest for s in batch) == tickets
    assert b.graphs == {}  # take() evicted everything the stream solved


def test_batcher_status_surfaces_vacant_lanes_of_partial_buckets():
    """A partially-filled bucket reports its unfilled lanes as vacant —
    no placeholder ticket ever pads a plane lane."""
    b = SolveBatcher(batch_size=4)
    for n in (20, 22, 24):
        b.submit(_FakeGraph(n))
    assert b.status() == {
        ("vertex_cover", 1): {"queued": 3, "admitted": 0, "vacant": 4}
    }
    batches = b.flush()  # 3 requests into a 4-lane plane: 1 lane vacant
    assert [len(batch) for batch in batches] == [3]
    assert b.status() == {
        ("vertex_cover", 1): {"queued": 0, "admitted": 0, "vacant": 4}
    }
    # exactly the real instances come back — no padded placeholder result
    assert sorted(g.n for g in b.take(batches[0])) == [20, 22, 24]


def test_batcher_take_rejects_undrained_tickets():
    """take() on a still-queued ticket would leave a stale queue entry to
    drain later with no instance behind it, so it must refuse."""
    b = SolveBatcher(batch_size=2)
    t1 = b.submit(_FakeGraph(20))
    with pytest.raises(ValueError, match=f"{t1}"):
        b.take([t1])  # never drained
    t2 = b.submit(_FakeGraph(22))
    (batch,) = b.ready_batches()
    with pytest.raises(ValueError, match="not in any drained batch"):
        b.take([t1, t2, 99])  # 99 unknown -> still an error, batch intact
    assert sorted(g.n for g in b.take(batch)) == [20, 22]
    with pytest.raises(ValueError):
        b.take(batch)  # double-take: already evicted


def test_solve_stream_returns_submission_order():
    gs = [_FakeGraph(n) for n in (20, 40, 22, 24, 44, 26, 28)]
    seen = []

    def fake_solver(batch, **kw):
        assert len({g.W for g in batch}) == 1  # never mixes buckets
        seen.append([g.n for g in batch])
        return [g.n * 100 for g in batch]

    out = solve_stream(gs, 2, solver=fake_solver)
    assert out == [g.n * 100 for g in gs]
    assert all(len(batch) <= 2 for batch in seen)


def test_buckets_key_on_problem_and_width():
    """Same W, different problem -> different planes: a solve batch compiles
    ONE problem's brancher, so the batcher must never mix problems."""
    b = SolveBatcher(batch_size=2)
    t_vc = [b.submit(_FakeGraph(n), "vertex_cover") for n in (20, 22)]
    t_cl = [b.submit(_FakeGraph(n), "max_clique") for n in (21, 23)]
    batches = b.ready_batches()
    assert len(batches) == 2
    probs = sorted(b.problem_of(batch[0]) for batch in batches)
    assert probs == ["max_clique", "vertex_cover"]
    for batch in batches:
        assert len({b.problem_of(t) for t in batch}) == 1
    assert sorted(t for batch in batches for t in batch) == sorted(t_vc + t_cl)


def test_solve_stream_mixed_problems():
    """A mixed request stream splits per problem and each batch's solver
    call carries its own problem name."""
    gs = [_FakeGraph(n) for n in (20, 21, 22, 23)]
    probs = ["vertex_cover", "mis", "vertex_cover", "mis"]
    calls = []

    def fake_solver(batch, problem=None, **kw):
        calls.append((problem, [g.n for g in batch]))
        return [f"{problem}:{g.n}" for g in batch]

    out = solve_stream(gs, 2, solver=fake_solver, problem=probs)
    assert out == [f"{p}:{g.n}" for p, g in zip(probs, gs)]
    assert sorted(p for p, _ in calls) == ["mis", "vertex_cover"]


def test_balancing_reduces_makespan():
    works = list(np.random.default_rng(0).integers(8, 128, 48))
    off = simulate(8, 4, works, balance=False)
    on = simulate(8, 4, works, balance=True)
    assert on["rounds"] < off["rounds"]
    assert on["idle_slot_steps"] < off["idle_slot_steps"]
    # control plane: two integers per replica per round (paper goal #2)
    assert on["control_ints_per_round"] == 16
