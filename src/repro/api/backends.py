"""The ``Backend`` protocol and its four implementations.

A backend turns ``(problem spec, graph(s), SolveConfig)`` into the unified
:class:`~repro.api.result.SolveResult` schema:

* ``spmd`` — the TPU-adapted superstep engine, driven through the
  parametric compiled planes so a :class:`~repro.api.cache.PlaneCache`
  makes warm repeat solves reuse executables;
* ``protocol_sim`` — the faithful asynchronous MPI-protocol discrete-event
  simulator (now problem-generic via the plugin's host callables);
* ``centralized`` — the fully-centralized Abu-Khzam baseline (ditto);
* ``sequential`` — the plugin's ground-truth reference solver.

The module also hosts the legacy-shim entry points (``legacy_solve`` /
``legacy_solve_many``) that keep ``repro.core.engine.solve``/``solve_many``
working — those shims share one process-wide :data:`LEGACY_CACHE`, so even
deprecated callers stop paying per-call re-compiles.
"""

from __future__ import annotations

import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.cache import PlaneCache
from repro.api.config import SolveConfig
from repro.api.result import (
    BatchSolveResult,
    LaneStats,
    SolveResult,
    from_engine_result,
    from_sequential,
    from_sim_result,
)
from repro.core import engine as _engine
from repro.core.encoding import make_codec
from repro.graphs.bitgraph import n_words
from repro.problems import base as problems_base


# -- the spmd drivers ----------------------------------------------------------
#
# Same solve loops as the legacy engine.solve/solve_many (whose helpers they
# reuse — startup scatter, result extraction, bucketing are single-sourced
# there), but the chunk executables come from a PlaneCache: ProblemData and
# FPT bounds are call-time arguments, so same-shape solves never re-trace.


def _solo_fingerprint(spec, g, cfg):
    from repro.checkpoint import solve as _ckpt

    return _ckpt.config_fingerprint(
        "solo", spec.name, cfg, [_ckpt.graph_digest(g)]
    )


def _write_solo_checkpoint(
    spec, g, cfg, fingerprint, state, rounds, spill=None,
    retry=None, fault_hook=None,
) -> None:
    """One atomic SolveCheckpoint of a solo solve at a chunk boundary."""
    from repro.checkpoint import solve as _ckpt
    from repro.core.superstep import worker_state_to_flat

    ck = _ckpt.SolveCheckpoint(
        kind="solo",
        problem=spec.name,
        config=cfg.replace(resume_from=None).to_dict(),
        fingerprint=fingerprint,
        rounds=rounds,
        arrays=worker_state_to_flat(state),
    )
    if spill is not None:
        ck.arrays.update(spill.to_flat())
    ck.pack_graphs([0], [g])
    ck.save(cfg.checkpoint_dir, rounds, retry=retry, fault_hook=fault_hook)


def solve_spmd(
    spec,
    g,
    cfg: SolveConfig,
    cache: PlaneCache,
    *,
    initial_state=None,
    mesh=None,
    injector=None,
):
    """One instance on the SPMD engine; returns a legacy ``EngineResult``
    (the session wraps it into the unified schema, the engine shim returns
    it as-is).

    Durability: with ``cfg.checkpoint_dir`` set, a
    :class:`~repro.checkpoint.solve.SolveCheckpoint` is written atomically
    every ``cfg.checkpoint_every`` chunks at the host-sync boundary (step
    number = rounds completed); with ``cfg.resume_from`` set, the solve
    restores the newest INTACT generation of that state
    (fingerprint-checked, falling back past corrupt generations with a
    warning) and continues — the loop is deterministic, so the final
    result is bit-identical to an uninterrupted run (modulo ``wall_s``).

    Robustness: ``injector`` (a :class:`repro.faults.FaultInjector`)
    exercises the recovery machinery at the host-sync boundaries only —
    a worker crash discards the device state and rebuilds it from the
    last good checkpoint (or the Algorithm-7 startup placement when the
    solve is not durable), cold-tier corruption is healed by checksum +
    redelivery inside the spiller, and checkpoint I/O errors retry under
    the injector's deterministic backoff policy.  Recovery re-executes a
    deterministic prefix, so the final result stays bit-identical.
    """
    k = cfg.solo_k()
    W = n_words(g.n)
    cap = cfg.capacity or (4 * g.n + 8 * cfg.lanes)
    initial_best = problems_base.initial_bound(spec, g, cfg.mode, k)
    data = problems_base.make_data(spec, g)
    pad = make_codec(cfg.codec, g.n, problem=spec).pad_words

    io_retry = injector.retry_policy() if injector is not None else None
    io_hook = injector.io_hook if injector is not None else None

    fingerprint = (
        _solo_fingerprint(spec, g, cfg)
        if (cfg.checkpoint_dir or cfg.resume_from)
        else None
    )

    def build_startup():
        s = jax.vmap(
            lambda _: _engine.make_worker_state(cap, W, initial_best)
        )(jnp.arange(cfg.num_workers))
        return _engine._scatter_startup(s, spec, g, cfg.num_workers)

    rounds = 0
    resumed_from = None
    resume_arrays = None
    if cfg.resume_from is not None:
        if initial_state is not None:
            raise ValueError("pass resume_from or initial_state, not both")
        from repro.checkpoint import solve as _ckpt
        from repro.core.superstep import worker_state_from_flat

        ck = _ckpt.SolveCheckpoint.load_latest_good(
            cfg.resume_from,
            expected_fingerprint=fingerprint,
            what=f"solve({spec.name})",
            retry=io_retry,
            fault_hook=io_hook,
        )
        if ck.kind != "solo":
            raise _ckpt.CheckpointError(
                f"{cfg.resume_from} holds a {ck.kind!r} checkpoint; "
                f"solve() resumes 'solo' checkpoints only"
            )
        state = worker_state_from_flat(ck.arrays)
        rounds = ck.rounds
        resumed_from = cfg.resume_from
        resume_arrays = ck.arrays
        cap = int(state.frontier.masks.shape[-2])
    elif initial_state is None:
        state = build_startup()
    else:
        state = initial_state
        cap = int(state.frontier.masks.shape[-2])

    if mesh is None and cfg.use_mesh:
        from repro.launch.mesh import make_solver_mesh

        mesh = make_solver_mesh(cfg.num_workers)

    spill = None
    if cfg.frontier_spill:
        if mesh is not None or cfg.use_mesh:
            raise ValueError(
                "frontier_spill has no mesh path yet (vmap virtual workers "
                "only) — drop use_mesh or disable frontier_spill"
            )
        from repro.core.spill import FrontierSpiller, make_spiller

        spill = make_spiller(cfg, spec, g, cap, cfg.num_workers, injector)
        if resume_arrays is not None and FrontierSpiller.present_in(
            resume_arrays
        ):
            spill.load_flat(resume_arrays)

    use_fpt = cfg.mode == "fpt"
    if mesh is not None:
        # mesh planes close over their mesh/sharding: not cacheable (yet)
        cache.note_bypass()
        chunk = _engine.build_chunk_fn(
            spec,
            data,
            num_workers=cfg.num_workers,
            steps_per_round=cfg.steps_per_round,
            lanes=cfg.lanes,
            policy_priority=cfg.policy_priority,
            transfer_pad_words=pad,
            packed_status=cfg.packed_status,
            skip_empty_transfer=cfg.skip_empty_transfer,
            transfer_impl=cfg.transfer_impl,
            explore_impl=cfg.explore_impl,
            donate_k=cfg.donate_k,
            chunk_rounds=cfg.chunk_rounds,
            fpt_bound=(spec.fpt_target(k) if use_fpt else None),
            mesh=mesh,
        )
        step = lambda s: chunk(s)  # noqa: E731
    else:
        plane = cache.solo_plane(spec, cfg, pad, use_fpt)
        cache.note(
            "solo", spec, cfg, pad, use_fpt,
            (g.n, W, cap, cfg.num_workers),
        )
        if use_fpt:
            bound = jnp.int32(spec.fpt_target(k))
            step = lambda s: plane(data, s, bound)  # noqa: E731
        else:
            step = lambda s: plane(data, s)  # noqa: E731

    t0 = time.perf_counter()
    chunks = 0
    checkpoints_written = 0
    while rounds < cfg.max_rounds:
        state, done, ran, hot = step(state)
        done, ran, hot = jax.device_get((done, ran, hot))
        rounds += int(ran)
        chunks += 1
        done = bool(done)
        if spill is not None and spill.wants_pump(hot, done):
            # an FPT bound hit finishes the solve regardless of cold backlog
            # (quiescent-done without the bound must refill and continue)
            fpt_hit = (
                done
                and use_fpt
                and int(jax.device_get(state.best_val.min()))
                <= int(spec.fpt_target(k))
            )
            if not fpt_hit:
                frontier, hot = spill.pump_frontier(state.frontier)
                state = state._replace(frontier=frontier)
                done = done and int(hot.sum()) == 0
        if injector is not None:
            injector.step_boundary()
            if injector.take_crash():
                # the worker plane died at this boundary: its device state
                # is gone.  Rebuild from the last good checkpoint when the
                # solve is durable, else replay from the deterministic
                # Algorithm-7 startup placement — both re-execute a prefix
                # of the SAME trajectory, so the final answer is unchanged.
                from repro.checkpoint import store as _store

                if (
                    cfg.checkpoint_dir is not None
                    and _store.latest_step(cfg.checkpoint_dir) is not None
                ):
                    from repro.checkpoint import solve as _ckpt
                    from repro.core.superstep import worker_state_from_flat

                    ck = _ckpt.SolveCheckpoint.load_latest_good(
                        cfg.checkpoint_dir,
                        expected_fingerprint=fingerprint,
                        what=f"solve({spec.name}) crash recovery",
                        retry=io_retry,
                        fault_hook=io_hook,
                    )
                    state = worker_state_from_flat(ck.arrays)
                    rounds = ck.rounds
                    if spill is not None:
                        from repro.core.spill import (
                            FrontierSpiller,
                            make_spiller,
                        )

                        spill = make_spiller(
                            cfg, spec, g, cap, cfg.num_workers, injector
                        )
                        if FrontierSpiller.present_in(ck.arrays):
                            spill.load_flat(ck.arrays)
                elif initial_state is not None:
                    state = initial_state
                    rounds = 0
                else:
                    state = build_startup()
                    rounds = 0
                    if spill is not None:
                        from repro.core.spill import make_spiller

                        spill = make_spiller(
                            cfg, spec, g, cap, cfg.num_workers, injector
                        )
                injector.note_recovered("crash")
                done = False
        if done:
            break
        if (
            cfg.checkpoint_dir is not None
            and chunks % cfg.checkpoint_every == 0
        ):
            _write_solo_checkpoint(
                spec, g, cfg, fingerprint, state, rounds, spill,
                retry=io_retry, fault_hook=io_hook,
            )
            checkpoints_written += 1
    wall = time.perf_counter() - t0

    host = _engine._fetch_batch_state(jax.tree.map(lambda x: x[None], state))
    r = _engine._extract_result(
        host,
        0,
        spec,
        g,
        rounds,
        wall,
        mode=cfg.mode,
        k=k,
        num_workers=cfg.num_workers,
        packed_status=cfg.packed_status,
    )
    r.checkpoints_written = checkpoints_written
    r.resumed_from = resumed_from
    if spill is not None:
        r.spilled_tasks = spill.spilled_total
        r.readmitted_tasks = spill.readmitted_total
        r.cold_bytes_peak = spill.cold_bytes_peak
    return r


def solve_many_spmd(spec, graphs, cfg: SolveConfig, cache: PlaneCache,
                    injector=None):
    """B instances on one batched plane; returns a legacy ``BatchResult``.

    Identical bucketing/padding/compaction behavior to the legacy
    ``engine.solve_many``; the one structural difference is that compaction
    RESLICES and keeps calling the same parametric plane function instead of
    rebuilding an executable, so a compacted width that was seen before
    (this call or any earlier one) is already warm.

    The loop runs on the :class:`~repro.core.superstep.LaneState` lifecycle
    (``tag`` = original instance index, per-lane ``rounds`` accumulated on
    device) — the same per-lane machinery the continuous service drives —
    and reports plane occupancy in ``BatchResult.lane_stats``.

    Durability mirrors :func:`solve_spmd`: every ``cfg.checkpoint_every``
    chunks the in-flight bucket's full LaneState/ProblemData plus every
    already-finalized result is checkpointed (step number = cumulative
    chunk count, monotonic across buckets); ``cfg.resume_from`` restores
    mid-bucket and skips the buckets whose results are already final.
    Results are finalized EAGERLY (at compaction / bucket end) so the
    checkpoint never needs a lane that was compacted away; per-instance
    ``wall_s`` (the amortized bucket share) is patched at bucket end and
    is the one field outside the bit-identity contract.
    """
    from repro.core.superstep import (
        LaneState,
        lane_resume,
        lane_state_from_flat,
        lane_state_to_flat,
        lane_swap_in,
        slice_lanes,
        step_lanes,
    )

    if cfg.frontier_spill:
        from repro.core.spill import FrontierSpiller, make_spiller

    if cfg.use_mesh:
        raise ValueError(
            "solve_many has no mesh path yet (vmap virtual workers only); "
            "use solve() per instance or a config with use_mesh=False"
        )
    graphs = list(graphs)
    B = len(graphs)
    use_fpt = cfg.mode == "fpt"
    if use_fpt:
        ks = list(cfg.k) if isinstance(cfg.k, tuple) else [cfg.k] * B
        if len(ks) != B or any(kk is None for kk in ks):
            raise ValueError("fpt mode needs one k (or one per instance)")
    else:
        ks = [None] * B
    results: dict = {}
    bucket_record = []
    compactions = 0
    wall_total = 0.0
    lane_stats = {"chunk_calls": 0, "lane_chunks": 0, "live_lane_chunks": 0}
    chunks_total = 0
    checkpoints_written = 0

    fingerprint = None
    if cfg.checkpoint_dir is not None or cfg.resume_from is not None:
        from repro.checkpoint import solve as _ckpt

        fingerprint = _ckpt.config_fingerprint(
            "many", spec.name, cfg, [_ckpt.graph_digest(g) for g in graphs]
        )

    def extract(host, lane, oi, rounds_i, wall):
        return _engine._extract_result(
            host,
            lane,
            spec,
            graphs[oi],
            rounds_i,
            wall,
            mode=cfg.mode,
            k=ks[oi],
            num_workers=cfg.num_workers,
            packed_status=cfg.packed_status,
        )

    io_retry = injector.retry_policy() if injector is not None else None
    io_hook = injector.io_hook if injector is not None else None

    resume_ck = None
    resume_bucket = -1
    if cfg.resume_from is not None:
        from repro.checkpoint import solve as _ckpt

        resume_ck = _ckpt.SolveCheckpoint.load_latest_good(
            cfg.resume_from,
            expected_fingerprint=fingerprint,
            what=f"solve_many({spec.name})",
            retry=io_retry,
            fault_hook=io_hook,
        )
        if resume_ck.kind != "many":
            raise _ckpt.CheckpointError(
                f"{cfg.resume_from} holds a {resume_ck.kind!r} checkpoint; "
                f"solve_many() resumes 'many' checkpoints only"
            )
        meta = resume_ck.meta
        results = {
            int(i): _ckpt.engine_result_from_dict(d)
            for i, d in meta["results"].items()
        }
        compactions = int(meta["compactions"])
        chunks_total = int(meta["chunks_total"])
        lane_stats.update(
            {k: int(v) for k, v in meta["lane_stats"].items() if k in lane_stats}
        )
        resume_bucket = int(meta["bucket_idx"])

    def patch_spill(r, sp):
        if sp is not None:
            r.spilled_tasks = sp.spilled_total
            r.readmitted_tasks = sp.readmitted_total
            r.cold_bytes_peak = sp.cold_bytes_peak

    def write_checkpoint(bi, lanes, datas, fpt_bounds, total_ran, spillers):
        from repro.checkpoint import solve as _ckpt

        ck = _ckpt.SolveCheckpoint(
            kind="many",
            problem=spec.name,
            config=cfg.replace(resume_from=None).to_dict(),
            fingerprint=fingerprint,
            rounds=total_ran,
            arrays=lane_state_to_flat(lanes),
            meta={
                "bucket_idx": bi,
                "total_ran": total_ran,
                "chunks_total": chunks_total,
                "compactions": compactions,
                "lane_stats": {
                    k: int(v) for k, v in lane_stats.items()
                },
                "results": {
                    str(i): _ckpt.engine_result_to_dict(r)
                    for i, r in results.items()
                },
            },
        )
        ck.arrays.update(_ckpt.data_to_flat(datas, "datas"))
        if fpt_bounds is not None:
            ck.arrays["fpt_bounds"] = np.asarray(jax.device_get(fpt_bounds))
        for lane, sp in enumerate(spillers):
            if sp is not None:
                ck.arrays.update(sp.to_flat(f"spill{lane}"))
        ck.pack_graphs(range(B), graphs)
        ck.save(cfg.checkpoint_dir, chunks_total,
                retry=io_retry, fault_hook=io_hook)

    buckets = _engine._bucket_instances(graphs, by_n=(cfg.codec == "basic"))
    for bi, ((W, _), idxs) in enumerate(sorted(buckets.items())):
        bucket_graphs = [graphs[i] for i in idxs]
        n_max = max(g.n for g in bucket_graphs)
        bucket_record.append((W, n_max, list(idxs)))
        if resume_ck is not None and bi < resume_bucket:
            continue  # fully finalized before the checkpoint — restored above
        t0 = time.perf_counter()
        cap = cfg.capacity or (4 * n_max + 8 * cfg.lanes)
        pad = make_codec(cfg.codec, n_max, problem=spec).pad_words

        if resume_ck is not None and bi == resume_bucket:
            from repro.checkpoint import solve as _ckpt

            lanes = lane_state_from_flat(resume_ck.arrays)
            datas = _ckpt.data_from_flat(resume_ck.arrays, "datas")
            fpt_bounds = (
                jnp.asarray(resume_ck.arrays["fpt_bounds"]) if use_fpt else None
            )
            total_ran = int(resume_ck.meta["total_ran"])
            live_h = ~np.asarray(jax.device_get(lanes.done))
            spillers = [None] * lanes.num_lanes
            if cfg.frontier_spill:
                for lane in range(lanes.num_lanes):
                    sp = make_spiller(
                        cfg, spec, graphs[int(lanes.tag[lane])], cap,
                        cfg.num_workers, injector,
                    )
                    if FrontierSpiller.present_in(
                        resume_ck.arrays, f"spill{lane}"
                    ):
                        sp.load_flat(resume_ck.arrays, f"spill{lane}")
                    spillers[lane] = sp
            resume_ck = None  # at most one in-flight bucket per checkpoint
        else:
            initial_bests = [
                problems_base.initial_bound(spec, g, cfg.mode, ks[i])
                for i, g in zip(idxs, bucket_graphs)
            ]
            datas = problems_base.make_batch_data(spec, bucket_graphs, n_max, W)
            lanes = LaneState(
                worker=_engine._make_batch_state(
                    spec, bucket_graphs, cfg.num_workers, cap, W, initial_bests
                ),
                done=jnp.zeros((len(idxs),), bool),
                tag=np.asarray(idxs, np.int32),
                rounds=jnp.zeros((len(idxs),), jnp.int32),
            )
            fpt_bounds = (
                jnp.asarray(
                    np.array([spec.fpt_target(ks[i]) for i in idxs], np.int32)
                )
                if use_fpt
                else None
            )
            total_ran = 0
            live_h = np.ones(len(idxs), bool)  # live entering the next chunk
            spillers = [None] * len(idxs)
            if cfg.frontier_spill:
                spillers = [
                    make_spiller(cfg, spec, graphs[i], cap, cfg.num_workers,
                                 injector)
                    for i in idxs
                ]

        plane = cache.batch_plane(spec, cfg, pad, use_fpt)

        def note(n_lanes):
            cache.note(
                "batch", spec, cfg, pad, use_fpt,
                (n_max, W, cap, cfg.num_workers, n_lanes),
            )

        note(lanes.num_lanes)
        while total_ran < cfg.max_rounds:
            lane_stats["chunk_calls"] += 1
            lane_stats["lane_chunks"] += lanes.num_lanes
            lane_stats["live_lane_chunks"] += int(live_h.sum())
            lanes, ran, hot = step_lanes(plane, datas, lanes, fpt_bounds)
            done_h, ran_h, hot_h = jax.device_get((lanes.done, ran, hot))
            total_ran += int(ran_h)
            chunks_total += 1
            done_h = np.array(done_h)
            if cfg.frontier_spill:
                hot_h = np.array(hot_h)
                best_h = bounds_h = None
                for lane, sp in enumerate(spillers):
                    if sp is None or not sp.wants_pump(
                        hot_h[lane], bool(done_h[lane])
                    ):
                        continue
                    if bool(done_h[lane]) and use_fpt:
                        if best_h is None:
                            best_h = np.asarray(
                                jax.device_get(lanes.worker.best_val)
                            )[:, 0]
                            bounds_h = np.asarray(jax.device_get(fpt_bounds))
                        if int(best_h[lane]) <= int(bounds_h[lane]):
                            continue  # FPT bound hit — finished for real
                    lanes, hot_lane = sp.pump_lane(lanes, lane)
                    hot_h[lane] = hot_lane
                    if bool(done_h[lane]) and int(hot_lane.sum()) > 0:
                        lanes = lane_resume(lanes, lane)
                        done_h[lane] = False
            if injector is not None:
                injector.step_boundary()
                live_lanes = [
                    lane for lane in range(lanes.num_lanes)
                    if not bool(done_h[lane])
                ]
                for lane in injector.take_crashes(live_lanes):
                    # the lane's occupant died with its device state; the
                    # center still knows WHICH instance was placed there
                    # (the tag), so re-admission rebuilds it from the
                    # Algorithm-7 startup placement — a deterministic
                    # replay whose final result is bit-identical.
                    oi = int(lanes.tag[lane])
                    worker = _engine.make_instance_state(
                        spec, graphs[oi], cfg.num_workers, cap, W,
                        problems_base.initial_bound(
                            spec, graphs[oi], cfg.mode, ks[oi]
                        ),
                    )
                    lanes = lane_swap_in(lanes, lane, worker, oi)
                    done_h[lane] = False
                    if cfg.frontier_spill:
                        spillers[lane] = make_spiller(
                            cfg, spec, graphs[oi], cap, cfg.num_workers,
                            injector,
                        )
                    injector.note_recovered("crash")
            live_h = ~done_h
            if done_h.all():
                break
            n_live = int(live_h.sum())
            n_lanes = lanes.num_lanes
            target = _engine._pow2_at_least(n_live)
            if (
                cfg.compact_threshold > 0
                and n_live <= cfg.compact_threshold * n_lanes
                and target < n_lanes
            ):
                # collect finished lanes now, keep live ones (plus frozen
                # finished fillers up to the pow2 target), reslice every
                # tensor — the SAME plane function serves the new width.
                host = _engine._fetch_batch_state(lanes.worker)
                rounds_h = np.asarray(jax.device_get(lanes.rounds))
                live = np.flatnonzero(~done_h)
                fillers = np.flatnonzero(done_h)[: target - n_live]
                for lane in np.flatnonzero(done_h):
                    oi = int(lanes.tag[lane])
                    if oi not in results and lane not in fillers:
                        results[oi] = extract(
                            host, lane, oi, int(rounds_h[lane]), 0.0
                        )
                        patch_spill(results[oi], spillers[lane])
                sel = np.concatenate([live, fillers]).astype(np.int64)
                lanes = slice_lanes(lanes, sel)
                datas = problems_base.slice_instances(datas, sel)
                spillers = [spillers[i] for i in sel]
                if fpt_bounds is not None:
                    fpt_bounds = fpt_bounds[sel]
                live_h = live_h[sel]
                compactions += 1
                note(lanes.num_lanes)
            if (
                cfg.checkpoint_dir is not None
                and chunks_total % cfg.checkpoint_every == 0
            ):
                write_checkpoint(
                    bi, lanes, datas, fpt_bounds, total_ran, spillers
                )
                checkpoints_written += 1

        host = _engine._fetch_batch_state(lanes.worker)
        rounds_h = np.asarray(jax.device_get(lanes.rounds))
        for lane in range(lanes.num_lanes):
            oi = int(lanes.tag[lane])
            if oi not in results:
                results[oi] = extract(host, lane, oi, int(rounds_h[lane]), 0.0)
                patch_spill(results[oi], spillers[lane])
        bucket_wall = time.perf_counter() - t0
        wall_total += bucket_wall
        per_wall = bucket_wall / max(len(idxs), 1)
        for oi in idxs:
            results[oi].wall_s = per_wall

    lane_stats["occupancy"] = (
        lane_stats["live_lane_chunks"] / lane_stats["lane_chunks"]
        if lane_stats["lane_chunks"]
        else 0.0
    )
    for r in results.values():
        r.checkpoints_written = checkpoints_written
        r.resumed_from = cfg.resume_from
    return _engine.BatchResult(
        results=[results[i] for i in range(B)],
        wall_s=wall_total,
        buckets=bucket_record,
        compactions=compactions,
        lane_stats=lane_stats,
    )


# -- the Backend protocol ------------------------------------------------------


class Backend:
    """One engine behind the session façade.

    ``solve``/``solve_many`` take the RESOLVED problem spec, the validated
    config and the session's plane cache, and return the unified schema.
    The default ``solve_many`` loops ``solve`` per instance (honoring
    per-instance ``k`` tuples); backends with a real batch plane override.
    """

    name: str = "?"

    def solve(self, spec, g, cfg: SolveConfig, cache: PlaneCache) -> SolveResult:
        raise NotImplementedError

    def solve_many(
        self, spec, graphs, cfg: SolveConfig, cache: PlaneCache
    ) -> BatchSolveResult:
        graphs = list(graphs)
        ks = (
            list(cfg.k)
            if isinstance(cfg.k, tuple)
            else [cfg.k] * len(graphs)
        )
        if len(ks) != len(graphs):
            raise ValueError("per-instance k needs one entry per graph")
        out = [
            self.solve(spec, g, cfg.replace(k=kk), cache)
            for g, kk in zip(graphs, ks)
        ]
        return BatchSolveResult(
            problem=spec.name,
            backend=self.name,
            results=out,
            wall_s=sum(r.wall_s for r in out),
        )


class SpmdBackend(Backend):
    name = "spmd"

    def solve(self, spec, g, cfg, cache, *, initial_state=None, mesh=None,
              injector=None):
        r = solve_spmd(spec, g, cfg, cache, initial_state=initial_state,
                       mesh=mesh, injector=injector)
        return from_engine_result(r, problem=spec.name, backend=self.name)

    def solve_many(self, spec, graphs, cfg, cache, *, injector=None):
        br = solve_many_spmd(spec, graphs, cfg, cache, injector=injector)
        return BatchSolveResult(
            problem=spec.name,
            backend=self.name,
            results=[
                from_engine_result(r, problem=spec.name, backend=self.name)
                for r in br.results
            ],
            wall_s=br.wall_s,
            buckets=br.buckets,
            compactions=br.compactions,
            lane_stats=LaneStats(**br.lane_stats),
        )


class ProtocolSimBackend(Backend):
    name = "protocol_sim"

    def solve(self, spec, g, cfg, cache):
        from repro.core.protocol_sim import run_protocol_sim

        t0 = time.perf_counter()
        r = run_protocol_sim(
            g,
            num_workers=cfg.num_workers,
            latency=cfg.latency,
            policy=cfg.policy,
            codec_name=cfg.codec,
            mode=cfg.mode,
            k=cfg.solo_k(),
            send_metadata=cfg.send_metadata,
            max_ticks=cfg.max_ticks,
            seed=cfg.seed,
            problem=spec,
        )
        wall = time.perf_counter() - t0
        return from_sim_result(r, problem=spec.name, backend=self.name, wall_s=wall)


class CentralizedBackend(Backend):
    name = "centralized"

    def solve(self, spec, g, cfg, cache):
        from repro.core.centralized import run_centralized_sim

        t0 = time.perf_counter()
        r = run_centralized_sim(
            g,
            num_workers=cfg.num_workers,
            latency=cfg.latency,
            codec_name=cfg.codec,
            queue_cap_per_p=cfg.queue_cap_per_p,
            use_priority_queue=cfg.use_priority_queue,
            max_ticks=cfg.max_ticks,
            mode=cfg.mode,
            k=cfg.solo_k(),
            problem=spec,
        )
        wall = time.perf_counter() - t0
        return from_sim_result(r, problem=spec.name, backend=self.name, wall_s=wall)


class SequentialBackend(Backend):
    name = "sequential"

    def solve(self, spec, g, cfg, cache):
        if spec.sequential is None:
            raise ValueError(f"problem {spec.name!r} has no sequential reference")
        t0 = time.perf_counter()
        best, sol, stats = spec.sequential(g, mode=cfg.mode, k=cfg.solo_k())
        wall = time.perf_counter() - t0
        return from_sequential(best, sol, stats, problem=spec.name, wall_s=wall)


# -- backend registry ----------------------------------------------------------

BACKENDS = {
    b.name: b
    for b in (
        SpmdBackend(),
        ProtocolSimBackend(),
        CentralizedBackend(),
        SequentialBackend(),
    )
}

BACKEND_ALIASES = {
    "protocol": "protocol_sim",
    "central": "centralized",
    "centralised": "centralized",
    "seq": "sequential",
}


def known_backends() -> list:
    return sorted(BACKENDS)


def get_backend(name) -> Backend:
    """Resolve a backend by name (or pass an instance through); unknown
    names raise a ``ValueError`` listing what IS available."""
    if isinstance(name, Backend):
        return name
    key = BACKEND_ALIASES.get(name, name)
    if key not in BACKENDS:
        raise ValueError(
            f"unknown backend {name!r}; known backends: "
            f"{', '.join(known_backends())} "
            f"(aliases: {', '.join(sorted(BACKEND_ALIASES))})"
        )
    return BACKENDS[key]


# -- legacy engine shim plumbing -----------------------------------------------

#: one process-wide cache for the deprecated ``engine.solve``/``solve_many``
#: shims — legacy callers pool their executables too.
LEGACY_CACHE = PlaneCache()


def config_from_legacy(policy_priority: bool = True, **kw) -> SolveConfig:
    """Map the legacy kwargs surface onto :class:`SolveConfig`."""
    return SolveConfig(
        policy=("priority" if policy_priority else "random"), **kw
    )
