"""Graph generators and DIMACS I/O.

The paper benchmarks on DIMACS challenge graphs (p_hat1000-2, p_hat700-1,
DSJ500.5) and on 100 G(n,p) random graphs with expected degree 4 (§4.4.1).
We reproduce the G(n,p) family exactly and provide a ``p_hat_like`` generator
(the p_hat family is G(n,p) with non-uniform, vertex-weighted edge densities,
giving the skewed degree distribution that makes those instances hard).
"""

from __future__ import annotations

import numpy as np

from repro.graphs.bitgraph import BitGraph


def erdos_renyi(n: int, p: float, seed: int) -> BitGraph:
    """G(n, p): each of the C(n,2) edges present independently w.p. ``p``.

    The paper's random family is n=600, p=4/(n-1) (expected degree 4).
    """
    rng = np.random.default_rng(seed)
    upper = rng.random((n, n)) < p
    dense = np.triu(upper, 1)
    return BitGraph.from_dense(dense | dense.T)


def p_hat_like(n: int, density: float, seed: int, spread: float = 2.0) -> BitGraph:
    """p_hat-style graph: vertex weights w_v ~ U(0,1)^spread, edge uv present
    w.p. clip(density * (w_u + w_v), 0, 1).  Produces the wide degree spread
    characteristic of the DIMACS p_hat instances (p_hat700-1 ~ density .25,
    p_hat1000-2 ~ density .5)."""
    rng = np.random.default_rng(seed)
    w = rng.random(n) ** spread
    prob = np.clip(density * (w[:, None] + w[None, :]), 0.0, 1.0)
    dense = np.triu(rng.random((n, n)) < prob, 1)
    return BitGraph.from_dense(dense | dense.T)


def parse_dimacs(text: str) -> BitGraph:
    """Parse DIMACS ``.clq``/``.col`` edge format ('p edge N M' + 'e u v')."""
    n = 0
    edges = []
    for line in text.splitlines():
        line = line.strip()
        if not line or line.startswith("c"):
            continue
        parts = line.split()
        if parts[0] == "p":
            n = int(parts[2])
        elif parts[0] == "e":
            u, v = int(parts[1]) - 1, int(parts[2]) - 1
            edges.append((u, v))
    return BitGraph.from_edges(n, edges)


def to_dimacs(g: BitGraph) -> str:
    edges = g.edges()
    lines = [f"p edge {g.n} {len(edges)}"]
    lines += [f"e {u + 1} {v + 1}" for u, v in edges]
    return "\n".join(lines) + "\n"
