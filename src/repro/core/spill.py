"""Hierarchical frontier memory: device-hot tier + compressed host cold tier.

The device frontier (:mod:`repro.core.frontier`) is a fixed-capacity pool, so
a search whose peak frontier exceeds it used to *drop* tasks (loudly, via
``overflow_count`` — but dropped is dropped).  This module turns that fixed
pool into the **hot tier** of a two-level memory:

* a **high-water mark**: when a host sync finds a worker's pool above it,
  the shallowest pending tasks (the paper's donation priority, Alg. 6 — the
  quasi-horizontal leaves a worker would part with anyway) are evicted,
  encoded with the registered §4.3 codec (57–2000× smaller than adjacency
  payloads for the optimized layout), and appended to a per-(worker,
  depth-band) host store;
* a **low-water mark**: when a worker's pool drains below it, cold records
  are decoded and re-admitted — the worker's own bands first, then stealing
  from the globally shallowest band, scanning donors in the Algorithm-7
  waiting-list order (:func:`repro.core.waiting_list.startup_assignment`),
  the same deterministic permutation that placed the startup frontier.

Everything here runs on the host between device chunks (plain numpy, no
tracing), so spilled solves are deterministic run-to-run and the whole cold
tier serializes into a :class:`~repro.checkpoint.solve.SolveCheckpoint` as a
handful of named arrays (kill-anywhere resume stays bit-identical).

The **no-drop guarantee**: :func:`resolve_watermarks` refuses any watermark
placement that leaves less headroom above the high mark than one chunk can
generate — per superstep a worker nets at most ``steps_per_round·lanes`` new
tasks from exploration plus ``donate_k`` received donations (plus a
transient ``lanes`` during the pop/push cycle), so capping the high mark at
``capacity - chunk_rounds·(steps_per_round·lanes + donate_k) - lanes``
means the hot tier cannot overflow between two pump points.  With spill
enabled, ``overflow_count`` stays 0 by construction (property-tested).
"""

from __future__ import annotations

import numpy as np

from .encoding import Task, checked_record, strip_record, verify_record
from .waiting_list import startup_assignment

# depth-band granularity of the cold tier: records are stored FIFO inside a
# band and re-admitted shallowest-band-first, so the cold tier preserves the
# engine's quasi-horizontal priority without keeping a global sorted order
BAND_WIDTH = 8


def chunk_headroom(
    *, chunk_rounds: int, steps_per_round: int, lanes: int, donate_k: int
) -> int:
    """Worst-case growth of ONE worker's pool between two host syncs.

    Each superstep nets at most ``steps_per_round * lanes`` tasks from
    exploration (every popped lane pushes back two children) plus
    ``donate_k`` received donations; the trailing ``+ lanes`` covers the
    transient inside a round where children are pushed before the popped
    parents' slots are reused.
    """
    return chunk_rounds * (steps_per_round * lanes + donate_k) + lanes


def resolve_watermarks(
    capacity: int,
    watermarks,
    *,
    chunk_rounds: int,
    steps_per_round: int,
    lanes: int,
    donate_k: int,
) -> tuple:
    """Turn fractional ``(low, high)`` watermarks into slot counts.

    The high mark is additionally capped at ``capacity - headroom`` so one
    chunk's growth can never overflow the hot tier (the no-drop guarantee);
    a capacity too small to leave ≥ 2 slots under that cap is a config
    error, reported with the arithmetic spelled out.
    """
    low_frac, high_frac = watermarks
    head = chunk_headroom(
        chunk_rounds=chunk_rounds,
        steps_per_round=steps_per_round,
        lanes=lanes,
        donate_k=donate_k,
    )
    high = min(int(high_frac * capacity), capacity - head)
    if high < 2:
        raise ValueError(
            f"frontier_spill needs hot capacity above the per-chunk growth "
            f"headroom: capacity={capacity} minus headroom={head} "
            f"(chunk_rounds*(steps_per_round*lanes + donate_k) + lanes = "
            f"{chunk_rounds}*({steps_per_round}*{lanes} + {donate_k}) + "
            f"{lanes}) leaves a high-water mark of {high} slots — raise "
            f"capacity or lower chunk_rounds/steps_per_round"
        )
    low = max(1, min(int(low_frac * capacity), high - 1))
    return low, high


class FrontierSpiller:
    """One instance's cold tier plus the host-side spill/refill pump.

    Owns per-(worker, depth-band) FIFO stores of codec-encoded task records
    and the two watermarks; :meth:`pump_host` is the pure-numpy core (spill
    above high, refill below low), :meth:`pump_frontier` /
    :meth:`pump_lane` are the device-boundary wrappers used by the solo and
    batched drivers.  All state is host-resident and the pump order is a
    fixed function of the pool contents, so spilled solves replay
    bit-identically — including across a checkpoint/resume cut
    (:meth:`to_flat` / :meth:`load_flat`).
    """

    def __init__(
        self,
        codec,
        num_workers: int,
        capacity: int,
        watermarks,
        *,
        chunk_rounds: int,
        steps_per_round: int,
        lanes: int,
        donate_k: int,
        graph=None,
        injector=None,
    ):
        self.codec = codec
        self.injector = injector
        self.delivery_retries = 0
        self.num_workers = num_workers
        self.low, self.high = resolve_watermarks(
            capacity,
            watermarks,
            chunk_rounds=chunk_rounds,
            steps_per_round=steps_per_round,
            lanes=lanes,
            donate_k=donate_k,
        )
        if getattr(codec, "name", "") == "basic":
            if graph is None:
                raise ValueError(
                    "spill_codec='basic' encodes the induced subgraph, so "
                    "the spiller needs the instance graph"
                )
            self._encode = lambda task: codec.encode(task, graph)
        else:
            self._encode = codec.encode
        self._graph = graph
        # Algorithm-7 startup permutation, 0-based: refill scan order
        self.order = tuple(
            o - 1 for o in startup_assignment(2, num_workers)
        )
        self._bands = [dict() for _ in range(num_workers)]
        self.spilled_total = 0
        self.readmitted_total = 0
        self.cold_tasks = 0
        self.cold_bytes_peak = 0

    @property
    def cold_bytes(self) -> int:
        return self.cold_tasks * self.codec.record_bytes

    # -- cold-tier store -------------------------------------------------------
    #
    # Records are stored CHECKED (codec payload + trailing CRC32 word, see
    # core/encoding.py) and every host-memory hand-off — the encode/write
    # into the cold tier and the pop/delivery back toward the hot frontier —
    # goes through :meth:`_deliver`, so corruption of a delivery copy is
    # detected by checksum and healed by redelivering from the intact
    # source, never propagated into the search.

    def _deliver(self, kind: str, rec: np.ndarray) -> np.ndarray:
        """One checked-record hand-off, with optional fault injection.

        The injector (if any) may corrupt the delivery COPY; verification
        catches it and the intact source record is redelivered (booked as
        one recovery + one retry)."""
        if self.injector is None:
            return rec
        delivered, injected = self.injector.corrupt(kind, rec)
        if injected and not verify_record(delivered):
            self.injector.note_recovered(kind)
            self.injector.note_retry()
            self.delivery_retries += 1
            return rec
        return delivered

    def _push_cold(self, w: int, mask, sol, depth: int) -> None:
        rec = checked_record(
            self._encode(
                Task(
                    mask=np.asarray(mask, np.uint32),
                    sol_mask=np.asarray(sol, np.uint32),
                    depth=int(depth),
                )
            )
        )
        rec = self._deliver("cold_corrupt", rec)
        self._bands[w].setdefault(int(depth) // BAND_WIDTH, []).append(rec)
        self.spilled_total += 1
        self.cold_tasks += 1
        self.cold_bytes_peak = max(self.cold_bytes_peak, self.cold_bytes)

    def _pop_band(self, w: int, band: int) -> np.ndarray:
        fifo = self._bands[w][band]
        rec = self._deliver("transfer_corrupt", fifo[0])
        fifo.pop(0)
        if not fifo:
            del self._bands[w][band]
        self.cold_tasks -= 1
        self.readmitted_total += 1
        return rec

    def _pop_cold(self, w: int):
        """Shallowest record for worker ``w``: its own store first, else
        steal from the globally shallowest band (donors in Alg-7 order).
        Returns a decoded :class:`Task`, or None when the tier is empty."""
        if self._bands[w]:
            rec = self._pop_band(w, min(self._bands[w]))
        elif self.cold_tasks:
            best = min(min(b) for b in self._bands if b)
            donor = next(d for d in self.order if self._bands[d].get(best))
            rec = self._pop_band(donor, best)
        else:
            return None
        return self.codec.decode(strip_record(rec), self._graph)

    # -- the pump --------------------------------------------------------------

    def wants_pump(self, hot, done: bool) -> bool:
        """Cheap trigger check from the chunk's per-worker hot counts: any
        worker above high, or cold records waiting while any worker is below
        low (or the plane went quiescent)."""
        hot = np.asarray(hot)
        if (hot > self.high).any():
            return True
        return bool(self.cold_tasks) and (done or bool((hot < self.low).any()))

    def pump_host(self, masks, sols, depths, active) -> bool:
        """Spill/refill pass over one instance's (P, CAP, ...) host pool.

        Mutates the arrays in place; returns True if anything moved.
        Eviction order is (depth asc, slot asc) — the donation priority;
        refill scans workers in Algorithm-7 order and places into the
        lowest free slot, so the pass is a deterministic function of the
        pool contents.
        """
        counts = active.sum(axis=1).astype(np.int64)
        moved = False
        for w in range(self.num_workers):
            if counts[w] > self.high:
                slots = np.flatnonzero(active[w])
                order = slots[np.argsort(depths[w][slots], kind="stable")]
                for s in order[: counts[w] - self.low]:
                    self._push_cold(w, masks[w, s], sols[w, s], depths[w, s])
                    active[w, s] = False
                counts[w] = self.low
                moved = True
        if self.cold_tasks:
            for w in self.order:
                while counts[w] < self.low and self.cold_tasks:
                    task = self._pop_cold(w)
                    slot = int(np.argmax(~active[w]))
                    masks[w, slot] = task.mask
                    sols[w, slot] = task.sol_mask
                    depths[w, slot] = task.depth
                    active[w, slot] = True
                    counts[w] += 1
                    moved = True
        return moved

    def pump_frontier(self, frontier):
        """Pump a solo (P, CAP, ...) device frontier.

        Returns ``(frontier, hot)`` with the post-pump per-worker pending
        counts — the driver clears its quiescence flag iff any survive."""
        import jax

        from .frontier import write_pool

        m, s, d, a = (
            np.array(x)
            for x in jax.device_get(
                (frontier.masks, frontier.sols, frontier.depths, frontier.active)
            )
        )
        if self.pump_host(m, s, d, a):
            frontier = write_pool(frontier, m, s, d, a)
        return frontier, a.sum(axis=1).astype(np.int64)

    def pump_lane(self, lanes, lane: int):
        """Pump ONE lane of a live (B, P, CAP, ...) plane.

        Returns ``(lanes, hot)`` like :meth:`pump_frontier`; the write-back
        is a jitted single-lane scatter, so the compiled plane is untouched
        (no re-trace)."""
        import jax

        from .frontier import read_lane_pool, write_lane_pool

        f = lanes.worker.frontier
        m, s, d, a = (
            np.array(x) for x in jax.device_get(read_lane_pool(f, lane))
        )
        if self.pump_host(m, s, d, a):
            f = write_lane_pool(f, lane, m, s, d, a)
            lanes = lanes._replace(worker=lanes.worker._replace(frontier=f))
        return lanes, a.sum(axis=1).astype(np.int64)

    # -- checkpoint (de)serialization ------------------------------------------

    def to_flat(self, prefix: str = "spill") -> dict:
        """The cold tier as named uint32/int64 arrays (checkpoint leaves):
        one ``(N_w, record_words + 1)`` block per worker (records travel
        CHECKED — payload plus CRC32 word), band-major FIFO order, plus a
        counters vector."""
        flat = {}
        rw = self.codec.record_words + 1
        for w in range(self.num_workers):
            recs = [
                rec
                for band in sorted(self._bands[w])
                for rec in self._bands[w][band]
            ]
            flat[f"{prefix}.w{w}"] = (
                np.stack(recs).astype(np.uint32)
                if recs
                else np.zeros((0, rw), np.uint32)
            )
        flat[f"{prefix}.counters"] = np.array(
            [self.spilled_total, self.readmitted_total, self.cold_bytes_peak],
            np.int64,
        )
        return flat

    @staticmethod
    def present_in(flat: dict, prefix: str = "spill") -> bool:
        return f"{prefix}.counters" in flat

    def load_flat(self, flat: dict, prefix: str = "spill") -> None:
        """Rebuild the cold tier from :meth:`to_flat` arrays.  Records are
        re-banded by their decoded depth; band-major FIFO storage order makes
        the rebuild exact, so a resumed solve replays bit-identically.

        Each record's CRC32 word is re-verified on load (raising
        :class:`~repro.core.encoding.PayloadCorruptionError` on rot — the
        checkpoint loader turns that into a fall-back to the previous good
        generation); bare pre-checksum blocks are accepted and upgraded."""
        counters = np.asarray(flat[f"{prefix}.counters"])
        self.spilled_total = int(counters[0])
        self.readmitted_total = int(counters[1])
        self.cold_bytes_peak = int(counters[2])
        self._bands = [dict() for _ in range(self.num_workers)]
        self.cold_tasks = 0
        rw = self.codec.record_words
        for w in range(self.num_workers):
            for rec in np.asarray(flat[f"{prefix}.w{w}"], np.uint32):
                if rec.size == rw:          # legacy bare record
                    rec = checked_record(rec)
                depth = self.codec.decode(strip_record(rec), self._graph).depth
                self._bands[w].setdefault(depth // BAND_WIDTH, []).append(rec)
                self.cold_tasks += 1


def make_spiller(cfg, problem, graph, capacity: int, num_workers: int,
                 injector=None):
    """Build a :class:`FrontierSpiller` from a SolveConfig — the one shared
    constructor for the solo, batched, and service drivers (all three must
    agree on the eviction/re-admission contract, so they all come here)."""
    from .encoding import make_codec

    codec = make_codec(cfg.spill_codec, graph.n, problem=problem)
    return FrontierSpiller(
        codec,
        num_workers,
        capacity,
        cfg.spill_watermarks,
        chunk_rounds=cfg.chunk_rounds,
        steps_per_round=cfg.steps_per_round,
        lanes=cfg.lanes,
        donate_k=cfg.donate_k,
        graph=graph,
        injector=injector,
    )
