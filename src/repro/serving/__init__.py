from repro.serving.balancer import BalancerState, RequestBatch, rebalance

__all__ = ["BalancerState", "RequestBatch", "rebalance"]
