"""RWKV6 "Finch" language model (attention-free, data-dependent decay).

Block = time-mix (the WKV6 recurrence, accelerated by kernels/wkv6) +
channel-mix, both with token-shift interpolation.  Decode carries O(1) state
per layer — (B, H, K, V) WKV state plus the last-token activations for the
two token-shifts — which is why rwkv6 runs the ``long_500k`` shape.

Faithful-but-lean parameterization of arXiv:2404.05892: learned token-shift
mixes for r/k/v/w/g, LoRA'd data-dependent decay
``w_t = w0 + tanh(x_t A) B``, per-head bonus ``u``, per-head group-norm on
the WKV output, SiLU output gate; squared-ReLU channel-mix.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels.wkv6.ops import wkv6_decode_step, wkv6_op
from repro.models import layers as L
from repro.models.sharding import constrain, gather_params, spec_tree_of

HEAD_SIZE = 64


def _heads(cfg: ModelConfig) -> int:
    return cfg.d_model // HEAD_SIZE


def _tmix_init(key, cfg: ModelConfig):
    d = cfg.d_model
    H, K = _heads(cfg), HEAD_SIZE
    r = cfg.decay_lora
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 8)
    p, s = {}, {}
    # token-shift interpolation weights (r, k, v, w, g)
    p["mu"], s["mu"] = jnp.full((5, d), 0.5, jnp.float32), ("stack", "embed")
    p["wr"], s["wr"] = L.dense_init(ks[0], d, d, "embed", "heads", dt)
    p["wk"], s["wk"] = L.dense_init(ks[1], d, d, "embed", "heads", dt)
    p["wv"], s["wv"] = L.dense_init(ks[2], d, d, "embed", "heads", dt)
    p["wg"], s["wg"] = L.dense_init(ks[3], d, d, "embed", "heads", dt)
    p["wo"], s["wo"] = L.dense_init(ks[4], d, d, "heads", "embed", dt)
    # data-dependent decay LoRA: w_t = w0 + tanh(x A) B
    p["w0"], s["w0"] = jnp.full((d,), -2.0, jnp.float32), ("heads",)
    p["wa"], s["wa"] = L.dense_init(ks[5], d, r, "embed", "lora", dt)
    p["wb"], s["wb"] = L.dense_init(ks[6], r, d, "lora", "heads", dt)
    p["u"], s["u"] = (
        jax.random.normal(ks[7], (H, K), jnp.float32) * 0.1,
        ("heads", None),
    )
    p["ln_g"], s["ln_g"] = jnp.ones((d,), jnp.float32), ("heads",)
    return p, s


def _cmix_init(key, cfg: ModelConfig):
    d, f = cfg.d_model, cfg.d_ff
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 3)
    p, s = {}, {}
    p["mu"], s["mu"] = jnp.full((2, d), 0.5, jnp.float32), ("stack", "embed")
    p["wk"], s["wk"] = L.dense_init(ks[0], d, f, "embed", "mlp", dt)
    p["wv"], s["wv"] = L.dense_init(ks[1], f, d, "mlp", "embed", dt)
    p["wr"], s["wr"] = L.dense_init(ks[2], d, d, "embed", None, dt)
    return p, s


def block_init(key, cfg: ModelConfig):
    k1, k2 = jax.random.split(key)
    p, s = {}, {}
    p["ln1"], s["ln1"] = L.rmsnorm_init(cfg.d_model)
    p["tmix"], s["tmix"] = _tmix_init(k1, cfg)
    p["ln2"], s["ln2"] = L.rmsnorm_init(cfg.d_model)
    p["cmix"], s["cmix"] = _cmix_init(k2, cfg)
    return p, s


def _token_shift(x, last: Optional[jnp.ndarray]):
    """xs[t] = x[t-1]; position 0 takes `last` (decode state) or zeros."""
    first = jnp.zeros_like(x[:, :1]) if last is None else last
    return jnp.concatenate([first, x[:, :-1]], axis=1)


def _mix(x, xs, mu):
    return x + (xs - x) * mu


def tmix_apply(cfg, p, x, *, wkv_state=None, shift_last=None, wkv_impl="ref"):
    """x (B, T, d).  Returns (out, (new_wkv_state, new_shift_last))."""
    B, T, d = x.shape
    H, K = _heads(cfg), HEAD_SIZE
    xs = _token_shift(x, shift_last)
    mu = p["mu"].astype(x.dtype)
    xr, xk, xv, xw, xg = (_mix(x, xs, mu[i]) for i in range(5))
    r = (xr @ p["wr"]).reshape(B, T, H, K)
    k = (xk @ p["wk"]).reshape(B, T, H, K)
    v = (xv @ p["wv"]).reshape(B, T, H, K)
    g = jax.nn.silu(xg @ p["wg"])
    w = p["w0"] + jnp.tanh(xw @ p["wa"]) @ p["wb"]  # (B, T, d) log-log decay
    decay = jnp.exp(-jnp.exp(w.astype(jnp.float32))).reshape(B, T, H, K)

    if T == 1 and wkv_state is not None:
        o, new_state = wkv6_decode_step(
            r[:, 0].astype(jnp.float32),
            k[:, 0].astype(jnp.float32),
            v[:, 0].astype(jnp.float32),
            decay[:, 0],
            p["u"],
            wkv_state,
        )
        o = o[:, None]  # (B, 1, H, K)
    else:
        o, new_state = wkv6_op(
            r.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32),
            decay, p["u"], wkv_state, impl=wkv_impl,
        )
    # per-head group norm, then gate
    o = o.reshape(B, T, H, K)
    o32 = o.astype(jnp.float32)
    o = (o32 - o32.mean(-1, keepdims=True)) * jax.lax.rsqrt(
        o32.var(-1, keepdims=True) + 64e-5
    )
    o = (o.reshape(B, T, d) * p["ln_g"]).astype(x.dtype)
    out = (o * g) @ p["wo"]
    return out, (new_state, x[:, -1:])


def cmix_apply(cfg, p, x, *, shift_last=None):
    xs = _token_shift(x, shift_last)
    mu = p["mu"].astype(x.dtype)
    xk, xr = _mix(x, xs, mu[0]), _mix(x, xs, mu[1])
    kk = jnp.square(jax.nn.relu(xk @ p["wk"]))
    out = jax.nn.sigmoid(xr @ p["wr"]) * (kk @ p["wv"])
    return out, x[:, -1:]


_BLOCK_SPEC_CACHE: dict = {}


def _block_specs(cfg):
    if cfg.name not in _BLOCK_SPEC_CACHE:
        _BLOCK_SPEC_CACHE[cfg.name] = spec_tree_of(
            lambda: block_init(jax.random.key(0), cfg)
        )
    return _BLOCK_SPEC_CACHE[cfg.name]


def block_apply(cfg, bp, x, *, state=None, rules=None, wkv_impl="ref"):
    """state = None (train/prefill) or dict(wkv, shift_t, shift_c)."""
    st = state or {}
    bp = gather_params(bp, _block_specs(cfg), rules)  # JIT-FSDP regather
    h, (wkv, shift_t) = tmix_apply(
        cfg, bp["tmix"], L.rmsnorm(x, bp["ln1"], cfg.norm_eps),
        wkv_state=st.get("wkv"), shift_last=st.get("shift_t"),
        wkv_impl=wkv_impl,
    )
    x = constrain(x + h, ("batch", "seq", None), rules)
    c, shift_c = cmix_apply(
        cfg, bp["cmix"], L.rmsnorm(x, bp["ln2"], cfg.norm_eps),
        shift_last=st.get("shift_c"),
    )
    x = constrain(x + c, ("batch", "seq", None), rules)
    new_state = {"wkv": wkv, "shift_t": shift_t, "shift_c": shift_c}
    return x, new_state


# -- model --------------------------------------------------------------------------


def init_lm(key, cfg: ModelConfig):
    k_emb, k_blocks, k_out = jax.random.split(key, 3)
    layer_keys = jax.random.split(k_blocks, cfg.n_layers)
    blocks_p = jax.vmap(lambda k: block_init(k, cfg)[0])(layer_keys)
    _, blocks_s = block_init(layer_keys[0], cfg)
    blocks_s = jax.tree.map(
        lambda ax: ("layers",) + ax, blocks_s, is_leaf=lambda x: isinstance(x, tuple)
    )
    dt = jnp.dtype(cfg.dtype)
    params = {
        "embed": (
            jax.random.normal(k_emb, (cfg.vocab, cfg.d_model), jnp.float32)
            * cfg.d_model**-0.5
        ).astype(dt),
        "blocks": blocks_p,
        "ln_f": L.rmsnorm_init(cfg.d_model)[0],
        "unembed": (
            jax.random.normal(k_out, (cfg.d_model, cfg.vocab), jnp.float32)
            * cfg.d_model**-0.5
        ).astype(dt),
    }
    specs = {
        "embed": ("vocab", "embed"),
        "blocks": blocks_s,
        "ln_f": ("embed",),
        "unembed": ("embed", "vocab"),
    }
    return params, specs


def forward(params, cfg: ModelConfig, tokens, *, rules=None, wkv_impl="ref", **_):
    x = params["embed"][tokens].astype(jnp.dtype(cfg.dtype))
    x = constrain(x, ("batch", "seq", None), rules)
    block = jax.checkpoint(
        lambda bp, x: block_apply(cfg, bp, x, rules=rules, wkv_impl=wkv_impl)[0],
        policy=L.remat_policy(),
        prevent_cse=False,
    )

    def scan_body(x, bp):
        return block(bp, x), jnp.float32(0)

    x, _ = jax.lax.scan(scan_body, x, params["blocks"], unroll=L.scan_unroll())
    x = L.rmsnorm(x, params["ln_f"], cfg.norm_eps)
    logits = x @ params["unembed"]
    return constrain(logits, ("batch", "seq", "vocab"), rules), jnp.float32(0)


def loss_fn(params, cfg, batch, *, rules=None, **kw):
    logits, _ = forward(params, cfg, batch["tokens"], rules=rules, **kw)
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    gold = jnp.take_along_axis(
        logits.astype(jnp.float32), batch["labels"][..., None], axis=-1
    )[..., 0]
    return (lse - gold).mean()


def init_decode_cache(cfg: ModelConfig, batch: int, max_len: int):
    """O(1)-in-seq state: WKV (L,B,H,K,K) + two token-shift slots."""
    H, K = _heads(cfg), HEAD_SIZE
    Lr = cfg.n_layers
    d = cfg.d_model
    cache = {
        "wkv": jnp.zeros((Lr, batch, H, K, K), jnp.float32),
        "shift_t": jnp.zeros((Lr, batch, 1, d), jnp.dtype(cfg.dtype)),
        "shift_c": jnp.zeros((Lr, batch, 1, d), jnp.dtype(cfg.dtype)),
        "len": jnp.int32(0),
    }
    specs = {
        "wkv": ("layers", "batch", "heads", None, None),
        "shift_t": ("layers", "batch", None, None),
        "shift_c": ("layers", "batch", None, None),
        "len": (),
    }
    return cache, specs


def decode_fn(params, cfg: ModelConfig, cache, tokens, *, rules=None):
    x = params["embed"][tokens].astype(jnp.dtype(cfg.dtype))
    x = constrain(x, ("batch", None, None), rules)

    def scan_body(x, inp):
        bp, wkv, sh_t, sh_c = inp
        state = {"wkv": wkv, "shift_t": sh_t, "shift_c": sh_c}
        x, new = block_apply(cfg, bp, x, state=state, rules=rules)
        return x, (new["wkv"], new["shift_t"], new["shift_c"])

    x, (wkv, sh_t, sh_c) = jax.lax.scan(
        scan_body,
        x,
        (params["blocks"], cache["wkv"], cache["shift_t"], cache["shift_c"]),
        unroll=L.scan_unroll(),
    )
    x = L.rmsnorm(x, params["ln_f"], cfg.norm_eps)
    logits = x @ params["unembed"]
    return logits, {
        "wkv": wkv,
        "shift_t": sh_t,
        "shift_c": sh_c,
        "len": cache["len"] + 1,
    }
