"""Decoder-only transformer LM (dense / MoE / VLM backbone).

Layers are **stacked and scanned**: block params carry a leading (L, ...)
axis and the forward pass is one ``lax.scan`` over it — the compiled HLO is
depth-independent, which is what keeps 94-layer × 512-device lowering
tractable.  ``jax.checkpoint`` (remat) wraps the scanned block with a
dots-saveable policy.

Three entry points per model (matching the assigned shape kinds):
  * ``loss_fn``     — teacher-forced CE + MoE aux (train_4k);
  * ``prefill_fn``  — forward only, returns logits (prefill_32k);
  * ``decode_fn``   — one token against a (L, B, Smax, KV, Dh) cache
                      (decode_32k / long_500k).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.moe import moe_apply, moe_init
from repro.models.sharding import constrain, gather_params, spec_tree_of


def _remat_policy():
    return L.remat_policy()


# -- init -------------------------------------------------------------------------


def _block_init(key, cfg: ModelConfig):
    ks = jax.random.split(key, 4)
    p, s = {}, {}
    p["ln1"], s["ln1"] = L.rmsnorm_init(cfg.d_model)
    p["attn"], s["attn"] = L.attention_init(ks[0], cfg)
    p["ln2"], s["ln2"] = L.rmsnorm_init(cfg.d_model)
    if cfg.is_moe:
        p["moe"], s["moe"] = moe_init(ks[1], cfg)
    else:
        p["mlp"], s["mlp"] = L.mlp_init(ks[1], cfg)
    return p, s


def init_lm(key, cfg: ModelConfig):
    dt = jnp.dtype(cfg.dtype)
    k_emb, k_blocks, k_out = jax.random.split(key, 3)
    layer_keys = jax.random.split(k_blocks, cfg.n_layers)
    blocks_p = jax.vmap(lambda k: _block_init(k, cfg)[0])(layer_keys)
    _, blocks_s = _block_init(layer_keys[0], cfg)
    blocks_s = jax.tree.map(
        lambda ax: ("layers",) + ax, blocks_s, is_leaf=lambda x: isinstance(x, tuple)
    )
    params = {
        "embed": (
            jax.random.normal(k_emb, (cfg.vocab, cfg.d_model), jnp.float32)
            * cfg.d_model**-0.5
        ).astype(dt),
        "blocks": blocks_p,
        "ln_f": L.rmsnorm_init(cfg.d_model)[0],
        "unembed": (
            jax.random.normal(k_out, (cfg.d_model, cfg.vocab), jnp.float32)
            * cfg.d_model**-0.5
        ).astype(dt),
    }
    specs = {
        "embed": ("vocab", "embed"),
        "blocks": blocks_s,
        "ln_f": ("embed",),
        "unembed": ("embed", "vocab"),
    }
    return params, specs


# -- forward ------------------------------------------------------------------------


_BLOCK_SPEC_CACHE: dict = {}


def _block_specs(cfg: ModelConfig):
    if cfg.name not in _BLOCK_SPEC_CACHE:
        _BLOCK_SPEC_CACHE[cfg.name] = spec_tree_of(
            lambda: _block_init(jax.random.key(0), cfg)
        )
    return _BLOCK_SPEC_CACHE[cfg.name]


def _block_apply(cfg: ModelConfig, bp, x, positions, rules, attn_impl):
    bp = gather_params(bp, _block_specs(cfg), rules)  # JIT-FSDP regather
    h, _ = L.attention_apply(
        cfg,
        bp["attn"],
        L.rmsnorm(x, bp["ln1"], cfg.norm_eps),
        positions,
        causal=True,
        window=cfg.window,
        attn_impl=attn_impl,
    )
    x = x + h
    x = constrain(x, ("batch", "seq", None), rules)
    y = L.rmsnorm(x, bp["ln2"], cfg.norm_eps)
    if cfg.is_moe:
        m, aux = moe_apply(cfg, bp["moe"], y, rules)
    else:
        m, aux = L.mlp_apply(bp["mlp"], y), jnp.float32(0)
    x = x + m
    return constrain(x, ("batch", "seq", None), rules), aux


def forward(
    params,
    cfg: ModelConfig,
    tokens,  # (B, S) int32
    *,
    rules=None,
    attn_impl: str = "blockwise",
    extra_embeds: Optional[jnp.ndarray] = None,  # VLM patch prefix (B, P, d)
):
    """Returns (logits (B, S_total, vocab), aux_loss)."""
    x = params["embed"][tokens].astype(jnp.dtype(cfg.dtype))
    if extra_embeds is not None:
        x = jnp.concatenate([extra_embeds.astype(x.dtype), x], axis=1)
    S = x.shape[1]
    positions = jnp.arange(S)
    x = constrain(x, ("batch", "seq", None), rules)

    raw_block = functools.partial(
        _block_apply, cfg, positions=positions, rules=rules, attn_impl=attn_impl
    )
    block = jax.checkpoint(
        lambda bp, x: raw_block(bp, x), policy=_remat_policy(), prevent_cse=False
    )

    def scan_body(x, bp):
        x, aux = block(bp, x)
        return x, aux

    x, auxes = jax.lax.scan(scan_body, x, params["blocks"], unroll=L.scan_unroll())
    x = L.rmsnorm(x, params["ln_f"], cfg.norm_eps)
    logits = x @ params["unembed"]
    logits = constrain(logits, ("batch", "seq", "vocab"), rules)
    return logits, auxes.sum()


def loss_fn(
    params, cfg: ModelConfig, batch, *, rules=None, attn_impl="blockwise",
    aux_coef: float = 0.01,
):
    """batch = {'tokens': (B,S), 'labels': (B,S)} -> scalar loss."""
    logits, aux = forward(
        params, cfg, batch["tokens"], rules=rules, attn_impl=attn_impl,
        extra_embeds=batch.get("patch_embeds"),
    )
    labels = batch["labels"]
    if logits.shape[1] != labels.shape[1]:  # VLM prefix: score token tail only
        logits = logits[:, logits.shape[1] - labels.shape[1] :]
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    gold = jnp.take_along_axis(
        logits.astype(jnp.float32), labels[..., None], axis=-1
    )[..., 0]
    ce = (lse - gold).mean()
    return ce + aux_coef * aux


# -- decode -------------------------------------------------------------------------


def init_decode_cache(cfg: ModelConfig, batch: int, max_len: int):
    k, v, spec = L.make_kv_cache(cfg, batch, max_len, cfg.n_layers)
    return {"k": k, "v": v, "len": jnp.int32(0)}, {
        "k": spec,
        "v": spec,
        "len": (),
    }


def decode_fn(
    params,
    cfg: ModelConfig,
    cache,
    tokens,  # (B, 1) int32 -- the new token
    *,
    rules=None,
):
    """One decode step.  Returns (logits (B, 1, vocab), new_cache)."""
    x = params["embed"][tokens].astype(jnp.dtype(cfg.dtype))
    x = constrain(x, ("batch", None, None), rules)
    pos = cache["len"]
    positions = jnp.full((1,), pos, jnp.int32)

    def scan_body(x, inp):
        bp, k_l, v_l = inp
        bp = gather_params(bp, _block_specs(cfg), rules)
        h, new_kv = L.attention_apply(
            cfg,
            bp["attn"],
            L.rmsnorm(x, bp["ln1"], cfg.norm_eps),
            positions,
            causal=True,
            window=cfg.window,
            cache=(k_l, v_l, pos),
        )
        x = x + h
        y = L.rmsnorm(x, bp["ln2"], cfg.norm_eps)
        if cfg.is_moe:
            m, _ = moe_apply(cfg, bp["moe"], y, rules)
        else:
            m = L.mlp_apply(bp["mlp"], y)
        x = x + m
        return x, (new_kv[0], new_kv[1])

    x, (new_k, new_v) = jax.lax.scan(
        scan_body, x, (params["blocks"], cache["k"], cache["v"]),
        unroll=L.scan_unroll(),
    )
    x = L.rmsnorm(x, params["ln_f"], cfg.norm_eps)
    logits = x @ params["unembed"]
    new_cache = {"k": new_k, "v": new_v, "len": cache["len"] + 1}
    return logits, new_cache
