"""``repro.api`` — the single public surface of the solve system.

One config (:class:`SolveConfig`), one result schema (:class:`SolveResult`
/ :class:`BatchSolveResult`), one façade (:class:`SolverSession`) over all
backends (``spmd``, ``protocol_sim``, ``centralized``, ``sequential``),
with a compiled-plane cache (:class:`PlaneCache`) so warm repeat solves
reuse executables.

Quickstart::

    from repro.api import SolverSession, SolveConfig

    session = SolverSession(problem="vertex_cover",
                            config=SolveConfig(num_workers=8))
    r = session.solve(g)            # SolveResult
    r.stats.transfer_bytes_total    # typed SolveStats (no more dict keys)
    batch = session.solve_many(gs)  # BatchSolveResult
    session.cache_stats()           # warm/cold executable accounting

Durability::

    cfg = SolveConfig(checkpoint_dir="ckpt", checkpoint_every=4)
    SolverSession(config=cfg).solve(g)      # checkpoints every 4 chunks
    SolverSession.resume("ckpt")            # ... after a kill: bit-identical
    svc.checkpoint("ckpt"); SolveService.restore("ckpt")   # live service

``__all__`` below is the pinned public API — ``tests/test_arch_guard.py``
snapshots it, so additions/removals are deliberate, reviewed changes.
"""

from repro.api.backends import (
    Backend,
    BACKENDS,
    get_backend,
    known_backends,
)
from repro.api.cache import CacheStats, PlaneCache
from repro.api.config import SolveConfig
from repro.api.result import (
    BatchSolveResult,
    LaneStats,
    ServiceStats,
    SolveResult,
    SolveStats,
)
from repro.api.service import AsyncSolveService, SolveService, SolveTimeout
from repro.api.session import SolverSession, solve_stream_session
from repro.checkpoint.solve import CheckpointError, SolveCheckpoint

__all__ = [
    "AsyncSolveService",
    "Backend",
    "BACKENDS",
    "BatchSolveResult",
    "CacheStats",
    "CheckpointError",
    "LaneStats",
    "PlaneCache",
    "ServiceStats",
    "SolveCheckpoint",
    "SolveConfig",
    "SolveResult",
    "SolveService",
    "SolveStats",
    "SolveTimeout",
    "SolverSession",
    "get_backend",
    "known_backends",
    "solve_stream_session",
]
