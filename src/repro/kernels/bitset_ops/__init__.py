from repro.kernels.bitset_ops.ops import (
    default_interpret,
    degrees_auto,
    degrees_op,
    expand_stats_auto,
    expand_stats_op,
    kernels_native,
    max_degree_vertex,
)
from repro.kernels.bitset_ops.ref import (
    batched_degrees_ref,
    expand_stats_ref,
    max_degree_vertex_ref,
)

__all__ = [
    "default_interpret",
    "degrees_auto",
    "degrees_op",
    "expand_stats_auto",
    "expand_stats_op",
    "kernels_native",
    "max_degree_vertex",
    "batched_degrees_ref",
    "expand_stats_ref",
    "max_degree_vertex_ref",
]
