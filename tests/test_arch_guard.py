"""Architecture guard: the core solve plane must stay problem-generic.

The PR-3 refactor extracted the :class:`BranchingProblem` plugin protocol so
no module under ``src/repro/core/`` depends on a concrete problem's device
ops.  This test pins that invariant: the refactor cannot silently regress by
someone re-importing ``repro.problems.vertex_cover`` (or any other concrete
plugin's device module) from core.  Core may import the protocol
(``repro.problems.base``) and the name registry
(``repro.problems.registry``); the host sims (protocol_sim / centralized)
may keep using the sequential REFERENCE module, which predates and is
independent of the device plane.
"""

import ast
import pathlib

SRC = pathlib.Path(__file__).resolve().parent.parent / "src" / "repro"
CORE = SRC / "core"
PROBLEMS = SRC / "problems"

# concrete problem plugins core must never import
FORBIDDEN = {
    "repro.problems.vertex_cover",
    "repro.problems.max_clique",
    "repro.problems.mis",
}


def _imports_of(path: pathlib.Path):
    tree = ast.parse(path.read_text())
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for alias in node.names:
                yield alias.name
        elif isinstance(node, ast.ImportFrom) and node.module:
            yield node.module


def test_core_never_imports_a_concrete_problem():
    assert CORE.is_dir(), CORE
    offenders = {}
    for path in sorted(CORE.glob("*.py")):
        bad = [
            mod
            for mod in _imports_of(path)
            if mod in FORBIDDEN
            or any(mod.startswith(f + ".") for f in FORBIDDEN)
        ]
        if bad:
            offenders[path.name] = bad
    assert not offenders, (
        f"core modules import concrete problem plugins: {offenders} — "
        f"route through repro.problems.registry / repro.problems.base instead"
    )


def _module_level_imports_of(path: pathlib.Path):
    """Every import executed AT IMPORT TIME: the module body plus any
    statement block reachable from it (if/try/with/for/while, class bodies)
    — only function bodies are excluded, because only those defer execution.
    Relative imports are resolved against the file's package so ``from
    ..kernels import x`` is caught like its absolute spelling."""
    tree = ast.parse(path.read_text())
    # package of this module, e.g. src/repro/problems/base.py -> repro.problems
    parts = path.with_suffix("").parts
    pkg = list(parts[parts.index("repro"):-1] or ["repro"])

    def walk(nodes):
        for node in nodes:
            if isinstance(
                node, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)
            ):
                continue  # deferred execution: lazy imports live here
            if isinstance(node, ast.Import):
                for alias in node.names:
                    yield alias.name
            elif isinstance(node, ast.ImportFrom):
                if node.level:  # relative: resolve against the package
                    base = pkg[: len(pkg) - (node.level - 1)]
                    yield ".".join(base + ([node.module] if node.module else []))
                elif node.module:
                    yield node.module
            else:
                for child in ast.iter_child_nodes(node):
                    yield from walk([child])

    yield from walk(tree.body)


def test_reference_explore_path_never_imports_kernels_at_module_level():
    """The reference explore path must stay Pallas-free: importing
    ``repro.core`` / ``repro.problems`` (what every CPU-only solve touches)
    may not pull in ``repro.kernels`` — the fused impls reach the bitset
    kernels through function-level lazy imports only, so they load only if
    a fused plane actually runs."""
    offenders = {}
    for directory in (CORE, PROBLEMS):
        for path in sorted(directory.glob("*.py")):
            bad = [
                mod
                for mod in _module_level_imports_of(path)
                if mod == "repro.kernels" or mod.startswith("repro.kernels.")
            ]
            if bad:
                offenders[path.name] = bad
    assert not offenders, (
        f"module-level repro.kernels imports in the solve plane: {offenders}"
        f" — keep kernel imports lazy (inside the fused expand functions)"
    )


def test_core_resolves_problems_through_the_registry():
    """The engine's defaults come from the registry, not a hardcoded plugin:
    the default-problem constant lives in problems/, and core references it
    by import."""
    from repro.core import engine
    from repro.problems.registry import DEFAULT_PROBLEM, get_problem

    assert engine.DEFAULT_PROBLEM == DEFAULT_PROBLEM
    # and the registry resolves it to a real spec
    assert get_problem(DEFAULT_PROBLEM).name == DEFAULT_PROBLEM


# -- the public API surface ----------------------------------------------------

# The PR-4 redesign made `repro.api` THE public surface.  This snapshot pins
# it: adding or removing a name is a deliberate, reviewed change (update the
# list here AND the README quickstart), never an accidental side effect of a
# refactor.
PUBLIC_API = [
    "AsyncSolveService",
    "BACKENDS",
    "Backend",
    "BatchSolveResult",
    "CacheStats",
    "CheckpointError",
    "LaneStats",
    "PlaneCache",
    "ServiceStats",
    "SolveCheckpoint",
    "SolveConfig",
    "SolveResult",
    "SolveService",
    "SolveStats",
    "SolveTimeout",
    "SolverSession",
    "get_backend",
    "known_backends",
    "solve_stream_session",
]


def test_public_api_snapshot():
    import repro.api as api

    assert sorted(api.__all__) == PUBLIC_API, (
        "repro.api.__all__ drifted from the pinned public-API snapshot — "
        "if intentional, update tests/test_arch_guard.py and the README"
    )
    # every advertised name must actually resolve
    for name in api.__all__:
        assert hasattr(api, name), f"repro.api.__all__ lists missing {name!r}"


def test_backend_registry_covers_the_advertised_backends():
    from repro.api import known_backends

    assert known_backends() == [
        "centralized", "protocol_sim", "sequential", "spmd"
    ]


# Field snapshot of the one public config: adding/removing/renaming a knob is
# a deliberate, reviewed change (update here AND the README perf-knobs
# section), never a refactor side effect.  Defaults are pinned for the knobs
# whose silent flip would change what every solve runs (hot-path selection).
SOLVE_CONFIG_FIELDS = [
    "admission",
    "batch_size",
    "capacity",
    "checkpoint_dir",
    "checkpoint_every",
    "chunk_rounds",
    "codec",
    "compact_threshold",
    "donate_k",
    "explore_impl",
    "frontier_spill",
    "k",
    "lane_stall_chunks",
    "lanes",
    "latency",
    "max_rounds",
    "max_ticks",
    "mode",
    "num_workers",
    "packed_status",
    "policy",
    "queue_cap_per_p",
    "request_timeout_s",
    "resume_from",
    "seed",
    "send_metadata",
    "service_lanes",
    "skip_empty_transfer",
    "spill_codec",
    "spill_watermarks",
    "steps_per_round",
    "tenant_max_lanes",
    "transfer_impl",
    "use_mesh",
    "use_priority_queue",
]


def test_solve_config_field_snapshot():
    import dataclasses

    from repro.api import SolveConfig

    assert sorted(
        f.name for f in dataclasses.fields(SolveConfig)
    ) == SOLVE_CONFIG_FIELDS, (
        "SolveConfig fields drifted from the pinned snapshot — if "
        "intentional, update tests/test_arch_guard.py and the README"
    )
    cfg = SolveConfig()
    # the fused exploration plane is the default hot path; the reference
    # path stays reachable for A/B
    assert cfg.explore_impl == "fused"
    assert cfg.transfer_impl == "sparse"


# Field snapshots of the typed stats schema (PR-7): every backend writes into
# ONE SolveStats shape, so renaming/dropping a counter is a schema change every
# consumer sees — pin it like the config.
SOLVE_STATS_FIELDS = [
    "center_bytes",
    "checkpoints_written",
    "cold_bytes_peak",
    "control_bytes_per_round",
    "failed_requests",
    "max_depth",
    "msg_bytes",
    "msg_count",
    "overflow",
    "overflow_count",
    "pruned",
    "readmitted_tasks",
    "resumed_from",
    "service",
    "solutions",
    "spilled_tasks",
    "termination_cancelled",
    "ticks",
    "total_bytes",
    "transfer_bytes_per_round",
    "transfer_bytes_total",
    "transfer_rounds",
]
SERVICE_STATS_FIELDS = [
    "deadline_hit",
    "faults_injected",
    "faults_recovered",
    "lane",
    "lanes_quarantined",
    "plane",
    "residency_s",
    "retries",
    "wait_s",
    "wall_deadline_hit",
]
LANE_STATS_FIELDS = ["chunk_calls", "lane_chunks", "live_lane_chunks", "occupancy"]


def test_stats_schema_field_snapshots():
    import dataclasses

    from repro.api import LaneStats, ServiceStats, SolveStats

    for cls, want in (
        (SolveStats, SOLVE_STATS_FIELDS),
        (ServiceStats, SERVICE_STATS_FIELDS),
        (LaneStats, LANE_STATS_FIELDS),
    ):
        assert sorted(f.name for f in dataclasses.fields(cls)) == want, (
            f"{cls.__name__} fields drifted from the pinned snapshot — if "
            f"intentional, update tests/test_arch_guard.py and the README"
        )


def test_stats_dict_access_shim_warns_and_delegates():
    """Legacy ``r.stats["overflow"]`` keeps working through the deprecation
    shim — but warns, and ``to_dict()`` stays the warning-free export."""
    import warnings

    import pytest

    from repro.api import SolveStats

    s = SolveStats(overflow_count=3)
    with pytest.warns(DeprecationWarning, match="dict-style access"):
        assert s["overflow_count"] == 3
    with pytest.warns(DeprecationWarning):
        assert "overflow" in s and s.get("missing", 7) == 7
    with warnings.catch_warnings():
        warnings.simplefilter("error")  # attribute + to_dict never warn
        assert s.overflow_count == 3
        assert s.to_dict()["overflow_count"] == 3
