"""Discrete-event simulation of the full asynchronous GemPBA protocol.

This is the *faithful* reproduction of the paper's MPI design (Algorithms 3-6
plus the §3.3 termination safety mechanisms), used to (a) validate the
protocol properties the paper claims — failure-free work requests, no lost
tasks, safe termination under message races — and (b) actually SOLVE vertex
cover instances with P virtual workers, producing the message/byte statistics
reported in the benchmarks.  The TPU SPMD engine (superstep.py) is the
hardware adaptation; this simulator is the semantics reference it is checked
against (same best value as the sequential solver, zero failed requests).

Time model: integer ticks.  Per tick every worker (1) drains its inbox
(updateWorkerIPC, Alg. 4), (2) expands ONE search-tree node, (3) services its
waiting list (updatePendingTasks).  Messages take ``latency`` ticks to arrive
(configurable; >1 exposes the §3.3 in-flight-task termination race).  The
center drains its inbox each tick (Alg. 3 loop).
"""

from __future__ import annotations

import dataclasses
import heapq
from collections import defaultdict
from typing import Any, Optional

import numpy as np

from repro.core.center import CenterState, Status
from repro.core.encoding import Task, make_codec
from repro.core.task_tree import TaskTree
from repro.graphs.bitgraph import BitGraph, mask_full
from repro.problems import base as problems_base
from repro.problems.registry import DEFAULT_PROBLEM, get_problem

CENTER = 0
INT_BYTES = 4  # "each message is small as it only requires sending a single integer"


@dataclasses.dataclass
class SimStats:
    msg_count: dict = dataclasses.field(default_factory=lambda: defaultdict(int))
    msg_bytes: dict = dataclasses.field(default_factory=lambda: defaultdict(int))
    failed_requests: int = 0  # must stay 0: the paper's key guarantee
    tasks_transferred: int = 0
    nodes_expanded: int = 0
    ticks: int = 0
    termination_cancelled: int = 0

    @property
    def total_bytes(self) -> int:
        return sum(self.msg_bytes.values())

    @property
    def center_bytes(self) -> int:
        """Bytes that flowed through the center (control plane only)."""
        return sum(
            b for tag, b in self.msg_bytes.items() if not tag.startswith("work")
        )


@dataclasses.dataclass(order=True)
class _Msg:
    deliver_at: int
    seq: int
    src: int = dataclasses.field(compare=False)
    dst: int = dataclasses.field(compare=False)
    tag: str = dataclasses.field(compare=False)
    data: Any = dataclasses.field(compare=False)


class _Network:
    def __init__(self, latency: int, stats: SimStats, codec):
        self.latency = latency
        self.stats = stats
        self.codec = codec
        self._q: list[_Msg] = []
        self._seq = 0

    def send(self, src: int, dst: int, tag: str, data: Any, now: int) -> None:
        self.stats.msg_count[tag] += 1
        nbytes = self.codec.record_bytes if tag == "work" else INT_BYTES
        self.stats.msg_bytes[tag] += nbytes
        self._seq += 1
        heapq.heappush(
            self._q, _Msg(now + self.latency, self._seq, src, dst, tag, data)
        )

    def deliver(self, dst: int, now: int) -> list[_Msg]:
        out = []
        rest = []
        while self._q and self._q[0].deliver_at <= now:
            m = heapq.heappop(self._q)
            (out if m.dst == dst else rest).append(m)
        for m in rest:
            heapq.heappush(self._q, m)
        return out

    def pending_for(self, dst: int) -> bool:
        return any(m.dst == dst for m in self._q)

    def in_flight(self) -> int:
        return len(self._q)


class _Worker:
    """One virtual worker process (Alg. 4 + the DFS exploration loop).

    Branching and bounding are resolved through the problem's
    :class:`~repro.problems.base.BranchingProblem` host callables
    (``branch_once_host`` / ``host_task_bound`` / ``host_terminal_value``),
    so the simulator runs any registry problem with host plumbing — all
    values are in the plugin's INTERNAL minimization sense.  ``g`` is the
    problem's host VIEW (e.g. the complement graph for MIS)."""

    def __init__(
        self, wid: int, g: BitGraph, net: _Network, stats: SimStats, mode, k,
        problem: problems_base.BranchingProblem,
    ):
        self.wid = wid
        self.g = g
        self.net = net
        self.stats = stats
        self.mode = mode
        self.k = k
        self.problem = problem
        self.tree = TaskTree()
        # DFS stack entries: [task, children(list of Task), next_child_idx]
        self.stack: list[list] = []
        self.local_best: int = problems_base.initial_bound(problem, g, mode, k)
        self.local_best_sol: Optional[np.ndarray] = None
        self.global_best_seen: int = self.local_best
        self.waiting: list[int] = []  # processes center told us to feed
        self.nb_sent_tasks = 0  # §3.3 safety mechanism 1
        self.announced_available = False
        self.requested_once = False  # to assert failure-free single requests

    # -- state ----------------------------------------------------------------
    def is_idle(self) -> bool:
        return not self.stack and self.tree.is_empty()

    def bound(self) -> int:
        return min(self.local_best, self.global_best_seen)

    # -- Alg. 4: updateWorkerIPC ------------------------------------------------
    def update_ipc(self, now: int) -> None:
        for m in self.net.deliver(self.wid, now):
            if m.tag == "bestval_update":
                if m.data < self.global_best_seen:
                    self.global_best_seen = m.data
            elif m.tag == "send_work":
                self.waiting.append(m.data)
            elif m.tag == "work":
                # can only be received when no task is running
                task: Task = m.data if isinstance(m.data, Task) else self._decode(m.data)
                self.net.send(self.wid, CENTER, "started_running", self.wid, now)
                self.net.send(self.wid, m.src, "work_ack", None, now)
                self._start_task(task)
                self.announced_available = False
            elif m.tag == "work_ack":
                self.nb_sent_tasks -= 1
            elif m.tag == "term_probe":
                quiescent = self.is_idle() and self.nb_sent_tasks == 0
                self.net.send(self.wid, CENTER, "term_ack", quiescent, now)

    def _decode(self, rec) -> Task:
        return self.net.codec.decode(np.asarray(rec), self.g)

    def _start_task(self, task: Task) -> None:
        assert self.is_idle(), f"worker {self.wid} got work while busy"
        self.tree = TaskTree()
        self.tree.set_root(task, depth=task.depth)
        self.stack = [[task, None, 0]]

    # -- exploration: one node expansion per tick --------------------------------
    def explore_step(self, now: int) -> None:
        if not self.stack:
            return
        frame = self.stack[-1]
        task, children, idx = frame
        if children is None:
            # first visit: bound check, then branch (Alg. 2 / Alg. 9)
            self.stats.nodes_expanded += 1
            spec = self.problem
            if spec.host_task_bound(self.g, task.mask, task.sol_mask) >= self.bound():
                self._finish_node(task)
                return
            kids, terminal = spec.branch_once_host(self.g, task.mask, task.sol_mask)
            if terminal is not None:
                tval = int(spec.host_terminal_value(self.g, terminal[0], terminal[1]))
                if tval < self.bound():
                    self.local_best = tval
                    self.local_best_sol = terminal[1]
                    # paper: inform center when a better value is found
                    self.net.send(self.wid, CENTER, "bestval_update", tval, now)
                self._finish_node(task)
                return
            child_tasks = [
                Task(mask=c[0], sol_mask=c[1], depth=task.depth + 1) for c in kids
            ]
            # Alg. 2 line 9 / Alg. 5: register BEFORE exploring
            self.tree.register_child_instances(child_tasks, task)
            frame[1] = child_tasks
            frame[2] = 0
            return
        if idx < len(children):
            frame[2] += 1
            child = children[idx]
            # Alg. 5 'search': claim unless it was donated meanwhile
            if self.tree.try_claim(child):
                self.stack.append([child, None, 0])
            return
        self._finish_node(task)

    def _finish_node(self, task: Task) -> None:
        self.tree.finish(task)
        self.stack.pop()

    # -- Alg. 4: updatePendingTasks ----------------------------------------------
    def update_pending(self, now: int) -> None:
        while self.waiting and self.tree.pending_count() > 0:
            dest = self.waiting.pop(0)
            payload = self.tree.pop_highest_priority()
            rec = payload  # Task object; byte size accounted via codec
            self.net.send(self.wid, dest, "work", rec, now)
            self.nb_sent_tasks += 1
            self.stats.tasks_transferred += 1

    def maybe_announce(self, now: int) -> None:
        if self.is_idle() and not self.announced_available:
            assert not self.requested_once or True
            self.net.send(self.wid, CENTER, "available", self.wid, now)
            self.announced_available = True

    def metadata_value(self) -> int:
        """Paper §3.2: size of the largest unexplored instance (one integer).
        We use n - depth of the top-priority task as the size proxy."""
        d = self.tree.top_priority_depth()
        return 0 if d is None else max(self.g.n - d, 1)


@dataclasses.dataclass
class SimResult:
    best_size: int
    best_sol: Optional[np.ndarray]
    stats: SimStats
    ticks: int


def run_protocol_sim(
    g: BitGraph,
    num_workers: int,
    latency: int = 1,
    policy: str = "random",
    codec_name: str = "optimized",
    mode: str = "bnb",
    k: Optional[int] = None,
    send_metadata: bool = False,
    max_ticks: int = 2_000_000,
    seed: int = 0,
    problem=DEFAULT_PROBLEM,
) -> SimResult:
    """Run the full asynchronous protocol until center-verified termination.

    ``problem`` is any registry problem (or spec) with host plumbing — the
    workers explore its host view with its host bounds, so
    ``problem="max_clique"`` runs the same Algorithms 3-6 protocol on the
    clique brancher."""
    spec = problems_base.require_host_bounds(get_problem(problem))
    view = spec.host_view(g)
    stats = SimStats()
    codec = make_codec(codec_name, view.n, problem=spec)
    net = _Network(latency=latency, stats=stats, codec=codec)
    center = CenterState(num_workers=num_workers, policy=policy, seed=seed)
    workers = {
        i: _Worker(i, view, net, stats, mode, k, spec)
        for i in range(1, num_workers + 1)
    }

    # Startup (§3.5): the seed goes to worker 1 (Fig. 1) and the center
    # pre-builds every worker's waiting list with Algorithm 7 (max_b = 2 for
    # vertex cover), so the first tasks spawned approximate the equitable
    # depth-log_b(p) split.  Every non-seed worker starts ASSIGNED to its
    # Alg. 7 assigner -- no startup 'available' storm, no failed requests.
    from repro.core.waiting_list import build_waiting_lists

    seed_task = Task(
        mask=mask_full(view.n), sol_mask=np.zeros(view.W, np.uint32), depth=0
    )
    workers[1]._start_task(seed_task)
    wlists = build_waiting_lists(max_b=2, p=num_workers)
    for wid, lst in wlists.items():
        workers[wid].waiting = list(lst)
        for r in lst:
            center.status[r] = Status.ASSIGNED
            center.assigned_to[r] = wid
            workers[r].announced_available = True  # pinned, must not announce

    termination_probe: Optional[dict] = None
    now = 0
    while now < max_ticks:
        now += 1
        # ---- center loop (Alg. 3) ----
        for m in net.deliver(CENTER, now):
            if m.tag == "bestval_update":
                if center.offer_best(m.src, m.data):
                    for wid in workers:
                        net.send(CENTER, wid, "bestval_update", m.data, now)
            elif m.tag == "available":
                w = center.on_available(m.src)
                if w is not None:
                    net.send(CENTER, w, "send_work", m.src, now)
            elif m.tag == "started_running":
                pair = center.on_started_running(m.src)
                if pair is not None:
                    src, r = pair
                    net.send(CENTER, src, "send_work", r, now)
                if termination_probe is not None:
                    stats.termination_cancelled += 1
                    termination_probe = None  # §3.3: cancel termination
            elif m.tag == "metadata":
                center.on_metadata(m.src, m.data)
            elif m.tag == "term_ack":
                if termination_probe is not None:
                    if m.data:  # worker says it is truly quiescent
                        termination_probe["acks"].add(m.src)
                    else:
                        stats.termination_cancelled += 1
                        termination_probe = None
        # ---- termination detection (§3.3, safety mechanism 1) ----
        if center.all_idle():
            if termination_probe is None:
                termination_probe = {"acks": set()}
                for wid in workers:
                    net.send(CENTER, wid, "term_probe", None, now)
            elif len(termination_probe["acks"]) == num_workers and net.in_flight() == 0:
                break
        else:
            termination_probe = None

        # ---- fpt early stop: reaching the internal decision target ends the
        # exploration (<= k for minimization, >= k for negated maximization) --
        if (
            mode == "fpt"
            and center.best_val is not None
            and center.best_val <= spec.fpt_target(k)
        ):
            break

        # ---- workers ----
        for wid, wk in workers.items():
            wk.update_ipc(now)
            was_idle = wk.is_idle()
            wk.explore_step(now)
            wk.update_pending(now)
            if send_metadata and not wk.is_idle():
                net.send(wid, CENTER, "metadata", wk.metadata_value(), now)
            wk.maybe_announce(now)
            if was_idle and not wk.is_idle():
                pass  # started_running already sent on work receipt

    stats.ticks = now
    # collect the best solution: center knows the holder (§3.1) and fetches it
    # only once, after exploration finishes.  "found nothing acceptable" is
    # exactly "the internal best never improved on the seed bound" — the same
    # objective-adapter contract as the SPMD engine's result extraction.
    initial = problems_base.initial_bound(spec, view, mode, k)
    internal_best = initial
    best_sol = None
    for wk in workers.values():
        if wk.local_best < internal_best:
            internal_best = wk.local_best
            best_sol = wk.local_best_sol
    found = internal_best < initial
    best_size = int(spec.external_value(internal_best))
    if not found:
        best_sol = None
        if mode == "fpt":
            best_size = -1
    return SimResult(best_size, best_sol, stats, now)
