"""Grouped MoE dispatch: routing correctness and group invariance."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.launch.mesh import make_mesh_compat
from repro.models.moe import moe_apply, moe_init


def _cfg(E=8, K=2, cf=8.0):
    return ModelConfig(
        name=f"moe-test-{E}-{K}-{cf}", family="moe", n_layers=1, d_model=32,
        n_heads=2, n_kv_heads=2, d_ff=16, vocab=64, n_experts=E, top_k=K,
        capacity_factor=cf, dtype="float32",
    )


def test_group_invariance_with_ample_capacity():
    """With capacity >> demand nothing drops, so the G-grouped dispatch must
    equal the ungrouped (G=1) computation exactly.  (G is taken from the
    rules' _sizes; the mesh axes themselves are size-1 on CPU, so the
    constrain calls are trivial but still traced.)"""
    cfg = _cfg(cf=16.0)
    p, _ = moe_init(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (4, 8, 32))
    out1, aux1 = moe_apply(cfg, p, x, rules=None)  # G=1
    mesh = make_mesh_compat((1, 1), ("data", "model"))
    rules = {"batch": ("data",), "_sizes": {"data": 4}}
    with mesh:
        out4, aux4 = moe_apply(cfg, p, x, rules=rules)
    assert float(jnp.abs(out1 - out4).max()) < 1e-5
    assert abs(float(aux1) - float(aux4)) < 1e-5


def test_manual_two_expert_routing():
    """Force deterministic routing and check outputs against a hand einsum."""
    cfg = _cfg(E=2, K=1, cf=8.0)
    p, _ = moe_init(jax.random.key(0), cfg)
    # router sends feature<0 tokens to expert 0, else expert 1
    router = np.zeros((32, 2), np.float32)
    router[0, 0] = -100.0
    router[0, 1] = 100.0
    p["router"] = jnp.asarray(router)
    x = jax.random.normal(jax.random.key(1), (1, 6, 32))
    out, _ = moe_apply(cfg, p, x)
    eid = (np.asarray(x[0, :, 0]) > 0).astype(int)
    want = []
    for t in range(6):
        e = eid[t]
        h = jax.nn.silu(x[0, t] @ p["w1"][e]) * (x[0, t] @ p["w3"][e])
        want.append(h @ p["w2"][e])
    want = jnp.stack(want)
    assert float(jnp.abs(out[0] - want).max()) < 1e-4


def test_capacity_drops_dont_nan():
    cfg = _cfg(E=4, K=2, cf=0.1)  # absurdly tight capacity: most tokens drop
    p, _ = moe_init(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (2, 16, 32))
    out, aux = moe_apply(cfg, p, x)
    assert not bool(jnp.isnan(out).any())
    assert jnp.isfinite(aux)


def test_grad_flows():
    cfg = _cfg()
    p, _ = moe_init(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (2, 8, 32))

    def loss(p):
        out, aux = moe_apply(cfg, p, x)
        return jnp.sum(out**2) + 0.01 * aux

    g = jax.grad(loss)(p)
    assert all(jnp.isfinite(v).all() for v in jax.tree.leaves(g))
    assert float(jnp.abs(g["w1"]).max()) > 0
