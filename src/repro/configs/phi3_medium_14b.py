"""phi3-medium-14b [dense] — RoPE SwiGLU GQA.  40L d=5120 40H kv=10
d_ff=17920 vocab=100352.  [arXiv:2404.14219]"""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="phi3-medium-14b",
        family="dense",
        n_layers=40,
        d_model=5120,
        n_heads=40,
        n_kv_heads=10,
        d_ff=17_920,
        vocab=100_352,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="phi3-smoke",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=1,
        d_ff=192,
        vocab=512,
        dtype="float32",
    )
