"""Batched multi-instance solve plane: instances/sec vs a sequential loop.

For B in {1, 4, 16}: B independent G(n, p) instances solved (a) by a loop of
B single-instance solves, each through a FRESH session — every call builds
and jits its own chunk executable and pays its own per-chunk host syncs,
which was the only option before the instance axis (and the compiled-plane
cache) existed — and (b) by ONE ``session.solve_many`` call, which packs the
batch into padded (B, n, W) problem tensors behind a single compiled
executable and one host sync per chunk for the whole batch.

Per-instance ``best_size``/``best_sol`` are asserted bit-identical between
the two paths (the batched plane is an amortization, not an approximation).
Warm-plane reuse within one long-lived session is measured separately by
``benchmarks/session_warm.py``.

``run(smoke=True)`` shrinks the instances for the CI bench-smoke job and the
returned dict lands in BENCH_smoke.json (EXPERIMENTS.md §C tracks the
full-size numbers).
"""

from __future__ import annotations

import time

from repro.api import SolveConfig, SolverSession
from repro.graphs.generators import erdos_renyi

BATCH_SIZES = (1, 4, 16)


def _bench_one(B: int, *, n: int, p: float, workers: int, spr: int) -> dict:
    graphs = [erdos_renyi(n, p, seed) for seed in range(B)]
    cfg = SolveConfig(num_workers=workers, steps_per_round=spr)

    t0 = time.perf_counter()
    # fresh session (fresh PlaneCache) per solve = the pre-batching baseline
    singles = [SolverSession(config=cfg).solve(g) for g in graphs]
    seq_wall = time.perf_counter() - t0

    batch = SolverSession(config=cfg).solve_many(graphs)
    batch_wall = batch.wall_s

    for s, b in zip(singles, batch.results):
        assert s.best_size == b.best_size
        same_sol = (s.best_sol is None and b.best_sol is None) or (
            (s.best_sol == b.best_sol).all()
        )
        assert same_sol and s.rounds == b.rounds
    return dict(
        B=B,
        seq_wall_s=round(seq_wall, 3),
        batch_wall_s=round(batch_wall, 3),
        seq_inst_per_s=round(B / seq_wall, 3),
        batch_inst_per_s=round(B / batch_wall, 3),
        speedup=round(seq_wall / batch_wall, 2),
    )


# the CI gate: the B=16 batched plane must hold at least this speedup over
# the sequential loop (acceptance bar; measured headroom is ~5x above it)
MIN_SPEEDUP_B16 = 2.0


def run(smoke: bool = False) -> dict:
    n, p, workers, spr = (24, 0.3, 4, 8) if smoke else (40, 0.28, 6, 8)
    rows = [
        _bench_one(B, n=n, p=p, workers=workers, spr=spr)
        for B in BATCH_SIZES
    ]
    if smoke:  # the CI gate; full-size local runs just report
        top = rows[-1]
        assert top["B"] == 16 and top["speedup"] >= MIN_SPEEDUP_B16, (
            f"batched plane regressed: B=16 speedup {top['speedup']}x "
            f"< {MIN_SPEEDUP_B16}x (benchmark-gated CI, EXPERIMENTS.md §C)"
        )
    print(f"G({n}, {p}), {workers} workers/instance, "
          f"steps_per_round={spr}; sequential loop = B x fresh-session solve")
    print(f"{'B':>4} {'seq inst/s':>12} {'batch inst/s':>13} {'speedup':>8}")
    for r in rows:
        print(f"{r['B']:>4} {r['seq_inst_per_s']:>12} "
              f"{r['batch_inst_per_s']:>13} {r['speedup']:>7}x")
    return dict(
        problem="vertex_cover", n=n, p=p, workers=workers,
        steps_per_round=spr, rows=rows,
    )


if __name__ == "__main__":
    run()
