"""Exploration-plane throughput: fused vs reference, in nodes expanded/sec.

The paper's premise is that workers spend their time *branching*; this
benchmark measures exactly that hot path and A/Bs the two ``explore_impl``
paths (EXPERIMENTS.md §F):

* **reference** — per-task callables (task_bound / branch_once /
  child_bound as separate vmapped sweeps) + the full-capacity ``top_k``
  frontier pop every round;
* **fused**     — the plugin's one-pass batched ``expand_tasks`` (shared
  degrees/popcounts, arithmetic child bounds, Pallas bitset kernel on TPU)
  + the cheap depth-major frontier pop.

Both planes are warmed first (compile excluded), solve the SAME instances,
and are asserted bit-identical (best, rounds, nodes) — the speedup is pure
hot-path efficiency, not a different search.

``run(smoke=True)`` is in the CI bench-smoke set and GATES the win: fused
must expand at least ``MIN_FUSED_SPEEDUP``× more nodes/sec than reference
on the gate shape (max-clique — no reduction fixpoint inside the expansion,
so the measurement isolates the expand+frontier costs this plane attacks).
A vertex-cover row is recorded alongside for the trajectory.
"""

from __future__ import annotations

import time

from repro.api import SolveConfig, SolverSession
from repro.graphs.generators import erdos_renyi

# acceptance bar (ISSUE 5): fused >= 1.3x reference nodes/sec on the smoke
# gate shape, recorded in BENCH_smoke.json per PR.
MIN_FUSED_SPEEDUP = 1.3


def _throughput(problem, graphs, impl, *, workers, spr, lanes, repeats):
    """Warm a plane for ``impl``, run ``repeats`` timed sweeps over
    ``graphs`` and keep the FASTEST (the sweep least disturbed by the host —
    every sweep does identical device work, so min-time is the honest
    throughput on a shared CI box); returns (nodes_per_sec, [results])."""
    session = SolverSession(
        problem=problem,
        config=SolveConfig(
            num_workers=workers,
            steps_per_round=spr,
            lanes=lanes,
            explore_impl=impl,
        ),
    )
    for g in graphs:  # cold pass: trace + compile once per shape
        session.solve(g)
    best_wall, results = float("inf"), []
    for _ in range(repeats):
        t0 = time.perf_counter()
        sweep = [session.solve(g) for g in graphs]
        wall = time.perf_counter() - t0
        if wall < best_wall:
            best_wall, results = wall, sweep
    nodes = sum(r.nodes_expanded for r in results)
    return nodes / max(best_wall, 1e-9), results


def _ab(problem, graphs, *, workers, spr, lanes, repeats):
    out = {}
    for impl in ("reference", "fused"):
        nps, results = _throughput(
            problem, graphs, impl,
            workers=workers, spr=spr, lanes=lanes, repeats=repeats,
        )
        out[impl] = (nps, results)
    # same search, bit for bit — the speedup is hot-path cost, not pruning
    for a, b in zip(out["reference"][1], out["fused"][1]):
        assert (a.best_size, a.rounds, a.nodes_expanded) == (
            b.best_size, b.rounds, b.nodes_expanded
        ), "fused explore diverged from reference"
        assert (a.best_sol == b.best_sol).all()
    return out["reference"][0], out["fused"][0]


def run(smoke: bool = False) -> dict:
    # engine-default explore knobs (steps_per_round=32, lanes=1): the gate
    # measures the path real solves run, not a cherry-picked shape
    if smoke:
        clique_kw = dict(n=40, p=0.5, seeds=(0, 1), workers=4, spr=32,
                         lanes=1, repeats=4)
        vc_kw = dict(n=28, p=0.3, seeds=(0,), workers=4, spr=32,
                     lanes=1, repeats=4)
    else:
        clique_kw = dict(n=64, p=0.4, seeds=(0, 1, 2), workers=8, spr=32,
                         lanes=1, repeats=5)
        vc_kw = dict(n=44, p=0.25, seeds=(0, 1), workers=8, spr=32,
                     lanes=1, repeats=5)

    rows = {}
    for problem, kw in (("max_clique", clique_kw), ("vertex_cover", vc_kw)):
        graphs = [erdos_renyi(kw["n"], kw["p"], s) for s in kw["seeds"]]
        ref_nps, fused_nps = _ab(
            problem, graphs, workers=kw["workers"], spr=kw["spr"],
            lanes=kw["lanes"], repeats=kw["repeats"],
        )
        speedup = fused_nps / max(ref_nps, 1e-9)
        rows[problem] = dict(
            n=kw["n"], p=kw["p"], instances=len(graphs),
            workers=kw["workers"], steps_per_round=kw["spr"],
            lanes=kw["lanes"],
            reference_nodes_per_s=round(ref_nps),
            fused_nodes_per_s=round(fused_nps),
            fused_speedup=round(speedup, 2),
        )
        print(f"{problem:13s} G({kw['n']}, {kw['p']}) x{len(graphs)}: "
              f"reference {ref_nps:10.0f} nodes/s | fused {fused_nps:10.0f} "
              f"nodes/s | {speedup:.2f}x")

    gate = rows["max_clique"]["fused_speedup"]
    if smoke:  # the CI gate; full-size local runs just report
        assert gate >= MIN_FUSED_SPEEDUP, (
            f"fused exploration plane regressed: only {gate:.2f}x the "
            f"reference nodes/sec (< {MIN_FUSED_SPEEDUP}x; benchmark-gated "
            f"CI, EXPERIMENTS.md §F)"
        )
    return dict(problem="max_clique", gate_speedup=gate, shapes=rows)


if __name__ == "__main__":
    run()
