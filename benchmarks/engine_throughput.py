"""SPMD superstep engine: expansion throughput + collective-traffic budget
per round vs worker count (the TPU-adaptation counterpart of Table 1)."""

from __future__ import annotations

import time

from repro.core.engine import solve
from repro.graphs.generators import erdos_renyi
from repro.problems.sequential import solve_sequential


def run(csv=True):
    g = erdos_renyi(48, 0.25, 2)
    want, _, _ = solve_sequential(g)
    rows = []
    for p in (2, 4, 8):
        for policy in (True, False):
            r = solve(g, num_workers=p, steps_per_round=8, policy_priority=policy)
            assert r.best_size == want
            rows.append(
                dict(
                    workers=p,
                    policy="priority" if policy else "round_robin",
                    rounds=r.rounds,
                    nodes=r.nodes_expanded,
                    transfers=r.tasks_transferred,
                    nodes_per_round=round(r.nodes_expanded / r.rounds, 1),
                    control_B_per_round=r.control_bytes_per_round,
                    transfer_B_per_round=r.transfer_bytes_per_round,
                )
            )
    if csv:
        keys = list(rows[0].keys())
        print(",".join(keys))
        for r in rows:
            print(",".join(str(r[k]) for k in keys))
    return rows


if __name__ == "__main__":
    run()
