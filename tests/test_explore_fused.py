"""The fused exploration plane: bit-identical to the reference, by contract.

Four guarantees from the fused-plane PR:

1. **Golden bit-identity** — ``explore_impl="fused"`` reproduces the pinned
   pre-fused vertex-cover goldens exactly (solo, fpt, solve_many incl.
   padding + compaction), and ``"reference"`` still does too: the knob
   switches implementations, never the search.
2. **Cross-problem identity** — max-clique and MIS full results (best,
   sol, rounds, nodes, transfers) agree between the two impls on random
   graphs, solo and batched.
3. **Expansion-level identity** — per problem, the hand-fused
   ``expand_tasks`` matches the composed per-task callables on random
   task batches (every engine-consumed field), and the composed default
   itself matches the callables it wraps — so third-party plugins without
   a fused impl are covered too.
4. **Cheap frontier pop** — ``pop_deepest_cheap`` is state- and
   lane-identical to the reference ``top_k`` pop on random frontiers.
"""

import dataclasses
import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.api import SolveConfig, SolverSession
from repro.api.backends import config_from_legacy
from repro.core.frontier import make_frontier, pop_deepest, pop_deepest_cheap, push_many
from repro.graphs.generators import erdos_renyi
from repro.problems import base as B
from repro.problems.registry import get_problem

GOLDEN = json.loads(
    (pathlib.Path(__file__).parent / "golden_vc.json").read_text()
)

IMPLS = ("fused", "reference")


def _check_golden(r, want: dict):
    got = {
        "best_size": int(r.best_size),
        "best_sol": [int(w) for w in np.asarray(r.best_sol, np.uint32)],
        "rounds": int(r.rounds),
        "nodes_expanded": int(r.nodes_expanded),
        "tasks_transferred": int(r.tasks_transferred),
        "transfer_rounds": int(r.stats.transfer_rounds),
        "transfer_bytes_total": int(r.stats.transfer_bytes_total),
        "overflow": bool(r.stats.overflow),
    }
    assert got == want


def _session(legacy_kw: dict, impl: str, **extra) -> SolverSession:
    return SolverSession(
        problem="vertex_cover",
        config=config_from_legacy(**legacy_kw, **extra).replace(
            explore_impl=impl
        ),
    )


# -- 1. both impls against the pinned pre-fused goldens ------------------------


@pytest.mark.parametrize("impl", IMPLS)
@pytest.mark.parametrize("label", sorted(GOLDEN["solo"]))
def test_solo_golden_bit_identical(impl, label):
    case = GOLDEN["solo"][label]
    gkw = case["graph"]
    g = erdos_renyi(gkw["n"], gkw["p"], gkw["seed"])
    r = _session(case["solve_kw"], impl).solve(g)
    _check_golden(r, case["result"])


@pytest.mark.parametrize("impl", IMPLS)
def test_fpt_golden_bit_identical(impl):
    case = GOLDEN["fpt"]
    gkw = case["graph"]
    g = erdos_renyi(gkw["n"], gkw["p"], gkw["seed"])
    r = _session({"num_workers": 4}, impl, mode="fpt", k=case["k"]).solve(g)
    _check_golden(r, case["result"])


@pytest.mark.parametrize("impl", IMPLS)
def test_solve_many_golden_bit_identical(impl):
    """The batched plane under both impls, including the padding (mixed n in
    one W bucket) and host-side compaction paths."""
    case = GOLDEN["many"]
    graphs = [
        erdos_renyi(n, case["p"], case["seed0"] + i)
        for i, n in enumerate(case["sizes"])
    ]
    batch = _session(case["solve_kw"], impl).solve_many(graphs)
    assert batch.compactions == case["compactions"]
    assert [[W, n_max, idxs] for W, n_max, idxs in batch.buckets] == case["buckets"]
    for r, want in zip(batch.results, case["results"]):
        _check_golden(r, want)


# -- 2. clique / MIS: fused == reference on full results -----------------------


def _result_key(r):
    return (
        r.best_size,
        tuple(int(w) for w in np.asarray(r.best_sol, np.uint32)),
        r.rounds,
        r.nodes_expanded,
        r.tasks_transferred,
        int(r.stats.overflow_count),
    )


@pytest.mark.parametrize("problem", ["max_clique", "mis"])
def test_clique_mis_fused_matches_reference_solo_and_fpt(problem):
    for seed in (0, 1, 2):
        g = erdos_renyi(16, 0.4, seed)
        keys = {}
        for impl in IMPLS:
            cfg = SolveConfig(
                num_workers=4, steps_per_round=8, explore_impl=impl
            )
            keys[impl] = _result_key(
                SolverSession(problem=problem, config=cfg).solve(g)
            )
        assert keys["fused"] == keys["reference"], (problem, seed)
    # decision mode too (the fpt early-exit runs through the same plane)
    g = erdos_renyi(16, 0.45, 11)
    keys = {}
    for impl in IMPLS:
        cfg = SolveConfig(
            num_workers=4, mode="fpt", k=3, explore_impl=impl
        )
        r = SolverSession(problem=problem, config=cfg).solve(g)
        keys[impl] = (r.best_size, r.rounds, r.nodes_expanded)
    assert keys["fused"] == keys["reference"]


@pytest.mark.parametrize("problem", ["max_clique", "mis"])
def test_clique_mis_fused_matches_reference_solve_many(problem):
    """Mixed sizes in one W bucket -> the padding AND compaction paths run
    under both impls; results must agree lane for lane."""
    sizes = [14, 10, 16, 12]
    graphs = [erdos_renyi(n, 0.4, 3 + i) for i, n in enumerate(sizes)]
    batches = {}
    for impl in IMPLS:
        cfg = SolveConfig(
            num_workers=4, steps_per_round=4, compact_threshold=0.6,
            explore_impl=impl,
        )
        batches[impl] = SolverSession(problem=problem, config=cfg).solve_many(
            graphs
        )
    assert batches["fused"].compactions == batches["reference"].compactions
    for a, b in zip(batches["fused"].results, batches["reference"].results):
        assert _result_key(a) == _result_key(b)


def test_plugin_without_fused_impl_runs_on_composed_default():
    """A problem that ships NO hand-fused expand_tasks must still run under
    explore_impl="fused" (composed default) and match the reference."""
    bare = dataclasses.replace(get_problem("max_clique"), expand_tasks=None)
    g = erdos_renyi(15, 0.4, 5)
    keys = {}
    for impl in IMPLS:
        cfg = SolveConfig(num_workers=4, steps_per_round=8, explore_impl=impl)
        keys[impl] = _result_key(
            SolverSession(problem=bare, config=cfg).solve(g)
        )
    assert keys["fused"] == keys["reference"]


# -- 3. expansion-level identity on random task batches ------------------------


def _random_task_batch(n, W, L, seed):
    """Random (masks, sols) with the engine invariant mask ∩ sol = ∅."""
    rng = np.random.default_rng(seed)
    masks = rng.integers(0, 2**32, size=(L, W), dtype=np.uint32)
    sols = rng.integers(0, 2**32, size=(L, W), dtype=np.uint32)
    rem = n % 32
    if rem:
        top = np.uint32((1 << rem) - 1)
        masks[:, -1] &= top
        sols[:, -1] &= top
    sols &= ~masks  # disjoint, like every reachable engine task
    # include an empty-mask (terminal) lane so that path is exercised
    masks[0] = 0
    return jnp.asarray(masks), jnp.asarray(sols)


@pytest.mark.parametrize("problem", ["vertex_cover", "max_clique", "mis"])
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_hand_fused_expand_matches_composed(problem, seed):
    """Every engine-consumed ExpandResult field agrees between the hand-
    fused one-pass impl and the composed per-task callables: task bounds and
    the branch step on every lane, child bounds on non-terminal lanes (the
    only lanes whose child bounds the engine reads)."""
    spec = get_problem(problem)
    assert spec.expand_tasks is not None
    g = erdos_renyi(21, 0.35, 100 + seed)
    data = B.make_data(spec, g)
    masks, sols = _random_task_batch(g.n, g.W, 6, seed)
    fused = spec.expand_tasks(data, masks, sols)
    composed = B.compose_expand_tasks(spec)(data, masks, sols)
    assert (fused.bound == composed.bound).all()
    for name in composed.step._fields:
        assert (
            getattr(fused.step, name) == getattr(composed.step, name)
        ).all(), name
    live = ~np.asarray(composed.step.is_terminal)
    assert (np.asarray(fused.left_bound)[live]
            == np.asarray(composed.left_bound)[live]).all()
    assert (np.asarray(fused.right_bound)[live]
            == np.asarray(composed.right_bound)[live]).all()


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000))
def test_composed_default_matches_per_task_callables(seed):
    """The composed default IS the per-task callables: property-checked over
    random graphs/batches for a problem picked by the seed."""
    rng = np.random.default_rng(seed)
    spec = get_problem(
        ("vertex_cover", "max_clique", "mis")[int(rng.integers(3))]
    )
    n = int(rng.integers(8, 40))
    g = erdos_renyi(n, float(rng.uniform(0.1, 0.5)), seed)
    data = B.make_data(spec, g)
    L = int(rng.integers(1, 5))
    masks, sols = _random_task_batch(g.n, g.W, L, seed + 1)
    ex = B.compose_expand_tasks(spec)(data, masks, sols)
    for i in range(L):
        m, s = masks[i], sols[i]
        assert int(ex.bound[i]) == int(spec.task_bound(data, m, s))
        step = spec.branch_once(data, m, s)
        assert (ex.step.left_mask[i] == step.left_mask).all()
        assert (ex.step.right_sol[i] == step.right_sol).all()
        assert bool(ex.step.is_terminal[i]) == bool(step.is_terminal)
        assert int(ex.left_bound[i]) == int(
            spec.child_bound(data, step.left_mask, step.left_sol)
        )
        assert int(ex.right_bound[i]) == int(
            spec.child_bound(data, step.right_mask, step.right_sol)
        )


def test_overflow_count_surfaces_in_solve_result():
    """Frontier saturation reaches the public result schema: an undersized
    capacity reports the exact number of dropped tasks (and the bool flag);
    engine-sized capacity stays at zero."""
    g = erdos_renyi(18, 0.35, 2)
    ok = SolverSession(
        problem="vertex_cover",
        config=SolveConfig(num_workers=4, steps_per_round=8),
    ).solve(g)
    assert ok.stats.overflow_count == 0 and not ok.stats.overflow
    starved = SolverSession(
        problem="vertex_cover",
        config=SolveConfig(num_workers=4, steps_per_round=8, capacity=2),
    ).solve(g)
    assert starved.stats.overflow
    assert starved.stats.overflow_count > 0


# -- 4. cheap frontier pop == reference top_k pop ------------------------------


@settings(max_examples=40, deadline=None)
@given(
    st.lists(st.integers(0, 60), min_size=0, max_size=24),
    st.integers(1, 4),
)
def test_pop_deepest_cheap_matches_top_k(depths, count):
    """Same valid lanes (tasks, order, flags) and same post-pop active set,
    for every frontier content and lane count."""
    W = 2
    f = make_frontier(32, W)
    if depths:
        k = len(depths)
        f = push_many(
            f,
            jnp.tile(jnp.arange(1, k + 1, dtype=jnp.uint32)[:, None], (1, W)),
            jnp.zeros((k, W), jnp.uint32),
            jnp.asarray(depths, jnp.int32),
            jnp.ones((k,), bool),
        )
    ref = pop_deepest(f, count)
    cheap = pop_deepest_cheap(f, count)
    assert (ref[0].active == cheap[0].active).all()
    rv, cv = np.asarray(ref[4]), np.asarray(cheap[4])
    assert (rv == cv).all()
    for a, b in zip(ref[1:4], cheap[1:4]):
        assert (np.asarray(a)[rv] == np.asarray(b)[rv]).all()
