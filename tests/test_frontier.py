"""Frontier push/pop properties (hypothesis): never loses or duplicates."""

import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, strategies as st

from repro.core.frontier import (
    make_frontier,
    pop_deepest,
    pop_shallowest,
    push_many,
)

W = 2


def _push(f, depth_vals):
    k = len(depth_vals)
    masks = jnp.tile(jnp.arange(1, k + 1, dtype=jnp.uint32)[:, None], (1, W))
    sols = jnp.zeros((k, W), jnp.uint32)
    depths = jnp.asarray(depth_vals, jnp.int32)
    valid = jnp.ones((k,), bool)
    return push_many(f, masks, sols, depths, valid)


def test_push_pop_deepest():
    f = make_frontier(8, W)
    f = _push(f, [3, 1, 5])
    f, masks, sols, depths, valid = pop_deepest(f, 2)
    assert valid.all()
    assert sorted(np.asarray(depths).tolist()) == [3, 5]
    assert int(f.pending()) == 1


def test_pop_shallowest():
    f = make_frontier(8, W)
    f = _push(f, [3, 1, 5])
    f, m, s, d, valid = pop_shallowest(f)
    assert bool(valid) and int(d) == 1
    assert int(f.pending()) == 2


def test_pop_empty_invalid():
    f = make_frontier(4, W)
    f, m, s, d, valid = pop_shallowest(f)
    assert not bool(valid)
    f, masks, sols, depths, valid = pop_deepest(f, 2)
    assert not bool(valid.any())


def test_overflow_flag():
    f = make_frontier(2, W)
    f = _push(f, [1, 2])
    assert not bool(f.overflow)
    f = _push(f, [3])
    assert bool(f.overflow)
    assert int(f.pending()) == 2  # dropped, not corrupted


def test_overflow_counts_every_dropped_push():
    """Saturation is never silent: ``dropped`` counts the exact number of
    lost tasks, cumulatively across pushes."""
    f = make_frontier(3, W)
    f = _push(f, [1, 2])
    assert int(f.dropped) == 0
    f = _push(f, [5, 6, 7])  # one slot free -> two dropped
    assert int(f.dropped) == 2 and bool(f.overflow)
    f = _push(f, [8])  # full -> one more dropped
    assert int(f.dropped) == 3
    assert int(f.pending()) == 3
    # the survivors are the FIRST valid pushes in order (5 took the slot)
    _, _, _, depths, valid = pop_deepest(f, 3)
    assert valid.all()
    assert sorted(np.asarray(depths).tolist()) == [1, 2, 5]


def test_push_pop_at_exact_capacity():
    """Behavior AT capacity is well-defined: a full frontier accepts zero
    pushes (counted), popping frees slots, and the freed slots take new
    pushes without disturbing survivors."""
    f = make_frontier(2, W)
    f = _push(f, [4, 9])
    assert int(f.pending()) == 2  # full
    f = _push(f, [7])
    assert int(f.dropped) == 1  # rejected at capacity
    f, _, _, d, v = pop_deepest(f, 1)
    assert bool(v.all()) and int(d[0]) == 9
    f = _push(f, [7])  # freed slot accepts again, nothing further dropped
    assert int(f.dropped) == 1 and int(f.pending()) == 2
    _, _, _, depths, valid = pop_deepest(f, 2)
    assert valid.all()
    assert sorted(np.asarray(depths).tolist()) == [4, 7]


@settings(max_examples=50, deadline=None)
@given(
    st.lists(
        st.one_of(
            st.tuples(st.just("push"), st.lists(st.integers(0, 100), min_size=1, max_size=4)),
            st.tuples(st.just("pop_deep"), st.integers(1, 3)),
            st.tuples(st.just("pop_shallow"), st.just(0)),
        ),
        min_size=1,
        max_size=40,
    )
)
def test_multiset_conservation(ops):
    """The frontier behaves as a multiset of depths: pushes add, pops remove
    the correct extremum, nothing is lost while capacity is respected."""
    cap = 32
    f = make_frontier(cap, W)
    model = []  # reference multiset of depths
    for op, arg in ops:
        if op == "push":
            take = arg[: max(0, cap - len(model))]
            f = _push(f, arg)
            model.extend(take)
        elif op == "pop_deep":
            f, _, _, depths, valid = pop_deepest(f, arg)
            got = sorted(
                int(d) for d, v in zip(np.asarray(depths), np.asarray(valid)) if v
            )
            want = sorted(model, reverse=True)[: len(got)]
            assert got == sorted(want)
            for d in got:
                model.remove(d)
        else:
            f, _, _, d, valid = pop_shallowest(f)
            if model:
                assert bool(valid) and int(d) == min(model)
                model.remove(int(d))
            else:
                assert not bool(valid)
        assert int(f.pending()) == len(model)


def test_batched_views_are_per_instance():
    """The instance-axis wrappers act on each stacked frontier independently
    (same results as looping the per-instance ops)."""
    from repro.core.frontier import (
        pending_per_worker,
        pop_deepest_b,
        pop_k_shallowest_b,
        push_many_b,
    )

    f0 = _push(make_frontier(8, W), [3, 1, 5])
    f1 = _push(make_frontier(8, W), [2, 7])
    stacked = jax.tree.map(lambda a, b: jnp.stack([a, b]), f0, f1)
    assert np.asarray(pending_per_worker(stacked)).tolist() == [3, 2]

    s2, masks, sols, depths, valid = pop_deepest_b(stacked, 1)
    assert np.asarray(depths)[:, 0].tolist() == [5, 7]
    assert np.asarray(pending_per_worker(s2)).tolist() == [2, 1]

    s3, _, _, depths, valid = pop_k_shallowest_b(
        stacked, 2, jnp.asarray([2, 1], jnp.int32)
    )
    assert np.asarray(depths)[0].tolist() == [1, 3]
    assert np.asarray(valid).tolist() == [[True, True], [True, False]]

    s4 = push_many_b(
        s3,
        jnp.zeros((2, 1, W), jnp.uint32),
        jnp.zeros((2, 1, W), jnp.uint32),
        jnp.full((2, 1), 9, jnp.int32),
        jnp.asarray([[True], [False]]),
    )
    assert np.asarray(pending_per_worker(s4)).tolist() == [2, 1]
