"""WKV6 chunked kernel vs the exact scan oracle + decode-step consistency."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.wkv6 import wkv6, wkv6_decode_step, wkv6_op, wkv6_ref

RNG = np.random.default_rng(3)


def mk(B, T, H, K, V):
    f = lambda *s: jnp.asarray(RNG.standard_normal(s) * 0.5, jnp.float32)
    r, k = f(B, T, H, K), f(B, T, H, K)
    v = f(B, T, H, V)
    w = jnp.asarray(RNG.uniform(0.2, 3.0, (B, T, H, K)), jnp.float32)
    d = jnp.exp(-jnp.exp(-w))
    u = f(H, K) * 0.6
    s0 = f(B, H, K, V) * 0.4
    return r, k, v, d, u, s0


@pytest.mark.parametrize(
    "B,T,H,K,V,chunk",
    [
        (2, 64, 2, 16, 16, 16),
        (1, 128, 4, 32, 32, 32),
        (2, 96, 1, 8, 24, 32),
        (1, 32, 2, 64, 64, 8),
        (1, 64, 3, 16, 48, 64),  # single chunk == whole sequence
    ],
)
def test_kernel_matches_scan(B, T, H, K, V, chunk):
    r, k, v, d, u, s0 = mk(B, T, H, K, V)
    oref, sref = wkv6_ref(r, k, v, d, u, s0)
    oker, sker = wkv6(r, k, v, d, u, s0, chunk=chunk)
    assert float(jnp.abs(oref - oker).max()) < 3e-4
    assert float(jnp.abs(sref - sker).max()) < 3e-4


def test_no_initial_state():
    r, k, v, d, u, _ = mk(1, 48, 2, 16, 16)
    oref, sref = wkv6_ref(r, k, v, d, u, None)
    oker, sker = wkv6(r, k, v, d, u, None, chunk=16)
    assert float(jnp.abs(oref - oker).max()) < 2e-4


def test_ragged_via_op_padding():
    """wkv6_op pads T to a chunk multiple with identity decays."""
    r, k, v, d, u, s0 = mk(2, 50, 2, 16, 16)
    oref, sref = wkv6_ref(r, k, v, d, u, s0)
    oker, sker = wkv6_op(r, k, v, d, u, s0, impl="pallas", chunk=16)
    assert oker.shape == oref.shape
    assert float(jnp.abs(oref - oker).max()) < 2e-4
    assert float(jnp.abs(sref - sker).max()) < 2e-4


def test_decode_step_chains_to_scan():
    """Running T single decode steps == the full recurrence."""
    B, T, H, K, V = 1, 12, 2, 8, 8
    r, k, v, d, u, s0 = mk(B, T, H, K, V)
    oref, sref = wkv6_ref(r, k, v, d, u, s0)
    S = s0
    outs = []
    for t in range(T):
        o, S = wkv6_decode_step(r[:, t], k[:, t], v[:, t], d[:, t], u, S)
        outs.append(o[:, None])
    got = jnp.concatenate(outs, axis=1)
    assert float(jnp.abs(oref - got).max()) < 1e-5
    assert float(jnp.abs(sref - S).max()) < 1e-5


def test_chunk_invariance():
    r, k, v, d, u, s0 = mk(1, 64, 2, 16, 16)
    o16, s16 = wkv6(r, k, v, d, u, s0, chunk=16)
    o32, s32 = wkv6(r, k, v, d, u, s0, chunk=32)
    assert float(jnp.abs(o16 - o32).max()) < 2e-4
