"""``SolveConfig``: every tuning knob of every backend, validated once.

Before this layer, ``engine.solve`` / ``engine.solve_many`` / the two
discrete-event simulators each grew their own ~15-keyword sprawl, and the
knob sets drifted (``mesh`` accepted by one, ``compact_threshold`` by the
other).  ``SolveConfig`` is the frozen superset: one immutable, hashable
dataclass that

* validates once at construction (enum knobs against their registries,
  integer ranges, mode/k coupling) and fails with the list of valid values;
* round-trips through JSON (``to_json``/``from_json``, ``save``/``load``)
  so a solve is reproducible from a config file — the ``launch.solve
  --config / --dump-config`` flow;
* is the compiled-plane cache key material: equal configs mean reusable
  executables (see :mod:`repro.api.cache`).

Backends read the subset they understand; unknown-to-a-backend knobs are
simply inert there (that is what kills the kwargs drift).
"""

from __future__ import annotations

import dataclasses
import json
from typing import Optional, Union

_MODES = ("bnb", "fpt")
_POLICIES = ("priority", "random")
_ADMISSIONS = ("fifo", "priority")


@dataclasses.dataclass(frozen=True)
class SolveConfig:
    """Frozen superset of all solve-plane tuning knobs.

    SPMD engine knobs mirror :func:`repro.core.engine.solve` /
    ``solve_many``; ``latency`` onward configure the discrete-event
    simulator backends (``protocol_sim`` / ``centralized``).  ``policy``
    replaces the old ``policy_priority`` bool and doubles as the simulator
    center's policy name.
    """

    # -- SPMD engine ----------------------------------------------------------
    num_workers: int = 8
    steps_per_round: int = 32
    lanes: int = 1
    policy: str = "priority"
    codec: str = "optimized"
    packed_status: bool = True
    skip_empty_transfer: bool = True
    transfer_impl: str = "sparse"
    # exploration hot path: "fused" = one-pass batched expand_tasks + cheap
    # depth-major frontier pop (bit-identical, faster); "reference" = the
    # per-task callables + full-capacity top_k kept for A/B and goldens.
    explore_impl: str = "fused"
    donate_k: int = 1
    chunk_rounds: int = 16
    mode: str = "bnb"
    # fpt decision target: one int, or (solve_many) one per instance
    k: Optional[Union[int, tuple]] = None
    max_rounds: int = 200_000
    capacity: Optional[int] = None
    compact_threshold: float = 0.25
    use_mesh: bool = False
    # -- hierarchical frontier memory (repro.core.spill) ----------------------
    # spill the device frontier to a codec-compressed host cold tier instead
    # of dropping tasks at saturation; (low, high) watermarks are fractions
    # of the hot capacity, and spill_codec picks the §4.3 record encoding
    frontier_spill: bool = False
    spill_watermarks: tuple = (0.5, 0.9)
    spill_codec: str = "optimized"
    # -- session admission (submit()/flush() via serving.SolveBatcher) --------
    batch_size: int = 8
    # -- continuous-batching service (SolverSession.serve / SolveService) -----
    # lanes per live plane: freed lanes re-admit queued instances in place
    service_lanes: int = 8
    # queue order: "fifo" = strict submission order; "priority" = by the
    # request's (priority desc, deadline asc, submit seq) key
    admission: str = "priority"
    # per-tenant cap on simultaneously occupied lanes (None = no fairness cap)
    tenant_max_lanes: Optional[int] = None
    # -- robustness (repro.faults + the service's self-healing) ---------------
    # wall-clock budget per request (None = none): queued or on-lane past
    # this age, the request resolves to a typed SolveTimeout carrying the
    # partial anytime result — an awaited solve can never hang forever.
    # Measured on the service's injectable clock (like deadline_s).
    request_timeout_s: Optional[float] = None
    # stall watchdog: a live lane whose occupant makes no superstep progress
    # for this many consecutive chunks is quarantined and its instance
    # re-admitted from the center's tracked placement
    lane_stall_chunks: int = 4
    # -- durability (checkpoint/resume via repro.checkpoint.solve) ------------
    # directory for periodic SolveCheckpoints (None = no checkpointing);
    # written atomically every `checkpoint_every` chunks (solo/solve_many)
    # or service steps, at the host-sync boundary
    checkpoint_dir: Optional[str] = None
    checkpoint_every: int = 8
    # resume a previous solve: a checkpoint dir (latest step) or one
    # step_<N> subdir; the trajectory-config fingerprint must match
    resume_from: Optional[str] = None
    # -- discrete-event simulator backends ------------------------------------
    latency: int = 1
    seed: int = 0
    send_metadata: bool = False
    max_ticks: int = 2_000_000
    queue_cap_per_p: int = 1000
    use_priority_queue: bool = True

    def __post_init__(self):
        if isinstance(self.k, list):
            object.__setattr__(self, "k", tuple(self.k))
        if isinstance(self.spill_watermarks, list):
            object.__setattr__(
                self, "spill_watermarks", tuple(self.spill_watermarks)
            )
        self._validate()

    # -- validation (once, here — not scattered across engines) ---------------

    def _validate(self) -> None:
        def choice(name, value, valid):
            if value not in valid:
                raise ValueError(
                    f"SolveConfig.{name}={value!r}; valid: {', '.join(valid)}"
                )

        choice("mode", self.mode, _MODES)
        choice("policy", self.policy, _POLICIES)
        choice("admission", self.admission, _ADMISSIONS)
        # impl names live with the engine (one source of truth — the config
        # can never accept a value the superstep rejects, or vice versa);
        # codec names live in the encoding registry.  Same fail-helpfully
        # contract as the problem registry, all imported lazily.
        from repro.core.superstep import EXPLORE_IMPLS, TRANSFER_IMPLS

        choice("transfer_impl", self.transfer_impl, TRANSFER_IMPLS)
        choice("explore_impl", self.explore_impl, EXPLORE_IMPLS)
        from repro.core.encoding import make_codec

        make_codec(self.codec, 1)
        make_codec(self.spill_codec, 1)
        wm = self.spill_watermarks
        if (
            not isinstance(wm, tuple)
            or len(wm) != 2
            or not all(isinstance(x, (int, float)) for x in wm)
            or not 0 < wm[0] < wm[1] <= 1
        ):
            raise ValueError(
                f"SolveConfig.spill_watermarks must be (low, high) fractions "
                f"with 0 < low < high <= 1, got {wm!r}"
            )
        for name in (
            "num_workers", "steps_per_round", "lanes", "donate_k",
            "chunk_rounds", "max_rounds", "batch_size", "service_lanes",
            "checkpoint_every", "max_ticks", "queue_cap_per_p",
            "lane_stall_chunks",
        ):
            v = getattr(self, name)
            if not isinstance(v, int) or isinstance(v, bool) or v < 1:
                raise ValueError(f"SolveConfig.{name} must be an int >= 1, got {v!r}")
        if self.latency < 1:
            raise ValueError(f"SolveConfig.latency must be >= 1, got {self.latency!r}")
        if self.capacity is not None and self.capacity < 1:
            raise ValueError(f"SolveConfig.capacity must be None or >= 1")
        if self.tenant_max_lanes is not None and self.tenant_max_lanes < 1:
            raise ValueError(
                "SolveConfig.tenant_max_lanes must be None or >= 1"
            )
        if self.request_timeout_s is not None and not (
            isinstance(self.request_timeout_s, (int, float))
            and not isinstance(self.request_timeout_s, bool)
            and self.request_timeout_s > 0
        ):
            raise ValueError(
                f"SolveConfig.request_timeout_s must be None or a positive "
                f"number of seconds, got {self.request_timeout_s!r}"
            )
        if not 0 <= self.compact_threshold <= 1:
            raise ValueError(
                f"SolveConfig.compact_threshold must be in [0, 1], "
                f"got {self.compact_threshold!r}"
            )
        if self.mode == "fpt" and self.k is None:
            raise ValueError("SolveConfig: mode='fpt' requires k")
        for name in ("checkpoint_dir", "resume_from"):
            v = getattr(self, name)
            if v is not None and not isinstance(v, str):
                raise ValueError(
                    f"SolveConfig.{name} must be None or a path string, "
                    f"got {v!r}"
                )

    # -- derived views ---------------------------------------------------------

    @property
    def policy_priority(self) -> bool:
        """The SPMD engine's bool view of ``policy``."""
        return self.policy == "priority"

    def solo_k(self) -> Optional[int]:
        """``k`` for a single-instance solve (per-instance tuples rejected)."""
        if isinstance(self.k, tuple):
            raise ValueError(
                "SolveConfig.k is a per-instance sequence; a solo solve "
                "needs one int"
            )
        return self.k

    # -- functional update -----------------------------------------------------

    def replace(self, **overrides) -> "SolveConfig":
        """A new validated config with ``overrides`` applied."""
        return dataclasses.replace(self, **overrides)

    # -- JSON round-trip -------------------------------------------------------

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        if isinstance(d["k"], tuple):
            d["k"] = list(d["k"])
        d["spill_watermarks"] = list(d["spill_watermarks"])
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "SolveConfig":
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = sorted(set(d) - known)
        if unknown:
            raise ValueError(
                f"unknown SolveConfig key(s): {', '.join(unknown)}; "
                f"known: {', '.join(sorted(known))}"
            )
        return cls(**d)

    def to_json(self, indent: int = 2) -> str:
        return json.dumps(self.to_dict(), indent=indent) + "\n"

    @classmethod
    def from_json(cls, text: str) -> "SolveConfig":
        return cls.from_dict(json.loads(text))

    def save(self, path) -> None:
        with open(path, "w") as f:
            f.write(self.to_json())

    @classmethod
    def load(cls, path) -> "SolveConfig":
        with open(path) as f:
            return cls.from_json(f.read())
