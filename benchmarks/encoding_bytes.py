"""Paper §4.3: bytes-per-task for the two serialization schemes.

basic     = (n+2)·W + 1 words  (adjacency rows travel with the task)
optimized = 2·W + 1 words      (n-bit mask of surviving vertices)

The table shows why the centralized scheduler collapses under the basic
encoding (every task crosses the wire twice) and why the optimized encoding
is what makes the fixed-shape TPU port natural.
"""

from __future__ import annotations

from repro.core.encoding import make_codec


def run(csv=True):
    rows = []
    for n in (128, 500, 700, 1000, 4096):
        opt = make_codec("optimized", n)
        bas = make_codec("basic", n)
        rows.append(
            dict(
                n=n,
                optimized_bytes=opt.record_bytes,
                basic_bytes=bas.record_bytes,
                ratio=round(bas.record_bytes / opt.record_bytes, 1),
            )
        )
    if csv:
        keys = list(rows[0].keys())
        print(",".join(keys))
        for r in rows:
            print(",".join(str(r[k]) for k in keys))
    return rows


if __name__ == "__main__":
    run()
