"""Production mesh construction (a FUNCTION so importing never touches jax
device state — required by the dry-run's device-count override ordering)."""

from __future__ import annotations

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (16, 16) = 256 chips, axes (data, model).
    Multi-pod:  (2, 16, 16) = 512 chips, axes (pod, data, model) — the pod
    axis composes with data for batch sharding (pure DP across pods; the
    only cross-pod collective is the gradient all-reduce, DCN-friendly)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(AxisType.Auto,) * len(axes)
    )


def make_solver_mesh(num_workers: int | None = None):
    """1-D mesh for the branching engine: one worker per device."""
    n = num_workers or len(jax.devices())
    return jax.make_mesh((n,), ("workers",), axis_types=(AxisType.Auto,))


def batch_axes_for(global_batch: int, mesh) -> tuple | None:
    """Largest prefix of (pod, data) that divides the global batch — decode
    shapes with batch 1 stay replicated, everything else shards."""
    names = [n for n in ("pod", "data") if n in mesh.axis_names]
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    chosen = []
    div = 1
    for n in names:
        if global_batch % (div * sizes[n]) == 0:
            chosen.append(n)
            div *= sizes[n]
    return tuple(chosen) if chosen else None
