"""Pallas bitset-degree kernel: shape sweep vs the jnp oracle."""

import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.graphs.generators import erdos_renyi
from repro.kernels.bitset_ops import (
    batched_degrees_ref,
    degrees_op,
    max_degree_vertex,
    max_degree_vertex_ref,
)


def _random_masks(n, W, T, seed):
    rng = np.random.default_rng(seed)
    masks = rng.integers(0, 2**32, size=(T, W), dtype=np.uint32)
    rem = n % 32
    if rem:
        masks[:, -1] &= np.uint32((1 << rem) - 1)
    return masks


@pytest.mark.parametrize(
    "n,T,block",
    [(32, 4, 2), (64, 16, 8), (100, 7, 4), (128, 32, 8), (257, 9, 8), (512, 24, 16)],
)
def test_kernel_matches_ref(n, T, block):
    g = erdos_renyi(n, 0.08, n * 31 + T)
    masks = jnp.asarray(_random_masks(n, g.W, T, T))
    adj = jnp.asarray(g.adj)
    got = degrees_op(adj, masks, block_tasks=block)
    want = batched_degrees_ref(adj, masks)
    assert (got == want).all()


def test_argmax_composition():
    g = erdos_renyi(96, 0.15, 5)
    masks = jnp.asarray(_random_masks(96, g.W, 10, 3))
    adj = jnp.asarray(g.adj)
    u1, d1 = max_degree_vertex(adj, masks)
    u2, d2 = max_degree_vertex_ref(adj, masks)
    assert (d1 == d2).all()
    # argmax ties may differ only if degrees tie; verify via degree equality
    deg = batched_degrees_ref(adj, masks)
    assert (jnp.take_along_axis(deg, u1[:, None], 1)[:, 0] == d2).all()


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10_000))
def test_property_random(seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(8, 200))
    T = int(rng.integers(2, 20))
    g = erdos_renyi(n, float(rng.uniform(0.02, 0.3)), seed)
    masks = jnp.asarray(_random_masks(n, g.W, T, seed + 1))
    got = degrees_op(jnp.asarray(g.adj), masks)
    want = batched_degrees_ref(jnp.asarray(g.adj), masks)
    assert (got == want).all()
