"""recurrentgemma-9b [hybrid] — RG-LRU + local attention, 1 attn : 2 rec.

38L d_model=4096 16H (GQA kv=1 -> MQA local attention) d_ff=12288
vocab=256000, window=2048, lru width = d_model.  [arXiv:2402.19427]
Sub-quadratic (RG-LRU state + windowed KV) => runs long_500k.
"""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-9b",
        family="hybrid",
        n_layers=38,
        d_model=4096,
        n_heads=16,
        n_kv_heads=1,
        d_ff=12288,
        vocab=256_000,
        pattern=("rec", "rec", "attn"),
        window=2048,
        d_rnn=4096,
        subquadratic=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-smoke",
        family="hybrid",
        n_layers=5,
        d_model=64,
        n_heads=4,
        n_kv_heads=1,
        d_ff=128,
        vocab=512,
        pattern=("rec", "rec", "attn"),
        window=16,
        d_rnn=64,
        subquadratic=True,
        dtype="float32",
    )
