"""Algorithm 7 (equitable-startup waiting lists): exactness + properties."""

from _hypothesis_compat import given, settings, strategies as st

from repro.core.waiting_list import (
    build_waiting_lists,
    max_startup_depth,
    startup_assignment,
)


def test_paper_example_binary():
    """max_b=2, p=8: process 1 feeds 2 (d=0), 3 (d=1), 5 (d=2); process 3
    feeds 7 (q = 1·2^2 + 3); etc — the q = j·b^d + p_i formula verbatim."""
    lists = build_waiting_lists(2, 8)
    assert lists[1] == [2, 3, 5]
    assert lists[2] == [4, 6]
    assert lists[3] == [7]
    assert lists[4] == [8]
    assert lists[5] == []


def test_figure3_ternary():
    """Fig. 3 (max_b=3): p1 sends to p2, p3, p4, ..., in that order."""
    lists = build_waiting_lists(3, 9)
    assert lists[1][:2] == [2, 3]  # j=1,2 at d=0
    assert 4 in lists[1]  # j=1 at d=1: 1·3+1
    assert 7 in lists[1]  # j=2 at d=1: 2·3+1
    assert lists[1] == [2, 3, 4, 7]


@settings(max_examples=60, deadline=None)
@given(st.integers(2, 5), st.integers(1, 300))
def test_every_process_assigned_exactly_once(max_b, p):
    lists = build_waiting_lists(max_b, p)
    assigned = [q for lst in lists.values() for q in lst]
    # every process except the seed (1) appears exactly once
    assert sorted(assigned + [1]) == list(range(1, p + 1))


@settings(max_examples=40, deadline=None)
@given(st.integers(2, 4), st.integers(1, 200))
def test_startup_assignment_is_permutation(max_b, p):
    order = startup_assignment(max_b, p)
    assert sorted(order) == list(range(1, p + 1))
    assert order[0] == 1  # the seed holder leads


@settings(max_examples=40, deadline=None)
@given(st.integers(2, 5), st.integers(2, 300))
def test_assigner_index_below_assignee(max_b, p):
    """Tasks flow 'downhill': q = j·b^d + p_i > p_i always."""
    lists = build_waiting_lists(max_b, p)
    for pi, lst in lists.items():
        for q in lst:
            assert q > pi


def test_max_depth():
    assert max_startup_depth(2, 1) == -1
    assert max_startup_depth(2, 8) == 3
    assert max_startup_depth(3, 9) == 2
