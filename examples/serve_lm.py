"""Batched serving with the semi-centralized request balancer (beyond-paper
integration): greedy decode on a smoke model + the balancer keeping 8
simulated replicas busy under a hot-shard arrival pattern.

  PYTHONPATH=src python examples/serve_lm.py
"""

import sys

sys.path.insert(0, "src")

import subprocess


def main():
    subprocess.run(
        [
            sys.executable, "-m", "repro.launch.serve",
            "--arch", "qwen1.5-0.5b", "--smoke",
            "--batch", "4", "--prompt-len", "12", "--gen", "24",
            "--replicas", "8",
        ],
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"},
        check=True,
    )


if __name__ == "__main__":
    main()
