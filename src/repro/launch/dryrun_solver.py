import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Dry-run of the PAPER'S OWN technique at production scale: the SPMD
superstep engine lowered with one worker per device on a 512-chip mesh.

Reports the same roofline terms as the LM cells, for the baseline engine
(3-int status rows, unconditional record all-gather — the straight port of
the protocol) and the optimized engine (bit-packed 1-int status + pmin bound,
record all-gather skipped on match-free rounds) — §Perf cell C.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun_solver [--n 1024] [--out f.json]
"""

import argparse
import json

import jax
import jax.numpy as jnp

from repro.core.superstep import build_superstep_fn, make_worker_state
from repro.graphs.bitgraph import n_words
from repro.graphs.generators import erdos_renyi
from repro.launch.analysis import collective_bytes, roofline
from repro.problems.vertex_cover import make_problem


def lower_engine(n: int, workers: int, *, packed_status, skip_empty_transfer,
                 steps_per_round=32, lanes=1, codec_pad=0):
    mesh = jax.make_mesh(
        (workers,), ("workers",), axis_types=(jax.sharding.AxisType.Auto,)
    )
    g = erdos_renyi(n, 4.0 / (n - 1), 0)
    problem = make_problem(jnp.asarray(g.adj), g.n)
    W = n_words(n)
    cap = 4 * n + 8 * lanes
    fn = build_superstep_fn(
        problem,
        num_workers=workers,
        steps_per_round=steps_per_round,
        lanes=lanes,
        transfer_pad_words=codec_pad,
        packed_status=packed_status,
        skip_empty_transfer=skip_empty_transfer,
        mesh=mesh,
    )
    state = jax.eval_shape(
        lambda: jax.vmap(lambda _: make_worker_state(cap, W, n + 1))(
            jnp.arange(workers)
        )
    )
    lowered = fn.lower(state)
    compiled = lowered.compile()
    cost = compiled.cost_analysis()
    coll = collective_bytes(compiled.as_text())
    mem = compiled.memory_analysis()
    flops = float(cost.get("flops", 0.0))
    rl = roofline(flops, float(cost.get("bytes accessed", 0.0)), coll["total"])
    return {
        "n": n,
        "workers": workers,
        "packed_status": packed_status,
        "skip_empty_transfer": skip_empty_transfer,
        "flops_per_dev": flops,
        "collectives": {k: v for k, v in coll.items() if k != "counts"},
        "collective_counts": coll["counts"],
        "temp_b": int(getattr(mem, "temp_size_in_bytes", 0)),
        "roofline": rl,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=1024)
    ap.add_argument("--workers", type=int, default=512)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    results = []
    for packed, skip, label in [
        (False, False, "baseline (3-int status, unconditional transfer)"),
        (True, False, "packed status word"),
        (True, True, "packed + skip-empty-transfer"),
    ]:
        r = lower_engine(
            args.n, args.workers, packed_status=packed, skip_empty_transfer=skip
        )
        r["label"] = label
        results.append(r)
        c = r["collectives"]
        print(
            f"{label:>50s}: coll_total={c['total']/2**10:.1f}KiB "
            f"(ag={c['all-gather']/2**10:.1f} ar={c['all-reduce']/2**10:.1f}) "
            f"counts={r['collective_counts']} temp={r['temp_b']/2**20:.1f}MiB",
            flush=True,
        )
    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)


if __name__ == "__main__":
    main()
