"""Griffin-style hybrid (recurrentgemma): RG-LRU recurrent blocks + local
attention, tiled in the config's ``pattern`` (recurrentgemma: rec,rec,attn).

The temporal stack is scanned per *group* (one pattern unit = one scan step;
remainder layers run unscanned), so heterogeneous layer kinds keep the
constant-size-HLO property.  The RG-LRU is a diagonal data-dependent linear
recurrence — ``jax.lax.associative_scan`` over (a_t, b_t) pairs, O(log T)
depth, no custom kernel needed (DESIGN.md §6); decode carries (B, d_rnn)
hidden + (B, conv_width-1, d_rnn) conv state + a window-sized KV cache for
the attention layers (O(window), which is why long_500k lowers).

RG-LRU (arXiv:2402.19427 eq. 3-4):
    r_t = σ(W_a x_t + b_a);  i_t = σ(W_x x_t + b_x)
    a_t = exp(c · r_t · (−softplus(Λ)))          (c = 8)
    h_t = a_t ⊙ h_{t−1} + sqrt(1 − a_t²) ⊙ (i_t ⊙ x_t)
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.sharding import constrain, gather_params, spec_tree_of

LRU_C = 8.0


# -- RG-LRU recurrent block -----------------------------------------------------


def _rec_init(key, cfg: ModelConfig):
    d = cfg.d_model
    dr = cfg.d_rnn or d
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 6)
    p, s = {}, {}
    p["w_gate"], s["w_gate"] = L.dense_init(ks[0], d, dr, "embed", "rnn", dt)
    p["w_in"], s["w_in"] = L.dense_init(ks[1], d, dr, "embed", "rnn", dt)
    p["conv"], s["conv"] = (
        jax.random.normal(ks[2], (cfg.conv_width, dr), jnp.float32) * 0.1
    ).astype(dt), ("conv", "rnn")
    p["w_a"], s["w_a"] = L.dense_init(ks[3], dr, dr, None, "rnn", dt)
    p["b_a"], s["b_a"] = jnp.zeros((dr,), jnp.float32), ("rnn",)
    p["w_x"], s["w_x"] = L.dense_init(ks[4], dr, dr, None, "rnn", dt)
    p["b_x"], s["b_x"] = jnp.zeros((dr,), jnp.float32), ("rnn",)
    # Λ init so that a ≈ uniform(0.9, 0.999) at r = 1 (paper appendix)
    lam = jnp.log(jnp.expm1(-jnp.log(jnp.linspace(0.9, 0.999, dr)) / LRU_C))
    p["lam"], s["lam"] = lam.astype(jnp.float32), ("rnn",)
    p["w_out"], s["w_out"] = L.dense_init(ks[5], dr, d, "rnn", "embed", dt)
    return p, s


def _causal_conv(x, w, state: Optional[jnp.ndarray]):
    """Depthwise causal conv over time.  x (B,T,dr), w (CW,dr);
    state (B, CW-1, dr) carries the tail for decode."""
    CW = w.shape[0]
    prev = (
        jnp.zeros((x.shape[0], CW - 1, x.shape[2]), x.dtype)
        if state is None
        else state.astype(x.dtype)
    )
    xp = jnp.concatenate([prev, x], axis=1)  # (B, T+CW-1, dr)
    out = sum(xp[:, i : i + x.shape[1]] * w[i] for i in range(CW))
    return out, xp[:, -(CW - 1) :]


def _rglru(x, r, i, lam, h0: Optional[jnp.ndarray]):
    """x,r,i (B,T,dr); h0 (B,dr) or None.  Returns (y, h_T)."""
    log_a = -LRU_C * jax.nn.softplus(lam) * r.astype(jnp.float32)  # ≤ 0
    a = jnp.exp(log_a)
    gated = (i * x).astype(jnp.float32)
    b = jnp.sqrt(jnp.clip(1.0 - jnp.exp(2.0 * log_a), 1e-12, 1.0)) * gated
    if x.shape[1] == 1 and h0 is not None:  # decode fast path
        h = a[:, 0] * h0 + b[:, 0]
        return h[:, None].astype(x.dtype), h
    if h0 is not None:
        b = b.at[:, 0].add(a[:, 0] * h0)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, b1 * a2 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h.astype(x.dtype), h[:, -1]


def rec_apply(cfg, p, x, *, state=None, rules=None):
    """Recurrent temporal block.  state = dict(h, conv) or None."""
    st = state or {}
    gate = jax.nn.gelu(x @ p["w_gate"])
    u = x @ p["w_in"]
    u, conv_state = _causal_conv(u, p["conv"], st.get("conv"))
    r = jax.nn.sigmoid(u @ p["w_a"] + p["b_a"])
    i = jax.nn.sigmoid(u @ p["w_x"] + p["b_x"])
    y, h = _rglru(u, r, i, p["lam"], st.get("h"))
    out = (y * gate) @ p["w_out"]
    return out, {"h": h, "conv": conv_state}


# -- block stack -------------------------------------------------------------------


def _group_init(key, cfg: ModelConfig):
    """One pattern unit (e.g. rec, rec, attn), each with its own norms+mlp."""
    p, s = {"sub": []}, {"sub": []}
    ks = jax.random.split(key, len(cfg.pattern))
    for kind, k in zip(cfg.pattern, ks):
        k1, k2 = jax.random.split(k)
        sp, ss = {}, {}
        sp["ln1"], ss["ln1"] = L.rmsnorm_init(cfg.d_model)
        if kind == "rec":
            sp["temporal"], ss["temporal"] = _rec_init(k1, cfg)
        else:
            sp["temporal"], ss["temporal"] = L.attention_init(k1, cfg)
        sp["ln2"], ss["ln2"] = L.rmsnorm_init(cfg.d_model)
        sp["mlp"], ss["mlp"] = L.gelu_mlp_init(k2, cfg)
        p["sub"].append(sp)
        s["sub"].append(ss)
    return p, s


_SUB_SPEC_CACHE: dict = {}


def _sub_specs(cfg, kind):
    key = (cfg.name, kind)
    if key not in _SUB_SPEC_CACHE:
        sub_cfg = dataclass_with_pattern(cfg, (kind,))
        specs = spec_tree_of(lambda: _group_init(jax.random.key(0), sub_cfg))
        _SUB_SPEC_CACHE[key] = specs["sub"][0]
    return _SUB_SPEC_CACHE[key]


def _sub_apply(cfg, kind, sp, x, positions, *, state=None, rules=None):
    sp = gather_params(sp, _sub_specs(cfg, kind), rules)  # JIT-FSDP regather
    h_in = L.rmsnorm(x, sp["ln1"], cfg.norm_eps)
    if kind == "rec":
        h, new_state = rec_apply(cfg, sp["temporal"], h_in, state=state, rules=rules)
    else:
        cache = None
        if state is not None:
            cache = (state["k"], state["v"], state["len"])
        h, new_kv = L.attention_apply(
            cfg, sp["temporal"], h_in, positions,
            causal=True, window=cfg.window, cache=cache,
        )
        new_state = (
            {"k": new_kv[0], "v": new_kv[1], "len": new_kv[2]} if new_kv else None
        )
    x = constrain(x + h, ("batch", "seq", None), rules)
    m = L.gelu_mlp_apply(sp["mlp"], L.rmsnorm(x, sp["ln2"], cfg.norm_eps))
    return constrain(x + m, ("batch", "seq", None), rules), new_state


def _plan(cfg: ModelConfig):
    """(n_groups, tail_kinds): scan n_groups full pattern units, then run the
    remainder layers unscanned."""
    unit = len(cfg.pattern)
    n_groups = cfg.n_layers // unit
    tail = cfg.layer_kinds()[n_groups * unit :]
    return n_groups, tail


def init_lm(key, cfg: ModelConfig):
    assert cfg.pattern, "hybrid config needs a layer pattern"
    n_groups, tail = _plan(cfg)
    k_emb, k_g, k_t, k_out = jax.random.split(key, 4)
    gkeys = jax.random.split(k_g, max(n_groups, 1))
    groups_p = jax.vmap(lambda k: _group_init(k, cfg)[0])(gkeys)
    _, groups_s = _group_init(gkeys[0], cfg)
    groups_s = jax.tree.map(
        lambda ax: ("layers",) + ax, groups_s, is_leaf=lambda x: isinstance(x, tuple)
    )
    tail_p, tail_s = [], []
    tkeys = jax.random.split(k_t, max(len(tail), 1))
    for kind, k in zip(tail, tkeys):
        tp, ts = _group_init(k, dataclass_with_pattern(cfg, (kind,)))
        tail_p.append(tp)
        tail_s.append(ts)
    dt = jnp.dtype(cfg.dtype)
    params = {
        "embed": (
            jax.random.normal(k_emb, (cfg.vocab, cfg.d_model), jnp.float32)
            * cfg.d_model**-0.5
        ).astype(dt),
        "groups": groups_p,
        "tail": tail_p,
        "ln_f": L.rmsnorm_init(cfg.d_model)[0],
        "unembed": (
            jax.random.normal(k_out, (cfg.d_model, cfg.vocab), jnp.float32)
            * cfg.d_model**-0.5
        ).astype(dt),
    }
    specs = {
        "embed": ("vocab", "embed"),
        "groups": groups_s,
        "tail": tail_s,
        "ln_f": ("embed",),
        "unembed": ("embed", "vocab"),
    }
    return params, specs


def dataclass_with_pattern(cfg: ModelConfig, pattern):
    import dataclasses

    return dataclasses.replace(cfg, pattern=tuple(pattern))


def forward(params, cfg: ModelConfig, tokens, *, rules=None, **_):
    n_groups, tail = _plan(cfg)
    x = params["embed"][tokens].astype(jnp.dtype(cfg.dtype))
    x = constrain(x, ("batch", "seq", None), rules)
    positions = jnp.arange(x.shape[1])

    def group_apply(gp, x):
        for kind, sp in zip(cfg.pattern, gp["sub"]):
            x, _ = _sub_apply(cfg, kind, sp, x, positions, rules=rules)
        return x

    block = jax.checkpoint(
        group_apply,
        policy=L.remat_policy(),
        prevent_cse=False,
    )

    def scan_body(x, gp):
        return block(gp, x), None

    if n_groups:
        x, _ = jax.lax.scan(
            scan_body, x, params["groups"], unroll=L.scan_unroll()
        )
    for kind, tp in zip(tail, params["tail"]):
        x, _ = _sub_apply(cfg, kind, tp["sub"][0], x, positions, rules=rules)
    x = L.rmsnorm(x, params["ln_f"], cfg.norm_eps)
    logits = x @ params["unembed"]
    return constrain(logits, ("batch", "seq", "vocab"), rules), jnp.float32(0)


def loss_fn(params, cfg, batch, *, rules=None, **kw):
    logits, _ = forward(params, cfg, batch["tokens"], rules=rules, **kw)
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    gold = jnp.take_along_axis(
        logits.astype(jnp.float32), batch["labels"][..., None], axis=-1
    )[..., 0]
    return (lse - gold).mean()


# -- decode -------------------------------------------------------------------------


def init_decode_cache(cfg: ModelConfig, batch: int, max_len: int):
    """Per-layer state; attention layers cache only ``window`` KV slots."""
    n_groups, tail = _plan(cfg)
    dr = cfg.d_rnn or cfg.d_model
    KV, Dh, CW = cfg.n_kv_heads, cfg.d_head, cfg.conv_width
    wlen = min(cfg.window or max_len, max_len)
    dt = jnp.dtype(cfg.dtype)

    def unit_state(stacked: int):
        def mk(shape, dtype):
            return jnp.zeros(((stacked,) + shape) if stacked else shape, dtype)

        states = []
        for kind in cfg.pattern:
            if kind == "rec":
                states.append(
                    {"h": mk((batch, dr), jnp.float32), "conv": mk((batch, CW - 1, dr), dt)}
                )
            else:
                states.append(
                    {"k": mk((batch, wlen, KV, Dh), dt), "v": mk((batch, wlen, KV, Dh), dt)}
                )
        return states

    cache = {
        "groups": unit_state(n_groups) if n_groups else [],
        "tail": [
            (
                {"h": jnp.zeros((batch, dr), jnp.float32),
                 "conv": jnp.zeros((batch, CW - 1, dr), dt)}
                if kind == "rec"
                else {"k": jnp.zeros((batch, wlen, KV, Dh), dt),
                      "v": jnp.zeros((batch, wlen, KV, Dh), dt)}
            )
            for kind in tail
        ],
        "len": jnp.int32(0),
    }

    def unit_spec(stacked: bool):
        pre = ("layers",) if stacked else ()
        states = []
        for kind in cfg.pattern:
            if kind == "rec":
                states.append(
                    {"h": pre + ("batch", "rnn"), "conv": pre + ("batch", None, "rnn")}
                )
            else:
                states.append(
                    {"k": pre + ("batch", "seq_kv", "kv", None),
                     "v": pre + ("batch", "seq_kv", "kv", None)}
                )
        return states

    specs = {
        "groups": unit_spec(True) if n_groups else [],
        "tail": [
            ({"h": ("batch", "rnn"), "conv": ("batch", None, "rnn")}
             if kind == "rec"
             else {"k": ("batch", "seq_kv", "kv", None),
                   "v": ("batch", "seq_kv", "kv", None)})
            for kind in tail
        ],
        "len": (),
    }
    return cache, specs


def decode_fn(params, cfg: ModelConfig, cache, tokens, *, rules=None):
    n_groups, tail = _plan(cfg)
    x = params["embed"][tokens].astype(jnp.dtype(cfg.dtype))
    pos = cache["len"]
    wlen = cache["tail"][0]["k"].shape[1] if (tail and "k" in cache["tail"][0]) else None

    def unit_apply(sub_params, sub_state, x):
        new_states = []
        for kind, sp, st in zip(cfg.pattern, sub_params, sub_state):
            if kind == "rec":
                x, ns = _sub_apply(cfg, kind, sp, x, None, state=st, rules=rules)
                new_states.append(ns)
            else:
                # ring-buffer window cache: slot = pos % window
                W = st["k"].shape[1]
                slot = pos % W
                h_in = L.rmsnorm(x, sp["ln1"], cfg.norm_eps)
                out, ns = _window_decode_attn(cfg, sp["temporal"], h_in, st, slot, pos)
                x = x + out
                m = L.gelu_mlp_apply(sp["mlp"], L.rmsnorm(x, sp["ln2"], cfg.norm_eps))
                x = x + m
                new_states.append(ns)
        return x, new_states

    if n_groups:
        def scan_body(x, inp):
            gp, gs = inp
            x, ns = unit_apply(gp["sub"], gs, x)
            return x, ns

        x, new_group_states = jax.lax.scan(
            scan_body, x, (params["groups"], cache["groups"]),
            unroll=L.scan_unroll(),
        )
    else:
        new_group_states = cache["groups"]
    new_tail = []
    for kind, tp, ts in zip(tail, params["tail"], cache["tail"]):
        x, ns = unit_apply([tp["sub"][0]], [ts], x)
        new_tail.append(ns[0])
    x = L.rmsnorm(x, params["ln_f"], cfg.norm_eps)
    logits = x @ params["unembed"]
    return logits, {
        "groups": new_group_states,
        "tail": new_tail,
        "len": cache["len"] + 1,
    }


def _window_decode_attn(cfg, ap, x, st, slot, pos):
    """MQA/GQA decode against a ring-buffer window cache."""
    B = x.shape[0]
    H, KV, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    q = (x @ ap["wq"]).reshape(B, 1, H, Dh)
    k_new = (x @ ap["wk"]).reshape(B, 1, KV, Dh)
    v_new = (x @ ap["wv"]).reshape(B, 1, KV, Dh)
    if cfg.qkv_bias:
        q = q + ap["bq"].reshape(1, 1, H, Dh)
        k_new = k_new + ap["bk"].reshape(1, 1, KV, Dh)
        v_new = v_new + ap["bv"].reshape(1, 1, KV, Dh)
    positions = jnp.full((1,), pos, jnp.int32)
    q = L.rope(q, positions, cfg.rope_theta)
    k_new = L.rope(k_new, positions, cfg.rope_theta)
    Wn = st["k"].shape[1]
    k_cache = jax.lax.dynamic_update_slice(st["k"], k_new.astype(st["k"].dtype), (0, slot, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(st["v"], v_new.astype(st["v"].dtype), (0, slot, 0, 0))
    # ring slots hold positions pos-W+1..pos; valid = slot age < window & <= pos
    ages = (slot - jnp.arange(Wn)) % Wn  # age of each slot in steps
    kpos = pos - ages
    valid = (kpos >= 0) & (kpos > pos - (cfg.window or Wn))
    G = H // KV
    qh = q.transpose(0, 2, 1, 3).reshape(B, KV, G, 1, Dh) * (Dh**-0.5)
    kh = k_cache.transpose(0, 2, 1, 3)
    vh = v_cache.transpose(0, 2, 1, 3)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qh, kh.astype(qh.dtype))
    s = jnp.where(valid[None, None, None, None, :], s, -1e30)
    p = jax.nn.softmax(s.astype(jnp.float32), -1).astype(qh.dtype)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", p, vh.astype(qh.dtype))
    o = o.reshape(B, H, 1, Dh).transpose(0, 2, 1, 3).reshape(B, 1, H * Dh)
    return o @ ap["wo"], {"k": k_cache, "v": v_cache}
