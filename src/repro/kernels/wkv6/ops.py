"""Jit'd public wrapper for the WKV6 recurrence.

``wkv6_op`` pads T to a chunk multiple, dispatches kernel vs oracle, and
exposes the single-step form used by the decode path (``wkv6_decode_step``:
one token against a carried (K, V) state — O(1) in sequence length, which is
what makes rwkv6's ``long_500k`` shape tractable).
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.wkv6.kernel import wkv6
from repro.kernels.wkv6.ref import wkv6_ref


def wkv6_op(
    r, k, v, decay, u, initial_state=None, *, impl: str = "ref", chunk: int = 32
):
    """(B, T, H, K/V) inputs -> (out, final_state).  impl: 'ref' | 'pallas'."""
    if impl == "pallas":
        T = r.shape[1]
        pad = (-T) % chunk
        if pad:
            zK = jnp.zeros((r.shape[0], pad, r.shape[2], r.shape[3]), r.dtype)
            zV = jnp.zeros((v.shape[0], pad, v.shape[2], v.shape[3]), v.dtype)
            one = jnp.ones_like(zK)
            out, state = wkv6(
                jnp.concatenate([r, zK], 1),
                jnp.concatenate([k, zK], 1),
                jnp.concatenate([v, zV], 1),
                jnp.concatenate([decay, one], 1),
                u,
                initial_state,
                chunk=chunk,
            )
            return out[:, :T], state
        return wkv6(r, k, v, decay, u, initial_state, chunk=chunk)
    return wkv6_ref(r, k, v, decay, u, initial_state)


def wkv6_decode_step(r_t, k_t, v_t, d_t, u, state):
    """One decode token: r_t/k_t/d_t (B, H, K), v_t (B, H, V),
    state (B, H, K, V) -> (o_t (B, H, V), new_state)."""
    kv = k_t[..., :, None] * v_t[..., None, :]
    o = jnp.einsum("bhk,bhkv->bhv", r_t, state + u[None, :, :, None] * kv)
    new_state = d_t[..., :, None] * state + kv
    return o, new_state
