"""Assigned-architecture configs (--arch <id>) + the run-config schema."""

from repro.configs.base import SHAPES, ModelConfig, ShapeConfig
from repro.configs.registry import ALIASES, ARCH_IDS, get_config, get_smoke_config

__all__ = [
    "ModelConfig",
    "ShapeConfig",
    "SHAPES",
    "ARCH_IDS",
    "ALIASES",
    "get_config",
    "get_smoke_config",
]
