"""Packed-bitset graph representation (host side, numpy).

The device-side (jnp) twins of these operations live in
``repro.problems.vertex_cover`` and ``repro.kernels.bitset_ops``.
"""

from __future__ import annotations

import dataclasses

import numpy as np

WORD_BITS = 32


def n_words(n: int) -> int:
    return (n + WORD_BITS - 1) // WORD_BITS


def pack_masks(bool_rows: np.ndarray) -> np.ndarray:
    """Pack a boolean array ``(..., n)`` into ``(..., W)`` uint32 words (LSB-first)."""
    bool_rows = np.asarray(bool_rows, dtype=bool)
    n = bool_rows.shape[-1]
    W = n_words(n)
    pad = W * WORD_BITS - n
    if pad:
        bool_rows = np.concatenate(
            [bool_rows, np.zeros(bool_rows.shape[:-1] + (pad,), dtype=bool)], axis=-1
        )
    bits = bool_rows.reshape(bool_rows.shape[:-1] + (W, WORD_BITS))
    weights = (np.uint64(1) << np.arange(WORD_BITS, dtype=np.uint64)).astype(np.uint64)
    packed = (bits.astype(np.uint64) * weights).sum(axis=-1)
    return packed.astype(np.uint32)


def unpack_mask(words: np.ndarray, n: int) -> np.ndarray:
    """Unpack ``(..., W)`` uint32 words back to a boolean array ``(..., n)``."""
    words = np.asarray(words, dtype=np.uint32)
    bits = (words[..., :, None] >> np.arange(WORD_BITS, dtype=np.uint32)) & np.uint32(1)
    flat = bits.reshape(words.shape[:-1] + (-1,))
    return flat[..., :n].astype(bool)


def popcount_rows(words: np.ndarray) -> np.ndarray:
    """Popcount summed over the trailing word axis."""
    w = np.asarray(words, dtype=np.uint32)
    # numpy>=2 exposes hardware popcount as np.bitwise_count
    return np.bitwise_count(w).sum(axis=-1).astype(np.int64)


def mask_full(n: int) -> np.ndarray:
    """Packed mask with bits 0..n-1 set."""
    W = n_words(n)
    out = np.full((W,), 0xFFFFFFFF, dtype=np.uint32)
    rem = n % WORD_BITS
    if rem:
        out[-1] = np.uint32((1 << rem) - 1)
    return out


def single_bit(v: int, W: int) -> np.ndarray:
    out = np.zeros((W,), dtype=np.uint32)
    out[v // WORD_BITS] = np.uint32(1) << np.uint32(v % WORD_BITS)
    return out


def complement(g: "BitGraph") -> "BitGraph":
    """The complement graph (no self-loops): uv in E' iff u != v and uv not
    in E.  The max-clique <-> independent-set reduction runs through this."""
    dense = g.to_dense()
    comp = ~dense & ~np.eye(g.n, dtype=bool)
    return BitGraph(n=g.n, adj=pack_masks(comp))


@dataclasses.dataclass(frozen=True)
class BitGraph:
    """Immutable packed-adjacency graph.

    adj:  (n, W) uint32, bit v of row u set iff uv in E.  Symmetric, no loops.
    """

    n: int
    adj: np.ndarray  # (n, W) uint32

    @property
    def W(self) -> int:
        return self.adj.shape[1]

    @staticmethod
    def from_edges(n: int, edges) -> "BitGraph":
        W = n_words(n)
        adj = np.zeros((n, W), dtype=np.uint32)
        for u, v in edges:
            if u == v:
                continue
            adj[u, v // WORD_BITS] |= np.uint32(1) << np.uint32(v % WORD_BITS)
            adj[v, u // WORD_BITS] |= np.uint32(1) << np.uint32(u % WORD_BITS)
        return BitGraph(n=n, adj=adj)

    @staticmethod
    def from_dense(dense: np.ndarray) -> "BitGraph":
        dense = np.asarray(dense, dtype=bool)
        n = dense.shape[0]
        dense = dense & ~np.eye(n, dtype=bool)
        dense = dense | dense.T
        return BitGraph(n=n, adj=pack_masks(dense))

    def to_dense(self) -> np.ndarray:
        return unpack_mask(self.adj, self.n)

    def edges(self):
        dense = self.to_dense()
        us, vs = np.nonzero(np.triu(dense, 1))
        return list(zip(us.tolist(), vs.tolist()))

    @property
    def num_edges(self) -> int:
        return int(np.bitwise_count(self.adj).sum()) // 2

    def degrees(self, mask: np.ndarray | None = None) -> np.ndarray:
        """Degrees restricted to the induced subgraph given by packed ``mask``.

        Vertices outside the mask get degree -1.
        """
        if mask is None:
            mask = mask_full(self.n)
        inside = unpack_mask(mask, self.n)
        deg = np.bitwise_count(self.adj & mask[None, :]).sum(axis=-1).astype(np.int64)
        deg[~inside] = -1
        return deg

    def edge_count(self, mask: np.ndarray) -> int:
        deg = self.degrees(mask)
        return int(deg[deg > 0].sum()) // 2

    def neighbors_mask(self, v: int, mask: np.ndarray) -> np.ndarray:
        return self.adj[v] & mask
