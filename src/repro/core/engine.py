"""Host driver for the SPMD branching engine.

Responsibilities (the paper's startup/termination bookkeeping):

* **startup** (§3.5): expand the root on the host until ≥ P open tasks exist
  (BFS = the equitable split), order them by the Algorithm-7 waiting-list
  traversal, and scatter one task per worker (the paper's seed→waiting-list
  topology); overflow tasks (BFS can over-expand past P) are routed through
  the SAME Algorithm-7 permutation so the equitable topology is preserved;
* **rounds**: the solve loop is device-resident — ``build_chunk_fn`` runs up
  to ``chunk_rounds`` supersteps per ``lax.while_loop`` on device, checking
  global quiescence (and, in FPT mode, the bound ``k``) on device; the host
  syncs ONE (done, ran) scalar pair per chunk instead of blocking on a
  ``device_get`` after every superstep (see EXPERIMENTS.md §Perf);
* **collect**: the center "knows which worker holds the best solution and
  fetches it only when the exploration has finished" (§3.1) — we argmin the
  per-worker local bests once, at the end; all stats (nodes, transfers,
  payload bytes) live in the carried ``WorkerState``, so collection is one
  host fetch;
* **elasticity / fault tolerance**: state is a plain pytree keyed only by
  (P, capacity, W).  ``snapshot``/``restore`` round-trip it through host
  memory; ``resize`` re-splits all pending tasks across a NEW worker count,
  which is how the engine survives losing (or gaining) devices mid-run.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.superstep import (
    WorkerState,
    build_chunk_fn,
    make_worker_state,
)
from repro.core.waiting_list import startup_assignment
from repro.graphs.bitgraph import BitGraph, n_words
from repro.problems.sequential import expand_frontier
from repro.problems.vertex_cover import make_problem


@dataclasses.dataclass
class EngineResult:
    best_size: int
    best_sol: Optional[np.ndarray]
    rounds: int
    nodes_expanded: int
    tasks_transferred: int
    wall_s: float
    overflow: bool
    # collective-traffic accounting (bytes) for the roofline / paper §4.3.
    # Control plane is a static per-round budget; the data plane is counted
    # on device: `transfer_rounds` supersteps ran the transfer collective and
    # carried `transfer_bytes_total` bytes of task-record payload (sparse
    # path: exactly 4·rec_words·records_moved — zero on no-match rounds;
    # gather path: the full P·k record table per transfer round).  This is
    # INFORMATION payload — the nonzero rows of the collective operand —
    # not physical wire traffic: the sparse psum's static operand is still
    # (P, k, REC) per device (see EXPERIMENTS.md §Perf B/C).
    control_bytes_per_round: int
    transfer_rounds: int
    transfer_bytes_total: int
    transfer_bytes_per_round: float


def _scatter_startup(
    state: WorkerState, g: BitGraph, num_workers: int, tasks=None
) -> WorkerState:
    """BFS-split the root into ~P tasks and place them per Algorithm 7 order.

    Every task — including overflow beyond the first ``num_workers`` when the
    BFS split over-expands (``tasks`` may hold more than P records) — goes
    through the same ``order`` permutation, so task i lands on worker
    ``order[i mod P]``: the §3.5 equitable topology wraps instead of
    degrading to raw round-robin.
    """
    if tasks is None:
        tasks = expand_frontier(g, num_tasks=num_workers)
    order = startup_assignment(max_b=2, p=num_workers)  # 1-based worker ids
    masks = np.array(state.frontier.masks)
    sols = np.array(state.frontier.sols)
    depths = np.array(state.frontier.depths)
    active = np.array(state.frontier.active)
    for i, (mask, sol, depth) in enumerate(tasks):
        w = order[i % num_workers] - 1
        # next free slot on worker w
        slot = int(np.argmin(active[w]))
        assert not active[w, slot], "startup overflow"
        masks[w, slot] = mask
        sols[w, slot] = sol
        depths[w, slot] = depth
        active[w, slot] = True
    return state._replace(
        frontier=state.frontier._replace(
            masks=jnp.asarray(masks),
            sols=jnp.asarray(sols),
            depths=jnp.asarray(depths),
            active=jnp.asarray(active),
        )
    )


def solve(
    g: BitGraph,
    num_workers: int = 8,
    *,
    steps_per_round: int = 32,
    lanes: int = 1,
    policy_priority: bool = True,
    codec: str = "optimized",
    packed_status: bool = True,
    skip_empty_transfer: bool = True,
    transfer_impl: str = "sparse",
    donate_k: int = 1,
    chunk_rounds: int = 16,
    mode: str = "bnb",
    k: Optional[int] = None,
    mesh=None,
    max_rounds: int = 200_000,
    capacity: Optional[int] = None,
    initial_state: Optional[WorkerState] = None,
) -> EngineResult:
    """Solve minimum vertex cover with P workers (virtual or one-per-device).

    ``chunk_rounds`` supersteps run per host sync (device-resident while
    loop); ``chunk_rounds=1`` reproduces the old per-round host loop for A/B
    benchmarking.  ``transfer_impl``/``donate_k`` select the data-plane path
    (see :func:`repro.core.superstep.superstep`).  ``max_rounds`` is a safety
    valve, enforced at chunk granularity (the run may overshoot it by at most
    ``chunk_rounds - 1`` supersteps).
    """
    W = n_words(g.n)
    cap = capacity or (4 * g.n + 8 * lanes)
    initial_best = g.n + 1 if mode == "bnb" else (k + 1)
    problem = make_problem(jnp.asarray(g.adj), g.n)
    pad = (g.n * W) if codec == "basic" else 0  # §4.3 basic encoding payload

    if initial_state is None:
        state = jax.vmap(lambda _: make_worker_state(cap, W, initial_best))(
            jnp.arange(num_workers)
        )
        state = _scatter_startup(state, g, num_workers)
    else:
        state = initial_state

    chunk_fn = build_chunk_fn(
        problem,
        num_workers=num_workers,
        steps_per_round=steps_per_round,
        lanes=lanes,
        policy_priority=policy_priority,
        transfer_pad_words=pad,
        packed_status=packed_status,
        skip_empty_transfer=skip_empty_transfer,
        transfer_impl=transfer_impl,
        donate_k=donate_k,
        chunk_rounds=chunk_rounds,
        fpt_bound=(k if mode == "fpt" else None),
        mesh=mesh,
    )

    t0 = time.perf_counter()
    rounds = 0
    while rounds < max_rounds:
        state, done, ran = chunk_fn(state)
        done, ran = jax.device_get((done, ran))
        rounds += int(ran)
        if bool(done):
            break
    wall = time.perf_counter() - t0

    local_bests = np.asarray(jax.device_get(state.local_best_val))
    wbest = int(np.argmin(local_bests))
    best_size = int(local_bests[wbest])
    best_sol = np.asarray(jax.device_get(state.best_sol))[wbest]
    if mode == "fpt" and best_size > k:
        best_size, best_sol = -1, None
    if best_size > g.n:
        best_sol = None

    # payload_words/transfer_rounds are replicated (derived from the shared
    # status table), so worker 0's view is the global truth.
    payload_words = int(np.asarray(state.payload_words)[0])
    transfer_rounds = int(np.asarray(state.transfer_rounds)[0])
    return EngineResult(
        best_size=best_size,
        best_sol=best_sol,
        rounds=rounds,
        nodes_expanded=int(np.asarray(state.nodes_expanded).sum()),
        tasks_transferred=int(np.asarray(state.tasks_sent).sum()),
        wall_s=wall,
        overflow=bool(np.asarray(state.frontier.overflow).any()),
        control_bytes_per_round=4 * (1 if packed_status else 3) * num_workers,
        transfer_rounds=transfer_rounds,
        transfer_bytes_total=4 * payload_words,
        transfer_bytes_per_round=4 * payload_words / max(rounds, 1),
    )


# -- elasticity -----------------------------------------------------------------


def snapshot(state: WorkerState) -> dict:
    """Host-side checkpoint of the entire engine state."""
    return jax.tree.map(np.asarray, state._asdict())


def restore(snap: dict) -> WorkerState:
    return WorkerState(**jax.tree.map(jnp.asarray, snap))


def resize(state: WorkerState, new_num_workers: int) -> WorkerState:
    """Re-split all pending tasks over a different worker count (elastic
    scale-up/down or failed-node recovery — any device count works because
    tasks are self-contained records over the original instance)."""
    masks = np.array(state.frontier.masks)
    sols = np.array(state.frontier.sols)
    depths = np.array(state.frontier.depths)
    active = np.array(state.frontier.active)
    P_old, cap, W = masks.shape[0], masks.shape[1], masks.shape[2]

    tasks = [
        (masks[w, s], sols[w, s], depths[w, s])
        for w in range(P_old)
        for s in range(cap)
        if active[w, s]
    ]
    best = int(np.asarray(state.local_best_val).min())
    bw = int(np.argmin(np.asarray(state.local_best_val)))
    new = jax.vmap(lambda _: make_worker_state(cap, W, best))(
        jnp.arange(new_num_workers)
    )
    nm = np.array(new.frontier.masks)
    ns = np.array(new.frontier.sols)
    nd = np.array(new.frontier.depths)
    na = np.array(new.frontier.active)
    for i, (m, s, d) in enumerate(tasks):
        w = i % new_num_workers
        slot = i // new_num_workers
        assert slot < cap, "resize: capacity too small for pending tasks"
        nm[w, slot], ns[w, slot], nd[w, slot], na[w, slot] = m, s, d, True
    sol = np.asarray(state.best_sol)[bw]
    return new._replace(
        frontier=new.frontier._replace(
            masks=jnp.asarray(nm),
            sols=jnp.asarray(ns),
            depths=jnp.asarray(nd),
            active=jnp.asarray(na),
        ),
        best_sol=jnp.broadcast_to(jnp.asarray(sol), new.best_sol.shape),
        local_best_val=jnp.full((new_num_workers,), best, jnp.int32),
    )
