"""Hierarchical frontier memory (``repro.core.spill``): the no-drop claims.

The contract, on every plane that can saturate (solo bnb, solo fpt,
``solve_many`` lanes, the live service):

1. **No task is ever dropped** — with ``frontier_spill=True`` a frontier
   driven past its high-water mark reports ``overflow=False`` /
   ``overflow_count=0`` and non-zero ``spilled_tasks``; the cold tier is
   fully drained back (``readmitted_tasks == spilled_tasks`` for a solve
   run to optimality).
2. **The optimum is unchanged** — the spilled solve lands on the SAME
   best value as the same instance solved with engine-sized (never
   saturating) capacity.
3. **Determinism** — spill/readmit decisions are host-side, stable-sorted
   and A7-ordered: running the same saturated solve twice is identical,
   counters included.
4. **Durability** — the cold tier rides SolveCheckpoints; a resume
   mid-spill finishes bit-identically, counters included.

Watermark resolution is pinned separately: the high mark must leave one
chunk's worth of growth headroom, and an impossible (capacity, chunk
shape) pair fails loudly at solve start, not silently mid-solve.
"""

import os

import numpy as np
import pytest

from repro.api import SolveConfig, SolveService, SolverSession
from repro.core.encoding import make_codec
from repro.core.spill import (
    BAND_WIDTH,
    FrontierSpiller,
    chunk_headroom,
    resolve_watermarks,
)
from repro.graphs.generators import erdos_renyi
from repro.problems.sequential import verify_cover

# a shape that saturates: n=40 VC explores ~150 nodes with hot peaks ~15
# per worker, so capacity 16 with a one-chunk headroom of 7 spills
_SAT = dict(num_workers=4, steps_per_round=2, chunk_rounds=2, capacity=16)


def _cfg(**over):
    return SolveConfig(**{**_SAT, **over})


def _solve(g, cfg, problem="vertex_cover"):
    return SolverSession(problem, config=cfg).solve(g)


# -- 1. watermark resolution ---------------------------------------------------


def test_chunk_headroom_arithmetic():
    assert (
        chunk_headroom(chunk_rounds=2, steps_per_round=2, lanes=1, donate_k=1)
        == 2 * (2 * 1 + 1) + 1
    )


def test_resolve_watermarks_clamps_to_headroom():
    low, high = resolve_watermarks(
        16, (0.5, 0.9), chunk_rounds=2, steps_per_round=2, lanes=1, donate_k=1
    )
    # high = min(int(0.9*16)=14, 16-7=9) = 9; low = min(int(0.5*16)=8, 8)
    assert (low, high) == (8, 9)
    assert 1 <= low < high


def test_resolve_watermarks_impossible_capacity_fails_loudly():
    with pytest.raises(ValueError, match="headroom"):
        resolve_watermarks(
            8,
            (0.5, 0.9),
            chunk_rounds=16,
            steps_per_round=32,
            lanes=1,
            donate_k=1,
        )


def test_undersized_capacity_fails_at_solve_start():
    g = erdos_renyi(18, 0.35, 2)
    cfg = SolveConfig(num_workers=4, steps_per_round=8, capacity=2,
                      frontier_spill=True)
    with pytest.raises(ValueError, match="headroom"):
        _solve(g, cfg)


def test_mesh_path_is_gated():
    g = erdos_renyi(18, 0.35, 2)
    with pytest.raises(ValueError, match="mesh"):
        _solve(g, _cfg(frontier_spill=True, use_mesh=True))


def test_config_validates_watermarks_and_codec():
    with pytest.raises(ValueError, match="spill_watermarks"):
        SolveConfig(spill_watermarks=(0.9, 0.5))
    with pytest.raises(ValueError, match="codec"):
        SolveConfig(spill_codec="zstd")


# -- 2. saturation property: no drop, same optimum, deterministic --------------


def test_solo_saturated_matches_unsaturated_and_is_deterministic():
    g = erdos_renyi(40, 0.28, 0)
    big = _solve(g, _cfg(capacity=None))
    a = _solve(g, _cfg(frontier_spill=True))
    b = _solve(g, _cfg(frontier_spill=True))

    assert a.stats.spilled_tasks > 0  # the shape really saturates
    assert a.stats.readmitted_tasks == a.stats.spilled_tasks
    assert a.stats.cold_bytes_peak > 0
    assert not a.stats.overflow and a.stats.overflow_count == 0
    # same optimum VALUE with a VALID witness — spill changes exploration
    # order, so the (equally optimal) witness may differ from big-capacity
    assert a.best_size == big.best_size
    assert verify_cover(g, np.asarray(a.best_sol))
    assert int(np.unpackbits(np.asarray(a.best_sol).view(np.uint8)).sum()) == a.best_size

    # run-to-run: everything identical, counters included
    assert a.best_size == b.best_size
    assert (np.asarray(a.best_sol) == np.asarray(b.best_sol)).all()
    assert a.rounds == b.rounds and a.nodes_expanded == b.nodes_expanded
    assert (
        a.stats.spilled_tasks,
        a.stats.readmitted_tasks,
        a.stats.cold_bytes_peak,
    ) == (
        b.stats.spilled_tasks,
        b.stats.readmitted_tasks,
        b.stats.cold_bytes_peak,
    )


def test_solo_fpt_saturated_matches_unsaturated():
    g = erdos_renyi(40, 0.28, 0)
    # feasible decision: spill must not change the witness
    sat_big = _solve(g, _cfg(capacity=None, mode="fpt", k=29))
    sat = _solve(g, _cfg(frontier_spill=True, mode="fpt", k=29))
    assert sat.found and sat_big.found
    assert sat.best_size == sat_big.best_size
    assert sat.stats.spilled_tasks > 0
    # infeasible decision: the WHOLE tree must drain through the cold tier
    # before the engine may answer "no"
    unsat = _solve(g, _cfg(frontier_spill=True, mode="fpt", k=20))
    assert not unsat.found and not unsat.stats.overflow


def test_solve_many_saturated_lanes_match_unsaturated():
    gs = [erdos_renyi(40, 0.28, s) for s in range(3)] + [
        erdos_renyi(18, 0.35, 2)
    ]
    big = SolverSession("vertex_cover", config=_cfg(capacity=None)).solve_many(gs)
    spl = SolverSession(
        "vertex_cover", config=_cfg(frontier_spill=True)
    ).solve_many(gs)
    for a, b in zip(big.results, spl.results):
        assert b.best_size == a.best_size
        assert not b.stats.overflow and b.stats.overflow_count == 0
        assert b.stats.readmitted_tasks == b.stats.spilled_tasks
    assert sum(r.stats.spilled_tasks for r in spl.results) > 0


def test_service_saturated_lanes_match_solve_many():
    gs = [erdos_renyi(40, 0.28, s) for s in range(3)]
    ref = SolverSession(
        "vertex_cover", config=_cfg(frontier_spill=True)
    ).solve_many(gs)
    svc = SolveService(
        "vertex_cover", _cfg(frontier_spill=True, service_lanes=2)
    )
    tix = [svc.submit(g) for g in gs]
    svc.drain()
    for t, want in zip(tix, ref.results):
        got = svc.result(t)
        assert got.best_size == want.best_size
        assert got.stats.spilled_tasks == want.stats.spilled_tasks
        assert not got.stats.overflow


# -- 3. durability: the cold tier rides checkpoints ----------------------------


def test_checkpoint_resume_mid_spill_is_bit_identical(tmp_path):
    g = erdos_renyi(40, 0.28, 0)
    ck = os.path.join(str(tmp_path), "ck")
    cfg = _cfg(frontier_spill=True, checkpoint_dir=ck, checkpoint_every=1)
    full = _solve(g, cfg)
    assert full.stats.spilled_tasks > 0

    # stop mid-solve (cold backlog checkpointed), then resume to the end
    part = _solve(g, cfg.replace(max_rounds=6))
    assert part.rounds == 6
    res = _solve(g, cfg.replace(resume_from=ck))
    assert res.stats.resumed_from is not None
    assert res.best_size == full.best_size
    assert (np.asarray(res.best_sol) == np.asarray(full.best_sol)).all()
    assert res.stats.spilled_tasks == full.stats.spilled_tasks
    assert res.stats.readmitted_tasks == full.stats.readmitted_tasks


def test_service_restore_rebuilds_spillers(tmp_path):
    gs = [erdos_renyi(40, 0.28, s) for s in range(3)]
    cfg = _cfg(frontier_spill=True, service_lanes=2)
    ref = SolveService("vertex_cover", cfg)
    tix = [ref.submit(g) for g in gs]
    ref.drain()
    want = {t: ref.result(t) for t in tix}

    svc = SolveService("vertex_cover", cfg)
    tix2 = [svc.submit(g) for g in gs]
    svc.step()
    svc.step()
    ck = os.path.join(str(tmp_path), "sck")
    svc.checkpoint(ck)
    back = SolveService.restore(ck)
    back.drain()
    for t, t2 in zip(tix, tix2):
        got = back.result(t2)
        assert got.best_size == want[t].best_size
        assert got.stats.spilled_tasks == want[t].stats.spilled_tasks


# -- 4. spiller unit behaviour -------------------------------------------------


def _unit_spiller(codec_name="optimized", n=12, P=4, cap=32, graph=None):
    codec = make_codec(codec_name, n)
    return FrontierSpiller(
        codec,
        P,
        cap,
        (0.25, 0.75),
        chunk_rounds=1,
        steps_per_round=2,
        lanes=1,
        donate_k=1,
        graph=graph,
    )


def _full_pool(P=4, CAP=32, W=1, per_worker=30):
    """A (P, CAP, ...) host pool with ``per_worker`` distinct active tasks
    per worker, depths spanning several bands."""
    masks = np.zeros((P, CAP, W), np.uint32)
    sols = np.zeros((P, CAP, W), np.uint32)
    depths = np.zeros((P, CAP), np.int32)
    active = np.zeros((P, CAP), bool)
    for w in range(P):
        for s in range(per_worker):
            masks[w, s] = w * CAP + s + 1
            depths[w, s] = (w * per_worker + s) % 24  # 3 depth bands
            active[w, s] = True
    return masks, sols, depths, active


def _pool_keys(masks, depths, active):
    return sorted(
        (int(masks[w, s, 0]), int(depths[w, s]))
        for w, s in zip(*np.nonzero(active))
    )


def test_pump_host_conserves_tasks_and_respects_watermarks():
    sp = _unit_spiller()  # cap 32 -> high 24, low 8
    assert (sp.low, sp.high) == (8, 24)
    masks, sols, depths, active = _full_pool()
    before = _pool_keys(masks, depths, active)
    assert sp.pump_host(masks, sols, depths, active)
    counts = active.sum(axis=1)
    # every worker spilled down to low; all workers AT low -> no refill
    assert (counts == sp.low).all()
    assert sp.spilled_total == 4 * (30 - sp.low) == sp.cold_tasks
    assert sp.readmitted_total == 0
    # survivors are the deepest tasks: everything cold is shallower than
    # (or band-equal to) what stayed hot, per worker
    for w in range(4):
        deepest_cold = max(b for b in sp._bands[w])
        assert depths[w][active[w]].min() // BAND_WIDTH >= deepest_cold - 1

    # drain everything back through repeated empty pools: the cold tier
    # must conserve the task multiset exactly (no drop, no duplication)
    recovered = _pool_keys(masks, depths, active)
    while sp.cold_tasks:
        m2 = np.zeros_like(masks)
        s2 = np.zeros_like(sols)
        d2 = np.zeros_like(depths)
        a2 = np.zeros_like(active)
        assert sp.pump_host(m2, s2, d2, a2)
        recovered += _pool_keys(m2, d2, a2)
    assert sorted(recovered) == before
    assert sp.readmitted_total == sp.spilled_total


def test_spiller_flat_roundtrip_rebands_by_depth():
    sp = _unit_spiller()
    masks, sols, depths, active = _full_pool()
    sp.pump_host(masks, sols, depths, active)
    assert sp.cold_tasks > 0

    flat = sp.to_flat("s")
    assert FrontierSpiller.present_in(flat, "s")
    assert not FrontierSpiller.present_in(flat, "other")
    sp2 = _unit_spiller()
    sp2.load_flat(flat, "s")
    assert sp2.cold_tasks == sp.cold_tasks
    assert sp2.spilled_total == sp.spilled_total
    assert sp2.cold_bytes_peak == sp.cold_bytes_peak
    flat2 = sp2.to_flat("s")
    assert flat2.keys() == flat.keys()
    for k in flat:
        assert (np.asarray(flat2[k]) == np.asarray(flat[k])).all()
    # bands are keyed by depth // BAND_WIDTH, rebuilt exactly
    for w in range(4):
        assert sorted(sp2._bands[w]) == sorted(sp._bands[w])


def test_basic_spill_codec_requires_graph():
    with pytest.raises(ValueError, match="graph"):
        _unit_spiller("basic")
    g = erdos_renyi(12, 0.4, 5)
    sp = _unit_spiller("basic", graph=g)
    assert sp.codec.record_words == 12 * sp.codec.W + 2 * sp.codec.W + 1


def test_basic_spill_codec_end_to_end():
    g = erdos_renyi(40, 0.28, 0)
    big = _solve(g, _cfg(capacity=None))
    r = _solve(g, _cfg(frontier_spill=True, spill_codec="basic"))
    assert r.best_size == big.best_size
    assert r.stats.spilled_tasks > 0 and not r.stats.overflow
    # basic records are (n+2)W+1 words: the cold tier is accordingly fatter
    opt = _solve(g, _cfg(frontier_spill=True))
    assert r.stats.cold_bytes_peak > opt.stats.cold_bytes_peak
