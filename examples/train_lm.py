"""End-to-end training driver with checkpoint/crash/resume demonstration.

Trains a ~100M-class reduced model for a few hundred steps and shows the
fault-tolerance contract: the resumed run reproduces the uninterrupted loss
curve exactly.

  PYTHONPATH=src python examples/train_lm.py [arch] [steps]
"""

import shutil
import sys
import tempfile

sys.path.insert(0, "src")

import numpy as np

from repro.checkpoint.store import wait_for_pending
from repro.configs.registry import get_smoke_config
from repro.launch.train import train_loop


def main():
    arch = sys.argv[1] if len(sys.argv) > 1 else "minitron_4b"
    steps = int(sys.argv[2]) if len(sys.argv) > 2 else 200
    cfg = get_smoke_config(arch)
    print(f"arch={cfg.name} steps={steps}")

    ckdir = tempfile.mkdtemp(prefix="repro_ck_")
    try:
        half = steps // 2
        print(f"\n--- phase 1: train to step {half}, checkpoint, 'crash' ---")
        _, _, l1 = train_loop(
            cfg, steps=half, batch=8, seq=128, ckpt_dir=ckdir,
            ckpt_every=max(half // 4, 1), seed=1, log_every=25,
        )
        wait_for_pending()
        print(f"\n--- phase 2: resume from the latest checkpoint ---")
        _, _, l2 = train_loop(
            cfg, steps=steps, batch=8, seq=128, ckpt_dir=ckdir,
            ckpt_every=10_000, resume=True, seed=1, log_every=25,
        )
        print(f"\n--- control: uninterrupted run ---")
        _, _, lc = train_loop(cfg, steps=steps, batch=8, seq=128, seed=1,
                              log_every=50)
        resumed = l1 + l2
        drift = float(np.abs(np.array(resumed) - np.array(lc)).max())
        print(f"\nresume-vs-control max loss drift: {drift:.2e} "
              f"({'EXACT' if drift < 1e-3 else 'MISMATCH'})")
        print(f"loss: {lc[0]:.3f} -> {lc[-1]:.3f}")
    finally:
        shutil.rmtree(ckdir, ignore_errors=True)


if __name__ == "__main__":
    main()
