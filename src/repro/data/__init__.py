from repro.data.pipeline import SyntheticTokens, make_batch_for

__all__ = ["SyntheticTokens", "make_batch_for"]
