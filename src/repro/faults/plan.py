"""Deterministic fault schedules for the self-healing solve plane.

A :class:`FaultPlan` is a seeded, fully reproducible list of
:class:`FaultEvent` entries — WHAT goes wrong and WHEN, where "when" is a
*chunk-boundary index* (the host-sync points of the solve loop), never a
wall clock.  Two runs of the same plan on different machines therefore
inject the exact same faults at the exact same points of the solve
trajectory, which is what lets ``benchmarks/chaos_smoke.py`` pin
``faults_injected`` / ``faults_recovered`` as exact baseline numbers.

Five fault kinds (``FAULT_KINDS``):

``crash``             a lane/worker dies at a chunk boundary — its device
                      state is lost and must be re-admitted from the
                      center's tracked placement
``stall``             a lane stops making superstep progress for
                      ``duration`` consecutive boundaries (a wedged host
                      or preempted device), caught by the service's
                      stall watchdog
``transfer_corrupt``  a sparse-transfer payload record is corrupted on
                      delivery (cold tier -> hot frontier leg)
``cold_corrupt``      a codec record is corrupted while being written
                      into the cold tier
``io_error``          a checkpoint-store read/write raises ``OSError``
                      (``op`` narrows it to one side)

The plan is pure data: build one by hand for targeted tests, or use
:meth:`FaultPlan.random` for a seeded randomized schedule; both JSON
round-trip via ``to_dict`` / ``from_dict`` for the launch CLI.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Tuple

import numpy as np

FAULT_KINDS = ("crash", "stall", "transfer_corrupt", "cold_corrupt",
               "io_error")

_IO_OPS = ("write", "read")


@dataclass(frozen=True)
class FaultEvent:
    """One scheduled fault.

    ``at`` is the chunk-boundary index (0-based, counted by the
    injector's ``step_boundary``) at or after which the event fires —
    corruption/io events fire at the first matching *operation* once due,
    crash/stall events at the first boundary with a live target lane.
    ``lane`` is a virtual slot, mapped modulo the live-lane list at fire
    time so plans stay valid for any plane width.
    """

    kind: str
    at: int
    lane: int = 0
    duration: int = 1          # stall only: boundaries the lane is wedged
    op: str = ""               # io_error only: "write", "read", or "" (any)

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(
                f"unknown fault kind {self.kind!r}; valid: {FAULT_KINDS}"
            )
        if self.at < 0 or self.lane < 0 or self.duration < 1:
            raise ValueError(f"bad fault event {self!r}")
        if self.op and self.op not in _IO_OPS:
            raise ValueError(f"io op must be one of {_IO_OPS}: {self!r}")

    def to_dict(self) -> dict:
        return dict(kind=self.kind, at=self.at, lane=self.lane,
                    duration=self.duration, op=self.op)

    @staticmethod
    def from_dict(d: dict) -> "FaultEvent":
        return FaultEvent(**d)


@dataclass(frozen=True)
class FaultPlan:
    """A seeded, ordered fault schedule (pure data, JSON round-trips)."""

    seed: int = 0
    events: Tuple[FaultEvent, ...] = field(default_factory=tuple)

    def __post_init__(self):
        object.__setattr__(
            self, "events",
            tuple(sorted(self.events, key=lambda e: (e.at, e.kind, e.lane))),
        )

    @staticmethod
    def random(seed: int, *, n_events: int = 6, horizon: int = 48,
               lanes: int = 8, kinds=FAULT_KINDS,
               max_stall: int = 4) -> "FaultPlan":
        """A seeded randomized schedule: ``n_events`` faults drawn
        uniformly over ``kinds``, boundaries ``[0, horizon)`` and lane
        slots ``[0, lanes)``.  Same seed -> same plan, everywhere."""
        rng = np.random.default_rng(seed)
        events = []
        for _ in range(n_events):
            kind = kinds[int(rng.integers(len(kinds)))]
            events.append(FaultEvent(
                kind=kind,
                at=int(rng.integers(horizon)),
                lane=int(rng.integers(max(1, lanes))),
                duration=1 + int(rng.integers(max(1, max_stall)))
                if kind == "stall" else 1,
                op=_IO_OPS[int(rng.integers(2))] if kind == "io_error"
                else "",
            ))
        return FaultPlan(seed=seed, events=tuple(events))

    def counts(self) -> dict:
        out = {k: 0 for k in FAULT_KINDS}
        for e in self.events:
            out[e.kind] += 1
        return out

    def to_dict(self) -> dict:
        return dict(seed=self.seed,
                    events=[e.to_dict() for e in self.events])

    @staticmethod
    def from_dict(d: dict) -> "FaultPlan":
        return FaultPlan(
            seed=int(d.get("seed", 0)),
            events=tuple(FaultEvent.from_dict(e)
                         for e in d.get("events", [])),
        )
