"""Branching-problem solver driver — any registry problem, any backend,
one config.

  --problem NAME     which branching problem (vertex_cover, max_clique, mis;
                     see repro.problems.registry)
  --engine spmd         the TPU-adapted superstep engine (vmap of P virtual
                        workers on CPU; one worker per device with --use-mesh)
  --engine protocol_sim the faithful asynchronous MPI-protocol simulator
                        (alias: protocol)
  --engine centralized  the fully-centralized baseline (Abu-Khzam 2006;
                        alias: central)
  --engine sequential   the problem's sequential reference (alias: seq)

All engines run behind one :class:`repro.api.SolverSession`, so every
combination of backend x problem with host plumbing works (e.g.
``--engine protocol_sim --problem max_clique``) and results arrive in the
unified :class:`repro.api.SolveResult` schema.

Config: every tuning knob is a :class:`repro.api.SolveConfig` field.
``--config cfg.json`` loads a base config, explicit CLI flags override it,
and ``--dump-config out.json`` writes the EFFECTIVE config next to the
results (``-`` prints it) — the solve is reproducible from that file.

Multi-instance mode (the batched solve plane): pass several DIMACS files
and/or ``--batch B`` to pack B instances onto one plane — one compiled
executable and one host sync per chunk for the whole batch.

Usage:
  PYTHONPATH=src python -m repro.launch.solve --graph gnp --n 60 --p 0.1 \
      --engine spmd --workers 8
  PYTHONPATH=src python -m repro.launch.solve --graph gnp --n 40 \
      --problem max_clique --engine protocol_sim --workers 8
  PYTHONPATH=src python -m repro.launch.solve --graph gnp --n 40 --batch 16
  PYTHONPATH=src python -m repro.launch.solve --config cfg.json --workers 4 \
      --dump-config effective.json
"""

from __future__ import annotations

import argparse
import sys

from repro.graphs.generators import erdos_renyi, p_hat_like, parse_dimacs


def build_graph(args, seed=None):
    seed = args.seed if seed is None else seed
    if args.graph == "gnp":
        return erdos_renyi(args.n, args.p if args.p else 4.0 / (args.n - 1), seed)
    if args.graph == "phat":
        return p_hat_like(args.n, args.density, seed)
    if args.graph == "dimacs":
        with open(args.file) as f:
            return parse_dimacs(f.read())
    raise ValueError(args.graph)


def build_graphs(args):
    """The multi-instance work list: every --files entry, plus --batch
    generated instances (consecutive seeds).  Empty unless one of those
    multi-instance flags was used."""
    graphs, labels = [], []
    for path in args.files or []:
        with open(path) as f:
            graphs.append(parse_dimacs(f.read()))
        labels.append(path)
    if args.batch is not None:
        if args.batch < 1:
            raise SystemExit("--batch must be >= 1")
        if args.graph == "dimacs":
            raise SystemExit("--batch needs a generated graph (gnp/phat)")
        for b in range(args.batch):
            graphs.append(build_graph(args, seed=args.seed + b))
            labels.append(f"{args.graph}-n{args.n}-seed{args.seed + b}")
    return graphs, labels


# CLI flag dest -> SolveConfig field.  These flags default to SUPPRESS so
# only EXPLICIT flags override a --config file (load -> override -> dump).
CONFIG_FLAGS = {
    "workers": "num_workers",
    "codec": "codec",
    "policy": "policy",
    "steps_per_round": "steps_per_round",
    "lanes": "lanes",
    "transfer": "transfer_impl",
    "explore": "explore_impl",
    "donate_k": "donate_k",
    "chunk_rounds": "chunk_rounds",
    "use_mesh": "use_mesh",
    "mode": "mode",
    "k": "k",
    "latency": "latency",
    "checkpoint_dir": "checkpoint_dir",
    "checkpoint_every": "checkpoint_every",
    "capacity": "capacity",
    "spill": "frontier_spill",
    "spill_codec": "spill_codec",
}


def effective_config(args):
    """--config base (or defaults), overridden by explicit CLI flags."""
    from repro.api import SolveConfig

    base = SolveConfig.load(args.config) if args.config else SolveConfig()
    provided = {
        CONFIG_FLAGS[dest]: value
        for dest, value in vars(args).items()
        if dest in CONFIG_FLAGS
    }
    return base.replace(**provided) if provided else base


def resume_solve(args):
    """--resume DIR: rebuild the session FROM the checkpoint (problem,
    config, graphs all live in it) and run to completion.  Explicit CLI
    flags act as config overrides; the fingerprint check refuses any that
    would change the solve trajectory."""
    from repro.api import BatchSolveResult, SolverSession

    overrides = {
        CONFIG_FLAGS[dest]: value
        for dest, value in vars(args).items()
        if dest in CONFIG_FLAGS
    }
    res = SolverSession.resume(args.resume, **overrides)
    if isinstance(res, BatchSolveResult):
        for i, r in enumerate(res.results):
            print(f"[solve]   instance {i}: best={r.best_size} "
                  f"rounds={r.rounds} nodes={r.nodes_expanded}")
        print(f"[solve] resumed batch from {args.resume}: "
              f"{len(res.results)} instances in {res.wall_s:.2f}s")
    else:
        print(f"[solve] resumed from {args.resume}: best={res.best_size} "
              f"rounds={res.rounds} nodes={res.nodes_expanded} "
              f"wall={res.wall_s:.2f}s")


def main():
    S = argparse.SUPPRESS
    ap = argparse.ArgumentParser()
    ap.add_argument("--graph", default="gnp", choices=["gnp", "phat", "dimacs"])
    ap.add_argument("--n", type=int, default=60)
    ap.add_argument("--p", type=float, default=0.0)
    ap.add_argument("--density", type=float, default=0.4)
    ap.add_argument("--file", default=None)
    ap.add_argument("--files", nargs="+", default=None,
                    help="several DIMACS files -> one solve_many batch")
    ap.add_argument("--batch", type=int, default=None,
                    help="generate B instances (seeds seed..seed+B-1) and "
                         "solve them on one batched plane (B=1 still uses "
                         "the batched engine)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument(
        "--engine", default="spmd",
        help="backend: spmd, protocol_sim (protocol), centralized "
             "(central), sequential (seq)",
    )
    ap.add_argument("--problem", default="vertex_cover",
                    help="branching problem from the registry "
                         "(vertex_cover, max_clique, mis, ...)")
    ap.add_argument("--config", default=None,
                    help="JSON SolveConfig to start from; explicit CLI "
                         "flags override it")
    ap.add_argument("--dump-config", default=None, metavar="PATH",
                    help="write the EFFECTIVE config as JSON ('-' prints) "
                         "and still run the solve")
    # -- SolveConfig knobs (SUPPRESS default = "not explicitly provided") ----
    ap.add_argument("--workers", type=int, default=S)
    ap.add_argument("--codec", default=S,
                    help="task codec: optimized (n-bit masks) or basic "
                         "(adjacency payload, §4.3)")
    ap.add_argument("--policy", default=S, choices=["priority", "random"])
    ap.add_argument("--steps-per-round", type=int, default=S)
    ap.add_argument("--lanes", type=int, default=S)
    ap.add_argument("--transfer", default=S, choices=["sparse", "gather"],
                    help="data-plane impl (sparse=masked psum, gather=all-gather)")
    ap.add_argument("--explore", default=S, choices=["fused", "reference"],
                    help="explore hot path (fused=one-pass expand + cheap "
                         "pop, reference=per-task callables + top_k)")
    ap.add_argument("--donate-k", type=int, default=S,
                    help="max tasks a matched donor ships per round")
    ap.add_argument("--chunk-rounds", type=int, default=S,
                    help="supersteps per host sync (device-resident loop)")
    ap.add_argument("--use-mesh", action="store_true", default=S,
                    help="one worker per jax device (shard_map)")
    ap.add_argument("--mode", default=S, choices=["bnb", "fpt"])
    ap.add_argument("--k", type=int, default=S)
    ap.add_argument("--latency", type=int, default=S,
                    help="simulator message latency in ticks")
    ap.add_argument("--checkpoint-dir", default=S, metavar="DIR",
                    help="write a resumable SolveCheckpoint every "
                         "--checkpoint-every chunks (spmd)")
    ap.add_argument("--checkpoint-every", type=int, default=S,
                    help="chunks between checkpoint writes (default 8)")
    ap.add_argument("--capacity", type=int, default=S,
                    help="hot frontier slots per worker "
                         "(default: engine-sized 4n + 8*lanes)")
    ap.add_argument("--spill", action="store_true", default=S,
                    help="hierarchical frontier memory: evict past the "
                         "high-water mark to a codec-compressed host cold "
                         "tier instead of dropping tasks (spmd)")
    ap.add_argument("--spill-codec", default=S,
                    choices=["optimized", "basic"],
                    help="record encoding for the cold tier (default: "
                         "optimized, 2W+1 words/task)")
    ap.add_argument("--resume", default=None, metavar="DIR",
                    help="resume a checkpointed solve (dir or step_N subdir); "
                         "problem/config/graphs come from the checkpoint, "
                         "explicit flags override non-trajectory knobs")
    ap.add_argument("--chaos", type=int, default=None, metavar="N",
                    help="deterministic fault injection (spmd): fire N "
                         "random faults from repro.faults (lane crashes, "
                         "stalls, payload corruption, checkpoint I/O "
                         "errors) and self-heal — results stay "
                         "bit-identical to a fault-free run")
    ap.add_argument("--chaos-seed", type=int, default=0,
                    help="seed for the --chaos fault plan (default 0)")
    args = ap.parse_args()

    if args.resume:
        resume_solve(args)
        return

    # one validation pass: config knobs, problem and backend names all fail
    # with the list of valid values, not a deep KeyError
    from repro.api import SolverSession, get_backend
    from repro.problems.registry import get_problem

    try:
        cfg = effective_config(args)
        spec = get_problem(args.problem)
        backend = get_backend(args.engine)
    except ValueError as e:
        raise SystemExit(f"error: {e}")

    if args.dump_config:
        if args.dump_config == "-":
            sys.stdout.write(cfg.to_json())
        else:
            cfg.save(args.dump_config)
            print(f"[solve] effective config -> {args.dump_config}")

    session = SolverSession(problem=spec, backend=backend, config=cfg)

    injector = None
    if args.chaos is not None:
        if backend.name != "spmd":
            raise SystemExit("--chaos needs the spmd engine")
        from repro.faults import FaultInjector, FaultPlan

        plan = FaultPlan.random(
            args.chaos_seed, n_events=args.chaos, lanes=cfg.lanes
        )
        injector = FaultInjector(plan)
        print(f"[solve] chaos: {args.chaos} seeded fault(s) "
              f"(seed {args.chaos_seed}): {plan.counts()}")
    extra = {"injector": injector} if injector is not None else {}

    batch_graphs, batch_labels = build_graphs(args)
    if batch_graphs:
        if cfg.use_mesh:
            raise SystemExit(
                "multi-instance mode has no mesh path yet (vmap virtual "
                "workers only) — drop --use-mesh"
            )
        print(f"[solve] batch of {len(batch_graphs)} instances "
              f"[{spec.name}] on {backend.name}, "
              f"workers/instance={cfg.num_workers}")
        res = session.solve_many(batch_graphs, **extra)
        for label, r in zip(batch_labels, res.results):
            print(f"[solve]   {label}: best={r.best_size} rounds={r.rounds} "
                  f"nodes={r.nodes_expanded} transfers={r.tasks_transferred}")
        print(f"[solve] batch done: {len(batch_graphs)} instances in "
              f"{res.wall_s:.2f}s "
              f"({len(batch_graphs) / max(res.wall_s, 1e-9):.2f} inst/s), "
              f"{len(res.buckets)} bucket(s), {res.compactions} "
              f"compaction(s); cache: {session.cache_stats()}")
        if injector is not None:
            print(f"[solve] chaos report: {injector.report()}")
        return

    g = build_graph(args)
    print(f"[solve] graph n={g.n} m={g.num_edges} engine={backend.name} "
          f"problem={spec.name}")
    r = session.solve(g, **extra)
    line = (f"[solve] best={r.best_size} rounds={r.rounds} "
            f"nodes={r.nodes_expanded} transfers={r.tasks_transferred} "
            f"wall={r.wall_s:.2f}s")
    s = r.stats
    if backend.name == "spmd":
        line += (f" overflow={s.overflow} "
                 f"control_B/round={s.control_bytes_per_round} "
                 f"transfer_B/round={s.transfer_bytes_per_round:.1f} "
                 f"(total {s.transfer_bytes_total}B over "
                 f"{s.transfer_rounds} transfer rounds, "
                 f"{cfg.transfer_impl})")
        if s.checkpoints_written:
            line += f" checkpoints={s.checkpoints_written}"
        if s.spilled_tasks:
            line += (f" spilled={s.spilled_tasks} "
                     f"readmitted={s.readmitted_tasks} "
                     f"cold_peak={s.cold_bytes_peak}B")
    elif backend.name in ("protocol_sim", "centralized"):
        line += (f" bytes={s.total_bytes}"
                 + (f" (center {s.center_bytes})"
                    f" failed_requests={s.failed_requests}"
                    if backend.name == "protocol_sim" else ""))
    print(line)
    if injector is not None:
        print(f"[solve] chaos report: {injector.report()}")


if __name__ == "__main__":
    main()
