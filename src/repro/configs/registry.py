"""--arch <id> resolution for the launcher, tests and benchmarks."""

from __future__ import annotations

import importlib

ARCH_IDS = [
    "recurrentgemma_9b",
    "whisper_large_v3",
    "qwen1_5_0_5b",
    "phi3_medium_14b",
    "minitron_4b",
    "starcoder2_3b",
    "pixtral_12b",
    "llama4_scout_17b_16e",
    "qwen3_moe_235b_a22b",
    "rwkv6_3b",
]

# canonical external names (the assignment spelling) -> module ids
ALIASES = {
    "recurrentgemma-9b": "recurrentgemma_9b",
    "whisper-large-v3": "whisper_large_v3",
    "qwen1.5-0.5b": "qwen1_5_0_5b",
    "phi3-medium-14b": "phi3_medium_14b",
    "minitron-4b": "minitron_4b",
    "starcoder2-3b": "starcoder2_3b",
    "pixtral-12b": "pixtral_12b",
    "llama4-scout-17b-a16e": "llama4_scout_17b_16e",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "rwkv6-3b": "rwkv6_3b",
}


def resolve(arch: str):
    """Return the config module for an arch id or alias."""
    mod_id = ALIASES.get(arch, arch.replace("-", "_").replace(".", "_"))
    if mod_id not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(ALIASES)}")
    return importlib.import_module(f"repro.configs.{mod_id}")


def get_config(arch: str):
    return resolve(arch).config()


def get_smoke_config(arch: str):
    return resolve(arch).smoke_config()
