"""``SolveService``: the continuous-batching solve front end.

The batched plane used to run batch-at-a-time: admit B instances, run the
compiled chunk loop to completion (compacting the stragglers), return all
B results.  Easy instances finish their lanes early, and those lanes sit
frozen — paid for every chunk — until the whole batch drains.  This module
refactors that into a *live lane lifecycle*, the branching-solver analogue
of an inference server's continuous batching:

* each ``(problem, plane shape)`` gets ONE long-lived compiled plane with
  ``config.service_lanes`` lanes, built from the parametric
  :func:`~repro.core.superstep.build_batch_plane_fn` (instance tensors are
  call-time arguments);
* ``submit(g)`` queues a request and returns a ticket; a
  :class:`LaneScheduler` admits queued requests into *vacant* lanes —
  swap-in is pure data (:func:`~repro.problems.base.write_instance` +
  :func:`~repro.core.engine.make_instance_state`), so admission into a
  freed lane triggers **zero new traces**;
* each :meth:`SolveService.step` runs one compiled chunk per live plane,
  retires lanes whose instance finished (streaming the result out while
  the other lanes keep solving), and re-admits into the freed lanes.

Because finished/vacant lanes are frozen by the plane's per-superstep
select, every admitted instance's trajectory — branching decisions AND
counters — is bit-identical to its solo ``solve`` (the shared goldens
assert this, including the basic codec's byte accounting, which is why
basic-codec planes key on exact ``(W, n)`` while the optimized codec keys
on ``W`` alone with full-width ``n_max = 32·W`` padding).

Scheduling is deterministic: admission order is a pure function of submit
order and completion order (``fifo``), or of the request's
``(priority desc, deadline asc, submit seq)`` key (``priority``), with an
optional per-tenant cap on simultaneously occupied lanes.  ``deadline`` is
a *superstep budget* (the anytime-algorithm deadline of Avis & Devroye) and
``deadline_s`` its wall-clock twin, both checked at chunk boundaries: a lane
over either budget is evicted with its best-so-far anytime result and
``r.stats.service.deadline_hit`` / ``.wall_deadline_hit`` set.  Wall time is
read from an injectable ``clock`` (monotonic seconds) so deadline behavior
is testable without sleeping — and never from inside traced code.

:class:`AsyncSolveService` wraps a service in an asyncio pump for the
``launch.serve`` front end: ``await svc.solve(g)`` resolves when the
instance's lane retires.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.cache import PlaneCache
from repro.api.config import SolveConfig
from repro.api.result import ServiceStats, SolveResult, from_engine_result
from repro.core import engine as _engine
from repro.core.encoding import make_codec
from repro.core.superstep import (
    lane_retire,
    lane_slice,
    lane_state_from_flat,
    lane_state_to_flat,
    lane_swap_in,
    lane_write_back,
    make_vacant_lanes,
    step_lanes,
)
from repro.problems import base as problems_base
from repro.problems.registry import get_problem


class SolveTimeout(TimeoutError):
    """A request exceeded ``SolveConfig.request_timeout_s`` on the
    service's (injectable) clock.

    Raised by :meth:`SolveService.result` and set as the awaited future's
    exception by :class:`AsyncSolveService` — so ``await svc.solve(g)``
    can never hang past the budget.  ``result`` carries the partial
    anytime :class:`~repro.api.result.SolveResult` when the request was on
    a lane (stats populated up to the timeout); ``None`` when it timed out
    still queued.
    """

    def __init__(self, ticket: int, result=None, waited_s: float = 0.0):
        self.ticket = ticket
        self.result = result
        self.waited_s = waited_s
        where = "on a lane" if result is not None else "still queued"
        super().__init__(
            f"request {ticket} timed out after {waited_s:.3f}s ({where})"
        )


@dataclasses.dataclass
class SolveRequest:
    """One queued instance: the graph plus its scheduling attributes."""

    ticket: int
    g: object
    priority: int = 0
    deadline: Optional[int] = None  # superstep budget (anytime eviction)
    deadline_s: Optional[float] = None  # wall-clock budget since submit
    tenant: Optional[str] = None
    k: Optional[int] = None  # fpt decision target (fpt mode only)
    submit_s: float = 0.0


def _req_meta(req: SolveRequest) -> dict:
    """JSON-able scheduling attributes (the graph rides in the checkpoint's
    array payload, keyed by ticket)."""
    return {
        "ticket": req.ticket,
        "priority": req.priority,
        "deadline": req.deadline,
        "deadline_s": req.deadline_s,
        "tenant": req.tenant,
        "k": req.k,
        "submit_s": req.submit_s,
    }


def _req_from_meta(m: dict, graphs: dict) -> SolveRequest:
    return SolveRequest(
        ticket=int(m["ticket"]),
        g=graphs[int(m["ticket"])],
        priority=int(m["priority"]),
        deadline=m["deadline"],
        deadline_s=m.get("deadline_s"),
        tenant=m["tenant"],
        k=m["k"],
        submit_s=float(m["submit_s"]),
    )


class LaneScheduler:
    """Deterministic admission queue over :class:`SolveRequest`.

    ``fifo`` admits in strict submit order; ``priority`` by
    ``(-priority, deadline, seq)`` (unset deadlines sort last).  Admission
    decisions never read the wall clock, so a replayed submit/completion
    sequence admits identically.  ``tenant_max_lanes`` callers pass the
    current per-tenant lane occupancy and requests whose tenant is at the
    cap are skipped (they stay queued, later requests may overtake — that
    is the fairness, not a bug).
    """

    def __init__(
        self, admission: str = "priority", tenant_max_lanes: Optional[int] = None
    ):
        self.admission = admission
        self.tenant_max_lanes = tenant_max_lanes
        self._queue: list = []

    def __len__(self) -> int:
        return len(self._queue)

    def push(self, req: SolveRequest) -> None:
        self._queue.append(req)

    def ordered(self) -> list:
        """The queue in admission order (a copy; callers iterate and
        :meth:`remove` what they admit)."""
        if self.admission == "fifo":
            return sorted(self._queue, key=lambda r: r.ticket)
        big = float("inf")
        return sorted(
            self._queue,
            key=lambda r: (
                -r.priority,
                r.deadline if r.deadline is not None else big,
                r.ticket,
            ),
        )

    def remove(self, req: SolveRequest) -> None:
        self._queue.remove(req)

    def tenant_blocked(self, req: SolveRequest, tenant_occupied: dict) -> bool:
        if self.tenant_max_lanes is None or req.tenant is None:
            return False
        return tenant_occupied.get(req.tenant, 0) >= self.tenant_max_lanes


class _LivePlane:
    """One long-lived compiled plane: ``service_lanes`` lanes over a fixed
    ``(n_max, W, capacity)`` packing, plus the host bookkeeping (which
    ticket occupies which lane, when it was admitted, its round budget)."""

    def __init__(self, spec, cfg: SolveConfig, cache: PlaneCache, key: tuple):
        W, n_exact = key
        self.key = key
        self.W = W
        # optimized codec: full-width pad (any n <= 32·W admits, padding
        # rows are isolated never-in-mask vertices — padding invariance);
        # basic codec: exact n (its §4.3 payload pad is n·W words, so the
        # per-instance byte accounting must see the solo n).
        self.n_max = n_exact if n_exact is not None else problems_base.WORD_BITS * W
        self.cap = cfg.capacity or (4 * self.n_max + 8 * cfg.lanes)
        self.pad = make_codec(cfg.codec, self.n_max, problem=spec).pad_words
        self.use_fpt = cfg.mode == "fpt"
        B = cfg.service_lanes
        self.lanes = make_vacant_lanes(B, cfg.num_workers, self.cap, W)
        self.datas = problems_base.make_blank_batch_data(B, self.n_max, W)
        self.fpt_bounds = jnp.zeros((B,), jnp.int32) if self.use_fpt else None
        self.plane = cache.batch_plane(spec, cfg, self.pad, self.use_fpt)
        # host-side per-lane occupancy records (None = vacant)
        self.requests: list = [None] * B
        self.admit_s: list = [0.0] * B
        # per-lane cold tiers (repro.core.spill), created at admission when
        # cfg.frontier_spill is on; survive chunks, dropped at retire
        self.spillers: list = [None] * B
        # -- self-healing bookkeeping (repro.faults) --------------------------
        # quarantined lanes (crashed/stalled occupants were re-queued; the
        # lane is excluded from admission until rehabilitated, oldest first),
        # load shedding under repeated faults, and the stall watchdog's
        # per-lane progress snapshots
        self.quarantined: list = []
        self.shed = 0
        self.fault_hits = 0  # accumulator: every 2 plane faults sheds 1 lane
        self.fault_free = 0  # consecutive fault-free chunks (heals shedding)
        self.last_rounds: list = [0] * B
        self.stall_chunks: list = [0] * B

    def occupied_count(self) -> int:
        return int(self.lanes.occupied().sum())

    def admit_limit(self) -> int:
        """Simultaneously usable lanes under quarantine + load shedding
        (never below one — a degraded plane still makes progress)."""
        return max(
            1, self.lanes.num_lanes - len(self.quarantined) - self.shed
        )

    def vacant_lane(self) -> Optional[int]:
        if self.occupied_count() >= self.admit_limit():
            return None
        free = np.flatnonzero(~self.lanes.occupied())
        for lane in free:
            if int(lane) not in self.quarantined:
                return int(lane)
        # every free lane sits quarantined yet the (floor-clamped) budget
        # admits: rehabilitate the oldest quarantine, so repeated faults
        # can never darken the whole plane
        if free.size and self.quarantined:
            return self.quarantined.pop(0)
        return None


class SolveService:
    """The continuous-batching service over one (problem, backend config).

    >>> svc = SolveService(problem="max_clique",
    ...                    config=SolveConfig(service_lanes=4))
    >>> t = svc.submit(g, priority=1)
    >>> done = svc.drain()          # or step() incrementally
    >>> svc.result(t).best_size     # pops; KeyError if not finished

    Only the SPMD engine has a live batched plane, so the service is
    spmd-only by construction (other backends solve instance-at-a-time —
    use :class:`~repro.api.session.SolverSession` directly).
    """

    def __init__(
        self,
        problem,
        config: Optional[SolveConfig] = None,
        *,
        cache: Optional[PlaneCache] = None,
        clock=None,
        injector=None,
    ):
        self.spec = get_problem(problem)
        # monotonic-seconds source for submit/admit/deadline bookkeeping;
        # injectable so wall-clock deadline tests advance time themselves
        self._clock = clock if clock is not None else time.perf_counter
        # optional repro.faults.FaultInjector: fires its plan at this
        # service's chunk boundaries; the quarantine/re-queue machinery
        # below is the paired recovery (None = nothing injected, but the
        # watchdog and timeout sweeps still protect against organic faults)
        self.injector = injector
        self.config = config if config is not None else SolveConfig()
        if self.config.use_mesh:
            raise ValueError(
                "SolveService runs on the vmap virtual-worker plane; "
                "use_mesh configs are not servable yet"
            )
        self.cache = cache if cache is not None else PlaneCache()
        self.scheduler = LaneScheduler(
            self.config.admission, self.config.tenant_max_lanes
        )
        self._planes: dict = {}  # (W, n_exact|None) -> _LivePlane
        self._results: dict = {}  # ticket -> SolveResult | SolveTimeout
        self._next_ticket = 0
        self._t0 = self._clock()
        # ticket -> [faults_injected, faults_recovered, lanes_quarantined]
        # (the per-request slice of the self-healing ledger)
        self._req_faults: dict = {}
        self._stats = {
            "submitted": 0,
            "completed": 0,
            "evicted": 0,
            "steps": 0,
            "chunk_calls": 0,
            "lane_chunks": 0,
            "live_lane_chunks": 0,
            "wait_s_total": 0.0,
            "residency_s_total": 0.0,
            "lanes_quarantined": 0,
            "timed_out": 0,
        }

    # -- submission ------------------------------------------------------------

    def submit(
        self,
        g,
        *,
        priority: int = 0,
        deadline: Optional[int] = None,
        deadline_s: Optional[float] = None,
        tenant: Optional[str] = None,
        k: Optional[int] = None,
    ) -> int:
        """Queue one instance; returns its ticket immediately.

        ``deadline`` is a superstep budget (anytime eviction at chunk
        granularity); ``deadline_s`` is a wall-clock budget in seconds
        since submit, measured on the service's clock and checked at the
        same chunk boundaries; ``k`` overrides the config's fpt target for
        this request (fpt mode only).
        """
        if k is not None and self.config.mode != "fpt":
            raise ValueError("per-request k needs mode='fpt'")
        if deadline is not None and deadline < 1:
            raise ValueError(f"deadline must be a superstep budget >= 1, got {deadline}")
        if deadline_s is not None and not deadline_s > 0:
            raise ValueError(
                f"deadline_s must be a wall-clock budget > 0 seconds, "
                f"got {deadline_s}"
            )
        if self.config.mode == "fpt" and k is None:
            k = self.config.solo_k()
        ticket = self._next_ticket
        self._next_ticket += 1
        self.scheduler.push(
            SolveRequest(
                ticket=ticket,
                g=g,
                priority=priority,
                deadline=deadline,
                deadline_s=deadline_s,
                tenant=tenant,
                k=k,
                submit_s=self._clock() - self._t0,
            )
        )
        self._stats["submitted"] += 1
        return ticket

    # -- the service loop ------------------------------------------------------

    def step(self) -> list:
        """Admit into vacant lanes, run ONE compiled chunk per live plane,
        retire finished lanes; returns the tickets completed this step.

        With ``config.checkpoint_dir`` set, every ``checkpoint_every``-th
        step also writes a service checkpoint (see :meth:`checkpoint`)."""
        self._stats["steps"] += 1
        completed = self._sweep_queue_timeouts()
        self._admit()
        for plane in self._planes.values():
            if plane.occupied_count() == 0:
                continue  # an all-vacant plane costs nothing
            completed.extend(self._step_plane(plane))
        if (
            self.config.checkpoint_dir is not None
            and self._stats["steps"] % self.config.checkpoint_every == 0
        ):
            self.checkpoint(self.config.checkpoint_dir)
        return completed

    def drain(self) -> list:
        """Run :meth:`step` until the queue is empty and every lane is
        vacant; returns all tickets completed (order = completion order)."""
        completed = []
        while len(self.scheduler) or any(
            p.occupied_count() for p in self._planes.values()
        ):
            completed.extend(self.step())
        return completed

    def idle(self) -> bool:
        return not len(self.scheduler) and not any(
            p.occupied_count() for p in self._planes.values()
        )

    # -- results ---------------------------------------------------------------

    def result(self, ticket: int) -> SolveResult:
        """Pop a finished ticket's result; ``KeyError`` if the ticket is
        unknown or still queued/solving (step/drain first).  A ticket that
        hit ``config.request_timeout_s`` raises its :class:`SolveTimeout`
        (carrying the partial anytime result when one exists)."""
        out = self._results.pop(ticket)
        if isinstance(out, SolveTimeout):
            raise out
        return out

    def ready(self, ticket: int) -> bool:
        return ticket in self._results

    def tickets(self) -> list:
        """Every outstanding ticket (queued or on a lane), sorted — after
        :meth:`restore` this is the work the service still owes."""
        out = {r.ticket for r in self.scheduler.ordered()}
        for p in self._planes.values():
            out.update(r.ticket for r in p.requests if r is not None)
        return sorted(out)

    # -- introspection ---------------------------------------------------------

    def status(self) -> dict:
        """Queue depth plus per-plane lane occupancy (vacant lanes are the
        admission capacity the next ``step`` can fill)."""
        planes = {}
        for key, p in self._planes.items():
            occ = p.occupied_count()
            planes[str(key)] = {
                "lanes": p.lanes.num_lanes,
                "occupied": occ,
                "vacant": p.lanes.num_lanes - occ,
                "tickets": sorted(
                    r.ticket for r in p.requests if r is not None
                ),
            }
        return {"queued": len(self.scheduler), "planes": planes}

    def stats(self) -> dict:
        """Service counters: throughput inputs (completed, chunk_calls),
        plane occupancy (live_lane_chunks / lane_chunks) and residency."""
        s = dict(self._stats)
        s["queued"] = len(self.scheduler)
        s["planes"] = len(self._planes)
        s["occupancy"] = (
            s["live_lane_chunks"] / s["lane_chunks"] if s["lane_chunks"] else 0.0
        )
        n_done = s["completed"]
        s["wait_s_mean"] = s["wait_s_total"] / n_done if n_done else 0.0
        s["residency_s_mean"] = s["residency_s_total"] / n_done if n_done else 0.0
        # the self-healing ledger (zeros without an injector: organic
        # quarantines/timeouts still show via lanes_quarantined/timed_out)
        inj = self.injector
        s["faults_injected"] = inj.faults_injected if inj is not None else 0
        s["faults_recovered"] = inj.faults_recovered if inj is not None else 0
        s["retries"] = inj.retries if inj is not None else 0
        s["lanes_shed"] = sum(p.shed for p in self._planes.values())
        return s

    def cache_stats(self) -> dict:
        return self.cache.stats().to_dict()

    # -- durability ------------------------------------------------------------

    def checkpoint(
        self, directory: Optional[str] = None, *, blocking: bool = True
    ) -> str:
        """Snapshot the ENTIRE service — every live plane's LaneState +
        instance tensors, the pending queue, finished-but-unclaimed
        results, ticket counter and service stats — atomically through
        :mod:`repro.checkpoint.store` (step number = service steps).

        A service restored from this checkpoint (:meth:`restore`) finishes
        every admitted ticket with answers bit-identical to the
        uninterrupted service: lane state is exact, admission is a pure
        function of the restored queue/occupancy, and deadlines are
        superstep budgets carried in the restored per-lane ``rounds``.
        """
        from repro.checkpoint import solve as _ckpt

        directory = directory or self.config.checkpoint_dir
        if directory is None:
            raise ValueError(
                "no checkpoint directory: pass one or set "
                "SolveConfig.checkpoint_dir"
            )
        ck = _ckpt.SolveCheckpoint(
            kind="service",
            problem=self.spec.name,
            config=self.config.replace(resume_from=None).to_dict(),
            fingerprint=_ckpt.config_fingerprint(
                "service", self.spec.name, self.config, []
            ),
            rounds=self._stats["steps"],
            arrays={},
        )
        planes_meta = []
        for pi, (key, plane) in enumerate(self._planes.items()):
            ck.arrays.update(lane_state_to_flat(plane.lanes, f"plane{pi}/lanes"))
            ck.arrays.update(_ckpt.data_to_flat(plane.datas, f"plane{pi}/datas"))
            if plane.use_fpt:
                ck.arrays[f"plane{pi}/fpt_bounds"] = np.asarray(
                    jax.device_get(plane.fpt_bounds)
                )
            for lane, sp in enumerate(plane.spillers):
                if sp is not None:
                    ck.arrays.update(sp.to_flat(f"plane{pi}/spill{lane}"))
            planes_meta.append(
                {
                    "key": list(key),
                    "requests": [
                        None if r is None else _req_meta(r)
                        for r in plane.requests
                    ],
                    "admit_s": [float(a) for a in plane.admit_s],
                }
            )
        live = [
            r
            for p in self._planes.values()
            for r in p.requests
            if r is not None
        ]
        queued = list(self.scheduler._queue)
        ck.pack_graphs(
            [r.ticket for r in live + queued], [r.g for r in live + queued]
        )
        ck.meta.update(
            {
                "planes": planes_meta,
                "queue": [_req_meta(r) for r in queued],
                "results": {
                    str(t): r.to_dict() for t, r in self._results.items()
                },
                "next_ticket": self._next_ticket,
                "stats": dict(self._stats),
            }
        )
        inj = self.injector
        return ck.save(
            directory,
            self._stats["steps"],
            blocking=blocking,
            retry=inj.retry_policy() if inj is not None else None,
            fault_hook=inj.io_hook if inj is not None else None,
        )

    @classmethod
    def restore(
        cls,
        path: str,
        *,
        step: Optional[int] = None,
        cache: Optional[PlaneCache] = None,
    ) -> "SolveService":
        """Rebuild a service from a :meth:`checkpoint` snapshot (a
        checkpoint dir — latest step — or one ``step_<N>`` subdir).

        The compiled planes come from ``cache`` via the normal
        :class:`_LivePlane` path, so restoring into a cache that is warm
        for the plane shapes re-traces NOTHING (``PLANE_TRACES``-asserted
        in the tests); pass no cache to (re)compile on first step.
        """
        from repro.checkpoint import solve as _ckpt

        if step is None:
            # walk the retained generations (latest first, each with its
            # .prev twin) past corrupt/truncated snapshots — same fallback
            # ladder as solo/batch resume
            ck = _ckpt.SolveCheckpoint.load_latest_good(path, what="service")
        else:
            ck = _ckpt.SolveCheckpoint.load(path, step)
        if ck.kind != "service":
            raise _ckpt.CheckpointError(
                f"{path} holds a {ck.kind!r} checkpoint; "
                f"SolveService.restore needs a 'service' checkpoint"
            )
        svc = cls(
            ck.problem, SolveConfig.from_dict(ck.config), cache=cache
        )
        meta = ck.meta
        graphs = {
            int(t): ck.unpack_graph(int(t)) for t in meta["graph_ns"]
        }
        for pi, pmeta in enumerate(meta["planes"]):
            W, n_exact = pmeta["key"]
            key = (int(W), None if n_exact is None else int(n_exact))
            plane = _LivePlane(svc.spec, svc.config, svc.cache, key)
            plane.lanes = lane_state_from_flat(ck.arrays, f"plane{pi}/lanes")
            plane.datas = _ckpt.data_from_flat(ck.arrays, f"plane{pi}/datas")
            if plane.use_fpt:
                plane.fpt_bounds = jnp.asarray(ck.arrays[f"plane{pi}/fpt_bounds"])
            plane.requests = [
                None if m is None else _req_from_meta(m, graphs)
                for m in pmeta["requests"]
            ]
            plane.admit_s = [float(a) for a in pmeta["admit_s"]]
            if svc.config.frontier_spill:
                from repro.core.spill import FrontierSpiller, make_spiller

                for lane, r in enumerate(plane.requests):
                    pref = f"plane{pi}/spill{lane}"
                    if r is not None and FrontierSpiller.present_in(
                        ck.arrays, pref
                    ):
                        sp = make_spiller(
                            svc.config, svc.spec, r.g, plane.cap,
                            svc.config.num_workers,
                        )
                        sp.load_flat(ck.arrays, pref)
                        plane.spillers[lane] = sp
            svc._planes[key] = plane
        for m in meta["queue"]:
            svc.scheduler.push(_req_from_meta(m, graphs))
        svc._results = {
            int(t): SolveResult.from_dict(d)
            for t, d in meta["results"].items()
        }
        svc._next_ticket = int(meta["next_ticket"])
        svc._stats.update(meta["stats"])
        return svc

    # -- internals -------------------------------------------------------------

    def _plane_key(self, g) -> tuple:
        return (g.W, g.n if self.config.codec == "basic" else None)

    def _plane_for(self, g) -> _LivePlane:
        key = self._plane_key(g)
        plane = self._planes.get(key)
        if plane is None:
            plane = _LivePlane(self.spec, self.config, self.cache, key)
            self._planes[key] = plane
        return plane

    def _tenant_occupied(self) -> dict:
        occ: dict = {}
        for p in self._planes.values():
            for r in p.requests:
                if r is not None and r.tenant is not None:
                    occ[r.tenant] = occ.get(r.tenant, 0) + 1
        return occ

    def _admit(self) -> None:
        tenant_occ = self._tenant_occupied()
        for req in self.scheduler.ordered():
            if self.scheduler.tenant_blocked(req, tenant_occ):
                continue
            plane = self._plane_for(req.g)
            lane = plane.vacant_lane()
            if lane is None:
                continue  # this plane is full; later keys may still admit
            self._admit_into(plane, lane, req)
            self.scheduler.remove(req)
            if req.tenant is not None:
                tenant_occ[req.tenant] = tenant_occ.get(req.tenant, 0) + 1

    def _admit_into(self, plane: _LivePlane, lane: int, req: SolveRequest) -> None:
        cfg, spec, g = self.config, self.spec, req.g
        # the solo pad for this n must match the plane's (true for the
        # native record schema; a problem with n-sized record extras under
        # the optimized codec would silently skew byte accounting — refuse)
        solo_pad = make_codec(cfg.codec, g.n, problem=spec).pad_words
        if solo_pad != plane.pad:
            raise ValueError(
                f"problem {spec.name!r} has n-dependent record padding "
                f"(pad {solo_pad} at n={g.n} vs plane {plane.pad}); "
                "serve it with codec='basic' (exact-n planes)"
            )
        initial_best = problems_base.initial_bound(spec, g, cfg.mode, req.k)
        worker = _engine.make_instance_state(
            spec, g, cfg.num_workers, plane.cap, plane.W, initial_best
        )
        plane.lanes = lane_swap_in(plane.lanes, lane, worker, req.ticket)
        plane.datas = problems_base.write_instance(plane.datas, lane, spec, g)
        if plane.use_fpt:
            plane.fpt_bounds = plane.fpt_bounds.at[lane].set(
                int(spec.fpt_target(req.k))
            )
        plane.requests[lane] = req
        plane.admit_s[lane] = self._clock() - self._t0
        plane.last_rounds[lane] = 0
        plane.stall_chunks[lane] = 0
        if cfg.frontier_spill:
            from repro.core.spill import make_spiller

            plane.spillers[lane] = make_spiller(
                cfg, spec, g, plane.cap, cfg.num_workers,
                injector=self.injector,
            )
        self.cache.note(
            "batch",
            spec,
            cfg,
            plane.pad,
            plane.use_fpt,
            (plane.n_max, plane.W, plane.cap, cfg.num_workers, plane.lanes.num_lanes),
        )

    def _sweep_queue_timeouts(self) -> list:
        """Resolve queued requests past ``config.request_timeout_s`` to a
        typed :class:`SolveTimeout` (no partial result — never admitted)."""
        budget = self.config.request_timeout_s
        if budget is None or not len(self.scheduler):
            return []
        now = self._clock() - self._t0
        out = []
        for req in self.scheduler.ordered():
            waited = now - req.submit_s
            if waited >= budget:
                self.scheduler.remove(req)
                self._req_faults.pop(req.ticket, None)
                self._results[req.ticket] = SolveTimeout(
                    req.ticket, result=None, waited_s=waited
                )
                self._stats["timed_out"] += 1
                out.append(req.ticket)
        return out

    def _quarantine(
        self, plane: _LivePlane, lane: int, *, injected: int, recovered: int
    ) -> None:
        """Retire a crashed/stalled lane, quarantine it, and push its
        occupant back through the scheduler.  The old ticket sorts first in
        both admission orders, so re-admission is deterministic — and since
        :meth:`_admit_into` rebuilds the instance from the same
        ``make_instance_state`` startup placement (fresh spiller, full
        replay), the re-run's result is bit-identical to an undisturbed
        solve."""
        req = plane.requests[lane]
        plane.lanes = lane_retire(plane.lanes, lane)
        plane.requests[lane] = None
        plane.spillers[lane] = None
        plane.stall_chunks[lane] = 0
        if lane not in plane.quarantined:
            plane.quarantined.append(lane)
        self._stats["lanes_quarantined"] += 1
        if req is not None:
            self.scheduler.push(req)
            ledger = self._req_faults.setdefault(req.ticket, [0, 0, 0])
            ledger[0] += injected
            ledger[1] += recovered
            ledger[2] += 1

    def _step_plane(self, plane: _LivePlane) -> list:
        inj = self.injector
        occupied_before = plane.lanes.occupied()
        self._stats["chunk_calls"] += 1
        self._stats["lane_chunks"] += plane.lanes.num_lanes
        self._stats["live_lane_chunks"] += int(occupied_before.sum())

        n_faults = 0
        frozen: dict = {}
        if inj is not None:
            inj.step_boundary()
            live = [int(l) for l in np.flatnonzero(plane.lanes.occupied())]
            # lane crashes: the occupant's device state is lost at this
            # boundary — quarantine the lane and re-queue the request (the
            # recovery: a bit-identical replay from startup placement)
            for lane in inj.take_crashes(live):
                self._quarantine(plane, lane, injected=1, recovered=1)
                inj.note_recovered("crash")
                n_faults += 1
            # stalled lanes: snapshot before the chunk, write back after —
            # the lane observably makes no progress, the compiled plane is
            # untouched, and the watchdog below eventually quarantines it
            live = [int(l) for l in np.flatnonzero(plane.lanes.occupied())]
            for lane in inj.stalled_lanes(live):
                frozen[lane] = (
                    lane_slice(plane.lanes, lane),
                    plane.lanes.done[lane],
                    plane.lanes.rounds[lane],
                )

        occupied = np.array(plane.lanes.occupied())
        plane.lanes, _ran, hot = step_lanes(
            plane.plane, plane.datas, plane.lanes, plane.fpt_bounds
        )
        for lane, (worker, done_snap, rounds_snap) in frozen.items():
            plane.lanes = lane_write_back(
                plane.lanes, lane, worker, done_snap, rounds_snap
            )
        done_h, rounds_h = map(
            np.asarray, jax.device_get((plane.lanes.done, plane.lanes.rounds))
        )
        done_h = np.array(done_h)

        # stall watchdog: an occupied, unfinished lane whose round counter
        # made no progress for lane_stall_chunks consecutive chunks is
        # quarantined and its instance re-queued (this is also what clears
        # injected stall windows — organic stalls heal the same way)
        for lane in [int(l) for l in np.flatnonzero(occupied & ~done_h)]:
            if int(rounds_h[lane]) == plane.last_rounds[lane]:
                plane.stall_chunks[lane] += 1
            else:
                plane.stall_chunks[lane] = 0
                plane.last_rounds[lane] = int(rounds_h[lane])
            if plane.stall_chunks[lane] >= self.config.lane_stall_chunks:
                cleared = inj.clear_stall(lane) if inj is not None else 0
                self._quarantine(
                    plane, lane, injected=cleared, recovered=cleared
                )
                occupied[lane] = False
                frozen.pop(lane, None)
                n_faults += 1

        # graceful degradation: every 2 plane faults sheds one admission
        # slot (floor of one usable lane); 8 consecutive fault-free chunks
        # heal one shed slot, then rehabilitate quarantined lanes
        if n_faults:
            plane.fault_free = 0
            plane.fault_hits += n_faults
            while plane.fault_hits >= 2:
                plane.fault_hits -= 2
                if plane.shed < plane.lanes.num_lanes - 1:
                    plane.shed += 1
        else:
            plane.fault_free += 1
            if plane.fault_free >= 8:
                plane.fault_free = 0
                if plane.shed > 0:
                    plane.shed -= 1
                elif plane.quarantined:
                    plane.quarantined.pop(0)

        if self.config.frontier_spill:
            # the spill pump runs BEFORE the finished verdict: a lane that
            # went quiescent with a cold backlog is refilled and resumed,
            # not retired (an FPT bound hit finishes regardless)
            from repro.core.superstep import lane_resume

            hot_h = np.array(jax.device_get(hot))
            best_h = bounds_h = None
            for lane in np.flatnonzero(occupied):
                sp = plane.spillers[lane]
                if int(lane) in frozen:
                    continue  # stalled this chunk: its hot counts are stale
                if sp is None or not sp.wants_pump(
                    hot_h[lane], bool(done_h[lane])
                ):
                    continue
                if bool(done_h[lane]) and plane.use_fpt:
                    if best_h is None:
                        best_h = np.asarray(
                            jax.device_get(plane.lanes.worker.best_val)
                        )[:, 0]
                        bounds_h = np.asarray(
                            jax.device_get(plane.fpt_bounds)
                        )
                    if int(best_h[lane]) <= int(bounds_h[lane]):
                        continue
                plane.lanes, hot_lane = sp.pump_lane(plane.lanes, int(lane))
                hot_h[lane] = hot_lane
                if bool(done_h[lane]) and int(hot_lane.sum()) > 0:
                    plane.lanes = lane_resume(plane.lanes, int(lane))
                    done_h[lane] = False

        now = self._clock() - self._t0
        timeout_s = self.config.request_timeout_s
        finished = np.flatnonzero(occupied & done_h)
        over_wall = set()
        timed_out = set()
        over_budget = []
        for lane in np.flatnonzero(occupied & ~done_h):
            req = plane.requests[lane]
            if rounds_h[lane] >= min(
                req.deadline or self.config.max_rounds, self.config.max_rounds
            ):
                over_budget.append(lane)
            elif (
                req.deadline_s is not None
                and now - req.submit_s >= req.deadline_s
            ):
                over_budget.append(lane)
                over_wall.add(int(lane))
            elif timeout_s is not None and now - req.submit_s >= timeout_s:
                over_budget.append(lane)
                timed_out.add(int(lane))
        if len(finished) == 0 and not over_budget:
            return []

        host = _engine._fetch_batch_state(plane.lanes.worker)
        completed = []
        for lane in list(finished) + list(over_budget):
            lane = int(lane)
            req = plane.requests[lane]
            evicted = lane not in finished
            r = _engine._extract_result(
                host,
                lane,
                self.spec,
                req.g,
                int(rounds_h[lane]),
                now - plane.admit_s[lane],
                mode=self.config.mode,
                k=req.k,
                num_workers=self.config.num_workers,
                packed_status=self.config.packed_status,
            )
            res = from_engine_result(r, problem=self.spec.name, backend="spmd")
            sp = plane.spillers[lane]
            if sp is not None:
                res.stats.spilled_tasks = sp.spilled_total
                res.stats.readmitted_tasks = sp.readmitted_total
                res.stats.cold_bytes_peak = sp.cold_bytes_peak
            fi, fr, fq = self._req_faults.pop(req.ticket, (0, 0, 0))
            res.stats.service = ServiceStats(
                lane=lane,
                plane=str(plane.key),
                wait_s=plane.admit_s[lane] - req.submit_s,
                residency_s=now - plane.admit_s[lane],
                deadline_hit=(
                    evicted
                    and req.deadline is not None
                    and lane not in over_wall
                    and lane not in timed_out
                ),
                wall_deadline_hit=lane in over_wall,
                faults_injected=fi,
                faults_recovered=fr,
                lanes_quarantined=fq,
                retries=sp.delivery_retries if sp is not None else 0,
            )
            if lane in timed_out:
                self._results[req.ticket] = SolveTimeout(
                    req.ticket, result=res, waited_s=now - req.submit_s
                )
                self._stats["timed_out"] += 1
            else:
                self._results[req.ticket] = res
            completed.append(req.ticket)
            self._stats["completed"] += 1
            self._stats["evicted"] += int(evicted)
            self._stats["wait_s_total"] += plane.admit_s[lane] - req.submit_s
            self._stats["residency_s_total"] += now - plane.admit_s[lane]
            plane.lanes = lane_retire(plane.lanes, lane)
            plane.requests[lane] = None
            plane.spillers[lane] = None
        return completed


class AsyncSolveService:
    """asyncio pump over a :class:`SolveService` for the serve front end.

    ``await svc.solve(g, ...)`` submits and resolves when the lane retires;
    the pump thread-pools :meth:`SolveService.step` so the event loop stays
    responsive while chunks run on device.  Submission and stepping share
    one lock (the service itself is not thread-safe).

    With ``SolveConfig.request_timeout_s`` set, an awaited solve can never
    hang: a request over budget — queued or on a lane — resolves the
    future with a :class:`SolveTimeout` exception (carrying the partial
    anytime result when one exists).
    """

    def __init__(self, service: SolveService, idle_sleep_s: float = 0.002):
        self.service = service
        self.idle_sleep_s = idle_sleep_s
        self._lock = threading.Lock()
        self._futures: dict = {}
        self._task = None
        self._closing = False

    async def __aenter__(self):
        import asyncio

        self._task = asyncio.get_running_loop().create_task(self._pump())
        return self

    async def __aexit__(self, *exc):
        import asyncio

        self._closing = True
        if self._task is not None:
            await self._task
            self._task = None
        return False

    async def solve(self, g, **submit_kw) -> SolveResult:
        import asyncio

        with self._lock:
            ticket = self.service.submit(g, **submit_kw)
        fut = asyncio.get_running_loop().create_future()
        self._futures[ticket] = fut
        return await fut

    async def _pump(self):
        import asyncio

        loop = asyncio.get_running_loop()

        def locked_step():
            with self._lock:
                return self.service.step()

        while True:
            with self._lock:
                idle = self.service.idle()
            if idle:
                if self._closing:
                    return
                await asyncio.sleep(self.idle_sleep_s)
                continue
            done = await loop.run_in_executor(None, locked_step)
            for ticket in done:
                fut = self._futures.pop(ticket, None)
                if fut is None:
                    continue
                try:
                    res = self.service.result(ticket)
                except SolveTimeout as exc:
                    if not fut.done():
                        fut.set_exception(exc)
                else:
                    if not fut.done():
                        fut.set_result(res)
            await asyncio.sleep(0)
