"""Semi-centralized request balancer for batched decode serving.

This is the BEYOND-PAPER integration of the paper's contribution into the LM
framework: the center/worker mechanics of §3.1-3.2 reapplied to continuous
batching across data-parallel decode replicas.

Mapping (paper → serving):
  worker                    → one data-parallel decode replica (a model mesh)
  task                      → an in-flight request (prompt + tokens-left)
  task "size" metadata      → the request's remaining-work estimate
  AVAILABLE worker          → replica whose batch occupancy fell below the
                              low-water mark (finished requests drain it)
  heaviest-pending donation → the donor replica hands over its LARGEST
                              remaining-work queued request
  center                    → the replicated matcher: every replica computes
                              the same pairing from an all-gathered O(R)
                              status vector (occupancy ⊕ top queue work);
                              request payloads (prompt ids / KV handles)
                              move replica→replica, never through a center

Failure-free property: a replica below the low-water mark is matched only to
replicas with queue depth ≥ 1, so a match always yields a request.  Exactly
the paper's guarantee, restated for serving.

This module is deliberately runnable at host level (numpy state machine) so
the scheduler can also front a real multi-process deployment; the device
twin reuses ``repro.core.superstep.match_idle_to_donors``.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class RequestBatch:
    """One replica's continuous-batching state."""

    capacity: int  # max concurrent decode slots
    active_work: list  # remaining tokens per active request
    queued_work: list  # remaining tokens per queued request

    @property
    def occupancy(self) -> int:
        return len(self.active_work)

    def admit(self) -> None:
        """Move queued requests into free slots (largest-work first — the
        paper's priority ordering keeps long requests from starving)."""
        self.queued_work.sort(reverse=True)
        while self.queued_work and self.occupancy < self.capacity:
            self.active_work.append(self.queued_work.pop(0))

    def step(self, tokens: int = 1) -> int:
        """Decode ``tokens`` for every active request; returns # finished."""
        self.active_work = [w - tokens for w in self.active_work]
        done = sum(w <= 0 for w in self.active_work)
        self.active_work = [w for w in self.active_work if w > 0]
        return done


@dataclasses.dataclass
class BalancerState:
    replicas: list  # list[RequestBatch]
    low_water: float = 0.5  # occupancy fraction that triggers an 'available'
    transfers: int = 0
    control_ints_per_round: int = 0

    def status(self) -> np.ndarray:
        """(R, 2) int status table — the center's ENTIRE state (paper §3.1):
        column 0 = deficit (free slots below low-water, 0 if none),
        column 1 = largest queued work (0 if queue empty)."""
        rows = []
        for r in self.replicas:
            lw = int(r.capacity * self.low_water)
            deficit = max(lw - (r.occupancy + len(r.queued_work)), 0)
            top = max(r.queued_work) if r.queued_work else 0
            rows.append((deficit, top))
        self.control_ints_per_round = 2 * len(self.replicas)
        return np.array(rows, dtype=np.int64)


def rebalance(state: BalancerState) -> int:
    """One matching round (the replicated center).  Donors = replicas with a
    queue; receivers = replicas under the low-water mark.  Matching is
    deterministic (sorted by metadata), so every replica computes the same
    answer from the same status table.  Returns # requests moved."""
    table = state.status()
    receivers = [i for i in np.argsort(-table[:, 0]) if table[i, 0] > 0]
    donors = sorted(
        (i for i in range(len(state.replicas)) if table[i, 1] > 0),
        key=lambda i: (-table[i, 1], i),
    )
    moved = 0
    for recv, donor in zip(receivers, donors):
        if recv == donor:
            continue
        dq = state.replicas[donor].queued_work
        dq.sort(reverse=True)
        req = dq.pop(0)  # heaviest pending request (paper §3.4 priority)
        state.replicas[recv].queued_work.append(req)
        moved += 1
    state.transfers += moved
    return moved


# -- solve-plane admission ------------------------------------------------------


@dataclasses.dataclass
class SolveBatcher:
    """Admit a stream of branching-problem solve requests into fixed-size
    batched-solve-plane (``SolverSession.solve_many``) batches.

    This is the serving front of the batched solve plane: a request's
    "replica" is one of the B lanes of a solve batch, so the continuous-
    batching occupancy machinery above applies unchanged — each
    ``(problem, W)`` packing bucket is a :class:`RequestBatch` whose
    ``capacity`` is the plane's batch size, and ``admit()``
    (largest-work-first) decides which queued instances fill the free lanes,
    so big instances never starve behind a stream of small ones.  Queue
    entries are ``(work, -seq)`` pairs — the work estimate is the instance
    size, the same §3.2 single-integer metadata the solver's center runs on;
    the negated sequence makes equal-size requests drain FIFO under the
    descending sort.  Buckets follow the solve plane's packing rule: one
    batch never mixes packed widths W, and never mixes PROBLEMS — a plane
    compiles one problem's brancher (`solve_many` pads n within a bucket).

    Only the admission half of :class:`RequestBatch` (``admit``/
    ``occupancy``) tolerates these tuple entries — never feed a batcher
    bucket to ``step()``/``status()``/``rebalance``, which do integer
    arithmetic on the work values.
    """

    batch_size: int
    # (problem, W) -> RequestBatch
    buckets: dict = dataclasses.field(default_factory=dict)
    graphs: dict = dataclasses.field(default_factory=dict)  # seq -> instance
    problems: dict = dataclasses.field(default_factory=dict)  # seq -> name
    _seq: int = 0
    # tickets drained into a batch but not yet taken by a solver
    _drained: set = dataclasses.field(default_factory=set)

    def submit(self, g, problem: str = "vertex_cover") -> int:
        """Queue one instance; returns its ticket (submission sequence)."""
        seq = self._seq
        self._seq += 1
        self.graphs[seq] = g
        self.problems[seq] = problem
        rb = self.buckets.setdefault(
            (problem, g.W), RequestBatch(self.batch_size, [], [])
        )
        rb.queued_work.append((g.n, -seq))
        return seq

    def _drain(self, rb: RequestBatch) -> list:
        lanes, rb.active_work = rb.active_work, []
        tickets = [-neg_seq for _, neg_seq in lanes]
        self._drained.update(tickets)
        return tickets

    def problem_of(self, ticket) -> str:
        """The problem a queued ticket was submitted under (call before
        ``take``, which evicts the record)."""
        return self.problems[ticket]

    def status(self) -> dict:
        """Per-bucket admission view: ``queued`` (not yet in a lane),
        ``admitted`` (in a lane awaiting drain) and ``vacant`` lanes.  A
        partially-filled bucket's unfilled lanes ARE vacant — a flush()
        solves only the real instances, the plane pads internally and no
        placeholder ticket ever exists for a padded lane."""
        out = {}
        for key, rb in self.buckets.items():
            out[key] = {
                "queued": len(rb.queued_work),
                "admitted": rb.occupancy,
                "vacant": rb.capacity - rb.occupancy,
            }
        return out

    def take(self, tickets) -> list:
        """Hand a drained batch's instances to the solver, EVICTING them —
        the batcher holds a graph only between submit and take, so a
        long-lived admission stream does not accumulate solved instances.

        Only tickets from a drained batch (``ready_batches``/``flush``
        output) are takeable: taking a still-queued ticket would leave its
        stale queue entry to drain later with no instance behind it — a
        placeholder result — so that raises instead."""
        not_ready = [t for t in tickets if t not in self._drained]
        if not_ready:
            raise ValueError(
                f"ticket(s) {not_ready} not in any drained batch yet; "
                "take() only accepts ready_batches()/flush() output"
            )
        self._drained.difference_update(tickets)
        for t in tickets:
            self.problems.pop(t, None)
        return [self.graphs.pop(t) for t in tickets]

    def ready_batches(self) -> list:
        """Every FULL plane currently admissible: lists of tickets, one list
        per batch.  Partially-filled planes stay queued (call ``flush``)."""
        out = []
        for rb in self.buckets.values():
            rb.admit()
            while rb.occupancy == rb.capacity:
                out.append(self._drain(rb))
                rb.admit()
        return out

    def flush(self) -> list:
        """Full planes plus every partially-filled one (end of stream)."""
        out = self.ready_batches()
        for rb in self.buckets.values():
            rb.admit()
            if rb.active_work:
                out.append(self._drain(rb))
        return out


def solve_stream(
    graphs, batch_size: int, solver=None, problem="vertex_cover", **solve_kw
) -> list:
    """Drive a request stream through the batcher onto the batched solve
    plane; returns per-instance results in SUBMISSION order.

    ``problem`` is one registry name for the whole stream, or a per-instance
    sequence — mixed streams split into (problem, W) planes and each plane is
    solved under its own problem.  With no ``solver``, the stream delegates
    to :func:`repro.api.solve_stream_session`: per-problem
    :class:`~repro.api.SolverSession` instances sharing ONE compiled-plane
    cache, so a long mixed stream replaying the same (problem, W, B) planes
    pays each trace/compile once instead of once per batch.  ``solve_kw``
    maps onto :class:`repro.api.SolveConfig` knobs (the legacy
    ``policy_priority`` bool is still accepted).  An injected ``solver``
    keeps the admission logic testable without the jax engine; it receives
    ``problem=`` per batch plus ``solve_kw`` verbatim.
    """
    if solver is None:
        from repro.api import solve_stream_session
        from repro.api.backends import config_from_legacy

        try:
            cfg = config_from_legacy(**solve_kw)
        except TypeError:
            import dataclasses

            from repro.api import SolveConfig

            known = sorted(
                {f.name for f in dataclasses.fields(SolveConfig)}
                | {"policy_priority"}
            )
            unknown = sorted(set(solve_kw) - set(known))
            raise ValueError(
                f"unknown solve_stream option(s): {', '.join(unknown)}; "
                f"known: {', '.join(known)}"
            )
        return solve_stream_session(
            graphs, batch_size, problem=problem, config=cfg
        )

    graphs = list(graphs)
    probs = (
        [problem] * len(graphs)
        if isinstance(problem, str)
        else list(problem)
    )
    if len(probs) != len(graphs):
        raise ValueError("need one problem, or one per instance")
    batcher = SolveBatcher(batch_size)
    tickets = [batcher.submit(g, p) for g, p in zip(graphs, probs)]
    results = {}
    for batch in batcher.flush():
        batch_problem = batcher.problem_of(batch[0])
        gs = batcher.take(batch)
        for seq, res in zip(batch, solver(gs, problem=batch_problem, **solve_kw)):
            results[seq] = res
    return [results[t] for t in tickets]


def simulate(
    num_replicas: int,
    capacity: int,
    request_works: list[int],
    *,
    balance: bool = True,
    seed: int = 0,
) -> dict:
    """Drive the balancer over a request trace; returns makespan + stats.
    Used by benchmarks to show the idle-slot reduction vs no balancing."""
    rng = np.random.default_rng(seed)
    reps = [RequestBatch(capacity, [], []) for _ in range(num_replicas)]
    # adversarial arrival: all requests land on replica 0 (a hot shard)
    reps[0].queued_work = list(request_works)
    state = BalancerState(reps)
    rounds = 0
    idle_slot_steps = 0
    while any(r.active_work or r.queued_work for r in reps):
        if balance:
            rebalance(state)
        for r in reps:
            r.admit()
            r.step()
            idle_slot_steps += r.capacity - r.occupancy
        rounds += 1
        if rounds > 10_000_000:
            raise RuntimeError("balancer livelock")
    return {
        "rounds": rounds,
        "idle_slot_steps": idle_slot_steps,
        "transfers": state.transfers,
        "control_ints_per_round": state.control_ints_per_round,
    }
