"""Host driver for the SPMD branching engine.

NOTE (PR 4): the public entry points are now :class:`repro.api.SolverSession`
(+ :class:`repro.api.SolveConfig`); ``solve``/``solve_many`` below are thin
deprecated shims over the session drivers in :mod:`repro.api.backends`,
which reuse this module's helpers (startup scatter, batch state stacking,
result extraction) as their single source of truth.  The legacy result
types (``EngineResult``/``BatchResult``) and the elasticity API
(``snapshot``/``restore``/``resize``) live on here.

Responsibilities (the paper's startup/termination bookkeeping):

* **startup** (§3.5): expand the root on the host until ≥ P open tasks exist
  (BFS = the equitable split), order them by the Algorithm-7 waiting-list
  traversal, and scatter one task per worker (the paper's seed→waiting-list
  topology); overflow tasks (BFS can over-expand past P) are routed through
  the SAME Algorithm-7 permutation so the equitable topology is preserved;
* **rounds**: the solve loop is device-resident — ``build_chunk_fn`` runs up
  to ``chunk_rounds`` supersteps per ``lax.while_loop`` on device, checking
  global quiescence (and, in FPT mode, the bound ``k``) on device; the host
  syncs ONE (done, ran) scalar pair per chunk instead of blocking on a
  ``device_get`` after every superstep (see EXPERIMENTS.md §Perf);
* **collect**: the center "knows which worker holds the best solution and
  fetches it only when the exploration has finished" (§3.1) — we argmin the
  per-worker local bests once, at the end; all stats (nodes, transfers,
  payload bytes) live in the carried ``WorkerState``, so collection is one
  host fetch;
* **elasticity / fault tolerance**: state is a plain pytree keyed only by
  (P, capacity, W).  ``snapshot``/``restore`` round-trip it through host
  memory; ``resize`` re-splits all pending tasks across a NEW worker count,
  which is how the engine survives losing (or gaining) devices mid-run.
"""

from __future__ import annotations

import dataclasses
import functools
import warnings
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.superstep import (
    WorkerState,
    build_chunk_fn,
    make_worker_state,
)
from repro.core.waiting_list import startup_assignment
from repro.graphs.bitgraph import BitGraph, n_words
from repro.problems import base as problems_base
from repro.problems.registry import DEFAULT_PROBLEM, get_problem


@dataclasses.dataclass
class EngineResult:
    best_size: int
    best_sol: Optional[np.ndarray]
    rounds: int
    nodes_expanded: int
    tasks_transferred: int
    wall_s: float
    overflow: bool
    # exact number of tasks lost to frontier saturation (summed over
    # workers) — 0 under engine-sized capacity; the loud twin of the bool
    overflow_count: int
    # collective-traffic accounting (bytes) for the roofline / paper §4.3.
    # Control plane is a static per-round budget; the data plane is counted
    # on device: `transfer_rounds` supersteps ran the transfer collective and
    # carried `transfer_bytes_total` bytes of task-record payload (sparse
    # path: exactly 4·rec_words·records_moved — zero on no-match rounds;
    # gather path: the full P·k record table per transfer round).  This is
    # INFORMATION payload — the nonzero rows of the collective operand —
    # not physical wire traffic: the sparse psum's static operand is still
    # (P, k, REC) per device (see EXPERIMENTS.md §Perf B/C).
    control_bytes_per_round: int
    transfer_rounds: int
    transfer_bytes_total: int
    transfer_bytes_per_round: float
    # durability: how many SolveCheckpoints this run wrote, and the
    # checkpoint path it restored from (None = started fresh).  Set by the
    # host drivers in repro.api.backends, not by result extraction.
    checkpoints_written: int = 0
    resumed_from: Optional[str] = None
    # hierarchical frontier memory (repro.core.spill): tasks evicted to /
    # re-admitted from the host cold tier, and its peak encoded size.  Set
    # by the host drivers when cfg.frontier_spill is on; with spill enabled
    # overflow/overflow_count stay 0 by construction (the no-drop
    # guarantee), so saturation shows up HERE instead.
    spilled_tasks: int = 0
    readmitted_tasks: int = 0
    cold_bytes_peak: int = 0


def _scatter_startup(
    state: WorkerState, problem, g: BitGraph, num_workers: int, tasks=None
) -> WorkerState:
    """BFS-split the root into ~P tasks and place them per Algorithm 7 order.

    ``problem`` is the :class:`~repro.problems.base.BranchingProblem` whose
    host brancher drives the split.  Every task — including overflow beyond
    the first ``num_workers`` when the BFS split over-expands (``tasks`` may
    hold more than P records) — goes through the same ``order`` permutation,
    so task i lands on worker ``order[i mod P]``: the §3.5 equitable topology
    wraps instead of degrading to raw round-robin.
    """
    if tasks is None:
        tasks = problems_base.expand_frontier(problem, g, num_tasks=num_workers)
    order = startup_assignment(max_b=2, p=num_workers)  # 1-based worker ids
    masks = np.array(state.frontier.masks)
    sols = np.array(state.frontier.sols)
    depths = np.array(state.frontier.depths)
    active = np.array(state.frontier.active)
    for i, (mask, sol, depth) in enumerate(tasks):
        w = order[i % num_workers] - 1
        # next free slot on worker w
        slot = int(np.argmin(active[w]))
        assert not active[w, slot], "startup overflow"
        masks[w, slot] = mask
        sols[w, slot] = sol
        depths[w, slot] = depth
        active[w, slot] = True
    return state._replace(
        frontier=state.frontier._replace(
            masks=jnp.asarray(masks),
            sols=jnp.asarray(sols),
            depths=jnp.asarray(depths),
            active=jnp.asarray(active),
        )
    )


def solve(
    g: BitGraph,
    num_workers: int = 8,
    *,
    problem=DEFAULT_PROBLEM,
    steps_per_round: int = 32,
    lanes: int = 1,
    policy_priority: bool = True,
    codec: str = "optimized",
    packed_status: bool = True,
    skip_empty_transfer: bool = True,
    transfer_impl: str = "sparse",
    explore_impl: str = "fused",
    donate_k: int = 1,
    chunk_rounds: int = 16,
    mode: str = "bnb",
    k: Optional[int] = None,
    mesh=None,
    max_rounds: int = 200_000,
    capacity: Optional[int] = None,
    initial_state: Optional[WorkerState] = None,
    compact_threshold: float = 0.25,
) -> EngineResult:
    """DEPRECATED shim over :class:`repro.api.SolverSession` — solve one
    instance of ``problem`` with P workers (virtual or one-per-device).

    Prefer ``SolverSession(problem=..., config=SolveConfig(...)).solve(g)``:
    the session validates the knobs once, returns the unified result schema
    and caches compiled planes across solves.  This shim maps the legacy
    kwargs onto :class:`~repro.api.SolveConfig` (it now accepts the full
    knob superset — ``compact_threshold`` is accepted-and-inert here, fixing
    the historical solve/solve_many kwargs drift) and shares one
    process-wide plane cache, then returns the legacy ``EngineResult``.
    """
    warnings.warn(
        "engine.solve is deprecated and will be REMOVED in v1.0; use "
        "repro.api.SolverSession(...).solve (see the README migration "
        "table: 'Migrating from the legacy engine API')",
        DeprecationWarning,
        stacklevel=2,
    )
    from repro.api import backends as _api

    spec = get_problem(problem)
    cfg = _api.config_from_legacy(
        policy_priority=policy_priority,
        num_workers=num_workers,
        steps_per_round=steps_per_round,
        lanes=lanes,
        codec=codec,
        packed_status=packed_status,
        skip_empty_transfer=skip_empty_transfer,
        transfer_impl=transfer_impl,
        explore_impl=explore_impl,
        donate_k=donate_k,
        chunk_rounds=chunk_rounds,
        mode=mode,
        k=k,
        max_rounds=max_rounds,
        capacity=capacity,
        compact_threshold=compact_threshold,
    )
    return _api.solve_spmd(
        spec, g, cfg, _api.LEGACY_CACHE, initial_state=initial_state, mesh=mesh
    )


# -- the multi-instance solve plane --------------------------------------------


@dataclasses.dataclass
class BatchResult:
    """Per-instance results of one ``solve_many`` call.

    ``results[i]`` corresponds to ``graphs[i]`` (submission order is
    preserved across bucketing).  ``wall_s`` is the total wall time over all
    buckets; each ``EngineResult.wall_s`` inside is the amortized share
    (bucket wall / bucket size) — instances in a batch are not individually
    timeable.
    """

    results: list
    wall_s: float
    # packing record: one (W, n_max, [instance indices]) triple per bucket
    buckets: list
    compactions: int
    # plane occupancy counters (see api.result.BatchSolveResult.lane_stats)
    lane_stats: dict = dataclasses.field(default_factory=dict)


def _bucket_instances(graphs, by_n: bool = False) -> dict:
    """Group instance indices by packed width W = n_words(n).

    Instances sharing W pad to the bucket's max n with isolated (never
    in-mask) vertices — padding rows change no branching decision, so the
    padded trace is bit-identical to the solo one (tests assert this).
    Distinct W would change the task-record width, so it starts a new bucket
    (and a new compiled executable).

    ``by_n`` buckets by exact (W, n) instead: the basic codec's §4.3 payload
    pad is n·W words, so mixing n under one pad would skew the per-instance
    byte accounting that codec exists to measure.
    """
    buckets: dict = {}
    for i, g in enumerate(graphs):
        buckets.setdefault((g.W, g.n if by_n else None), []).append(i)
    return buckets


@functools.lru_cache(maxsize=None)
def _blank_state_builder(num_workers: int, cap: int, W: int):
    """Jitted per-shape blank (P, ...) state constructor: live-lane
    admission calls this once per swap-in, so the eager vmap's per-op
    dispatch would dominate the service's host loop."""
    return jax.jit(
        lambda best: jax.vmap(lambda _: make_worker_state(cap, W, best))(
            jnp.arange(num_workers)
        )
    )


def make_instance_state(
    problem, g, num_workers: int, cap: int, W: int, initial_best
) -> WorkerState:
    """One instance's (P, ...) worker state, initialized and §3.5-startup-
    scattered by exactly the solo-solve code path (:func:`make_worker_state`
    + :func:`_scatter_startup`) — one source of truth for the Algorithm-7
    placement, shared by solo solves, batch stacking, and live-lane
    admission (the service writes this state into a freed lane)."""
    state = _blank_state_builder(num_workers, cap, W)(jnp.int32(initial_best))
    return _scatter_startup(state, problem, g, num_workers)


def _make_batch_state(
    problem, graphs, num_workers: int, cap: int, W: int, initial_bests
) -> WorkerState:
    """(B, P, ...) stacked worker state: each instance initialized via
    :func:`make_instance_state`, then stacked."""
    per_instance = [
        make_instance_state(problem, g, num_workers, cap, W, initial_best)
        for g, initial_best in zip(graphs, initial_bests)
    ]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *per_instance)


def _extract_result(
    host_state: dict,
    lane: int,
    problem,
    g: BitGraph,
    rounds: int,
    wall_s: float,
    *,
    mode: str,
    k,
    num_workers: int,
    packed_status: bool,
) -> EngineResult:
    """Build one instance's EngineResult from a device-fetched batch state.

    ``best_size`` is reported in the problem's EXTERNAL objective
    (``external_value``); "found nothing acceptable" is exactly "the internal
    best never improved on the seed bound".
    """
    local_bests = host_state["local_best_val"][lane]
    wbest = int(np.argmin(local_bests))
    internal_best = int(local_bests[wbest])
    found = internal_best < problems_base.initial_bound(problem, g, mode, k)
    best_size = int(problem.external_value(internal_best))
    best_sol = host_state["best_sol"][lane][wbest]
    if not found:
        best_sol = None
        if mode == "fpt":
            best_size = -1
    # payload_words/transfer_rounds are replicated (derived from the shared
    # status table), so worker 0's view is the instance truth.
    payload_words = int(host_state["payload_words"][lane][0])
    transfer_rounds = int(host_state["transfer_rounds"][lane][0])
    return EngineResult(
        best_size=best_size,
        best_sol=best_sol,
        rounds=rounds,
        nodes_expanded=int(host_state["nodes_expanded"][lane].sum()),
        tasks_transferred=int(host_state["tasks_sent"][lane].sum()),
        wall_s=wall_s,
        overflow=bool(host_state["overflow"][lane].any()),
        overflow_count=int(host_state["dropped"][lane].sum()),
        control_bytes_per_round=4 * (1 if packed_status else 3) * num_workers,
        transfer_rounds=transfer_rounds,
        transfer_bytes_total=4 * payload_words,
        transfer_bytes_per_round=4 * payload_words / max(rounds, 1),
    )


def _fetch_batch_state(state: WorkerState) -> dict:
    s = jax.device_get(state)
    return {
        "local_best_val": np.asarray(s.local_best_val),
        "best_sol": np.asarray(s.best_sol),
        "nodes_expanded": np.asarray(s.nodes_expanded),
        "tasks_sent": np.asarray(s.tasks_sent),
        "overflow": np.asarray(s.frontier.overflow),
        "dropped": np.asarray(s.frontier.dropped),
        "transfer_rounds": np.asarray(s.transfer_rounds),
        "payload_words": np.asarray(s.payload_words),
    }


def _pow2_at_least(x: int) -> int:
    p = 1
    while p < x:
        p *= 2
    return p


def solve_many(
    graphs,
    num_workers: int = 8,
    *,
    problem=DEFAULT_PROBLEM,
    steps_per_round: int = 32,
    lanes: int = 1,
    policy_priority: bool = True,
    codec: str = "optimized",
    packed_status: bool = True,
    skip_empty_transfer: bool = True,
    transfer_impl: str = "sparse",
    explore_impl: str = "fused",
    donate_k: int = 1,
    chunk_rounds: int = 16,
    mode: str = "bnb",
    k=None,
    mesh=None,
    max_rounds: int = 200_000,
    capacity: Optional[int] = None,
    compact_threshold: float = 0.25,
) -> BatchResult:
    """DEPRECATED shim over :class:`repro.api.SolverSession` — solve B
    independent instances of ``problem`` on ONE solve plane.

    Prefer ``SolverSession(...).solve_many(graphs)``.  This shim accepts the
    full legacy knob superset (``mesh`` is accepted for solve/solve_many
    parity but must stay ``None`` — the batched plane has no mesh path yet)
    and returns the legacy ``BatchResult``.

    The paper's center is cheap so one coordinator can drive huge worker
    pools; this extends the same amortization across *instances*: the batch
    shares a single compiled chunk executable, one host sync per chunk for
    the whole batch, and P workers per instance.  Per-instance
    ``best_size``/``best_sol`` are bit-identical to B solo ``solve`` calls
    (property-tested), because padding adds only isolated never-in-mask
    vertices and all collectives are bound to the worker axis.

    Packing: instances are bucketed by packed width ``W = n_words(n)`` and
    padded to the bucket's max n — one executable per (n_max, W) bucket.
    ``k`` (FPT mode) may be a single int or a per-instance sequence.

    Compaction: finished instances are frozen no-op lanes; when the live
    fraction of a bucket drops to ``compact_threshold`` or below, the batch
    is compacted to the next power of two above the live count (bounding
    recompiles to log2 B) and the finished lanes' results are collected
    early.  ``compact_threshold=0`` disables compaction.

    Capacity: one frontier size per bucket, ``4·n_max + 8·lanes`` — at least
    the solo solve's ``4·n + 8·lanes``.  The engine sizes capacity so
    overflow never fires (tests assert it), so the extra tail slots are
    behaviorally inert; a solo run that DID overflow (an engine-sizing bug)
    could drop tasks its batched lane keeps.  Pass ``capacity`` to pin an
    exact size.
    """
    warnings.warn(
        "engine.solve_many is deprecated and will be REMOVED in v1.0; use "
        "repro.api.SolverSession(...).solve_many (see the README migration "
        "table: 'Migrating from the legacy engine API')",
        DeprecationWarning,
        stacklevel=2,
    )
    if mesh is not None:
        raise ValueError(
            "solve_many has no mesh path yet (vmap virtual workers only); "
            "pass mesh=None"
        )
    from repro.api import backends as _api

    spec = get_problem(problem)
    cfg = _api.config_from_legacy(
        policy_priority=policy_priority,
        num_workers=num_workers,
        steps_per_round=steps_per_round,
        lanes=lanes,
        codec=codec,
        packed_status=packed_status,
        skip_empty_transfer=skip_empty_transfer,
        transfer_impl=transfer_impl,
        explore_impl=explore_impl,
        donate_k=donate_k,
        chunk_rounds=chunk_rounds,
        mode=mode,
        k=(tuple(k) if hasattr(k, "__len__") else k),
        max_rounds=max_rounds,
        capacity=capacity,
        compact_threshold=compact_threshold,
    )
    return _api.solve_many_spmd(spec, graphs, cfg, _api.LEGACY_CACHE)


# -- elasticity -----------------------------------------------------------------


def snapshot(state: WorkerState) -> dict:
    """Host-side checkpoint of the entire engine state."""
    return jax.tree.map(np.asarray, state._asdict())


def restore(snap: dict) -> WorkerState:
    return WorkerState(**jax.tree.map(jnp.asarray, snap))


def resize(state: WorkerState, new_num_workers: int) -> WorkerState:
    """Re-split all pending tasks over a different worker count (elastic
    scale-up/down or failed-node recovery — any device count works because
    tasks are self-contained records over the original instance)."""
    masks = np.array(state.frontier.masks)
    sols = np.array(state.frontier.sols)
    depths = np.array(state.frontier.depths)
    active = np.array(state.frontier.active)
    P_old, cap, W = masks.shape[0], masks.shape[1], masks.shape[2]

    tasks = [
        (masks[w, s], sols[w, s], depths[w, s])
        for w in range(P_old)
        for s in range(cap)
        if active[w, s]
    ]
    best = int(np.asarray(state.local_best_val).min())
    bw = int(np.argmin(np.asarray(state.local_best_val)))
    new = jax.vmap(lambda _: make_worker_state(cap, W, best))(
        jnp.arange(new_num_workers)
    )
    nm = np.array(new.frontier.masks)
    ns = np.array(new.frontier.sols)
    nd = np.array(new.frontier.depths)
    na = np.array(new.frontier.active)
    for i, (m, s, d) in enumerate(tasks):
        w = i % new_num_workers
        slot = i // new_num_workers
        assert slot < cap, "resize: capacity too small for pending tasks"
        nm[w, slot], ns[w, slot], nd[w, slot], na[w, slot] = m, s, d, True
    sol = np.asarray(state.best_sol)[bw]
    return new._replace(
        frontier=new.frontier._replace(
            masks=jnp.asarray(nm),
            sols=jnp.asarray(ns),
            depths=jnp.asarray(nd),
            active=jnp.asarray(na),
        ),
        best_sol=jnp.broadcast_to(jnp.asarray(sol), new.best_sol.shape),
        local_best_val=jnp.full((new_num_workers,), best, jnp.int32),
    )
