"""Pallas TPU kernel: batched bitset degrees (the B&B compute hot spot).

TPU-native rethink of the GPU bitset tricks (no warp ballots / popc
intrinsics assumed): the adjacency bitset matrix ``(n, W)`` lives wholly in
VMEM (n ≤ 2048 ⇒ ≤ 512 KiB), a grid over task blocks streams packed task
masks through the VPU, and popcount is a SWAR reduction (shift/mask adds) so
it vectorizes over the (8, 128) VREG tile regardless of Mosaic popcount
support.  Degrees come out as an ``(T, n)`` int32 panel: one AND + popcount
per (task, vertex, word) triple, reduced over words with a fori_loop so the
VMEM working set stays at ``BT × n`` instead of ``BT × n × W``.

Grid:  (ceil(T / BT),)
  masks block  (BT, W)   VMEM
  adj          (n, W)    VMEM (whole matrix, every grid step)
  out block    (BT, n)   VMEM
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

WORD_BITS = 32


def _swar_popcount_u32(x: jnp.ndarray) -> jnp.ndarray:
    """Branch-free SWAR popcount on uint32 (VPU shift/mask adds)."""
    x = x - ((x >> 1) & jnp.uint32(0x55555555))
    x = (x & jnp.uint32(0x33333333)) + ((x >> 2) & jnp.uint32(0x33333333))
    x = (x + (x >> 4)) & jnp.uint32(0x0F0F0F0F)
    return ((x * jnp.uint32(0x01010101)) >> 24).astype(jnp.int32)


def _degrees_kernel(masks_ref, adj_ref, out_ref, *, n: int, W: int):
    BT = masks_ref.shape[0]
    masks = masks_ref[...]  # (BT, W) uint32

    def word_step(w, acc):
        mw = masks[:, w]  # (BT,)
        aw = adj_ref[:, w]  # (n,)
        inter = mw[:, None] & aw[None, :]  # (BT, n)
        return acc + _swar_popcount_u32(inter)

    deg = jax.lax.fori_loop(
        0, W, word_step, jnp.zeros((BT, n), jnp.int32)
    )

    # mask out vertices not in the task: bit v of masks word v//32
    v = jax.lax.broadcasted_iota(jnp.int32, (BT, n), 1)
    word_idx = v // WORD_BITS
    bit_idx = (v % WORD_BITS).astype(jnp.uint32)
    mask_words = jnp.take_along_axis(masks, word_idx.astype(jnp.int32), axis=1)
    inside = ((mask_words >> bit_idx) & 1).astype(bool)
    out_ref[...] = jnp.where(inside, deg, jnp.int32(-1))


@functools.partial(jax.jit, static_argnames=("block_tasks", "interpret"))
def batched_degrees(
    adj: jnp.ndarray,
    masks: jnp.ndarray,
    *,
    block_tasks: int = 8,
    interpret: bool = True,
) -> jnp.ndarray:
    """adj (n, W) uint32, masks (T, W) uint32 -> (T, n) int32 degrees.

    ``interpret=True`` runs the kernel body in Python on CPU (validation);
    on a TPU runtime pass ``interpret=False``.
    """
    n, W = adj.shape
    T = masks.shape[0]
    BT = min(block_tasks, T)
    grid = (pl.cdiv(T, BT),)
    return pl.pallas_call(
        functools.partial(_degrees_kernel, n=n, W=W),
        grid=grid,
        in_specs=[
            pl.BlockSpec((BT, W), lambda i: (i, 0)),  # task masks block
            pl.BlockSpec((n, W), lambda i: (0, 0)),  # whole adjacency
        ],
        out_specs=pl.BlockSpec((BT, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((T, n), jnp.int32),
        interpret=interpret,
    )(masks, adj)
