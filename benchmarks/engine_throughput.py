"""SPMD superstep engine: throughput + collective-traffic budget.

Three sections (EXPERIMENTS.md §Perf):

  budget   expansion/transfer accounting per worker count and matching
           policy (the TPU-adaptation counterpart of Table 1);
  chunked  supersteps/sec, K-round device-resident stepping (one host sync
           per ``lax.while_loop`` chunk) vs the per-round host loop
           (blocking ``device_get(done)`` every round) at P=64 virtual
           workers.  Reported for pure *coordination rounds*
           (steps_per_round=0: all-gather + replicated matching + transfer,
           i.e. the per-round coordination cost the paper says caps
           scaling) and for compute-carrying rounds (steps_per_round=1);
  transfer gather vs sparse data-plane A/B on the DIMACS-style sample from
           examples/solve_dimacs.py: identical best_size/best_sol, payload
           bytes per round, zero-byte no-match rounds.
"""

from __future__ import annotations

import statistics
import time

import jax
import jax.numpy as jnp

from repro.api import SolveConfig, SolverSession
from repro.core import engine as E  # startup-scatter helper for chunked_ab
from repro.core.superstep import (
    build_chunk_fn,
    build_superstep_fn,
    make_worker_state,
)
from repro.graphs.bitgraph import n_words
from repro.graphs.generators import erdos_renyi, p_hat_like
from repro.problems.base import make_data
from repro.problems.registry import get_problem
from repro.problems.sequential import solve_sequential


def budget_rows():
    g = erdos_renyi(48, 0.25, 2)
    want, _, _ = solve_sequential(g)
    rows = []
    for p in (2, 4, 8):
        for policy in ("priority", "random"):
            r = SolverSession(config=SolveConfig(
                num_workers=p, steps_per_round=8, policy=policy
            )).solve(g)
            assert r.best_size == want
            rows.append(
                dict(
                    workers=p,
                    policy="priority" if policy == "priority" else "round_robin",
                    rounds=r.rounds,
                    nodes=r.nodes_expanded,
                    transfers=r.tasks_transferred,
                    nodes_per_round=round(r.nodes_expanded / r.rounds, 1),
                    control_B_per_round=r.stats.control_bytes_per_round,
                    transfer_B_per_round=round(
                        r.stats.transfer_bytes_per_round, 1
                    ),
                )
            )
    return rows


def _median_rate(fn, reps=3):
    return statistics.median(fn() for _ in range(reps))


def chunked_ab(P=64, K=32, R=96, n=32, seed=1):
    """supersteps/sec: per-round host loop vs K-round device-resident."""
    g = erdos_renyi(n, 0.3, seed)
    W = n_words(g.n)
    cap = 4 * g.n + 8
    spec = get_problem("vertex_cover")
    data = make_data(spec, g)
    s0 = jax.vmap(lambda _: make_worker_state(cap, W, g.n + 1))(jnp.arange(P))
    s0 = E._scatter_startup(s0, spec, g, P)
    out = []
    for spr, label in ((0, "coordination (steps_per_round=0)"),
                       (1, "compute round (steps_per_round=1)")):
        step_fn = build_superstep_fn(
            spec, data, num_workers=P, steps_per_round=spr, lanes=1
        )
        chunk_fn = build_chunk_fn(
            spec, data, num_workers=P, steps_per_round=spr, lanes=1,
            chunk_rounds=K,
        )
        # compile
        _, d = step_fn(s0)
        jax.device_get(d)
        jax.device_get(chunk_fn(s0)[2])

        def host_rate():
            s, t0 = s0, time.perf_counter()
            for _ in range(R):
                s, d = step_fn(s)
                jax.device_get(d)  # the seed's per-round blocking sync
            return R / (time.perf_counter() - t0)

        def device_rate():
            s, t0, ran_tot = s0, time.perf_counter(), 0
            while ran_tot < R:
                s, d, ran, _hot = chunk_fn(s)
                d, ran = jax.device_get((d, ran))
                ran_tot += int(ran)
                if bool(d):
                    break
            return ran_tot / (time.perf_counter() - t0)

        h = _median_rate(host_rate)
        v = _median_rate(device_rate)
        out.append(
            dict(
                mode=label, workers=P, chunk_rounds=K,
                host_steps_per_s=round(h, 1),
                device_steps_per_s=round(v, 1),
                speedup=round(v / h, 2),
            )
        )
    return out


def transfer_ab():
    """gather vs sparse on the solve_dimacs.py sample: identical results,
    payload ∝ matches for sparse (zero on no-match rounds)."""
    g = p_hat_like(60, 0.4, seed=0)
    out = []
    results = {}
    for impl in ("gather", "sparse"):
        r = SolverSession(config=SolveConfig(
            num_workers=8, steps_per_round=16, transfer_impl=impl
        )).solve(g)
        results[impl] = r
        rec_words = 2 * n_words(g.n) + 1
        out.append(
            dict(
                impl=impl,
                best=r.best_size,
                rounds=r.rounds,
                transfer_rounds=r.stats.transfer_rounds,
                tasks_moved=r.tasks_transferred,
                payload_B_total=r.stats.transfer_bytes_total,
                payload_B_per_round=round(
                    r.stats.transfer_bytes_per_round, 1
                ),
                record_B=4 * rec_words,
            )
        )
    a, b = results["gather"], results["sparse"]
    assert a.best_size == b.best_size and (a.best_sol == b.best_sol).all(), (
        "transfer paths diverged"
    )
    # sparse payload is exactly the matched records; no-match rounds are free
    rec_words = 2 * n_words(g.n) + 1
    assert b.stats.transfer_bytes_total == 4 * rec_words * b.tasks_transferred
    return out


def _print_csv(rows):
    keys = list(rows[0].keys())
    print(",".join(keys))
    for r in rows:
        print(",".join(str(r[k]) for k in keys))


def run(csv=True):
    sections = {
        "budget": budget_rows(),
        "chunked": chunked_ab(),
        "transfer": transfer_ab(),
    }
    if csv:
        for name, rows in sections.items():
            print(f"# {name}")
            _print_csv(rows)
    return sections


if __name__ == "__main__":
    run()
