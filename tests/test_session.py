"""The ``repro.api`` façade: one config, one result schema, warm planes.

Four guarantees from the PR-4 redesign:

1. **Bit-identity** — vertex-cover results through ``SolverSession`` are
   bit-identical to the pre-redesign engine outputs pinned in
   ``tests/golden_vc.json`` (solo, fpt, solve_many incl. padding +
   compaction), i.e. the façade + compiled-plane cache is a pure reshaping.
2. **Warm-plane reuse** — two same-shape solves trigger exactly ONE
   trace/compile (asserted via ``cache_stats`` AND the
   ``superstep.PLANE_TRACES`` ground-truth counter).
3. **Backend parity** — spmd, protocol_sim and centralized agree with the
   sequential reference on small graphs for vertex_cover AND max_clique.
4. **Legacy shims** — ``engine.solve``/``solve_many`` still work, accept
   the unified knob superset (the historical kwargs drift is gone), and
   warn via ``DeprecationWarning``.
"""

import json
import pathlib

import numpy as np
import pytest

from repro.api import (
    PlaneCache,
    SolveConfig,
    SolveResult,
    SolverSession,
    get_backend,
    known_backends,
)
from repro.api.backends import config_from_legacy
from repro.core import engine as E
from repro.core import superstep
from repro.graphs.generators import erdos_renyi
from repro.problems.sequential import (
    solve_sequential,
    solve_sequential_max_clique,
    verify_clique,
    verify_cover,
)

GOLDEN = json.loads(
    (pathlib.Path(__file__).parent / "golden_vc.json").read_text()
)


def _check_golden(r: SolveResult, want: dict):
    got = {
        "best_size": int(r.best_size),
        "best_sol": [int(w) for w in np.asarray(r.best_sol, np.uint32)],
        "rounds": int(r.rounds),
        "nodes_expanded": int(r.nodes_expanded),
        "tasks_transferred": int(r.tasks_transferred),
        "transfer_rounds": int(r.stats.transfer_rounds),
        "transfer_bytes_total": int(r.stats.transfer_bytes_total),
        "overflow": bool(r.stats.overflow),
    }
    assert got == want


def _session_for(legacy_kw: dict, **extra) -> SolverSession:
    """A session configured from a golden case's LEGACY solve kwargs."""
    return SolverSession(
        problem="vertex_cover",
        config=config_from_legacy(**legacy_kw, **extra),
    )


# -- 1. session-vs-legacy bit-identity against the goldens ---------------------


@pytest.mark.parametrize("label", sorted(GOLDEN["solo"]))
def test_session_solo_bit_identical_to_golden(label):
    case = GOLDEN["solo"][label]
    gkw = case["graph"]
    g = erdos_renyi(gkw["n"], gkw["p"], gkw["seed"])
    r = _session_for(case["solve_kw"]).solve(g)
    assert (r.problem, r.backend, r.found) == ("vertex_cover", "spmd", True)
    _check_golden(r, case["result"])


def test_session_fpt_bit_identical_to_golden():
    case = GOLDEN["fpt"]
    gkw = case["graph"]
    g = erdos_renyi(gkw["n"], gkw["p"], gkw["seed"])
    r = _session_for(
        {"num_workers": 4}, mode="fpt", k=case["k"]
    ).solve(g)
    _check_golden(r, case["result"])


def test_session_solve_many_bit_identical_to_golden():
    """The batched plane through the session, including the padding (mixed n
    within a W bucket) and host-side compaction paths."""
    case = GOLDEN["many"]
    graphs = [
        erdos_renyi(n, case["p"], case["seed0"] + i)
        for i, n in enumerate(case["sizes"])
    ]
    batch = _session_for(case["solve_kw"]).solve_many(graphs)
    assert batch.compactions == case["compactions"]
    assert [[W, n_max, idxs] for W, n_max, idxs in batch.buckets] == case["buckets"]
    for r, want in zip(batch.results, case["results"]):
        _check_golden(r, want)


# -- 2. compiled-plane cache: hit/miss accounting + exactly one trace ----------


def test_warm_plane_reuse_two_same_shape_solves_one_trace():
    session = SolverSession(
        problem="vertex_cover",
        config=SolveConfig(num_workers=4, steps_per_round=8),
    )
    g1, g2 = erdos_renyi(22, 0.3, 0), erdos_renyi(22, 0.3, 1)
    traces0 = superstep.PLANE_TRACES
    r1 = session.solve(g1)
    r2 = session.solve(g2)
    assert superstep.PLANE_TRACES - traces0 == 1, (
        "the second same-shape solve must reuse the compiled plane"
    )
    stats = session.cache_stats()
    assert stats["misses"] == 1 and stats["hits"] == 1
    assert stats["planes"] == 1 and stats["shapes"] == 1
    # results are real solves, not cache artifacts
    assert r1.best_size == solve_sequential(g1)[0]
    assert r2.best_size == solve_sequential(g2)[0]
    # a repeat of the SAME graph is warm and bit-identical
    r1b = session.solve(g1)
    assert superstep.PLANE_TRACES - traces0 == 1
    assert r1b.best_size == r1.best_size and r1b.rounds == r1.rounds
    assert (r1b.best_sol == r1.best_sol).all()


def test_cache_distinguishes_shapes_and_is_shareable():
    cache = PlaneCache()
    cfg = SolveConfig(num_workers=4, steps_per_round=8)
    s1 = SolverSession(problem="vertex_cover", config=cfg, cache=cache)
    s2 = SolverSession(problem="vertex_cover", config=cfg, cache=cache)
    s1.solve(erdos_renyi(20, 0.3, 0))
    # different n -> different shape -> miss; same plane function though
    s1.solve(erdos_renyi(26, 0.3, 0))
    # second session, same cache, same shape as the first -> warm
    s2.solve(erdos_renyi(20, 0.3, 1))
    st = cache.stats()
    assert st.misses == 2 and st.hits == 1 and st.planes == 1


def test_batch_cache_key_includes_batch_width():
    session = SolverSession(
        problem="vertex_cover",
        config=SolveConfig(num_workers=4, steps_per_round=8),
    )
    gs = [erdos_renyi(18, 0.3, s) for s in range(2)]
    session.solve_many(gs)
    stats1 = session.cache_stats()
    session.solve_many([erdos_renyi(18, 0.3, s) for s in range(2, 4)])
    stats2 = session.cache_stats()
    assert stats1["misses"] == 1
    assert stats2["misses"] == 1 and stats2["hits"] == stats1["hits"] + 1


# -- 3. backend parity across problems -----------------------------------------


@pytest.mark.parametrize("problem,seq_ref,verify", [
    ("vertex_cover", solve_sequential, verify_cover),
    ("max_clique", solve_sequential_max_clique, verify_clique),
])
@pytest.mark.parametrize("backend", ["spmd", "protocol_sim", "centralized"])
def test_backend_parity_with_sequential_reference(problem, seq_ref, verify, backend):
    cfg = SolveConfig(num_workers=4, steps_per_round=8)
    for seed in (0, 1, 2):
        g = erdos_renyi(15, 0.35, seed)
        want, _, _ = seq_ref(g)
        r = SolverSession(problem=problem, backend=backend, config=cfg).solve(g)
        assert r.best_size == want, (problem, backend, seed)
        assert r.backend == backend and r.problem == problem and r.found
        assert verify(g, r.best_sol)


def test_sequential_backend_matches_reference_too():
    g = erdos_renyi(16, 0.35, 5)
    want, _, _ = solve_sequential(g)
    r = SolverSession(backend="sequential").solve(g)
    assert r.best_size == want and r.backend == "sequential"


def test_unknown_backend_lists_known_names():
    with pytest.raises(ValueError, match="protocol_sim"):
        get_backend("mpi")
    assert known_backends() == sorted(known_backends())
    # aliases resolve to the canonical backends
    assert get_backend("protocol").name == "protocol_sim"
    assert get_backend("central").name == "centralized"
    assert get_backend("seq").name == "sequential"


# -- 4. config validation + JSON round-trip ------------------------------------


def test_config_json_round_trip_and_replace():
    cfg = SolveConfig(num_workers=5, transfer_impl="gather", k=None)
    again = SolveConfig.from_json(cfg.to_json())
    assert again == cfg
    assert cfg.replace(num_workers=7).num_workers == 7
    # per-instance k survives the round trip as a tuple
    many = SolveConfig(mode="fpt", k=[3, 4, 5])
    assert many.k == (3, 4, 5)
    assert SolveConfig.from_json(many.to_json()).k == (3, 4, 5)


def test_config_save_load(tmp_path):
    path = tmp_path / "cfg.json"
    cfg = SolveConfig(num_workers=3, codec="basic")
    cfg.save(path)
    assert SolveConfig.load(path) == cfg


@pytest.mark.parametrize("bad", [
    dict(transfer_impl="rdma"),
    dict(mode="ilp"),
    dict(policy="fifo"),
    dict(codec="huffman"),
    dict(num_workers=0),
    dict(compact_threshold=1.5),
    dict(mode="fpt"),  # fpt without k
])
def test_config_validates_once_with_helpful_errors(bad):
    with pytest.raises(ValueError):
        SolveConfig(**bad)


def test_config_rejects_unknown_keys():
    with pytest.raises(ValueError, match="unknown SolveConfig key"):
        SolveConfig.from_dict({"num_wrokers": 4})


def test_solo_solve_rejects_per_instance_k():
    cfg = SolveConfig(mode="fpt", k=(3, 4))
    with pytest.raises(ValueError, match="per-instance"):
        SolverSession(config=cfg).solve(erdos_renyi(10, 0.3, 0))


# -- 5. async admission (submit -> ticket -> flush) ----------------------------


def test_submit_flush_result_round_trip():
    session = SolverSession(
        problem="vertex_cover",
        config=SolveConfig(num_workers=4, steps_per_round=8, batch_size=2),
    )
    gs = [erdos_renyi(18, 0.3, s) for s in range(3)]
    tickets = [session.submit(g) for g in gs]
    assert session.pending() == 3
    # two of three fill a batch_size=2 plane; poll solves just that plane
    polled = session.poll()
    assert len(polled) == 2 and session.pending() == 1
    flushed = session.flush()
    assert len(flushed) == 1 and session.pending() == 0
    for t, g in zip(tickets, gs):
        want, _, _ = solve_sequential(g)
        assert session.result(t).best_size == want
    with pytest.raises(KeyError):
        session.result(tickets[0])  # result() pops


# -- 6. legacy shims: superset kwargs + DeprecationWarning ---------------------


def test_legacy_solve_warns_and_accepts_compact_threshold():
    g = erdos_renyi(18, 0.3, 0)
    with pytest.warns(DeprecationWarning, match="SolverSession"):
        r = E.solve(g, num_workers=4, steps_per_round=8, compact_threshold=0.5)
    assert r.best_size == solve_sequential(g)[0]


def test_legacy_solve_many_warns_and_accepts_mesh_none():
    gs = [erdos_renyi(18, 0.3, s) for s in range(2)]
    with pytest.warns(DeprecationWarning, match="SolverSession"):
        batch = E.solve_many(gs, num_workers=4, steps_per_round=8, mesh=None)
    assert [r.best_size for r in batch.results] == [
        solve_sequential(g)[0] for g in gs
    ]
    # a real mesh on the batched plane is still unimplemented -> loud error
    with pytest.raises(ValueError, match="mesh"):
        E.solve_many(gs, num_workers=4, mesh=object())


def test_legacy_shims_share_one_plane_cache():
    from repro.api.backends import LEGACY_CACHE

    g1, g2 = erdos_renyi(21, 0.3, 0), erdos_renyi(21, 0.3, 1)
    with pytest.warns(DeprecationWarning):
        E.solve(g1, num_workers=4, steps_per_round=8)
        hits0 = LEGACY_CACHE.stats().hits
        E.solve(g2, num_workers=4, steps_per_round=8)
    assert LEGACY_CACHE.stats().hits == hits0 + 1


# -- 7. the session-backed serving stream --------------------------------------


def test_solve_stream_session_mixed_problems_shared_cache():
    from repro.api import solve_stream_session

    gs = [erdos_renyi(16, 0.35, s) for s in range(4)]
    probs = ["vertex_cover", "max_clique", "vertex_cover", "max_clique"]
    cache = PlaneCache()
    out = solve_stream_session(
        gs, batch_size=2, problem=probs, cache=cache,
        config=SolveConfig(num_workers=4, steps_per_round=8),
    )
    assert [r.problem for r in out] == probs
    for g, r in zip(gs, out):
        if r.problem == "vertex_cover":
            assert r.best_size == solve_sequential(g)[0]
        else:
            assert r.best_size == solve_sequential_max_clique(g)[0]
    # two problems x one (W, B) plane each -> exactly two compiled planes
    assert cache.stats().planes == 2
