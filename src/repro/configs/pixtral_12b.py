"""pixtral-12b [vlm] — pixtral-ViT frontend (STUB) + mistral-nemo backbone.

40L d=5120 32H kv=8 d_ff=14336 vocab=131072; input_specs feeds precomputed
patch embeddings (1024 patches) prepended to the token stream.
[hf:mistralai/Pixtral-12B-2409]
"""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="pixtral-12b",
        family="vlm",
        n_layers=40,
        d_model=5120,
        n_heads=32,
        n_kv_heads=8,
        d_head=128,
        d_ff=14_336,
        vocab=131_072,
        n_patches=1024,
        rope_theta=1_000_000.0,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="pixtral-smoke",
        family="vlm",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab=512,
        n_patches=8,
        dtype="float32",
    )
