from repro.kernels.wkv6.kernel import wkv6
from repro.kernels.wkv6.ops import wkv6_decode_step, wkv6_op
from repro.kernels.wkv6.ref import wkv6_ref

__all__ = ["wkv6", "wkv6_op", "wkv6_decode_step", "wkv6_ref"]
