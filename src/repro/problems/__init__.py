"""Branching problems (plug-ins for the paper's Algorithm 1 / 2 structure).

The contract is :class:`repro.problems.base.BranchingProblem`; concrete
workloads (``vertex_cover``, ``max_clique``, ``mis``) register in
:mod:`repro.problems.registry`, and :mod:`repro.problems.sequential` holds
the host-side ground-truth references.
"""

from repro.problems.sequential import (
    SeqStats,
    reduce_instance,
    branch_once,
    branch_once_clique,
    solve_sequential,
    solve_sequential_max_clique,
    solve_sequential_mis,
    expand_frontier,
)

__all__ = [
    "SeqStats",
    "reduce_instance",
    "branch_once",
    "branch_once_clique",
    "solve_sequential",
    "solve_sequential_max_clique",
    "solve_sequential_mis",
    "expand_frontier",
]
