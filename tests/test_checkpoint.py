"""Checkpoint/restart fault tolerance: atomicity, resume-exactness, async."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.store import (
    latest_step,
    restore_checkpoint,
    save_checkpoint,
    wait_for_pending,
)
from repro.configs.registry import get_smoke_config
from repro.launch.train import train_loop


def test_roundtrip(tmp_path):
    tree = {"a": jnp.arange(12.0).reshape(3, 4), "b": {"c": jnp.int32(7)}}
    save_checkpoint(str(tmp_path), 5, tree, extra={"x": 1})
    got, step, extra = restore_checkpoint(str(tmp_path), tree)
    assert step == 5 and extra == {"x": 1}
    assert (np.asarray(got["a"]) == np.asarray(tree["a"])).all()
    assert int(got["b"]["c"]) == 7


def test_latest_step_and_atomicity(tmp_path):
    tree = {"a": jnp.zeros(3)}
    save_checkpoint(str(tmp_path), 1, tree)
    save_checkpoint(str(tmp_path), 9, tree)
    # a stale .tmp dir must be ignored
    os.makedirs(tmp_path / "step_50.tmp")
    assert latest_step(str(tmp_path)) == 9


def test_async_write(tmp_path):
    tree = {"a": jnp.ones((64, 64))}
    save_checkpoint(str(tmp_path), 3, tree, blocking=False)
    wait_for_pending()
    got, step, _ = restore_checkpoint(str(tmp_path), tree)
    assert step == 3 and float(got["a"].sum()) == 64 * 64


def test_kill_mid_write_leaves_previous_step_intact(tmp_path, monkeypatch):
    """A writer dying inside the npz write (the long I/O phase) must leave
    the directory exactly as before: latest_step unchanged, no tmp litter,
    and the previous step still restorable."""
    tree = {"a": jnp.arange(8.0)}
    save_checkpoint(str(tmp_path), 1, tree, extra={"x": "old"})

    real_savez = np.savez

    def dying_savez(path, **payload):
        with open(path, "wb") as f:
            f.write(b"PK\x03\x04 partial garbage")  # half-written archive
        raise RuntimeError("simulated kill mid-write")

    monkeypatch.setattr(np, "savez", dying_savez)
    with pytest.raises(RuntimeError, match="simulated kill"):
        save_checkpoint(str(tmp_path), 2, {"a": jnp.zeros(8)}, extra={"x": "new"})
    monkeypatch.setattr(np, "savez", real_savez)

    assert latest_step(str(tmp_path)) == 1
    assert not [p for p in os.listdir(tmp_path) if p.endswith(".tmp")]
    got, step, extra = restore_checkpoint(str(tmp_path), tree)
    assert step == 1 and extra == {"x": "old"}
    assert (np.asarray(got["a"]) == np.arange(8.0)).all()


def _dummy_solve_checkpoint():
    from repro.checkpoint.solve import SolveCheckpoint

    return SolveCheckpoint(
        kind="solo",
        problem="vertex_cover",
        config={},
        fingerprint="f" * 64,
        rounds=3,
        arrays={"worker.rounds": np.arange(4, dtype=np.int32)},
    )


def test_truncated_solve_checkpoint_raises_checkpoint_error(tmp_path):
    from repro.checkpoint.solve import CheckpointError, SolveCheckpoint

    step_dir = _dummy_solve_checkpoint().save(str(tmp_path), 3)
    npz = os.path.join(step_dir, "arrays.npz")
    with open(npz, "r+b") as f:  # truncate mid-archive
        f.truncate(os.path.getsize(npz) // 2)
    with pytest.raises(CheckpointError, match="corrupt or truncated"):
        SolveCheckpoint.load(str(tmp_path))


def test_corrupt_manifest_raises_checkpoint_error(tmp_path):
    from repro.checkpoint.solve import CheckpointError, SolveCheckpoint

    step_dir = _dummy_solve_checkpoint().save(str(tmp_path), 1)
    with open(os.path.join(step_dir, "manifest.msgpack"), "wb") as f:
        f.write(b"\xc1\xc1 not msgpack")
    with pytest.raises(CheckpointError, match="corrupt or truncated"):
        SolveCheckpoint.load(step_dir)  # step_<N> path form


def test_missing_manifest_raises_checkpoint_error(tmp_path):
    from repro.checkpoint.solve import CheckpointError, SolveCheckpoint

    step_dir = _dummy_solve_checkpoint().save(str(tmp_path), 1)
    os.remove(os.path.join(step_dir, "manifest.msgpack"))
    with pytest.raises(CheckpointError, match="incomplete checkpoint"):
        SolveCheckpoint.load(str(tmp_path))


def test_raw_store_checkpoint_is_not_a_solve_checkpoint(tmp_path):
    from repro.checkpoint.solve import CheckpointError, SolveCheckpoint

    save_checkpoint(str(tmp_path), 4, {"a": jnp.zeros(2)}, extra={"x": 1})
    with pytest.raises(CheckpointError, match="not a solve checkpoint"):
        SolveCheckpoint.load(str(tmp_path))


def test_fingerprint_mismatch_refuses_resume(tmp_path):
    """Resuming under ANY changed trajectory knob (here num_workers) or a
    different instance graph must refuse with CheckpointError, not silently
    run a different solve."""
    from repro.api import CheckpointError, SolveConfig, SolverSession
    from repro.graphs.generators import erdos_renyi

    g = erdos_renyi(24, 0.3, seed=5)
    cfg = SolveConfig(
        num_workers=4, steps_per_round=2, chunk_rounds=1, checkpoint_every=1
    )
    d = str(tmp_path / "ck")
    SolverSession(config=cfg).solve(g, checkpoint_dir=d)
    assert latest_step(d) is not None
    with pytest.raises(CheckpointError, match="fingerprint mismatch"):
        SolverSession.resume(d, num_workers=8)
    # changing a POST-trajectory knob is allowed
    r = SolverSession.resume(d, max_rounds=10_000)
    assert r.found


def test_resume_reproduces_loss_curve(tmp_path):
    """Train 12 steps straight vs 6 + crash + resume 6: identical losses —
    the deterministic pipeline + checkpoint contract."""
    cfg = get_smoke_config("qwen1_5_0_5b")
    ck = str(tmp_path / "ck")
    _, _, full = train_loop(cfg, steps=12, batch=4, seq=32, ckpt_dir=None, seed=3)
    _, _, first = train_loop(
        cfg, steps=6, batch=4, seq=32, ckpt_dir=ck, ckpt_every=3, seed=3
    )
    wait_for_pending()
    _, _, second = train_loop(
        cfg, steps=12, batch=4, seq=32, ckpt_dir=ck, ckpt_every=100,
        resume=True, seed=3,
    )
    resumed = first + second
    assert len(resumed) == len(full)
    np.testing.assert_allclose(resumed, full, rtol=2e-4, atol=2e-4)
