"""Pure-jnp oracle for the batched bitset-degree kernel.

For a batch of tasks (packed vertex masks), compute every vertex's induced-
subgraph degree and the maximum-degree vertex — the inner loop of the paper's
vertex-cover branching (Alg. 8 line 7: "find a vertex u of maximum degree").
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

WORD_BITS = 32


def batched_degrees_ref(adj: jnp.ndarray, masks: jnp.ndarray) -> jnp.ndarray:
    """adj (n, W) uint32, masks (T, W) uint32 -> degrees (T, n) int32.

    deg[t, v] = popcount(adj[v] & masks[t]) if v in masks[t] else -1.
    """
    n, W = adj.shape
    inter = adj[None, :, :] & masks[:, None, :]  # (T, n, W)
    deg = jax.lax.population_count(inter).astype(jnp.int32).sum(axis=-1)
    v = jnp.arange(n)
    word_idx, bit_idx = v // WORD_BITS, (v % WORD_BITS).astype(jnp.uint32)
    inside = ((masks[:, word_idx] >> bit_idx[None, :]) & 1).astype(bool)  # (T, n)
    return jnp.where(inside, deg, jnp.int32(-1))


def max_degree_vertex_ref(adj: jnp.ndarray, masks: jnp.ndarray):
    """-> (u (T,) int32, maxdeg (T,) int32): the branching vertex per task."""
    deg = batched_degrees_ref(adj, masks)
    return jnp.argmax(deg, axis=1).astype(jnp.int32), deg.max(axis=1)


def expand_stats_ref(adj: jnp.ndarray, masks: jnp.ndarray, sols: jnp.ndarray):
    """Oracle for the fused expand panel:
    -> (deg (T, n) int32, pc_mask (T,) int32, pc_sol (T,) int32)."""
    deg = batched_degrees_ref(adj, masks)
    pc = jax.lax.population_count(masks).astype(jnp.int32).sum(axis=-1)
    ps = jax.lax.population_count(sols).astype(jnp.int32).sum(axis=-1)
    return deg, pc, ps
