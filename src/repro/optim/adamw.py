"""AdamW + cosine schedule + global-norm clipping (sharded-state friendly).

Optimizer moments mirror the parameter pytree, so the same logical-axis
specs shard them (ZeRO-1 over the 'embed'→data FSDP rule: each data shard
owns the slice of m/v matching its parameter slice).  Moments are fp32
regardless of the parameter dtype (mixed-precision training discipline).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class OptState(NamedTuple):
    step: jnp.ndarray  # () int32
    m: dict
    v: dict


def adamw_init(params) -> OptState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return OptState(step=jnp.int32(0), m=zeros, v=jax.tree.map(jnp.copy, zeros))


def opt_state_specs(param_specs) -> OptState:
    """Logical-axis specs for the optimizer state (mirrors params)."""
    return OptState(step=(), m=param_specs, v=param_specs)


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves)
    )


def cosine_schedule(step, *, peak_lr, warmup_steps, total_steps, final_frac=0.1):
    step = step.astype(jnp.float32)
    warm = peak_lr * step / jnp.maximum(warmup_steps, 1)
    prog = jnp.clip(
        (step - warmup_steps) / jnp.maximum(total_steps - warmup_steps, 1), 0, 1
    )
    cos = peak_lr * (final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
    return jnp.where(step < warmup_steps, warm, cos)


def adamw_update(
    params,
    grads,
    opt: OptState,
    *,
    peak_lr: float = 3e-4,
    warmup_steps: int = 100,
    total_steps: int = 10_000,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    clip_norm: float = 1.0,
):
    """One AdamW step with global-norm clipping.  Returns (params, opt, stats)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, clip_norm / jnp.maximum(gnorm, 1e-9))
    step = opt.step + 1
    lr = cosine_schedule(
        step, peak_lr=peak_lr, warmup_steps=warmup_steps, total_steps=total_steps
    )
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32) * scale
        m_new = b1 * m + (1 - b1) * g32
        v_new = b2 * v + (1 - b2) * g32 * g32
        update = (m_new / bc1) / (jnp.sqrt(v_new / bc2) + eps)
        update = update + weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * update
        return p_new.astype(p.dtype), m_new, v_new

    out = jax.tree.map(upd, params, grads, opt.m, opt.v)
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return (
        new_params,
        OptState(step=step, m=new_m, v=new_v),
        {"grad_norm": gnorm, "lr": lr},
    )
