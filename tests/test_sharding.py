"""Logical-axis sharding rules + the MoE group math (single-device mesh)."""

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.launch.mesh import make_mesh_compat
from repro.models.moe import num_groups
from repro.models.sharding import (
    DEFAULT_RULES,
    constrain,
    gather_params,
    logical_to_spec,
    rules_for_mesh,
    spec_tree_of,
)


def _mesh11():
    return make_mesh_compat((1, 1), ("data", "model"))


def test_logical_to_spec():
    rules = {"embed": ("data",), "heads": ("model",), "batch": ("pod", "data"),
             None: None}
    assert logical_to_spec(("embed", "heads"), rules) == P("data", "model")
    assert logical_to_spec(("batch", None), rules) == P(("pod", "data"), None)
    assert logical_to_spec((None, "missing"), rules) == P(None, None)


def test_rules_drop_missing_axes():
    rules = rules_for_mesh(_mesh11())
    assert rules["batch"] == ("data",)  # 'pod' dropped on the single-pod mesh
    assert rules["_sizes"] == {"data": 1, "model": 1}


def test_num_groups():
    assert num_groups(None) == 1
    rules = {"batch": ("data",), "_sizes": {"data": 16, "model": 16}}
    assert num_groups(rules) == 16
    rules2 = {"batch": ("pod", "data"), "_sizes": {"pod": 2, "data": 16}}
    assert num_groups(rules2) == 32
    assert num_groups({"batch": None, "_sizes": {}}) == 1


def test_constrain_noop_without_rules():
    x = jnp.zeros((4, 4))
    assert constrain(x, ("batch", None), None) is x


def test_gather_params_drops_fsdp_axes():
    """Under a real (1,1) mesh the regather is a semantic no-op but must
    trace/compile cleanly through jit."""
    mesh = _mesh11()
    rules = rules_for_mesh(mesh)
    tree = {"w": jnp.ones((8, 8))}
    spec = {"w": ("embed", "heads")}
    with mesh:
        out = jax.jit(lambda t: gather_params(t, spec, rules))(tree)
    assert (out["w"] == 1).all()


def test_spec_tree_of_no_allocation():
    calls = []

    def init():
        calls.append(1)
        return {"w": jnp.zeros((1024, 1024))}, {"w": ("embed", "heads")}

    specs = spec_tree_of(init)
    assert specs == {"w": ("embed", "heads")}


def test_default_rules_cover_all_logical_names():
    for name in ["batch", "embed", "heads", "kv", "mlp", "experts", "vocab",
                 "seq", "seq_kv", "layers", "rnn", "conv", "lora", "stack"]:
        assert name in DEFAULT_RULES
