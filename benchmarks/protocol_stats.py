"""Paper §3 claims, measured: message counts/bytes by tag, zero failed
requests, and the center's control-plane share of total traffic."""

from __future__ import annotations

from repro.core.protocol_sim import run_protocol_sim
from repro.graphs.generators import erdos_renyi


def run(csv=True):
    g = erdos_renyi(60, 4 / 59, 3)
    rows = []
    for p in (4, 8, 16):
        res = run_protocol_sim(g, num_workers=p, codec_name="optimized")
        s = res.stats
        rows.append(
            dict(
                workers=p,
                mvc=res.best_size,
                ticks=res.ticks,
                nodes=s.nodes_expanded,
                transfers=s.tasks_transferred,
                failed_requests=s.failed_requests,
                msgs_total=sum(s.msg_count.values()),
                bytes_total=s.total_bytes,
                center_bytes=s.center_bytes,
                center_share=round(s.center_bytes / max(s.total_bytes, 1), 3),
                term_cancelled=s.termination_cancelled,
            )
        )
    if csv:
        keys = list(rows[0].keys())
        print(",".join(keys))
        for r in rows:
            print(",".join(str(r[k]) for k in keys))
    return rows


if __name__ == "__main__":
    run()
