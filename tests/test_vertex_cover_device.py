"""Device-side (jnp) vertex-cover ops vs the host reference."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.graphs.bitgraph import mask_full, popcount_rows
from repro.graphs.generators import erdos_renyi
from repro.problems import sequential as seq
from repro.problems import vertex_cover as vc


@pytest.mark.parametrize("seed", range(4))
def test_degrees_match_host(seed):
    g = erdos_renyi(40, 0.2, seed)
    prob = vc.make_problem(jnp.asarray(g.adj), g.n)
    rng = np.random.default_rng(seed)
    mask = rng.integers(0, 2**32, g.W, dtype=np.uint32)
    rem = g.n % 32
    if rem:
        mask[-1] &= np.uint32((1 << rem) - 1)
    got = np.asarray(vc.degrees(prob, jnp.asarray(mask)))
    want = g.degrees(mask)
    assert (got == want).all()


@pytest.mark.parametrize("seed", range(4))
def test_reduce_instance_equivalent(seed):
    """Device and host reductions may pick different (equally valid) vertices
    but must produce covers of identical size on terminal instances and keep
    the invariant sol ∪ optimal(remaining) optimal."""
    g = erdos_renyi(30, 0.12, seed)  # sparse: reductions dominate
    prob = vc.make_problem(jnp.asarray(g.adj), g.n)
    m0 = jnp.asarray(mask_full(g.n))
    s0 = jnp.zeros(g.W, jnp.uint32)
    dm, ds = vc.reduce_instance(prob, m0, s0)
    hm, hs = seq.reduce_instance(g, mask_full(g.n), np.zeros(g.W, np.uint32))
    assert int(vc.popcount(ds)) == int(popcount_rows(hs))


def test_branch_once_terminal_detection():
    g = erdos_renyi(20, 0.3, 1)
    prob = vc.make_problem(jnp.asarray(g.adj), g.n)
    res = vc.branch_once(prob, jnp.asarray(mask_full(g.n)), jnp.zeros(g.W, jnp.uint32))
    # full graph with edges is never terminal
    assert not bool(res.is_terminal)
    # empty instance is
    res2 = vc.branch_once(prob, jnp.zeros(g.W, jnp.uint32), jnp.zeros(g.W, jnp.uint32))
    assert bool(res2.is_terminal)


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 100_000))
def test_pack_unpack_roundtrip(seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(1, 70))
    W = (n + 31) // 32
    bits = rng.random(n) < 0.5
    packed = vc.pack_bits(jnp.asarray(bits), W)
    assert (np.asarray(vc.unpack_bits(packed, n)) == bits).all()


def test_verify_cover_device():
    g = erdos_renyi(24, 0.3, 2)
    best, sol, _ = seq.solve_sequential(g)
    assert bool(vc.verify_cover(jnp.asarray(g.adj), jnp.asarray(sol), g.n))
    # removing a used vertex breaks it (unless size-0 cover)
    used = np.flatnonzero(np.asarray(vc.unpack_bits(jnp.asarray(sol), g.n)))
    if len(used):
        broken = np.array(sol)
        v = int(used[0])
        broken[v // 32] &= ~np.uint32(1 << (v % 32))
        assert not bool(vc.verify_cover(jnp.asarray(g.adj), jnp.asarray(broken), g.n))
