"""whisper-large-v3 [audio] — encoder-decoder, stubbed conv frontend.

32(+32 enc)L d_model=1280 20H (kv=20) d_ff=5120 vocab=51866; the frontend is
a stub: input_specs feeds 1500 precomputed frame embeddings.
[arXiv:2212.04356]
"""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-large-v3",
        family="encdec",
        n_layers=32,
        n_enc_layers=32,
        enc_seq=1500,
        d_model=1280,
        n_heads=20,
        n_kv_heads=20,
        d_ff=5120,
        vocab=51_866,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="whisper-smoke",
        family="encdec",
        n_layers=2,
        n_enc_layers=2,
        enc_seq=24,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=128,
        vocab=512,
        dtype="float32",
    )
