"""Center logic (paper Alg. 3): matching, pinning, cycle check, best value."""

from repro.core.center import CenterState, Status


def test_offer_best_verifies():
    c = CenterState(num_workers=3)
    assert c.offer_best(1, 10)
    assert not c.offer_best(2, 12)  # center re-verifies claims
    assert c.offer_best(2, 7)
    assert c.best_holder == 2


def test_available_assignment_pins():
    c = CenterState(num_workers=3, seed=1)
    w = c.on_available(2)
    assert w in (1, 3)
    assert c.status[2] == Status.ASSIGNED
    assert c.assigned_to[2] == w


def test_no_donor_stays_available():
    c = CenterState(num_workers=2)
    c.status[1] = Status.AVAILABLE
    got = c.on_available(2)  # only worker 1 left and it is not RUNNING
    assert got is None
    assert c.status[2] == Status.AVAILABLE


def test_started_running_feeds_waiting_available():
    c = CenterState(num_workers=3)
    c.status[3] = Status.AVAILABLE
    pair = c.on_started_running(1)
    assert pair == (1, 3)
    assert c.status[3] == Status.ASSIGNED


def test_cycle_check():
    """§3.2: before assigning r -> w, follow the chain from r to avoid
    creating a dependency cycle."""
    c = CenterState(num_workers=2, seed=0)
    c.assigned_to[1] = 2  # 1 waits on 2
    # 2 asks for work; the only candidate donor is 1, but 1's chain leads to 2
    got = c.get_next_working_node(2)
    assert got is None


def test_priority_policy_picks_heaviest():
    c = CenterState(num_workers=3, policy="priority")
    c.on_metadata(1, 5)
    c.on_metadata(3, 9)
    assert c.get_next_working_node(2) == 3


def test_all_idle():
    c = CenterState(num_workers=2)
    assert not c.all_idle()
    c.status[1] = Status.AVAILABLE
    c.status[2] = Status.ASSIGNED  # ASSIGNED counts as idle (§3.3)
    assert c.all_idle()
