"""Pallas TPU flash attention (blockwise online softmax).

Standard TPU decomposition: grid over (batch·q-heads, q blocks); the kernel
loops over KV blocks with a fori_loop, maintaining the running max ``m``,
normalizer ``l`` and accumulator in registers/VMEM — no (S, S) score matrix
ever exists.  Block shapes are (Bq, D) × (Bk, D) with D padded to a lane
multiple by the caller; Bq/Bk default to 128/128 (MXU-aligned) and shrink to
the sequence when shorter.

Causal and sliding-window masks are applied per KV block; whole blocks that
are fully masked are skipped via the loop bounds (the causal upper bound),
which is what makes the kernel O(S·w) for local attention.

GQA: the caller maps q heads to kv heads in the grid index map, so KV blocks
are fetched once per *kv* head regardless of the group size.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(
    q_ref,  # (1, Bq, D)
    k_ref,  # (1, Sk, D)  -- whole K panel for this (b, kv-head)
    v_ref,  # (1, Sk, D)
    o_ref,  # (1, Bq, D)
    *,
    causal: bool,
    window: int | None,
    scale: float,
    block_k: int,
    q_offset: int,  # Sk - Sq (decode: queries sit at the end of the timeline)
    seq_k: int,  # TRUE KV length (panels are padded to a block_k multiple)
):
    _, Bq, D = q_ref.shape
    Sk = seq_k
    qi = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32) * scale
    qpos = qi * Bq + jax.lax.broadcasted_iota(jnp.int32, (Bq, 1), 0) + q_offset

    nblocks = pl.cdiv(Sk, block_k)
    if causal:
        # last KV block that any query in this q-block can see
        hi = jnp.minimum(
            (qi * Bq + Bq - 1 + q_offset) // block_k + 1, nblocks
        )
    else:
        hi = nblocks
    if window is not None:
        lo = jnp.maximum((qi * Bq + q_offset - window + 1) // block_k, 0)
    else:
        lo = 0

    def kv_step(j, carry):
        acc, m, l = carry
        k = k_ref[0, pl.ds(j * block_k, block_k), :].astype(jnp.float32)
        v = v_ref[0, pl.ds(j * block_k, block_k), :].astype(jnp.float32)
        s = q @ k.T  # (Bq, Bk)
        kpos = j * block_k + jax.lax.broadcasted_iota(jnp.int32, (1, block_k), 1)
        mask = kpos < Sk  # tail padding
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m - m_new)
        l_new = alpha * l + p.sum(axis=1, keepdims=True)
        acc_new = acc * alpha + p @ v
        return acc_new, m_new, l_new

    acc0 = jnp.zeros((Bq, D), jnp.float32)
    m0 = jnp.full((Bq, 1), NEG_INF, jnp.float32)
    l0 = jnp.zeros((Bq, 1), jnp.float32)
    acc, m, l = jax.lax.fori_loop(lo, hi, kv_step, (acc0, m0, l0))
    o_ref[0] = (acc / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "scale", "block_q", "block_k", "interpret"),
)
def flash_attention(
    q: jnp.ndarray,  # (B, Sq, Hq, D)
    k: jnp.ndarray,  # (B, Sk, Hkv, D)
    v: jnp.ndarray,  # (B, Sk, Hkv, D)
    *,
    causal: bool = True,
    window: int | None = None,
    scale: float | None = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    B, Sq, Hq, D = q.shape
    _, Sk, Hkv, _ = k.shape
    assert Hq % Hkv == 0, "query heads must be a multiple of kv heads"
    G = Hq // Hkv
    scale = scale if scale is not None else 1.0 / (D**0.5)
    Bq = min(block_q, Sq)
    Bk = min(block_k, Sk)

    # layout: fold (batch, head) into the grid; (BH, S, D) panels.
    # K/V are padded to a Bk multiple because the kernel slices them with
    # pl.ds, whose out-of-bounds reads clamp the start index (wrong rows);
    # the kpos < Sk mask neutralizes the padded tail.
    pad_k = (-Sk) % Bk
    qt = q.transpose(0, 2, 1, 3).reshape(B * Hq, Sq, D)
    kt = k.transpose(0, 2, 1, 3).reshape(B * Hkv, Sk, D)
    vt = v.transpose(0, 2, 1, 3).reshape(B * Hkv, Sk, D)
    if pad_k:
        kt = jnp.pad(kt, ((0, 0), (0, pad_k), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, pad_k), (0, 0)))
    Sk_pad = Sk + pad_k

    grid = (B * Hq, pl.cdiv(Sq, Bq))

    def kv_index(h, i):
        b, hq = h // Hq, h % Hq
        return (b * Hkv + hq // G, 0, 0)

    out = pl.pallas_call(
        functools.partial(
            _flash_kernel,
            causal=causal,
            window=window,
            scale=scale,
            block_k=Bk,
            q_offset=Sk - Sq,
            seq_k=Sk,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, Bq, D), lambda h, i: (h, i, 0)),
            pl.BlockSpec((1, Sk_pad, D), kv_index),
            pl.BlockSpec((1, Sk_pad, D), kv_index),
        ],
        out_specs=pl.BlockSpec((1, Bq, D), lambda h, i: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * Hq, Sq, D), q.dtype),
        interpret=interpret,
    )(qt, kt, vt)
    return out.reshape(B, Hq, Sq, D).transpose(0, 2, 1, 3)
