"""Durable solve plane: kill at ANY chunk boundary, resume, and the final
result is bit-identical to the uninterrupted run.

The engine carries its whole trajectory on device (frontier records, bounds,
stat counters, the round-robin donor salt in ``WorkerState.rounds``) and the
host loop holds only a rounds counter — so a checkpoint written at a
host-sync boundary plus that counter IS the full state.  These tests pin the
contract end-to-end for every plane: solo, fpt, the batched solve_many plane
(across a compaction), and an occupied live :class:`SolveService`.

Bit-identity covers result fields and device-carried stats.  Explicitly
OUTSIDE the contract: ``wall_s`` (wall clock) and the durability bookkeeping
itself (``checkpoints_written``, ``resumed_from``), which legitimately
differ between a resumed and an uninterrupted run.
"""

import os

import numpy as np
import pytest

from repro.api import (
    PlaneCache,
    SolveConfig,
    SolverSession,
    SolveService,
)
from repro.core import superstep
from repro.graphs.generators import erdos_renyi

# checkpoint at EVERY host-sync boundary: one round per chunk, tiny rounds
CFG = dict(num_workers=4, steps_per_round=2, chunk_rounds=1, checkpoint_every=1)


def _assert_same(a, b):
    """Bit-identity modulo wall-clock and durability bookkeeping."""
    assert a.best_size == b.best_size
    assert a.found == b.found
    assert a.rounds == b.rounds
    assert a.nodes_expanded == b.nodes_expanded
    assert a.tasks_transferred == b.tasks_transferred
    assert a.stats.transfer_rounds == b.stats.transfer_rounds
    assert a.stats.transfer_bytes_total == b.stats.transfer_bytes_total
    assert a.stats.overflow_count == b.stats.overflow_count
    assert (a.best_sol is None) == (b.best_sol is None)
    if a.best_sol is not None:
        assert (np.asarray(a.best_sol) == np.asarray(b.best_sol)).all()


def _steps(d):
    return sorted(
        int(p[5:]) for p in os.listdir(d)
        if p.startswith("step_") and not p.endswith(".tmp")
    )


@pytest.mark.parametrize(
    "mode_kw",
    [dict(), dict(mode="fpt", k=20)],
    ids=["bnb", "fpt"],
)
def test_solo_resume_bit_identical_at_every_boundary(tmp_path, mode_kw):
    g = erdos_renyi(34, 0.25, seed=3)
    cfg = SolveConfig(**CFG, **mode_kw)
    cache = PlaneCache()
    base = SolverSession(config=cfg, cache=cache).solve(g)
    assert base.rounds > 3  # the run really spans several chunk boundaries

    d = str(tmp_path / "ck")
    r = SolverSession(config=cfg, cache=cache).solve(g, checkpoint_dir=d)
    _assert_same(r, base)
    steps = _steps(d)
    assert r.stats.checkpoints_written == len(steps) > 0

    traces_before = superstep.PLANE_TRACES
    for s in steps:  # a kill after ANY chunk is resumable
        rr = SolverSession.resume(
            os.path.join(d, f"step_{s}"), cache=cache, checkpoint_dir=None
        )
        _assert_same(rr, base)
        assert rr.stats.resumed_from
    # resuming into the warm plane cache compiles NOTHING new
    assert superstep.PLANE_TRACES == traces_before


def test_solve_many_resume_bit_identical_across_compaction(tmp_path):
    sizes = [(20, 1), (30, 2), (34, 3), (18, 4), (33, 5), (26, 6)]
    gs = [erdos_renyi(n, 0.3, seed=s) for n, s in sizes]
    cfg = SolveConfig(**CFG)
    cache = PlaneCache()
    base = SolverSession(config=cfg, cache=cache).solve_many(gs)
    assert base.compactions >= 1  # the batch really crosses a compaction

    d = str(tmp_path / "ck")
    r = SolverSession(config=cfg, cache=cache).solve_many(gs, checkpoint_dir=d)
    for a, b in zip(r.results, base.results):
        _assert_same(a, b)
    steps = _steps(d)
    assert steps

    traces_before = superstep.PLANE_TRACES
    for s in steps:
        rr = SolverSession.resume(
            os.path.join(d, f"step_{s}"), cache=cache, checkpoint_dir=None
        )
        assert len(rr.results) == len(base.results)
        for a, b in zip(rr.results, base.results):
            _assert_same(a, b)
        # host-side plane accounting resumes too, not just results
        assert rr.compactions == base.compactions
        assert rr.lane_stats.chunk_calls == base.lane_stats.chunk_calls
    assert superstep.PLANE_TRACES == traces_before


def test_occupied_service_restores_and_finishes_every_ticket(tmp_path):
    sizes = [(20, 1), (30, 2), (34, 3), (18, 4), (33, 5), (26, 6), (24, 7)]
    gs = [erdos_renyi(n, 0.3, seed=s) for n, s in sizes]
    cfg = SolveConfig(
        num_workers=4, steps_per_round=2, chunk_rounds=1, service_lanes=3
    )
    cache = PlaneCache()

    svc = SolveService("vertex_cover", cfg, cache=cache)
    tickets = [svc.submit(g) for g in gs]
    svc.drain()
    base = {t: svc.result(t) for t in tickets}

    # occupy the plane: live lanes AND a pending queue at checkpoint time
    svc = SolveService("vertex_cover", cfg, cache=cache)
    tickets = [svc.submit(g) for g in gs]
    done_before = []
    for _ in range(4):
        done_before.extend(svc.step())
    d = str(tmp_path / "ck")
    svc.checkpoint(d)
    assert svc.tickets()  # still occupied — this checkpoint holds live lanes

    traces_before = superstep.PLANE_TRACES
    svc2 = SolveService.restore(d, cache=cache)
    assert svc2.tickets() == svc.tickets()
    svc2.drain()
    for t in tickets:
        _assert_same(svc2.result(t), base[t])
    assert superstep.PLANE_TRACES == traces_before
    # tickets finished before the kill came back from the checkpoint too
    assert set(done_before) <= set(base)


def test_auto_checkpoint_from_config_and_stats_fields(tmp_path):
    """checkpoint_dir in the CONFIG (not the call) also checkpoints, and the
    durability bookkeeping lands in the typed stats."""
    g = erdos_renyi(30, 0.25, seed=3)
    d = str(tmp_path / "ck")
    cfg = SolveConfig(**CFG, checkpoint_dir=d)
    r = SolverSession(config=cfg).solve(g)
    assert r.stats.checkpoints_written == len(_steps(d)) > 0
    assert r.stats.resumed_from is None

    rr = SolverSession.resume(d, checkpoint_dir=None)
    _assert_same(rr, r)
    assert rr.stats.resumed_from == d
    assert rr.stats.checkpoints_written == 0
