"""Branching-problem solver driver — any registry problem, four engines.

  --problem NAME     which branching problem (vertex_cover, max_clique, mis;
                     see repro.problems.registry)
  --engine spmd      the TPU-adapted superstep engine (vmap of P virtual
                     workers on CPU; one worker per device with --use-mesh)
  --engine protocol  the faithful asynchronous MPI-protocol simulator
                     (vertex-cover only)
  --engine central   the fully-centralized baseline (Abu-Khzam 2006;
                     vertex-cover only)
  --engine seq       the problem's sequential reference

Multi-instance mode (the batched solve plane, `engine.solve_many`): pass
several DIMACS files and/or `--batch B` to pack B instances onto one plane —
one compiled executable and one host sync per chunk for the whole batch.

Usage:
  PYTHONPATH=src python -m repro.launch.solve --graph gnp --n 60 --p 0.1 \
      --engine spmd --workers 8
  PYTHONPATH=src python -m repro.launch.solve --graph gnp --n 40 \
      --problem max_clique --workers 8
  PYTHONPATH=src python -m repro.launch.solve --graph phat --n 120 \
      --density 0.4 --engine protocol --workers 16 --codec basic
  PYTHONPATH=src python -m repro.launch.solve --graph dimacs \
      --files a.col b.col c.col --workers 8
  PYTHONPATH=src python -m repro.launch.solve --graph gnp --n 40 --batch 16
"""

from __future__ import annotations

import argparse
import sys
import time

from repro.core.encoding import make_codec
from repro.graphs.generators import erdos_renyi, p_hat_like, parse_dimacs
from repro.problems.registry import get_problem


def build_graph(args, seed=None):
    seed = args.seed if seed is None else seed
    if args.graph == "gnp":
        return erdos_renyi(args.n, args.p if args.p else 4.0 / (args.n - 1), seed)
    if args.graph == "phat":
        return p_hat_like(args.n, args.density, seed)
    if args.graph == "dimacs":
        with open(args.file) as f:
            return parse_dimacs(f.read())
    raise ValueError(args.graph)


def build_graphs(args):
    """The multi-instance work list: every --files entry, plus --batch
    generated instances (consecutive seeds).  Empty unless one of those
    multi-instance flags was used."""
    graphs, labels = [], []
    for path in args.files or []:
        with open(path) as f:
            graphs.append(parse_dimacs(f.read()))
        labels.append(path)
    if args.batch is not None:
        if args.batch < 1:
            raise SystemExit("--batch must be >= 1")
        if args.graph == "dimacs":
            raise SystemExit("--batch needs a generated graph (gnp/phat)")
        for b in range(args.batch):
            graphs.append(build_graph(args, seed=args.seed + b))
            labels.append(f"{args.graph}-n{args.n}-seed{args.seed + b}")
    return graphs, labels


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--graph", default="gnp", choices=["gnp", "phat", "dimacs"])
    ap.add_argument("--n", type=int, default=60)
    ap.add_argument("--p", type=float, default=0.0)
    ap.add_argument("--density", type=float, default=0.4)
    ap.add_argument("--file", default=None)
    ap.add_argument("--files", nargs="+", default=None,
                    help="several DIMACS files -> one solve_many batch")
    ap.add_argument("--batch", type=int, default=None,
                    help="generate B instances (seeds seed..seed+B-1) and "
                         "solve them on one batched plane (B=1 still uses "
                         "the batched engine)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument(
        "--engine", default="spmd", choices=["spmd", "protocol", "central", "seq"]
    )
    ap.add_argument("--problem", default="vertex_cover",
                    help="branching problem from the registry "
                         "(vertex_cover, max_clique, mis, ...)")
    ap.add_argument("--workers", type=int, default=8)
    ap.add_argument("--codec", default="optimized",
                    help="task codec: optimized (n-bit masks) or basic "
                         "(adjacency payload, §4.3)")
    ap.add_argument("--policy", default="priority", choices=["priority", "random"])
    ap.add_argument("--steps-per-round", type=int, default=32)
    ap.add_argument("--lanes", type=int, default=1)
    ap.add_argument("--transfer", default="sparse", choices=["sparse", "gather"],
                    help="data-plane impl (sparse=masked psum, gather=all-gather)")
    ap.add_argument("--donate-k", type=int, default=1,
                    help="max tasks a matched donor ships per round")
    ap.add_argument("--chunk-rounds", type=int, default=16,
                    help="supersteps per host sync (device-resident loop)")
    ap.add_argument("--use-mesh", action="store_true",
                    help="one worker per jax device (shard_map)")
    ap.add_argument("--mode", default="bnb", choices=["bnb", "fpt"])
    ap.add_argument("--k", type=int, default=None)
    args = ap.parse_args()

    # validate names through the registries up front: a typo'd --problem or
    # --codec dies with the list of known names, not a deep KeyError (the
    # same fix pattern as the benchmarks.run name validation)
    try:
        spec = get_problem(args.problem)
        make_codec(args.codec, 1)
    except ValueError as e:
        raise SystemExit(f"error: {e}")
    if args.engine in ("protocol", "central") and spec.name != "vertex_cover":
        raise SystemExit(
            f"--engine {args.engine} simulates the paper's vertex-cover "
            f"protocol only; use --engine spmd or seq for {spec.name}"
        )

    batch_graphs, batch_labels = build_graphs(args)
    if batch_graphs:
        if args.engine != "spmd":
            raise SystemExit("multi-instance mode is spmd-only")
        if args.use_mesh:
            raise SystemExit(
                "multi-instance mode has no mesh path yet (vmap virtual "
                "workers only) — drop --use-mesh"
            )
        from repro.core.engine import solve_many

        print(f"[solve] batch of {len(batch_graphs)} instances "
              f"[{spec.name}], workers/instance={args.workers}")
        res = solve_many(
            batch_graphs,
            num_workers=args.workers,
            problem=spec,
            steps_per_round=args.steps_per_round,
            lanes=args.lanes,
            policy_priority=(args.policy == "priority"),
            codec=args.codec,
            transfer_impl=args.transfer,
            donate_k=args.donate_k,
            chunk_rounds=args.chunk_rounds,
            mode=args.mode,
            k=args.k,
        )
        for label, r in zip(batch_labels, res.results):
            print(f"[solve]   {label}: best={r.best_size} rounds={r.rounds} "
                  f"nodes={r.nodes_expanded} transfers={r.tasks_transferred}")
        n_buckets = len(res.buckets)
        print(f"[solve] batch done: {len(batch_graphs)} instances in "
              f"{res.wall_s:.2f}s "
              f"({len(batch_graphs) / max(res.wall_s, 1e-9):.2f} inst/s), "
              f"{n_buckets} bucket(s), {res.compactions} compaction(s)")
        return

    g = build_graph(args)
    print(f"[solve] graph n={g.n} m={g.num_edges} engine={args.engine} "
          f"problem={spec.name}")
    t0 = time.perf_counter()

    if args.engine == "seq":
        best, sol, stats = spec.sequential(g, mode=args.mode, k=args.k)
        dt = time.perf_counter() - t0
        print(f"[solve] best={best} nodes={stats.nodes} {dt:.2f}s")
        return

    if args.engine == "protocol":
        from repro.core.protocol_sim import run_protocol_sim

        res = run_protocol_sim(
            g, num_workers=args.workers, policy=args.policy,
            codec_name=args.codec, mode=args.mode, k=args.k,
        )
        dt = time.perf_counter() - t0
        s = res.stats
        print(
            f"[solve] mvc={res.best_size} ticks={res.ticks} "
            f"nodes={s.nodes_expanded} transfers={s.tasks_transferred} "
            f"failed_requests={s.failed_requests} "
            f"bytes={s.total_bytes} (center {s.center_bytes}) {dt:.2f}s"
        )
        return

    if args.engine == "central":
        from repro.core.centralized import run_centralized_sim

        res = run_centralized_sim(
            g, num_workers=args.workers, codec_name=args.codec
        )
        dt = time.perf_counter() - t0
        s = res.stats
        print(
            f"[solve] mvc={res.best_size} ticks={res.ticks} "
            f"nodes={s.nodes_expanded} transfers={s.tasks_transferred} "
            f"bytes={s.total_bytes} {dt:.2f}s"
        )
        return

    from repro.core.engine import solve

    mesh = None
    if args.use_mesh:
        from repro.launch.mesh import make_solver_mesh

        mesh = make_solver_mesh(args.workers)
    res = solve(
        g,
        num_workers=args.workers,
        problem=spec,
        steps_per_round=args.steps_per_round,
        lanes=args.lanes,
        policy_priority=(args.policy == "priority"),
        codec=args.codec,
        transfer_impl=args.transfer,
        donate_k=args.donate_k,
        chunk_rounds=args.chunk_rounds,
        mode=args.mode,
        k=args.k,
        mesh=mesh,
    )
    print(
        f"[solve] best={res.best_size} rounds={res.rounds} "
        f"nodes={res.nodes_expanded} transfers={res.tasks_transferred} "
        f"overflow={res.overflow} wall={res.wall_s:.2f}s "
        f"control_B/round={res.control_bytes_per_round} "
        f"transfer_B/round={res.transfer_bytes_per_round:.1f} "
        f"(total {res.transfer_bytes_total}B over "
        f"{res.transfer_rounds} transfer rounds, {args.transfer})"
    )


if __name__ == "__main__":
    main()
