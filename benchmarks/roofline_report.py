"""Render the §Roofline table from dry-run JSON results.

Usage:
  PYTHONPATH=src python -m benchmarks.roofline_report results/dryrun_single.json
  PYTHONPATH=src python -m benchmarks.roofline_report in.json --md --out report.md

``--out`` writes the rendered table (CI uploads it as the roofline
artifact next to the dry-run JSON); ``--md`` renders a markdown table.
"""

from __future__ import annotations

import argparse
import json


def fmt_s(x):
    if x >= 1:
        return f"{x:7.2f}s "
    return f"{x * 1e3:7.1f}ms"


def render(path: str, md: bool = False):
    with open(path) as f:
        results = json.load(f)
    results.sort(key=lambda r: (r["arch"], r["shape"]))
    sep = "|" if md else " "
    hdr = [
        "arch", "shape", "status", "compute", "memory", "collect",
        "dominant", "mfu%", "useful", "temp_GiB", "args_GiB",
    ]
    lines = [sep.join(f"{h:>12s}" for h in hdr)]
    if md:
        lines.append(sep.join(["---"] * len(hdr)))
    for r in results:
        if r["status"] != "OK":
            lines.append(
                sep.join(
                    [f"{r['arch']:>12s}", f"{r['shape']:>12s}",
                     f"{r['status']:>12s}",
                     f"{r.get('reason', r.get('traceback', ''))[:60]:>12s}"]
                )
            )
            continue
        rl = r["roofline"]
        mem = r.get("memory", {})
        mfu = 100.0 * rl["model_flops_per_dev"] / 197e12 / rl["bound_s"] if rl["bound_s"] else 0
        lines.append(
            sep.join(
                [
                    f"{r['arch']:>12.12s}",
                    f"{r['shape']:>12s}",
                    f"{'OK':>12s}",
                    f"{fmt_s(rl['compute_s']):>12s}",
                    f"{fmt_s(rl['memory_s']):>12s}",
                    f"{fmt_s(rl['collective_s']):>12s}",
                    f"{rl['dominant']:>12s}",
                    f"{mfu:>12.1f}",
                    f"{rl['useful_flop_ratio']:>12.2f}",
                    f"{mem.get('temp_size_b', 0) / 2**30:>12.2f}",
                    f"{mem.get('argument_size_b', 0) / 2**30:>12.2f}",
                ]
            )
        )
    return "\n".join(lines)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(prog="benchmarks.roofline_report")
    ap.add_argument(
        "results", nargs="?", default="results/dryrun_single.json",
        help="dry-run JSON (repro.launch.dryrun output)",
    )
    ap.add_argument("--md", action="store_true", help="markdown table")
    ap.add_argument("--out", default=None, help="also write the table here")
    args = ap.parse_args(argv)
    table = render(args.results, md=args.md)
    print(table)
    if args.out:
        with open(args.out, "w") as f:
            f.write(table + "\n")


if __name__ == "__main__":
    main()
