from repro.checkpoint.store import (
    latest_step,
    restore_checkpoint,
    save_checkpoint,
)

__all__ = [
    "save_checkpoint",
    "restore_checkpoint",
    "latest_step",
    "CheckpointError",
    "SolveCheckpoint",
]


def __getattr__(name):
    # the solve-plane schema names resolve lazily so the store's import
    # graph stays independent of the schema module's
    if name in ("CheckpointError", "SolveCheckpoint"):
        from repro.checkpoint import solve

        return getattr(solve, name)
    raise AttributeError(name)
