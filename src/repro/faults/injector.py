"""The runtime half of fault injection: fire a :class:`FaultPlan` at the
host-sync boundaries of a live solve, and account for every recovery.

The injector is a small host-side state machine threaded (optionally)
through ``solve_spmd`` / ``solve_many_spmd`` / :class:`SolveService` /
:class:`FrontierSpiller` / the checkpoint store.  It never touches traced
code: every hook sits at a chunk boundary or inside a host-side
encode/deliver/IO call, so a run with ``injector=None`` compiles and
executes byte-for-byte the same plane executables.

Determinism: the injector is clocked by ``step_boundary()`` (one tick per
host sync), corruption targets are drawn from a generator seeded off the
plan, and backoff "sleeps" advance a virtual ``clock_s`` instead of the
wall — so the full injected-fault/recovery trajectory is reproducible
cross-machine and ``faults_injected`` / ``faults_recovered`` /
``retries`` can be pinned exactly in ``benchmarks/baseline.json``.

Accounting contract (summed into ``ServiceStats`` / chaos gates):

- ``injected[kind]``  incremented the moment a fault actually fires
- ``recovered[kind]`` incremented when its recovery action lands: a
  crashed/stalled lane re-admitted, a corrupt payload redelivered from
  the intact source, a failed checkpoint I/O retried to success, a stall
  window that drains without harm
- ``retries``         every extra delivery/IO attempt recovery needed
"""

from __future__ import annotations

import random

import numpy as np

from repro.faults.plan import FAULT_KINDS, FaultPlan


class FaultInjector:
    """Fires a :class:`FaultPlan` against a live solve and keeps the
    injected/recovered/retries ledgers.  One injector per solve run; all
    tiers (backend loop, service, spillers, checkpoint store) share it so
    the boundary clock is global."""

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self._pending = list(plan.events)        # sorted by (at, kind, lane)
        self._rng = np.random.default_rng([plan.seed & 0x7FFFFFFF, 0xFA017])
        self._backoff_rng = random.Random(plan.seed)
        self.t = 0                               # chunk-boundary clock
        self.clock_s = 0.0                       # virtual backoff clock
        self.injected = {k: 0 for k in FAULT_KINDS}
        self.recovered = {k: 0 for k in FAULT_KINDS}
        self.retries = 0
        self._active_stalls = []                 # [lane, expires_at] pairs
        self._io_owed = {"write": 0, "read": 0}  # failed attempts awaiting
                                                 # a successful retry

    # -- clocking ---------------------------------------------------------

    def step_boundary(self) -> None:
        """One host-sync boundary elapsed (call once per chunk)."""
        self.t += 1

    # -- ledgers ----------------------------------------------------------

    @property
    def faults_injected(self) -> int:
        return sum(self.injected.values())

    @property
    def faults_recovered(self) -> int:
        return sum(self.recovered.values())

    def note_recovered(self, kind: str, n: int = 1) -> None:
        self.recovered[kind] += n

    def note_retry(self, n: int = 1) -> None:
        self.retries += n

    def report(self) -> dict:
        return dict(
            boundaries=self.t,
            injected=dict(self.injected),
            recovered=dict(self.recovered),
            retries=self.retries,
            backoff_s=round(self.clock_s, 6),
            pending=len(self._pending),
        )

    def _due(self, kind: str, match=None):
        """Pop the first pending event of ``kind`` whose boundary has
        arrived (and that ``match`` accepts), or None."""
        for i, ev in enumerate(self._pending):
            if ev.kind == kind and ev.at <= self.t and (
                match is None or match(ev)
            ):
                return self._pending.pop(i)
        return None

    # -- crash ------------------------------------------------------------

    def take_crash(self) -> bool:
        """Solo-plane crash: did the (single) worker state die at this
        boundary?  Consumes at most one due crash event per call."""
        if self._due("crash") is None:
            return False
        self.injected["crash"] += 1
        return True

    def take_crashes(self, live_lanes) -> list:
        """Batched/service planes: which of ``live_lanes`` die at this
        boundary?  Each due crash event is mapped onto a concrete lane
        modulo the live list (events wait if no lane is live)."""
        targets = []
        live_lanes = list(live_lanes)
        while live_lanes:
            ev = self._due("crash")
            if ev is None:
                break
            lane = live_lanes[ev.lane % len(live_lanes)]
            self.injected["crash"] += 1
            if lane not in targets:
                targets.append(lane)
        return targets

    # -- stall ------------------------------------------------------------

    def stalled_lanes(self, live_lanes) -> set:
        """Lanes frozen at this boundary.  Due stall events bind to a
        concrete live lane and stay active for ``duration`` boundaries;
        a window that drains without the watchdog firing counts as
        recovered (the lane resumed by itself)."""
        live_lanes = list(live_lanes)
        if live_lanes:
            while True:
                ev = self._due("stall")
                if ev is None:
                    break
                lane = live_lanes[ev.lane % len(live_lanes)]
                self.injected["stall"] += 1
                self._active_stalls.append([lane, self.t + ev.duration])
        out = set()
        kept = []
        for lane, until in self._active_stalls:
            if self.t >= until or lane not in live_lanes:
                # window drained (or the lane was already retired/
                # quarantined under it) — the system is healthy again
                self.recovered["stall"] += 1
            else:
                out.add(lane)
                kept.append([lane, until])
        self._active_stalls = kept
        return out

    def clear_stall(self, lane: int) -> int:
        """The watchdog quarantined ``lane``: its active stall windows are
        resolved (recovery = quarantine + re-admission).  Returns how many
        windows were cleared (0 = the stall was organic, not injected)."""
        kept = []
        cleared = 0
        for entry in self._active_stalls:
            if entry[0] == lane:
                self.recovered["stall"] += 1
                cleared += 1
            else:
                kept.append(entry)
        self._active_stalls = kept
        return cleared

    # -- payload corruption ----------------------------------------------

    def corrupt(self, kind: str, rec):
        """Maybe corrupt a delivery copy of a payload record.

        Returns ``(delivered, injected)`` — ``delivered`` is a COPY with
        one deterministic bit flipped when a ``kind`` event was due
        (``transfer_corrupt`` / ``cold_corrupt``), else ``rec`` itself.
        The caller keeps the intact source, so checksum verification plus
        one redelivery always recovers."""
        ev = self._due(kind)
        if ev is None:
            return rec, False
        self.injected[kind] += 1
        bad = np.array(rec, copy=True)
        if bad.size:
            i = int(self._rng.integers(bad.size))
            bit = int(self._rng.integers(32))
            flat = bad.reshape(-1)
            flat[i] = np.uint32(int(flat[i]) ^ (1 << bit))
        return bad, True

    # -- checkpoint-store I/O ---------------------------------------------

    def io_hook(self, op: str) -> None:
        """Checkpoint-store fault hook, called at the top of every I/O
        attempt (``op`` is ``"write"`` or ``"read"``).  Raises ``OSError``
        when an io_error event is due; the store's retry/backoff loop
        re-enters, and the first clean attempt after a failure books the
        recovery + retry."""
        owed = self._io_owed.get(op, 0)
        ev = self._due("io_error", match=lambda e: e.op in ("", op))
        if ev is not None:
            self.injected["io_error"] += 1
            self._io_owed[op] = owed + 1
            raise OSError(
                f"injected checkpoint {op} fault (boundary {self.t})"
            )
        if owed:
            self.recovered["io_error"] += owed
            self.retries += owed
            self._io_owed[op] = 0

    def retry_policy(self):
        """A :class:`repro.checkpoint.store.RetryPolicy` whose backoff
        sleeps advance the injector's virtual clock (no real waiting) and
        whose jitter draws from the plan seed — fully deterministic."""
        from repro.checkpoint.store import RetryPolicy

        return RetryPolicy(sleep=self._virtual_sleep,
                           rng=self._backoff_rng)

    def _virtual_sleep(self, seconds: float) -> None:
        self.clock_s += seconds
