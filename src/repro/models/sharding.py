"""Logical-axis sharding rules (MaxText-style), mesh-shape agnostic.

Every parameter/activation is annotated with a tuple of *logical* axis names;
``logical_to_spec`` maps them onto the physical mesh axes:

    batch   -> (pod, data)     activations' leading dim (pure DP across pods)
    embed   -> data            FSDP: params + optimizer states sharded over
                               the data axis, all-gathered per layer
    heads   -> model           TP over the fused head*head_dim projection dim
    kv      -> model           TP over fused kv_heads*head_dim (when it divides)
    mlp     -> model           TP over d_ff
    experts -> model           EP: expert bank sharded over the model axis
    vocab   -> model           TP over the (un)embedding vocab dim
    seq     -> None             (sequence kept whole by default; the decode
                                cache can opt into 'seq->model' SP, see below)
    layers / stack / conv / window / lora -> None (scan-stacked dims)

``param_specs`` trees are built by the model inits alongside the params and
carry these names; nothing in the model code mentions physical axes.
"""

from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

DEFAULT_RULES: dict[str, Optional[tuple]] = {
    "batch": ("pod", "data"),
    "embed": ("data",),
    "heads": ("model",),
    "kv": ("model",),
    "mlp": ("model",),
    "experts": ("model",),
    "vocab": ("model",),
    "seq": None,
    "seq_kv": None,
    "layers": None,
    "stack": None,
    "conv": None,
    "window": None,
    "lora": None,
    "rnn": ("model",),
    "state": None,
    None: None,
}


def rules_for_mesh(mesh: Mesh, overrides: dict | None = None) -> dict:
    """Drop rule components whose mesh axis does not exist (e.g. 'pod' on the
    single-pod mesh) and apply per-experiment overrides (§Perf knobs).
    Mesh-axis sizes ride along under '_sizes' (used by the MoE group math)."""
    axes = set(mesh.axis_names)
    rules = dict(DEFAULT_RULES)
    if overrides:
        rules.update(overrides)
    out = {}
    for k, v in rules.items():
        if isinstance(k, str) and k.startswith("_"):
            out[k] = v  # private metadata (e.g. _moe_impl), not an axis rule
        elif v is None:
            out[k] = None
        else:
            kept = tuple(a for a in v if a in axes)
            out[k] = kept if kept else None
    out["_sizes"] = dict(zip(mesh.axis_names, mesh.devices.shape))
    out["_mesh"] = mesh
    return out


def logical_to_spec(logical: tuple, rules: dict) -> P:
    """('embed', 'heads') -> PartitionSpec(('data',), ('model',))."""
    parts = []
    for name in logical:
        r = rules.get(name, None)
        if r is None:
            parts.append(None)
        elif len(r) == 1:
            parts.append(r[0])
        else:
            parts.append(r)
    return P(*parts)


def tree_to_shardings(spec_tree, mesh: Mesh, rules: dict | None = None):
    """Map a pytree of logical-axis tuples to NamedShardings."""
    rules = rules or rules_for_mesh(mesh)
    return jax.tree.map(
        lambda logical: NamedSharding(mesh, logical_to_spec(logical, rules)),
        spec_tree,
        is_leaf=lambda x: isinstance(x, tuple),
    )


def constrain(x, logical: tuple, rules: dict | None):
    """with_sharding_constraint using logical names (no-op without rules)."""
    if rules is None:
        return x
    return jax.lax.with_sharding_constraint(x, logical_to_spec(logical, rules))


_FSDP_AXES = {"data", "pod"}


def gather_params(tree, spec_tree, rules: dict | None):
    """Just-in-time FSDP regather: constrain every param leaf to its spec with
    the data/pod (FSDP) mesh axes dropped, keeping only tensor-parallel axes.

    Called at the TOP of each scanned block body, this makes XLA all-gather
    the layer's weight slice (params-sized traffic, one layer live at a time)
    instead of all-reducing activation-sized partial matmul sums — the
    standard ZeRO-3 streaming pattern.  At rest, params/grads/moments stay
    fully sharded over (data × model)."""
    if rules is None:
        return tree

    def f(p, logical):
        l2 = tuple(
            None
            if (n is not None and rules.get(n) and set(rules[n]) & _FSDP_AXES)
            else n
            for n in logical
        )
        return constrain(p, l2, rules)

    return jax.tree.map(f, tree, spec_tree)


def spec_tree_of(init_fn):
    """Extract the STATIC logical-spec tree of an ``init() -> (params, specs)``
    initializer without allocating any arrays (eval_shape + side channel)."""
    cap = {}

    def wrapper():
        p, s = init_fn()
        cap["s"] = s
        return p

    jax.eval_shape(wrapper)
    return cap["s"]
