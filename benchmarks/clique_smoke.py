"""Max-clique smoke solve on the generic problem plane.

The bench-smoke CI job runs this alongside the vertex-cover benchmarks so
every PR exercises a SECOND registry problem end to end: a small batch of
G(n, p) instances solved by a max-clique ``SolverSession`` on one batched
plane, checked against the sequential reference, with throughput recorded in
BENCH_smoke.json (tagged with the problem name).
"""

from __future__ import annotations

import time

from repro.api import SolveConfig, SolverSession
from repro.graphs.generators import erdos_renyi
from repro.problems.sequential import solve_sequential_max_clique, verify_clique


def run(smoke: bool = False) -> dict:
    n, p, B, workers, spr = (20, 0.4, 4, 4, 8) if smoke else (32, 0.35, 8, 6, 8)
    graphs = [erdos_renyi(n, p, seed) for seed in range(B)]
    session = SolverSession(
        problem="max_clique",
        config=SolveConfig(num_workers=workers, steps_per_round=spr),
    )

    t0 = time.perf_counter()
    batch = session.solve_many(graphs)
    wall = time.perf_counter() - t0

    sizes = []
    for g, r in zip(graphs, batch.results):
        want, _, _ = solve_sequential_max_clique(g)
        assert r.best_size == want, (
            f"max-clique plane disagrees with the sequential reference: "
            f"{r.best_size} != {want}"
        )
        assert verify_clique(g, r.best_sol)
        assert not r.stats.overflow
        sizes.append(r.best_size)

    print(f"max_clique on G({n}, {p}) x {B}: sizes={sizes}, "
          f"{B / max(batch.wall_s, 1e-9):.2f} inst/s "
          f"(all verified vs sequential reference)")
    return dict(
        problem="max_clique",
        n=n,
        p=p,
        B=B,
        workers=workers,
        sizes=sizes,
        wall_s=round(wall, 3),
        inst_per_s=round(B / max(batch.wall_s, 1e-9), 3),
    )


if __name__ == "__main__":
    run()
