"""Task-tree (paper §3.4, Alg. 5-6): caterpillar invariant + priority order."""

import random

from _hypothesis_compat import given, settings, strategies as st

from repro.core.task_tree import TaskTree


class T:
    """Identity-keyed payload."""

    def __init__(self, name):
        self.name = name

    def __repr__(self):
        return f"T({self.name})"


def test_register_and_claim():
    tree = TaskTree()
    root = T("root")
    tree.set_root(root)
    kids = [T("a"), T("b")]
    tree.register_child_instances(kids, root)
    assert tree.pending_count() == 2
    assert tree.try_claim(kids[0])
    assert tree.pending_count() == 1
    assert tree.check_caterpillar()


def test_donation_is_shallowest_leftmost():
    tree = TaskTree()
    root = T("root")
    tree.set_root(root)
    a, b = T("a"), T("b")
    tree.register_child_instances([a, b], root)
    tree.try_claim(a)  # explore a; b stays pending at depth 1
    a1, a2 = T("a1"), T("a2")
    tree.register_child_instances([a1, a2], a)  # depth 2
    got = tree.pop_highest_priority()
    assert got is b, "must donate the shallowest pending task"
    got2 = tree.pop_highest_priority()
    assert got2 is a1, "then the leftmost deeper one"


def test_rerooting_past_single_child():
    tree = TaskTree()
    root = T("root")
    tree.set_root(root)
    a = T("a")
    tree.register_child_instances([a], root)
    tree.try_claim(a)
    a1, a2 = T("a1"), T("a2")
    tree.register_child_instances([a1, a2], a)
    # root has a single (exploring) child -> Alg. 6 re-roots to a
    got = tree.pop_highest_priority()
    assert got is a1
    assert tree.root.payload is a


def test_finish_removes_and_empties():
    tree = TaskTree()
    root = T("root")
    tree.set_root(root)
    a, b = T("a"), T("b")
    tree.register_child_instances([a, b], root)
    tree.try_claim(a)
    tree.finish(a)
    assert tree.pop_highest_priority() is b
    tree.finish(root)
    assert tree.is_empty()


def test_register_after_donation_is_ignored():
    """Children of an already-donated task are not tracked (Alg. 5 guard)."""
    tree = TaskTree()
    root = T("root")
    tree.set_root(root)
    a, b = T("a"), T("b")
    tree.register_child_instances([a, b], root)
    donated = tree.pop_highest_priority()
    assert donated is a
    tree.register_child_instances([T("a1")], a)  # parent gone: no-op
    assert tree.pending_count() == 1  # only b


@settings(max_examples=60, deadline=None)
@given(st.lists(st.sampled_from(["branch2", "branch3", "donate", "up"]),
                min_size=1, max_size=120), st.integers(0, 2**31))
def test_caterpillar_invariant_random_walk(ops, seed):
    """Simulated DFS with random donations never violates the caterpillar
    topology and pending counts stay consistent."""
    rng = random.Random(seed)
    tree = TaskTree()
    root = T("root")
    tree.set_root(root)
    stack = [root]
    made = 0
    for op in ops:
        cur = stack[-1]
        if op in ("branch2", "branch3") and len(stack) < 12:
            k = 2 if op == "branch2" else 3
            kids = [T(f"n{made + i}") for i in range(k)]
            made += k
            tree.register_child_instances(kids, cur)
            child = rng.choice(kids)
            if tree.try_claim(child):
                stack.append(child)
        elif op == "donate":
            before = tree.pending_count()
            got = tree.pop_highest_priority()
            assert (got is None) == (before == 0)
            if got is not None:
                assert tree.pending_count() == before - 1
        elif op == "up" and len(stack) > 1:
            done = stack.pop()
            # finishing requires no pending children: donate them all first
            node = tree._index.get(id(done))
            if node is not None:
                while node.children:
                    c = node.children[0]
                    node.children.remove(c)
                    tree._index.pop(id(c.payload), None)
                tree.finish(done)
        assert tree.check_caterpillar()
