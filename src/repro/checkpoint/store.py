"""Checkpoint store: atomic, mesh-agnostic save/restore with async writes.

Layout:  <dir>/step_<N>/  arrays.npz  (flattened pytree leaves)
                          manifest.msgpack  (treedef paths, shapes, dtypes,
                                             step, data-pipeline state,
                                             per-array CRC32 checksums)
         <dir>/step_<N>.prev/   the previous generation of the same step
                                (kept, not clobbered, on overwrite)

* **atomic**: written to a UNIQUE ``step_<N>.<rand>.tmp`` dir then swapped
  into place under a process-wide lock — a crash mid-write never corrupts
  the latest checkpoint, and concurrent writers of the same step (e.g. an
  async save racing a final blocking save) are last-writer-wins instead of
  colliding on a shared tmp path.  Overwriting an existing step rotates it
  to ``step_<N>.prev`` instead of deleting it, so one bad write never
  destroys the last good generation;
* **checked**: the manifest records a CRC32 per array, so silent bit-rot
  inside a structurally valid npz is *detected* at load (and the solve
  loader falls back to the previous good generation, see
  :mod:`repro.checkpoint.solve`);
* **retried**: save/load take an optional :class:`RetryPolicy` — bounded
  exponential backoff with injectable sleep + rng (tests and the fault
  injector use a virtual clock, production uses ``time.sleep``) — and an
  optional ``fault_hook(op)`` called at the top of every I/O attempt (the
  fault injector's entry point);
* **mesh-agnostic**: leaves are saved unsharded (device_get) and restored
  with ``jax.device_put(leaf, sharding)`` against whatever mesh the restart
  runs on — re-meshing on restart is how elastic scale-up/down works;
* **async**: ``save_checkpoint(..., blocking=False)`` snapshots to host
  memory synchronously (cheap) and writes on a daemon thread, overlapping
  I/O with the next training steps.
"""

from __future__ import annotations

import dataclasses
import os
import random
import shutil
import tempfile
import threading
import time
import warnings
import zlib
from typing import Any, Callable, Optional

import jax
import msgpack
import numpy as np

_PENDING: list[threading.Thread] = []
# Serializes the final tmp->step_<N> swap across writer threads; the bulk
# np.savez I/O stays outside the lock so async saves still overlap compute.
_SWAP_LOCK = threading.Lock()
# Process umask, read once at import (before writer threads exist — the
# os.umask read is a racy set/restore).
_UMASK = os.umask(0)
os.umask(_UMASK)


# -- bounded retry/backoff -----------------------------------------------------


@dataclasses.dataclass(eq=False)
class RetryPolicy:
    """Bounded exponential backoff for checkpoint-store I/O.

    ``sleep`` and ``rng`` are injectable: tests and the fault injector pass
    a virtual clock + seeded ``random.Random`` so retry trajectories are
    deterministic; production defaults to ``time.sleep`` and a fixed seed
    (jitter only decorrelates writers, it carries no entropy contract).
    """

    max_attempts: int = 4
    base_s: float = 0.05
    multiplier: float = 2.0
    jitter: float = 0.25
    sleep: Callable[[float], None] = time.sleep
    rng: Optional[random.Random] = None
    retry_on: tuple = (OSError,)
    retries: int = 0  # attempts beyond the first, across all wrapped calls

    def __post_init__(self):
        if self.max_attempts < 1:
            raise ValueError("max_attempts must be >= 1")
        if self.rng is None:
            self.rng = random.Random(0)

    def backoff_s(self, attempt: int) -> float:
        """Delay before retry ``attempt`` (0-based): exponential with
        multiplicative jitter in ``[1, 1 + jitter]``."""
        return (
            self.base_s
            * (self.multiplier ** attempt)
            * (1.0 + self.jitter * self.rng.random())
        )


def call_with_retry(fn: Callable[[], Any], policy: Optional[RetryPolicy],
                    *, what: str = "checkpoint I/O") -> Any:
    """Run ``fn`` under ``policy`` (None = single attempt, today's
    behavior).  Only ``policy.retry_on`` exceptions are retried — corrupt
    *content* (CheckpointError) is not an I/O flake and falls through to
    the generation-fallback path instead."""
    if policy is None:
        return fn()
    last = None
    for attempt in range(policy.max_attempts):
        try:
            return fn()
        except policy.retry_on as e:
            last = e
            if attempt + 1 >= policy.max_attempts:
                break
            delay = policy.backoff_s(attempt)
            policy.retries += 1
            warnings.warn(
                f"{what} failed (attempt {attempt + 1}/"
                f"{policy.max_attempts}): {e}; retrying in {delay:.3f}s",
                RuntimeWarning,
                stacklevel=2,
            )
            policy.sleep(delay)
    raise last


# -- save/restore --------------------------------------------------------------


def _flatten(tree) -> tuple[list[tuple[str, np.ndarray]], Any]:
    leaves_with_paths = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in leaves_with_paths:
        key = "/".join(str(p) for p in path)
        out.append((key, np.asarray(jax.device_get(leaf))))
    return out, jax.tree.structure(tree)


def array_checksum(arr: np.ndarray) -> int:
    """CRC32 over an array's raw bytes (the manifest integrity record)."""
    return zlib.crc32(np.ascontiguousarray(arr).tobytes()) & 0xFFFFFFFF


def save_checkpoint(
    directory: str,
    step: int,
    tree: Any,
    extra: Optional[dict] = None,
    *,
    blocking: bool = True,
    retry: Optional[RetryPolicy] = None,
    fault_hook: Optional[Callable[[str], None]] = None,
) -> str:
    """Snapshot ``tree`` (any pytree of arrays) + ``extra`` metadata."""
    flat, _ = _flatten(tree)
    payload = {k: v for k, v in flat}
    meta = {
        "step": int(step),
        "keys": list(payload.keys()),
        "checksums": {k: array_checksum(v) for k, v in payload.items()},
        "extra": extra or {},
    }

    def write():
        os.makedirs(directory, exist_ok=True)
        final = os.path.join(directory, f"step_{step}")

        def attempt():
            if fault_hook is not None:
                fault_hook("write")
            # Unique tmp dir per writer: concurrent saves of the same step
            # never share a path (the old fixed ``step_<N>.tmp`` raced with
            # itself), and a failed attempt's debris never blocks the retry.
            tmp = tempfile.mkdtemp(
                prefix=f"step_{step}.", suffix=".tmp", dir=directory
            )
            # mkdtemp creates 0700; restore umask-default perms so the
            # renamed step_<N> dir stays readable by other users/services
            # (as the old os.makedirs-based writer left it)
            os.chmod(tmp, 0o777 & ~_UMASK)
            try:
                np.savez(os.path.join(tmp, "arrays.npz"), **payload)
                with open(os.path.join(tmp, "manifest.msgpack"), "wb") as f:
                    f.write(msgpack.packb(meta))
                with _SWAP_LOCK:
                    if os.path.exists(final):
                        # keep the previous generation of this step: one
                        # bad write must never destroy the last good state
                        prev = final + ".prev"
                        shutil.rmtree(prev, ignore_errors=True)
                        os.rename(final, prev)
                    os.rename(tmp, final)
            except BaseException:
                shutil.rmtree(tmp, ignore_errors=True)
                raise

        call_with_retry(attempt, retry, what=f"checkpoint write step_{step}")

    if blocking:
        write()
    else:
        t = threading.Thread(target=write, daemon=True)
        t.start()
        _PENDING.append(t)
    return os.path.join(directory, f"step_{step}")


def wait_for_pending() -> None:
    while _PENDING:
        _PENDING.pop().join()


def _step_of(name: str) -> Optional[int]:
    """step_<N> -> N; tmp dirs, .prev generations and junk -> None."""
    if not name.startswith("step_") or name.endswith(".tmp"):
        return None
    try:
        return int(name.split("_", 1)[1])
    except ValueError:
        return None


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [
        s for s in (_step_of(name) for name in os.listdir(directory))
        if s is not None
    ]
    return max(steps) if steps else None


def generation_dirs(directory: str) -> list:
    """Candidate checkpoint dirs, most recent first: every ``step_<N>``
    in descending step order, each followed by its retained
    ``step_<N>.prev`` generation.  The solve loader walks this list when
    the newest generation turns out corrupt."""
    if not os.path.isdir(directory):
        return []
    steps = sorted(
        {
            s for s in (_step_of(name) for name in os.listdir(directory))
            if s is not None
        },
        reverse=True,
    )
    out = []
    for s in steps:
        p = os.path.join(directory, f"step_{s}")
        if os.path.isdir(p):
            out.append(p)
        if os.path.isdir(p + ".prev"):
            out.append(p + ".prev")
    return out


def verify_checksums(manifest: dict, arrays: dict, *, where: str) -> None:
    """Compare loaded arrays against the manifest's CRC32 record; raises
    ``ValueError`` naming the first mismatching array.  Manifests written
    before checksums existed verify vacuously."""
    sums = manifest.get("checksums") or {}
    for key, expected in sums.items():
        if key in arrays and array_checksum(arrays[key]) != expected:
            raise ValueError(
                f"checksum mismatch for array {key!r} in {where} — "
                f"the checkpoint is corrupt (bit-rot or a torn write)"
            )


def restore_checkpoint(
    directory: str,
    template: Any,
    step: Optional[int] = None,
    shardings: Any = None,
    *,
    retry: Optional[RetryPolicy] = None,
    fault_hook: Optional[Callable[[str], None]] = None,
):
    """Restore into the structure of ``template``.  ``shardings`` (optional)
    mirrors the template with jax.sharding.Sharding leaves — leaves are
    device_put against them (re-meshing happens here).

    Returns (tree, step, extra).
    """
    step = step if step is not None else latest_step(directory)
    if step is None:
        raise FileNotFoundError(f"no checkpoint under {directory}")
    path = os.path.join(directory, f"step_{step}")

    def attempt():
        if fault_hook is not None:
            fault_hook("read")
        with open(os.path.join(path, "manifest.msgpack"), "rb") as f:
            meta = msgpack.unpackb(f.read())
        with np.load(os.path.join(path, "arrays.npz")) as z:
            raw = {k: z[k] for k in z.files}
        return meta, raw

    meta, raw = call_with_retry(
        attempt, retry, what=f"checkpoint read step_{step}"
    )
    verify_checksums(meta, raw, where=path)

    leaves_with_paths = jax.tree_util.tree_flatten_with_path(template)[0]
    treedef = jax.tree.structure(template)
    shard_leaves = (
        jax.tree.leaves(shardings, is_leaf=lambda x: hasattr(x, "device_set"))
        if shardings is not None
        else [None] * len(leaves_with_paths)
    )
    restored = []
    for (path_elems, leaf), shard in zip(leaves_with_paths, shard_leaves):
        key = "/".join(str(p) for p in path_elems)
        arr = raw[key]
        if hasattr(leaf, "dtype"):
            arr = arr.astype(leaf.dtype)
        restored.append(
            jax.device_put(arr, shard) if shard is not None else jax.numpy.asarray(arr)
        )
    return jax.tree.unflatten(treedef, restored), meta["step"], meta["extra"]
