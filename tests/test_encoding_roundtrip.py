"""Property tests: every registered codec round-trips every task (§4.3).

``encode`` then ``decode`` must reproduce the task bit-for-bit — mask,
partial solution and depth — for EVERY codec in ``encoding.CODECS``, over
randomized instance sizes and record schemas (including schemas with extra
payload fields, i.e. ``pad_words > 0``).  The byte-accounting identities
the benchmarks quote (``record_words``/``record_bytes``/``pad_words``)
are pinned against the schema arithmetic at the same time, so the wire
sizes in EXPERIMENTS can never drift from the implementation.
"""

import numpy as np

from repro.core.encoding import (
    CODECS,
    DEFAULT_RECORD_FIELDS,
    Task,
    make_codec,
    resolve_record_words,
)
from repro.graphs.bitgraph import n_words
from repro.graphs.generators import erdos_renyi

from tests._hypothesis_compat import given, settings, strategies as st


class _Problem:
    """A stand-in plugin carrying only the record schema."""

    def __init__(self, fields):
        self.record_fields = tuple(fields)


# schema menu: the native triple alone, plus variants with extra payload
# words (a literal-width scalar, a bitset, and an adjacency-sized blob) —
# the shapes that exercise pad_words = 0, small, W-sized and n·W-sized
_EXTRA_FIELDS = st.sampled_from(
    [
        (),
        (("score", 1),),
        (("bound", 2), ("tiebreak", 1)),
        (("aux_mask", "W"),),
        (("blob", "n*W"),),
        (("score", 1), ("aux_mask", "W")),
    ]
)


def _random_task(rng, n, W):
    mask_bits = rng.randint(0, 2**n - 1)
    # the partial solution is a subset of the OUT-of-instance vertices in
    # real traffic, but the codecs must not care: draw it independently
    sol_bits = rng.randint(0, 2**n - 1)

    def pack(bits):
        words = np.zeros(W, np.uint32)
        for w in range(W):
            words[w] = (bits >> (32 * w)) & 0xFFFFFFFF
        return words

    return Task(
        mask=pack(mask_bits), sol_mask=pack(sol_bits), depth=rng.randint(0, n)
    )


@settings(max_examples=30, deadline=None)
@given(
    st.sampled_from(sorted(CODECS)),
    st.integers(1, 70),
    _EXTRA_FIELDS,
    st.integers(0, 2**31),
)
def test_codec_roundtrip_bit_exact(name, n, extra, seed):
    import random

    rng = random.Random(seed)
    W = n_words(n)
    fields = DEFAULT_RECORD_FIELDS + tuple(extra)
    codec = make_codec(name, n, problem=_Problem(fields))
    g = erdos_renyi(n, 0.4, seed % 1000)
    task = _random_task(rng, n, W)

    rec = codec.encode(task, g) if name == "basic" else codec.encode(task)
    assert rec.dtype == np.uint32 and rec.shape == (codec.record_words,)

    back = codec.decode(rec, g)
    assert (back.mask == task.mask).all()
    assert (back.sol_mask == task.sol_mask).all()
    assert back.depth == task.depth

    # byte accounting: record_words is the schema arithmetic exactly
    want = resolve_record_words(fields, n, W)
    if name == "basic":
        want += n * W  # adjacency rows ride on top of the schema
    assert codec.record_words == want
    assert codec.record_bytes == 4 * want
    assert codec.pad_words == codec.record_words - codec.native_words
    if name == "optimized" and not extra:
        assert codec.pad_words == 0


@settings(max_examples=20, deadline=None)
@given(st.sampled_from(sorted(CODECS)), st.integers(1, 70))
def test_codec_depth_word_survives_extremes(name, n):
    """Depth is carried in a u32 word: 0 and the deepest possible value
    (n, a leaf) must both survive, for every codec and width class."""
    W = n_words(n)
    codec = make_codec(name, n)
    g = erdos_renyi(n, 0.3, 1)
    for depth in (0, n):
        t = Task(
            mask=np.full(W, 0xFFFFFFFF, np.uint32),
            sol_mask=np.zeros(W, np.uint32),
            depth=depth,
        )
        rec = codec.encode(t, g) if name == "basic" else codec.encode(t)
        assert codec.decode(rec, g).depth == depth
