"""Hypothesis, or a deterministic stand-in when it is not installed.

Tier-1 must be green on a bare interpreter (the container does not ship
``hypothesis``).  When the real library is importable we re-export it
unchanged; otherwise a minimal fallback provides the subset of the API the
test suite uses — ``given``, ``settings`` and the ``integers`` / ``lists`` /
``sampled_from`` / ``one_of`` / ``tuples`` / ``just`` strategies — driving
each property with ``max_examples`` pseudo-random examples drawn from a PRNG
seeded by the test name, so failures reproduce exactly across runs.

The fallback does no shrinking and no example database; it is an example
generator, not a property-based testing engine.  Install the pinned
``requirements-dev.txt`` to get real hypothesis back.
"""

from __future__ import annotations

try:  # pragma: no cover - exercised only when hypothesis is installed
    from hypothesis import given, settings  # noqa: F401
    from hypothesis import strategies  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:
    import random

    HAVE_HYPOTHESIS = False

    class _Strategy:
        """A sampler: ``draw(rng) -> value``."""

        def __init__(self, draw):
            self.draw = draw

    class strategies:  # noqa: N801 - mimics the hypothesis module name
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda r: r.randint(min_value, max_value))

        @staticmethod
        def just(value):
            return _Strategy(lambda r: value)

        @staticmethod
        def sampled_from(elements):
            elements = list(elements)
            return _Strategy(lambda r: r.choice(elements))

        @staticmethod
        def one_of(*strats):
            return _Strategy(lambda r: r.choice(strats).draw(r))

        @staticmethod
        def tuples(*strats):
            return _Strategy(lambda r: tuple(s.draw(r) for s in strats))

        @staticmethod
        def lists(elements, *, min_size=0, max_size=10):
            def draw(r):
                k = r.randint(min_size, max_size)
                return [elements.draw(r) for _ in range(k)]

            return _Strategy(draw)

    def settings(*, max_examples=20, deadline=None, **_kwargs):
        """Record ``max_examples`` on the decorated test (deadline ignored)."""

        def deco(fn):
            fn._compat_max_examples = max_examples
            return fn

        return deco

    def given(*strats):
        def deco(fn):
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_compat_max_examples", 20)
                rng = random.Random(f"{fn.__module__}.{fn.__qualname__}")
                for i in range(n):
                    example = tuple(s.draw(rng) for s in strats)
                    try:
                        fn(*args, *example, **kwargs)
                    except Exception as e:  # re-raise with the failing example
                        raise AssertionError(
                            f"falsifying example #{i}: {example!r}"
                        ) from e

            # Copy identity but NOT __wrapped__/signature: pytest must see a
            # zero-argument test, exactly like real hypothesis's wrapper.
            wrapper.__name__ = fn.__name__
            wrapper.__qualname__ = fn.__qualname__
            wrapper.__module__ = fn.__module__
            wrapper.__doc__ = fn.__doc__
            wrapper._compat_max_examples = getattr(
                fn, "_compat_max_examples", 20
            )
            return wrapper

        return deco
