import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry run: lower + compile every (arch × shape × mesh) cell.

For each cell this builds the REAL step function (train_step with AdamW, or
prefill / serve step with the model's cache), jits it with production
in/out shardings, and runs ``.lower(...).compile()`` against abstract
ShapeDtypeStruct inputs — no weights are ever allocated.  The compiled
artifact yields ``memory_analysis()`` (proves per-device fit),
``cost_analysis()`` (FLOPs / bytes for §Roofline) and the HLO text from
which collective traffic is parsed.

Usage:
  python -m repro.launch.dryrun --arch qwen1.5-0.5b --shape train_4k
  python -m repro.launch.dryrun --all --mesh single --out dryrun_single.json
  python -m repro.launch.dryrun --all --mesh multi  --out dryrun_multi.json
  python -m repro.launch.dryrun --arch rwkv6-3b --shape train_4k \
      --set batch=data,model --set embed=          # §Perf sharding overrides
"""

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import SHAPES, ShapeConfig
from repro.configs.registry import ALIASES
from repro.launch.analysis import collective_bytes, model_flops, roofline
from repro.launch.mesh import batch_axes_for, make_production_mesh
from repro.models.registry import Model, get_model
from repro.models.sharding import logical_to_spec, rules_for_mesh
from repro.optim.adamw import OptState, adamw_init, adamw_update

SKIP = {
    # long_500k needs sub-quadratic attention (DESIGN.md §4): only the SSM
    # and hybrid archs run it; pure full-attention archs skip by assignment.
    ("whisper-large-v3", "long_500k"): "full attention (O(S) KV decode at 512k infeasible)",
    ("qwen1.5-0.5b", "long_500k"): "full attention",
    ("phi3-medium-14b", "long_500k"): "full attention",
    ("minitron-4b", "long_500k"): "full attention",
    ("starcoder2-3b", "long_500k"): "full attention",
    ("pixtral-12b", "long_500k"): "full attention",
    ("llama4-scout-17b-a16e", "long_500k"): "full attention",
    ("qwen3-moe-235b-a22b", "long_500k"): "full attention",
}


def _eval_shape_with_specs(fn):
    """eval_shape an (arrays, static_spec_tree) initializer: returns
    (ShapeDtypeStruct tree, spec tree) without allocating anything."""
    captured = {}

    def wrapper():
        arrays, specs = fn()
        captured["specs"] = specs
        return arrays

    shapes = jax.eval_shape(wrapper)
    return shapes, captured["specs"]


def _sharding_for_leaf(shape_struct, logical, mesh, rules):
    """NamedSharding for one leaf; mesh axes that do not divide the dim are
    dropped (e.g. whisper's vocab 51866 on a 16-way model axis)."""
    spec = logical_to_spec(tuple(logical), rules)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    parts = list(spec) + [None] * (len(shape_struct.shape) - len(spec))
    out = []
    for dim, names in zip(shape_struct.shape, parts):
        if names is None:
            out.append(None)
            continue
        tup = (names,) if isinstance(names, str) else tuple(names)
        total = 1
        for n in tup:
            total *= sizes[n]
        out.append(names if total and dim % total == 0 else None)
    return NamedSharding(mesh, P(*out))


def _is_spec_leaf(x):
    return isinstance(x, tuple) and all(isinstance(e, (str, type(None))) for e in x)


def shardings_for(tree_shapes, spec_tree, mesh, rules):
    return jax.tree.map(
        lambda s, logical: _sharding_for_leaf(s, logical, mesh, rules),
        tree_shapes,
        spec_tree,
    )


def build_cell(model: Model, shape: ShapeConfig, mesh, rules):
    """Returns (step_fn, abstract_args, in_shardings, out_shardings, donate)."""
    cfg = model.cfg
    key = jax.random.key(0)
    params_shapes, specs = _eval_shape_with_specs(lambda: model.init(key))
    params_sh = shardings_for(params_shapes, specs, mesh, rules)
    repl = NamedSharding(mesh, P())

    batch_axes = batch_axes_for(shape.global_batch, mesh)
    bspec = NamedSharding(mesh, P(batch_axes))
    batch_shapes = model.batch_spec(shape)
    batch_sh = {k: bspec for k in batch_shapes}

    if shape.kind == "train":
        opt_shapes = jax.eval_shape(adamw_init, params_shapes)
        opt_sh = OptState(step=repl, m=params_sh, v=params_sh)

        def train_step(params, opt, batch):
            loss, grads = jax.value_and_grad(
                lambda p: model.loss_fn(p, batch, rules=rules)
            )(params)
            params, opt, stats = adamw_update(params, grads, opt)
            return params, opt, loss, stats["grad_norm"]

        return (
            train_step,
            (params_shapes, opt_shapes, batch_shapes),
            (params_sh, opt_sh, batch_sh),
            (params_sh, opt_sh, repl, repl),
            (0, 1),
        )

    if shape.kind == "prefill":

        def prefill_step(params, batch):
            return model.forward(params, batch, rules=rules)

        return (
            prefill_step,
            (params_shapes, batch_shapes),
            (params_sh, batch_sh),
            None,
            (),
        )

    # decode / serve step: one new token against a seq_len-deep cache
    cache_shapes, cache_specs = _eval_shape_with_specs(
        lambda: model.init_decode_cache(shape.global_batch, shape.seq_len)
    )
    if cache_specs is None:
        cache_sh = jax.tree.map(lambda s: bspec, cache_shapes)
    else:
        cache_sh = shardings_for(cache_shapes, cache_specs, mesh, rules)
    tokens = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)

    def serve_step(params, cache, tokens):
        return model.decode_fn(params, cache, tokens, rules=rules)

    return (
        serve_step,
        (params_shapes, cache_shapes, tokens),
        (params_sh, cache_sh, bspec),
        (None, cache_sh),
        (1,),
    )


def _compile_cell(cfg, shape, mesh, rules):
    model = get_model(cfg)
    fn, args, in_sh, out_sh, donate = build_cell(model, shape, mesh, rules)
    with mesh:
        jitted = jax.jit(
            fn, in_shardings=in_sh, out_shardings=out_sh, donate_argnums=donate
        )
        lowered = jitted.lower(*args)
        compiled = lowered.compile()
    return compiled


def _cost_of(compiled):
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):  # 0.4.x jax: one dict per device
        cost = cost[0] if cost else {}
    coll = collective_bytes(compiled.as_text())
    return (
        float(cost.get("flops", 0.0)),
        float(cost.get("bytes accessed", 0.0)),
        float(coll["total"]),
        coll,
    )


def _reduced_depths(cfg):
    """(cfg_2units, cfg_4units, units): XLA's cost analysis counts a while
    body ONCE, so the cost pass compiles with scans UNROLLED at 2 and 4 depth
    units and fits the per-unit slope — exact for homogeneous stacks (hybrid
    tails are a documented fractional-unit approximation)."""
    import dataclasses

    unit = max(len(cfg.pattern), 1)
    if cfg.family == "encdec":
        c1 = dataclasses.replace(cfg, n_layers=2, n_enc_layers=2)
        c2 = dataclasses.replace(cfg, n_layers=4, n_enc_layers=4)
        units = cfg.n_layers  # whisper: enc and dec counts are equal
    else:
        c1 = dataclasses.replace(cfg, n_layers=2 * unit)
        c2 = dataclasses.replace(cfg, n_layers=4 * unit)
        units = cfg.n_layers / unit
    return c1, c2, units


def run_cell(arch: str, shape_name: str, mesh, *, rule_overrides=None) -> dict:
    from repro.configs.registry import get_config

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if (cfg.name, shape_name) in SKIP:
        return {
            "arch": cfg.name,
            "shape": shape_name,
            "status": "SKIP",
            "reason": SKIP[(cfg.name, shape_name)],
        }
    overrides = dict(rule_overrides or {})
    overrides.setdefault("batch", batch_axes_for(shape.global_batch, mesh))
    if shape.kind == "decode":
        # decode caches shard their SEQUENCE dim over the model axis (split-K
        # flash-decoding): kv-head counts rarely divide a 16-way axis, and the
        # softmax partitions cleanly (local q·K + small psum for max/sum/p·V).
        overrides.setdefault("seq_kv", ("model",))
        overrides.setdefault("kv", None)
    rules = rules_for_mesh(mesh, overrides)

    # 1. the REQUIRED pass: full config lower+compile (memory proof)
    t0 = time.time()
    compiled = _compile_cell(cfg, shape, mesh, rules)
    t_compile = time.time() - t0
    result = {
        "arch": cfg.name,
        "shape": shape_name,
        "mesh": "x".join(map(str, mesh.devices.shape)),
        "status": "OK",
        "compile_s": round(t_compile, 1),
    }
    try:
        mem = compiled.memory_analysis()
        result["memory"] = {
            "argument_size_b": int(getattr(mem, "argument_size_in_bytes", 0)),
            "output_size_b": int(getattr(mem, "output_size_in_bytes", 0)),
            "temp_size_b": int(getattr(mem, "temp_size_in_bytes", 0)),
            "generated_code_size_b": int(
                getattr(mem, "generated_code_size_in_bytes", 0)
            ),
        }
    except Exception as e:  # pragma: no cover - backend dependent
        result["memory"] = {"error": str(e)}

    # 2. cost terms: compile UNROLLED at 2 and 4 depth units, fit the slope
    # (XLA counts while bodies once; unrolling makes every layer visible)
    from repro.models import layers as _L

    c1_cfg, c2_cfg, units = _reduced_depths(cfg)
    _L.SCAN_UNROLL[0] = True
    try:
        f1, b1, k1, coll1 = _cost_of(_compile_cell(c1_cfg, shape, mesh, rules))
        f2, b2, k2, coll2 = _cost_of(_compile_cell(c2_cfg, shape, mesh, rules))
    finally:
        _L.SCAN_UNROLL[0] = False

    def fit(v1, v2):  # linear through (2 units, v1), (4 units, v2)
        slope = (v2 - v1) / 2.0
        return v1 + (units - 2) * slope

    flops = fit(f1, f2)
    bytes_accessed = fit(b1, b2)
    coll_total = fit(k1, k2)
    result["cost"] = {
        "flops": flops,
        "bytes_accessed": bytes_accessed,
        "extrapolation": {
            "units": units,
            "at_2units": {"flops": f1, "bytes": b1, "coll": k1},
            "at_4units": {"flops": f2, "bytes": b2, "coll": k2},
        },
    }
    per_kind = {
        k: fit(coll1[k], coll2[k])
        for k in coll1
        if k not in ("total", "counts")
    }
    result["collectives"] = {**per_kind, "total": coll_total}
    result["collective_counts"] = coll2["counts"]

    n_dev = mesh.devices.size
    rl = roofline(flops, bytes_accessed, coll_total)
    mf = model_flops(cfg, shape)
    rl["model_flops_global"] = mf
    rl["model_flops_per_dev"] = mf / n_dev
    rl["hlo_flops_per_dev"] = flops
    rl["useful_flop_ratio"] = (mf / n_dev) / flops if flops else 0.0
    result["roofline"] = rl
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="arch id (assignment spelling)")
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--all", action="store_true", help="run every cell")
    ap.add_argument("--out", default=None, help="write/merge JSON results here")
    ap.add_argument(
        "--set",
        action="append",
        default=[],
        help="logical=axis1,axis2 sharding-rule override (repeatable)",
    )
    ap.add_argument(
        "--moe-impl",
        default=None,
        choices=["gspmd", "shard_map"],
        help="MoE dispatch implementation (§Perf cell A)",
    )
    ap.add_argument(
        "--remat",
        default=None,
        choices=["nothing", "dots"],
        help="remat policy (§Perf knob)",
    )
    args = ap.parse_args()

    mesh = make_production_mesh(multi_pod=(args.mesh == "multi"))
    overrides = {}
    for item in args.set:
        k, _, v = item.partition("=")
        overrides[k] = tuple(x for x in v.split(",") if x) or None
    if args.moe_impl:
        overrides["_moe_impl"] = args.moe_impl
    if args.remat:
        from repro.models import layers as _L

        _L.REMAT_POLICY[0] = args.remat

    cells = []
    if args.all:
        for arch in ALIASES:
            for shape in SHAPES:
                cells.append((arch, shape))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells.append((args.arch, args.shape))

    results = []
    if args.out and os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)
    done = {(r["arch"], r["shape"]) for r in results if r.get("status") != "ERROR"}

    for arch, shape in cells:
        from repro.configs.registry import get_config

        name = get_config(arch).name
        if (name, shape) in done:
            print(f"[skip-done] {name} × {shape}")
            continue
        print(f"[dryrun] {name} × {shape} on {args.mesh} ...", flush=True)
        try:
            r = run_cell(arch, shape, mesh, rule_overrides=overrides or None)
        except Exception:
            r = {
                "arch": name,
                "shape": shape,
                "status": "ERROR",
                "traceback": traceback.format_exc(limit=10),
            }
        results = [
            x for x in results if not (x["arch"] == name and x["shape"] == shape)
        ] + [r]
        if r["status"] == "OK":
            m = r.get("memory", {})
            print(
                f"  OK compile={r['compile_s']}s "
                f"args={m.get('argument_size_b', 0)/2**30:.2f}GiB "
                f"temp={m.get('temp_size_b', 0)/2**30:.2f}GiB "
                f"flops/dev={r['cost'].get('flops', 0):.3g} "
                f"coll={r['collectives'].get('total', 0)/2**20:.1f}MiB "
                f"dominant={r['roofline']['dominant']}",
                flush=True,
            )
        else:
            print(f"  {r['status']}: {r.get('reason', '')}"
                  f"{r.get('traceback', '')[-600:]}", flush=True)
        if args.out:
            with open(args.out, "w") as f:
                json.dump(results, f, indent=1)
    n_ok = sum(r["status"] == "OK" for r in results)
    n_skip = sum(r["status"] == "SKIP" for r in results)
    n_err = sum(r["status"] == "ERROR" for r in results)
    print(f"dryrun complete: {n_ok} OK, {n_skip} SKIP, {n_err} ERROR")
    return 1 if n_err else 0


if __name__ == "__main__":
    raise SystemExit(main())
