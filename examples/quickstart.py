"""Quickstart: the paper's solver + the LM substrate in two minutes on CPU.

  PYTHONPATH=src python examples/quickstart.py
"""

import sys

sys.path.insert(0, "src")

import jax

from repro.api import SolveConfig, SolverSession
from repro.graphs.generators import erdos_renyi
from repro.launch.train import train_loop
from repro.configs.registry import get_smoke_config
from repro.problems.sequential import verify_cover


def main():
    # --- 1. the paper's workload: minimum vertex cover, three engines -----
    # one façade over every engine: pick a backend, get one result schema
    g = erdos_renyi(50, 4 / 49, seed=7)
    print(f"graph: n={g.n} m={g.num_edges}")
    cfg = SolveConfig(num_workers=6, steps_per_round=16)

    seq = SolverSession(backend="sequential", config=cfg).solve(g)
    print(f"sequential:        mvc={seq.best_size} "
          f"({seq.nodes_expanded} nodes)")

    sim = SolverSession(backend="protocol_sim", config=cfg).solve(g)
    print(
        f"semi-centralized:  mvc={sim.best_size} "
        f"(async protocol sim, {sim.tasks_transferred} transfers, "
        f"{sim.stats.failed_requests} failed requests)"
    )

    r = SolverSession(backend="spmd", config=cfg).solve(g)
    ok = r.best_size == seq.best_size and verify_cover(g, r.best_sol)
    print(
        f"SPMD engine:       mvc={r.best_size} "
        f"({r.rounds} supersteps, {r.tasks_transferred} transfers, "
        f"verified={ok})"
    )

    # --- 2. the LM substrate: a tiny qwen-style model learns --------------
    cfg = get_smoke_config("qwen1_5_0_5b")
    print(f"\ntraining {cfg.name} (d={cfg.d_model}, L={cfg.n_layers}) ...")
    _, _, losses = train_loop(cfg, steps=60, batch=8, seq=64, log_every=20)
    first, last = sum(losses[:6]) / 6, sum(losses[-6:]) / 6
    print(f"loss {first:.3f} -> {last:.3f} ({'OK' if last < first else 'FLAT'})")


if __name__ == "__main__":
    main()
