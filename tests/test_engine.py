"""The SPMD superstep engine vs the sequential ground truth (+ elasticity)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import engine as E
from repro.core.superstep import build_superstep_fn, make_worker_state
from repro.graphs.bitgraph import n_words
from repro.graphs.generators import erdos_renyi
from repro.problems.sequential import solve_sequential, verify_cover
from repro.problems.vertex_cover import make_problem


@pytest.mark.parametrize("policy", [True, False])
@pytest.mark.parametrize("codec", ["optimized", "basic"])
def test_matches_sequential(policy, codec):
    g = erdos_renyi(40, 0.28, 0)
    want, _, _ = solve_sequential(g)
    r = E.solve(
        g, num_workers=6, steps_per_round=8,
        policy_priority=policy, codec=codec,
    )
    assert r.best_size == want
    assert verify_cover(g, r.best_sol)
    assert not r.overflow


def test_lanes():
    g = erdos_renyi(44, 0.25, 4)
    want, _, _ = solve_sequential(g)
    r = E.solve(g, num_workers=4, steps_per_round=4, lanes=4)
    assert r.best_size == want
    assert not r.overflow


def test_fpt_mode():
    g = erdos_renyi(34, 0.3, 9)
    opt, _, _ = solve_sequential(g)
    r = E.solve(g, num_workers=4, mode="fpt", k=opt)
    assert r.best_size != -1 and r.best_size <= opt


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 10_000))
def test_random_graphs_property(seed):
    g = erdos_renyi(30, 0.22, seed)
    want, _, _ = solve_sequential(g)
    r = E.solve(g, num_workers=5, steps_per_round=8)
    assert r.best_size == want
    assert not r.overflow


def test_snapshot_restore_resize():
    """Fault tolerance: checkpoint mid-run, restart on a DIFFERENT worker
    count, still optimal (elastic re-meshing of the frontier)."""
    g = erdos_renyi(46, 0.25, 2)
    want, _, _ = solve_sequential(g)
    W = n_words(g.n)
    cap = 4 * g.n + 8
    state = jax.vmap(lambda _: make_worker_state(cap, W, g.n + 1))(jnp.arange(8))
    state = E._scatter_startup(state, g, 8)
    problem = make_problem(jnp.asarray(g.adj), g.n)
    fn = build_superstep_fn(problem, num_workers=8, steps_per_round=4, lanes=1)
    for _ in range(3):
        state, done = fn(state)
    snap = E.snapshot(state)  # "node failure" here
    resized = E.resize(E.restore(snap), 5)
    r = E.solve(g, num_workers=5, steps_per_round=8, initial_state=resized)
    assert r.best_size == want


def test_transfer_accounting():
    g = erdos_renyi(40, 0.28, 0)
    W = n_words(g.n)
    r_opt = E.solve(g, num_workers=4, codec="optimized")
    r_bas = E.solve(g, num_workers=4, codec="basic")
    assert r_opt.transfer_bytes_per_round == 4 * (2 * W + 1) * 4
    assert r_bas.transfer_bytes_per_round == 4 * ((g.n + 2) * W + 1) * 4
    # the paper's point: control plane is O(P) integers regardless of codec —
    # ONE packed i32 per worker by default, three with packed_status=False
    assert r_opt.control_bytes_per_round == r_bas.control_bytes_per_round == 16
    r_unpacked = E.solve(g, num_workers=4, packed_status=False)
    assert r_unpacked.control_bytes_per_round == 48
