"""Max-clique plugin: a native candidate-set brancher on the generic plane.

Task state (paper-optimized encoding, unchanged layout): ``mask`` is the
candidate set P (vertices adjacent to everything already picked), ``sol`` is
the clique R being grown.  One expansion branches on a maximum-degree
candidate u — either u joins (candidates shrink to P ∩ N(u)) or u is
discarded — and a task is terminal when P is empty.

The engine minimizes, so the internal objective is ``-|R|``; the admissible
bound ``-(|R| + |P|)`` (every candidate could, at best, join) prunes both
popped tasks and freshly-born children.  ``external_value`` flips the sign
back for reporting.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.problems import sequential
from repro.problems.base import (
    BranchingProblem,
    BranchStep,
    ExpandResult,
    ProblemData,
    degrees,
    expand_stats_batch,
    popcount,
    single_bit,
)


def branch_once(data: ProblemData, mask, sol) -> BranchStep:
    """Branch on a maximum-degree candidate (degree within P, ties lowest)."""
    W = data.adj.shape[1]
    deg = degrees(data, mask)
    u = jnp.argmax(deg).astype(jnp.int32)
    u_bit = single_bit(u, W)
    nb = data.adj[u] & mask
    return BranchStep(
        left_mask=nb,  # u joins: only its neighbours stay candidates
        left_sol=sol | u_bit,
        right_mask=mask & ~u_bit,  # u discarded
        right_sol=sol,
        is_terminal=popcount(mask) == 0,
        terminal_sol=sol,
        terminal_value=-popcount(sol),
    )


def bound(data: ProblemData, mask, sol) -> jnp.ndarray:
    """-(|R| + |P|): no completion can beat adding every candidate."""
    return -(popcount(sol) + popcount(mask))


def expand_tasks(data: ProblemData, masks, sols) -> ExpandResult:
    """One-pass fused expansion of an (L, W) lane batch.

    The per-task path reads every packed word five times (task_bound's two
    popcounts, branch_once's degrees + two popcounts, child_bound's four);
    here ONE ``expand_stats_batch`` panel (Pallas kernel on TPU) yields
    degrees + |P| + |R| for the whole batch, and the child bounds become
    arithmetic on known quantities instead of fresh popcounts:

    * ``|left_sol| = |R| + 1`` — the pivot u is a candidate (u ∈ P, P∩R=∅);
    * ``|left_mask| = |N(u)∩P| = deg[u]`` — degrees already computed it;
    * ``|right_mask| = |P| - 1``, ``|right_sol| = |R|``.

    On terminal lanes (P empty) the pivot is arbitrary, so the child bounds
    are not the composed values there — the engine never consumes child
    bounds of terminal lanes (see :class:`ExpandResult`); every consumed
    quantity is bit-identical to the composed path (property-tested).
    """
    W = data.adj.shape[1]
    deg, pc_mask, pc_sol = expand_stats_batch(data, masks, sols)  # (L,n),(L,),(L,)
    task_bound_v = -(pc_sol + pc_mask)
    u = jnp.argmax(deg, axis=1).astype(jnp.int32)  # (L,)
    deg_u = deg.max(axis=1)  # == deg[u] (the argmax row max), one reduce
    u_bit = jax.vmap(lambda v: single_bit(v, W))(u)  # (L, W)
    nb = data.adj[u] & masks  # (L, W)
    step = BranchStep(
        left_mask=nb,
        left_sol=sols | u_bit,
        right_mask=masks & ~u_bit,
        right_sol=sols,
        is_terminal=pc_mask == 0,
        terminal_sol=sols,
        terminal_value=-pc_sol,
    )
    return ExpandResult(
        bound=task_bound_v,
        step=step,
        left_bound=-(pc_sol + 1 + deg_u),
        right_bound=-(pc_sol + pc_mask - 1),
    )


def host_bound(g, mask, sol_mask) -> int:
    """Host twin of :func:`bound`: -(|R| + |P|) over packed host bitsets."""
    from repro.graphs.bitgraph import popcount_rows

    return -int(popcount_rows(sol_mask) + popcount_rows(mask))


def host_terminal_value(g, mask, sol_mask) -> int:
    from repro.graphs.bitgraph import popcount_rows

    return -int(popcount_rows(sol_mask))


SPEC = BranchingProblem(
    name="max_clique",
    objective="maximize |clique|",
    branch_once=branch_once,
    task_bound=bound,
    child_bound=bound,
    expand_tasks=expand_tasks,
    bnb_bound=lambda g: 1,  # just worse than the empty clique (value 0)
    external_value=lambda v: -v,
    fpt_target=lambda k: -k,
    branch_once_host=sequential.branch_once_clique,
    sequential=sequential.solve_sequential_max_clique,
    verify=sequential.verify_clique,
    host_task_bound=host_bound,
    host_child_bound=host_bound,
    host_terminal_value=host_terminal_value,
)
