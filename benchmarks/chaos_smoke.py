"""Self-healing gate: a seeded fault schedule must cost nothing but time.

Two legs, one deterministic chaos schedule (``repro.faults``), covering all
five fault kinds:

* **service leg** — a saturated :class:`~repro.api.SolveService` (spill
  capacity pinned low, durable checkpoints on) under lane crashes, a stall
  window, corrupted sparse-transfer and cold-tier payloads, and a
  checkpoint-write I/O error;
* **solo leg**   — a checkpointed ``solve`` whose worker state crashes
  mid-run: recovery restores the last good generation through an injected
  checkpoint-read I/O error (retry/backoff) and a later write error.

The gate asserts, in-process:

* every request completes with answers **bit-identical** to the fault-free
  reference run of the same configs (same warm plane cache);
* **zero tasks lost** — ``overflow_count == 0`` everywhere and every
  submitted ticket completes;
* **all five fault kinds fired** and every injected fault was recovered
  (``pending == 0``: the schedule was not silently skipped);
* recovery wall stays within ``MAX_WALL_RATIO`` of the fault-free wall.

``check_regression`` additionally pins the injected/recovered/retry
counters exactly against ``benchmarks/baseline.json`` — the chaos
trajectory is chunk-clocked, so the numbers are reproducible, not flaky.
"""

from __future__ import annotations

import tempfile
import time

MAX_WALL_RATIO = 1.5


def _service_events():
    from repro.faults import FaultEvent

    return (
        FaultEvent("crash", at=2, lane=1),
        FaultEvent("stall", at=3, lane=2, duration=3),
        FaultEvent("transfer_corrupt", at=1),
        FaultEvent("transfer_corrupt", at=5),
        FaultEvent("cold_corrupt", at=1),
        FaultEvent("cold_corrupt", at=4),
        FaultEvent("io_error", at=2, op="write"),
    )


def _solo_events():
    from repro.faults import FaultEvent

    return (
        FaultEvent("io_error", at=1, op="read"),
        FaultEvent("crash", at=4),
        FaultEvent("io_error", at=5, op="write"),
    )


def _run_service(sess, graphs, ckpt_dir, injector=None):
    svc = sess.serve(
        injector=injector,
        lane_stall_chunks=2,
        checkpoint_dir=ckpt_dir,
        checkpoint_every=3,
    )
    tickets = [svc.submit(g) for g in graphs]
    svc.drain()
    return {t: svc.result(t) for t in tickets}, svc


def _run_solo(sess, g, ckpt_dir, injector=None):
    extra = {"injector": injector} if injector is not None else {}
    return sess.solve(g, checkpoint_dir=ckpt_dir, **extra)


def run(smoke: bool = False) -> dict:
    from repro.api import PlaneCache, SolveConfig, SolverSession
    from repro.faults import FAULT_KINDS, FaultInjector, FaultPlan
    from repro.graphs.generators import erdos_renyi

    n0, count = (36, 5) if smoke else (40, 6)
    graphs = [erdos_renyi(n0 + i, 0.28, seed=i) for i in range(count)]
    solo_g = erdos_renyi(n0 + 4, 0.3, seed=11)

    cache = PlaneCache()
    svc_cfg = SolveConfig(
        num_workers=4, steps_per_round=2, chunk_rounds=2,
        service_lanes=3, frontier_spill=True, capacity=12,
    )
    solo_cfg = SolveConfig(
        num_workers=4, steps_per_round=2, chunk_rounds=2,
        frontier_spill=True, capacity=16, checkpoint_every=2,
    )
    svc_sess = SolverSession("vertex_cover", config=svc_cfg, cache=cache)
    solo_sess = SolverSession("vertex_cover", config=solo_cfg, cache=cache)

    def reference():
        with tempfile.TemporaryDirectory() as d1, \
                tempfile.TemporaryDirectory() as d2:
            ref_svc, _ = _run_service(svc_sess, graphs, d1)
            ref_solo = _run_solo(solo_sess, solo_g, d2)
        return ref_svc, ref_solo

    def chaos():
        inj_svc = FaultInjector(FaultPlan(seed=0, events=_service_events()))
        inj_solo = FaultInjector(FaultPlan(seed=1, events=_solo_events()))
        with tempfile.TemporaryDirectory() as d1, \
                tempfile.TemporaryDirectory() as d2:
            out_svc, svc = _run_service(
                svc_sess, graphs, d1, injector=inj_svc
            )
            out_solo = _run_solo(solo_sess, solo_g, d2, injector=inj_solo)
        return out_svc, out_solo, svc, inj_svc, inj_solo

    # warm every executable BOTH trajectories touch (incl. the stall
    # write-back and crash re-admission paths) so the timed walls compare
    # steady-state recovery cost, not one-time jit compiles; the chaos
    # trajectory is chunk-clocked, so the warm pass is bit-identical to
    # the timed one
    reference()
    chaos()
    t0 = time.perf_counter()
    ref_svc, ref_solo = reference()
    ref_wall = time.perf_counter() - t0
    t0 = time.perf_counter()
    out_svc, out_solo, svc, inj_svc, inj_solo = chaos()
    chaos_wall = time.perf_counter() - t0

    # -- the gate claims, asserted ----------------------------------------
    assert sorted(out_svc) == sorted(ref_svc), "tickets were lost"
    for t in ref_svc:
        a, b = ref_svc[t], out_svc[t]
        assert (a.best_size, tuple(a.best_sol)) == (
            b.best_size, tuple(b.best_sol)
        ), f"ticket {t}: {b.best_size} under faults vs {a.best_size} clean"
        assert b.stats.overflow_count == 0, f"ticket {t} dropped tasks"
    assert (ref_solo.best_size, tuple(ref_solo.best_sol)) == (
        out_solo.best_size, tuple(out_solo.best_sol)
    ), "solo solve diverged under faults"
    assert out_solo.stats.overflow_count == 0

    injected = {
        k: inj_svc.injected[k] + inj_solo.injected[k] for k in FAULT_KINDS
    }
    recovered = {
        k: inj_svc.recovered[k] + inj_solo.recovered[k] for k in FAULT_KINDS
    }
    all_kinds = all(injected[k] >= 1 for k in FAULT_KINDS)
    assert all_kinds, f"fault kinds not covered: {injected}"
    assert injected == recovered, (
        f"unrecovered faults: injected {injected} vs recovered {recovered}"
    )
    for inj in (inj_svc, inj_solo):
        assert inj.report()["pending"] == 0, "scheduled faults never fired"

    wall_ratio = chaos_wall / max(ref_wall, 1e-9)
    assert wall_ratio <= MAX_WALL_RATIO, (
        f"recovery took {wall_ratio:.2f}x the fault-free wall "
        f"(budget {MAX_WALL_RATIO}x) — self-healing is no longer cheap"
    )

    s = svc.stats()
    out = dict(
        instances=count + 1,
        faults_injected=sum(injected.values()),
        faults_recovered=sum(recovered.values()),
        retries=inj_svc.retries + inj_solo.retries,
        lanes_quarantined=int(s["lanes_quarantined"]),
        injected_by_kind={k: int(v) for k, v in injected.items()},
        all_kinds_covered=bool(all_kinds),
        bit_identical=True,  # asserted above — recorded for the baseline pin
        no_drop=True,
        ref_wall_s=round(ref_wall, 3),
        chaos_wall_s=round(chaos_wall, 3),
        wall_ratio=round(wall_ratio, 2),
    )
    print(
        f"chaos gate: {out['faults_injected']} faults over "
        f"{out['instances']} instances, all recovered "
        f"({out['retries']} retries, {out['lanes_quarantined']} lanes "
        f"quarantined), bit-identical at {out['wall_ratio']}x the "
        f"fault-free wall"
    )
    return out


if __name__ == "__main__":
    run()
