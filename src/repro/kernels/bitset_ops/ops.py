"""Jit'd public wrapper for the bitset-degree kernel.

``degrees_op`` dispatches to the Pallas kernel (interpret-mode on CPU, native
on TPU) and falls back to the jnp oracle for shapes the kernel does not tile
well (tiny T).  ``max_degree_vertex`` composes the branching-vertex argmax.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.bitset_ops.kernel import batched_degrees
from repro.kernels.bitset_ops.ref import batched_degrees_ref


def degrees_op(
    adj: jnp.ndarray,
    masks: jnp.ndarray,
    *,
    use_kernel: bool = True,
    block_tasks: int = 8,
    interpret: bool = True,
) -> jnp.ndarray:
    """(n, W) adj × (T, W) masks -> (T, n) induced-subgraph degrees."""
    if not use_kernel or masks.shape[0] < 2:
        return batched_degrees_ref(adj, masks)
    return batched_degrees(
        adj, masks, block_tasks=block_tasks, interpret=interpret
    )


def max_degree_vertex(adj, masks, **kw):
    deg = degrees_op(adj, masks, **kw)
    return jnp.argmax(deg, axis=1).astype(jnp.int32), deg.max(axis=1)
