"""Gate a smoke-benchmark run against the committed baseline.

``benchmarks/baseline.json`` pins, per benchmark, what a healthy run looks
like — exact values for deterministic outputs (encoded record bytes,
golden clique sizes, the plane-trace count), floors/ceilings with a
tolerance band for anything wall-clock-derived (throughput speedups drift
with machine load, so those gate loosely).  CI runs::

    PYTHONPATH=src python -m benchmarks.run --smoke
    PYTHONPATH=src python -m benchmarks.check_regression

and fails the job on any violated pin.  Update baseline.json (a reviewed,
committed file) when a PR legitimately moves a pinned number.

Rule schema, per ``benchmarks.<name>.checks[]``:

  {"path": "rows.0.optimized_bytes", "eq": 36}          exact match
  {"path": "warm_speedup",           "min": 5.0}        floor
  {"path": "wall_ratio",             "max": 1.5}        ceiling
  {"path": "gate_speedup", "min": 1.81, "rtol": 0.35}   floor with slack:
      effective floor = min * (1 - rtol)

Missing benchmark entries fail (a silently skipped gate is a regression
too); extra benchmarks in the run are ignored.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

HERE = os.path.dirname(__file__)
BASELINE = os.path.join(HERE, "baseline.json")
SMOKE = os.path.join(HERE, "out", "BENCH_smoke.json")


def _lookup(entry, path: str):
    cur = entry
    for part in path.split("."):
        if isinstance(cur, list):
            cur = cur[int(part)]
        elif isinstance(cur, dict):
            cur = cur[part]
        else:
            raise KeyError(path)
    return cur


def check(baseline: dict, smoke: dict) -> list:
    """All violations as human-readable strings (empty == green)."""
    problems = []
    ran = smoke.get("benchmarks", {})
    for name, spec in baseline["benchmarks"].items():
        entry = ran.get(name)
        if entry is None:
            problems.append(f"{name}: missing from the smoke run")
            continue
        for rule in spec["checks"]:
            path = rule["path"]
            try:
                got = _lookup(entry, path)
            except (KeyError, IndexError, ValueError):
                problems.append(f"{name}.{path}: missing from the run")
                continue
            if "eq" in rule and got != rule["eq"]:
                problems.append(
                    f"{name}.{path}: {got!r} != pinned {rule['eq']!r}"
                )
            if "min" in rule:
                floor = rule["min"] * (1.0 - rule.get("rtol", 0.0))
                if got < floor:
                    problems.append(
                        f"{name}.{path}: {got} below floor {floor:g} "
                        f"(baseline {rule['min']}, rtol {rule.get('rtol', 0)})"
                    )
            if "max" in rule:
                ceil = rule["max"] * (1.0 + rule.get("rtol", 0.0))
                if got > ceil:
                    problems.append(
                        f"{name}.{path}: {got} above ceiling {ceil:g} "
                        f"(baseline {rule['max']}, rtol {rule.get('rtol', 0)})"
                    )
    return problems


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(prog="benchmarks.check_regression")
    ap.add_argument("--baseline", default=BASELINE)
    ap.add_argument("--smoke", default=SMOKE)
    args = ap.parse_args(argv)

    with open(args.baseline) as f:
        baseline = json.load(f)
    try:
        with open(args.smoke) as f:
            smoke = json.load(f)
    except FileNotFoundError:
        print(
            f"no smoke run at {args.smoke} — run "
            f"`PYTHONPATH=src python -m benchmarks.run --smoke` first",
            file=sys.stderr,
        )
        raise SystemExit(2)

    problems = check(baseline, smoke)
    n_checks = sum(
        len(s["checks"]) for s in baseline["benchmarks"].values()
    )
    if problems:
        print(f"REGRESSION: {len(problems)} of {n_checks} pins violated:")
        for p in problems:
            print(f"  - {p}")
        raise SystemExit(1)
    print(f"bench-smoke within baseline ({n_checks} pins green)")


if __name__ == "__main__":
    main()
