"""starcoder2-3b [dense] — GQA, RoPE.  30L d=3072 24H kv=2 d_ff=12288
vocab=49152.  [arXiv:2402.19173]"""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-3b",
        family="dense",
        n_layers=30,
        d_model=3072,
        n_heads=24,
        n_kv_heads=2,
        d_ff=12_288,
        vocab=49_152,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-smoke",
        family="dense",
        n_layers=2,
        d_model=48,
        n_heads=4,
        n_kv_heads=2,
        d_ff=96,
        vocab=512,
        dtype="float32",
    )
