"""Explicit task tree with caterpillar topology (paper §3.4, Algorithms 5-6).

Each exploration thread owns a :class:`TaskTree`.  The root is the task the
thread was given; children are registered by ``register_child_instances``
before the thread explores them (``search`` claims a child for sequential
exploration, removing it on completion).  At any time the *highest-priority*
(shallowest, leftmost) pending task can be extracted for donation with
``send_highest_priority_task`` (Alg. 6: re-root past single-child nodes, then
take the leftmost non-exploring leaf-child).

Invariant (paper §3.4 "Size of task trees"): the tree is always a caterpillar
— every internal node has at most one internal child, since only the node
currently being explored sequentially can grow children.  Hence memory is
O(max_b · D).  ``check_caterpillar`` asserts this and is exercised by tests
and (optionally) by the simulator after every operation.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional


@dataclasses.dataclass(eq=False)  # identity semantics: payloads may be arrays
class _Node:
    payload: Any
    depth: int
    exploring: bool = False
    children: list["_Node"] = dataclasses.field(default_factory=list)
    parent: Optional["_Node"] = None


class TaskTree:
    """Alg. 5/6 task tree for one exploration thread."""

    def __init__(self):
        self.root: Optional[_Node] = None
        self._index: dict[int, _Node] = {}  # id(payload-key) -> node

    # -- bookkeeping ------------------------------------------------------
    def __len__(self) -> int:
        def count(node):
            return 1 + sum(count(c) for c in node.children) if node else 0

        return count(self.root)

    def is_empty(self) -> bool:
        return self.root is None

    def _key(self, payload: Any) -> int:
        return id(payload)

    # -- Alg. 5: registerChildInstances ------------------------------------
    def set_root(self, payload: Any, depth: int = 0) -> None:
        assert self.root is None, "root already set"
        self.root = _Node(payload=payload, depth=depth, exploring=True)
        self._index[self._key(payload)] = self.root

    def register_child_instances(self, children: list[Any], parent: Any) -> None:
        """Add each child under ``parent`` in the task tree (Alg. 5 lines 1-5).

        In practice the parent is the node currently being explored by this
        thread; children are appended in heuristic order (leftmost = most
        promising, §3.4)."""
        pnode = self._index.get(self._key(parent))
        if pnode is None:
            # parent already finished/donated: children are explored by the
            # caller directly and are not tracked (cannot be donated).
            return
        for child in children:
            cnode = _Node(payload=child, depth=pnode.depth + 1, parent=pnode)
            pnode.children.append(cnode)
            self._index[self._key(child)] = cnode

    # -- Alg. 5: search ----------------------------------------------------
    def try_claim(self, payload: Any) -> bool:
        """If ``payload`` is still in the tree, mark it Exploring and return
        True (the caller then explores it sequentially); else return False
        (it was donated to another thread/process)."""
        node = self._index.get(self._key(payload))
        if node is None:
            return False
        node.exploring = True
        return True

    def finish(self, payload: Any) -> None:
        """Remove a fully-explored task (Alg. 5 line 10)."""
        node = self._index.pop(self._key(payload), None)
        if node is None:
            return
        assert not node.children, "finishing a task with pending children"
        if node.parent is not None:
            node.parent.children.remove(node)
        if node is self.root:
            self.root = None

    # -- Alg. 6: sendHighestPriorityTask ------------------------------------
    def pop_highest_priority(self) -> Optional[Any]:
        """Extract the shallowest, leftmost pending task; None if no pending
        task exists.  Implements the re-rooting walk of Alg. 6."""
        r = self.root
        while True:
            if r is None:
                return None
            if not r.children:
                # only the exploring path remains
                return None
            if len(r.children) == 1 and (
                r.children[0].exploring or r.children[0].children
            ):
                # single child on the exploration path: re-root (Alg. 6 line 8)
                old = r
                r = r.children[0]
                self._index.pop(self._key(old.payload), None)
                r.parent = None
                self.root = r
                continue
            # leftmost leaf-child not marked Exploring
            cand = None
            for c in r.children:
                if not c.exploring and not c.children:
                    cand = c
                    break
            if cand is None:
                # all children exploring / internal: descend the exploration path
                nxt = next((c for c in r.children if c.exploring or c.children), None)
                if nxt is None:
                    return None
                r = nxt
                continue
            r.children.remove(cand)
            self._index.pop(self._key(cand.payload), None)
            return cand.payload

    def pending_count(self) -> int:
        """Number of tasks that could be donated (non-exploring leaves)."""
        cnt = 0

        def walk(node):
            nonlocal cnt
            if node is None:
                return
            for c in node.children:
                if not c.exploring and not c.children:
                    cnt += 1
                walk(c)

        walk(self.root)
        return cnt

    def top_priority_depth(self) -> Optional[int]:
        """Depth of the task pop_highest_priority would return (metadata int)."""
        best = None

        def walk(node):
            nonlocal best
            if node is None:
                return
            for c in node.children:
                if not c.exploring and not c.children:
                    if best is None or c.depth < best:
                        best = c.depth
                walk(c)

        walk(self.root)
        return best

    # -- invariant ----------------------------------------------------------
    def check_caterpillar(self) -> bool:
        """Every node has at most one non-leaf child (paper §3.4)."""

        def walk(node) -> bool:
            if node is None:
                return True
            internal_children = [c for c in node.children if c.children]
            if len(internal_children) > 1:
                return False
            return all(walk(c) for c in node.children)

        return walk(self.root)
