"""Production mesh construction (a FUNCTION so importing never touches jax
device state — required by the dry-run's device-count override ordering).

``make_mesh_compat`` papers over the jax API skew around explicit axis types:
``jax.sharding.AxisType`` (and the matching ``axis_types=`` kwarg of
``jax.make_mesh``) only exists on newer jax; on 0.4.37 every mesh axis is
implicitly Auto, so omitting the kwarg is semantically identical.  All mesh
construction in this repo (and in tests) must go through this helper.
"""

from __future__ import annotations

import jax


def make_mesh_compat(shape, axis_names):
    """``jax.make_mesh`` with Auto axis types where the API supports them."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axis_names)
    return jax.make_mesh(
        shape, axis_names, axis_types=(axis_type.Auto,) * len(axis_names)
    )


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (16, 16) = 256 chips, axes (data, model).
    Multi-pod:  (2, 16, 16) = 512 chips, axes (pod, data, model) — the pod
    axis composes with data for batch sharding (pure DP across pods; the
    only cross-pod collective is the gradient all-reduce, DCN-friendly)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)


def make_solver_mesh(num_workers: int | None = None):
    """1-D mesh for the branching engine: one worker per device."""
    n = num_workers or len(jax.devices())
    return make_mesh_compat((n,), ("workers",))


def batch_axes_for(global_batch: int, mesh) -> tuple | None:
    """Largest prefix of (pod, data) that divides the global batch — decode
    shapes with batch 1 stay replicated, everything else shards."""
    names = [n for n in ("pod", "data") if n in mesh.axis_names]
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    chosen = []
    div = 1
    for n in names:
        if global_batch % (div * sizes[n]) == 0:
            chosen.append(n)
            div *= sizes[n]
    return tuple(chosen) if chosen else None
