"""The problem-generic solve plane.

Three guarantees from the PR-3 refactor:

1. **Vertex-cover bit-identity** — the generic plane reproduces the
   pre-refactor engine outputs exactly (best_size, best_sol AND every
   deterministic stat), solo and batched (padding + compaction paths
   included), pinned by ``tests/golden_vc.json`` (regenerate with
   ``python tests/gen_golden_vc.py`` — only ever from a known-good tree).
2. **New workloads are exact** — max-clique and MIS on the unchanged
   coordination machinery agree with their sequential references across
   ≥50 random G(n, p) graphs, solo and on the batched plane, and their
   solutions verify structurally (clique edges / independence).
3. **Registries fail helpfully** — unknown problem/codec names raise a
   ``ValueError`` listing what is available.
"""

import json
import pathlib

import numpy as np
import pytest

from repro.core import engine as E
from repro.core.encoding import make_codec
from repro.graphs.bitgraph import complement
from repro.graphs.generators import erdos_renyi
from repro.problems.registry import get_problem
from repro.problems.sequential import (
    solve_sequential,
    solve_sequential_max_clique,
    solve_sequential_mis,
    verify_clique,
    verify_independent_set,
)

GOLDEN = json.loads(
    (pathlib.Path(__file__).parent / "golden_vc.json").read_text()
)


def _check_golden(result, want: dict):
    got = {
        "best_size": int(result.best_size),
        "best_sol": [int(w) for w in np.asarray(result.best_sol, np.uint32)],
        "rounds": int(result.rounds),
        "nodes_expanded": int(result.nodes_expanded),
        "tasks_transferred": int(result.tasks_transferred),
        "transfer_rounds": int(result.transfer_rounds),
        "transfer_bytes_total": int(result.transfer_bytes_total),
        "overflow": bool(result.overflow),
    }
    assert got == want


# -- 1. vertex-cover bit-identity vs pre-refactor goldens ----------------------


@pytest.mark.parametrize("label", sorted(GOLDEN["solo"]))
def test_vc_solo_bit_identical_to_golden(label):
    case = GOLDEN["solo"][label]
    gkw = case["graph"]
    g = erdos_renyi(gkw["n"], gkw["p"], gkw["seed"])
    r = E.solve(g, **case["solve_kw"])
    _check_golden(r, case["result"])


def test_vc_fpt_bit_identical_to_golden():
    case = GOLDEN["fpt"]
    gkw = case["graph"]
    g = erdos_renyi(gkw["n"], gkw["p"], gkw["seed"])
    r = E.solve(g, num_workers=4, mode="fpt", k=case["k"])
    _check_golden(r, case["result"])


def test_vc_solve_many_bit_identical_to_golden():
    """The batched plane, including the padding (mixed n within a W bucket)
    and host-side compaction paths, against the pre-refactor goldens."""
    case = GOLDEN["many"]
    graphs = [
        erdos_renyi(n, case["p"], case["seed0"] + i)
        for i, n in enumerate(case["sizes"])
    ]
    batch = E.solve_many(graphs, **case["solve_kw"])
    assert batch.compactions == case["compactions"]
    assert [[W, n_max, idxs] for W, n_max, idxs in batch.buckets] == case["buckets"]
    for r, want in zip(batch.results, case["results"]):
        _check_golden(r, want)


# -- 2. max-clique / MIS vs their sequential references ------------------------

# ≥50 random G(n, p) graphs across both new problems (the satellite's floor);
# solved on the BATCHED plane (one compiled executable per W bucket) plus
# solo spot-checks below.
N_GRAPHS = 30  # per problem -> 60 total


def _random_graphs(problem_seed: int):
    rng = np.random.default_rng(problem_seed)
    sizes = rng.integers(10, 19, size=N_GRAPHS)
    ps = rng.uniform(0.25, 0.55, size=N_GRAPHS)
    return [
        erdos_renyi(int(n), float(p), int(s))
        for n, p, s in zip(sizes, ps, rng.integers(0, 10_000, size=N_GRAPHS))
    ]


def test_max_clique_matches_sequential_reference_many():
    graphs = _random_graphs(1)
    batch = E.solve_many(
        graphs, num_workers=4, steps_per_round=4, problem="max_clique"
    )
    for g, r in zip(graphs, batch.results):
        want, _, _ = solve_sequential_max_clique(g)
        assert r.best_size == want
        assert verify_clique(g, r.best_sol)
        assert not r.overflow


def test_mis_matches_sequential_reference_many():
    graphs = _random_graphs(2)
    batch = E.solve_many(graphs, num_workers=4, steps_per_round=4, problem="mis")
    for g, r in zip(graphs, batch.results):
        want, _, _ = solve_sequential_mis(g)
        assert r.best_size == want
        assert verify_independent_set(g, r.best_sol)
        assert not r.overflow


@pytest.mark.parametrize("problem,seq_ref,verify", [
    ("max_clique", solve_sequential_max_clique, verify_clique),
    ("mis", solve_sequential_mis, verify_independent_set),
])
def test_new_problems_solo_solve(problem, seq_ref, verify):
    for seed in (0, 1, 2):
        g = erdos_renyi(16, 0.4, seed)
        want, _, _ = seq_ref(g)
        r = E.solve(g, num_workers=4, steps_per_round=8, problem=problem)
        assert r.best_size == want
        assert verify(g, r.best_sol)
        assert not r.overflow


def test_reductions_tie_the_three_problems_together():
    """Gallai identities on the same graph: mis(G) = n - vc(G) and
    clique(G) = mis(complement(G)) — all three measured on the engine."""
    g = erdos_renyi(15, 0.35, 7)
    kw = dict(num_workers=4, steps_per_round=8)
    vc = E.solve(g, problem="vertex_cover", **kw).best_size
    mis = E.solve(g, problem="mis", **kw).best_size
    clique = E.solve(g, problem="max_clique", **kw).best_size
    mis_comp = E.solve(complement(g), problem="mis", **kw).best_size
    assert mis == g.n - vc
    assert clique == mis_comp


def test_fpt_mode_max_clique():
    """Decision mode generalizes across the objective flip: "is there a
    clique of size >= k" stops at the first hit; k+1 is unsatisfiable."""
    g = erdos_renyi(16, 0.45, 11)
    opt, _, _ = solve_sequential_max_clique(g)
    hit = E.solve(g, num_workers=4, problem="max_clique", mode="fpt", k=opt)
    assert hit.best_size != -1 and hit.best_size >= opt
    miss = E.solve(g, num_workers=4, problem="max_clique", mode="fpt", k=opt + 1)
    assert miss.best_size == -1 and miss.best_sol is None


def test_sequential_clique_fpt_reference():
    g = erdos_renyi(14, 0.5, 3)
    opt, _, _ = solve_sequential_max_clique(g)
    size, sol, _ = solve_sequential_max_clique(g, mode="fpt", k=opt)
    assert size >= opt and verify_clique(g, sol)
    size, sol, _ = solve_sequential_max_clique(g, mode="fpt", k=opt + 1)
    assert size == -1 and sol is None


# -- 3. registry validation ----------------------------------------------------


def test_unknown_problem_lists_known_names():
    with pytest.raises(ValueError, match="vertex_cover"):
        get_problem("knapsack")
    with pytest.raises(ValueError, match="max_clique"):
        E.solve(erdos_renyi(8, 0.3, 0), problem="nope")


def test_unknown_codec_lists_known_names():
    with pytest.raises(ValueError, match="optimized"):
        make_codec("huffman", 10)
    with pytest.raises(ValueError, match="basic"):
        E.solve(erdos_renyi(8, 0.3, 0), codec="nope")


def test_problem_aliases_resolve():
    assert get_problem("vc").name == "vertex_cover"
    assert get_problem("clique").name == "max_clique"
    assert get_problem("independent_set").name == "mis"


def test_codec_record_schema_parameterized():
    """Codecs derive their byte counts from the problem's record schema."""
    spec = get_problem("max_clique")
    opt = make_codec("optimized", 40, problem=spec)
    bas = make_codec("basic", 40, problem=spec)
    W = opt.W
    assert opt.record_words == 2 * W + 1
    assert opt.pad_words == 0
    assert bas.record_words == (40 + 2) * W + 1
    assert bas.pad_words == 40 * W


def test_codec_extra_record_fields_travel():
    """Schema extras beyond the native triple are real payload: encode()
    emits them (zero-filled) and pad_words tells the data plane to move
    them, so byte accounting always matches the wire."""
    import dataclasses

    from repro.core.encoding import CODECS, Task
    import numpy as np

    spec = dataclasses.replace(
        get_problem("vertex_cover"),
        record_fields=get_problem("vertex_cover").record_fields
        + (("extra", 2),),
    )
    opt = make_codec("optimized", 40, problem=spec)
    W = opt.W
    assert opt.record_words == 2 * W + 1 + 2
    assert opt.pad_words == 2
    task = Task(
        mask=np.zeros(W, np.uint32), sol_mask=np.zeros(W, np.uint32), depth=3
    )
    assert len(opt.encode(task)) == opt.record_words
    bas = make_codec("basic", 40, problem=spec)
    assert bas.pad_words == 40 * W + 2
    # a schema that does not start with the native triple is rejected
    with pytest.raises(ValueError, match="native"):
        CODECS["optimized"](40, (("sol", "W"), ("mask", "W"), ("depth", 1)))
