"""Benchmark harness: one module per paper table/figure (+ beyond-paper).

  speedup           Fig. 4 / Table 1 (semi vs central x encodings)
  encoding_bytes    §4.3 serialization sizes
  protocol_stats    §3 message accounting (failed requests == 0)
  engine_throughput TPU-adapted engine rounds/transfers budget
  batch_throughput  multi-instance solve plane vs sequential loop
  clique_smoke      max-clique on the generic plane vs sequential reference
  session_warm      cold-vs-warm SolverSession (compiled-plane cache gate)
  explore_throughput fused vs reference exploration plane, nodes/sec (gated)
  serve_load        continuous-admission service vs fixed batching (gated)
  spill_throughput  hierarchical frontier memory: no-drop + wall gate
  chaos_smoke       seeded fault schedule: bit-identical self-healing gate
  resume_smoke      SIGKILL mid-solve + bit-identical resume (durability gate)
  balancer_bench    beyond-paper serving balancer
  kernel_bench      kernel arithmetic-intensity table

Usage:  PYTHONPATH=src python -m benchmarks.run [--smoke] [name ...]

``--smoke`` runs shrunken versions of the smoke-capable benchmarks (the
default name set becomes SMOKE_DEFAULT) and records every dict a benchmark
returns in benchmarks/out/BENCH_smoke.json — the per-PR perf trajectory the
CI bench-smoke job uploads as an artifact and ``benchmarks.check_regression``
compares against the committed ``benchmarks/baseline.json``.  Every recorded entry is tagged with the
branching problem it exercised (``problem``; vertex_cover unless the
benchmark says otherwise).
"""

import argparse
import inspect
import json
import os
import sys
import time

from benchmarks import (
    balancer_bench,
    batch_throughput,
    chaos_smoke,
    clique_smoke,
    encoding_bytes,
    engine_throughput,
    explore_throughput,
    kernel_bench,
    protocol_stats,
    resume_smoke,
    serve_load,
    session_warm,
    speedup,
    spill_throughput,
)

ALL = {
    "encoding_bytes": encoding_bytes,
    "protocol_stats": protocol_stats,
    "engine_throughput": engine_throughput,
    "batch_throughput": batch_throughput,
    "clique_smoke": clique_smoke,
    "session_warm": session_warm,
    "explore_throughput": explore_throughput,
    "serve_load": serve_load,
    "spill_throughput": spill_throughput,
    "chaos_smoke": chaos_smoke,
    "resume_smoke": resume_smoke,
    "balancer_bench": balancer_bench,
    "kernel_bench": kernel_bench,
    "speedup": speedup,
}

# kept fast enough for a per-PR CI job; full runs remain opt-in by name
SMOKE_DEFAULT = (
    "encoding_bytes", "batch_throughput", "clique_smoke", "session_warm",
    "explore_throughput", "serve_load", "spill_throughput", "chaos_smoke",
)

# generated artifacts live under benchmarks/out/ (gitignored); only the
# reviewed baseline.json is committed
OUT_DIR = os.path.join(os.path.dirname(__file__), "out")
SMOKE_JSON = os.path.join(OUT_DIR, "BENCH_smoke.json")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(prog="benchmarks.run")
    ap.add_argument("names", nargs="*", help="benchmarks to run (default: all)")
    ap.add_argument(
        "--smoke",
        action="store_true",
        help=f"shrunken sizes; record results in {SMOKE_JSON}",
    )
    args = ap.parse_args(argv)

    names = args.names or (
        list(SMOKE_DEFAULT) if args.smoke else list(ALL)
    )
    unknown = [n for n in names if n not in ALL]
    if unknown:
        print(
            f"unknown benchmark(s): {', '.join(unknown)}\n"
            f"available: {', '.join(sorted(ALL))}",
            file=sys.stderr,
        )
        raise SystemExit(2)

    recorded = {}
    for name in names:
        run_fn = ALL[name].run
        kwargs = (
            {"smoke": True}
            if args.smoke and "smoke" in inspect.signature(run_fn).parameters
            else {}
        )
        print(f"== {name} ==")
        t0 = time.perf_counter()
        out = run_fn(**kwargs)
        elapsed = time.perf_counter() - t0
        print(f"-- {name} done in {elapsed:.1f}s\n", flush=True)
        if isinstance(out, dict):
            entry = dict(out, elapsed_s=round(elapsed, 1))
            # every BENCH_smoke.json entry names the problem it exercised
            entry.setdefault("problem", "vertex_cover")
            recorded[name] = entry

    if args.smoke:
        os.makedirs(OUT_DIR, exist_ok=True)
        with open(SMOKE_JSON, "w") as f:
            json.dump({"smoke": True, "benchmarks": recorded}, f, indent=2)
            f.write("\n")
        print(f"wrote {SMOKE_JSON} ({', '.join(recorded) or 'no dict results'})")


if __name__ == "__main__":
    main()
