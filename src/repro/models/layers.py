"""Building-block layers (functional: explicit params + logical-axis specs).

Every ``init_*`` returns ``(params, specs)`` where ``specs`` mirrors the
params pytree with tuples of logical axis names (see models/sharding.py).
Apply functions take the params dict; nothing here knows about meshes.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels.flash_attention.ops import attention_op


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# Scan-over-layers unrolling knob.  Production lowering keeps the while loop
# (constant-size HLO); the dry-run's cost pass sets this True because XLA's
# HloCostAnalysis counts a while body ONCE regardless of trip count — the
# unrolled module is measured at two depths and extrapolated (launch/dryrun).
SCAN_UNROLL = [False]


def scan_unroll() -> bool:
    return SCAN_UNROLL[0]


# Remat policy knob (§Perf): 'nothing' = full per-layer remat (min memory,
# collectives recomputed in backward); 'dots' = save matmul outputs (no
# forward recompute — fewer bytes/collectives, more resident memory).
REMAT_POLICY = ["nothing"]


def remat_policy():
    import jax

    if REMAT_POLICY[0] == "dots":
        return jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
    return jax.checkpoint_policies.nothing_saveable


def dense_init(key, d_in, d_out, in_axis, out_axis, dtype, scale=None):
    scale = scale if scale is not None else d_in**-0.5
    w = (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)
    return w, (in_axis, out_axis)


# -- norms ----------------------------------------------------------------------


def rmsnorm_init(d, axis="embed"):
    return jnp.ones((d,), jnp.float32), (axis,)


def rmsnorm(x, g, eps=1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps) * g).astype(x.dtype)


# -- rotary embeddings ------------------------------------------------------------


def rope(x, positions, theta: float):
    """x (..., S, H, D) with positions (..., S) or (S,)."""
    D = x.shape[-1]
    half = D // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(angles)[..., :, None, :]  # (..., S, 1, half)
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


# -- attention --------------------------------------------------------------------


def attention_init(key, cfg: ModelConfig):
    d, H, KV, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    dt = _dtype(cfg)
    ks = jax.random.split(key, 4)
    p, s = {}, {}
    p["wq"], s["wq"] = dense_init(ks[0], d, H * Dh, "embed", "heads", dt)
    p["wk"], s["wk"] = dense_init(ks[1], d, KV * Dh, "embed", "kv", dt)
    p["wv"], s["wv"] = dense_init(ks[2], d, KV * Dh, "embed", "kv", dt)
    p["wo"], s["wo"] = dense_init(ks[3], H * Dh, d, "heads", "embed", dt)
    if cfg.qkv_bias:
        p["bq"], s["bq"] = jnp.zeros((H * Dh,), dt), ("heads",)
        p["bk"], s["bk"] = jnp.zeros((KV * Dh,), dt), ("kv",)
        p["bv"], s["bv"] = jnp.zeros((KV * Dh,), dt), ("kv",)
    return p, s


def attention_apply(
    cfg: ModelConfig,
    p,
    x,  # (B, S, d)
    positions,  # (S,) or (B, S)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    cache: Optional[tuple] = None,  # (k_cache, v_cache, cache_len) for decode
    attn_impl: str = "blockwise",
    attn_block_k: int = 512,
):
    """Returns (out (B,S,d), new_cache | None)."""
    B, S, d = x.shape
    H, KV, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, H, Dh)
    k = k.reshape(B, S, KV, Dh)
    v = v.reshape(B, S, KV, Dh)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)

    if cache is not None:
        k_cache, v_cache, cache_len = cache
        # decode: S == 1; write at cache_len, attend over the whole cache
        k_cache = jax.lax.dynamic_update_slice(
            k_cache, k.astype(k_cache.dtype), (0, cache_len, 0, 0)
        )
        v_cache = jax.lax.dynamic_update_slice(
            v_cache, v.astype(v_cache.dtype), (0, cache_len, 0, 0)
        )
        Smax = k_cache.shape[1]
        # causal-by-length mask: positions > cache_len are invalid; implement
        # via window/causal on a virtual timeline by masking padded keys with
        # a length mask folded into the window machinery of attention_op:
        out = _cached_attention(
            q, k_cache, v_cache, cache_len, window, attn_block_k
        )
        new_cache = (k_cache, v_cache, cache_len + S)
    else:
        out = attention_op(
            q, k, v, causal=causal, window=window,
            impl=attn_impl, block_k=min(attn_block_k, S),
        )
        new_cache = None
    out = out.reshape(B, S, H * Dh) @ p["wo"]
    return out, new_cache


def _cached_attention(q, k_cache, v_cache, cache_len, window, block_k):
    """Decode attention over a fixed-size cache with a dynamic valid length."""
    B, S, H, Dh = q.shape
    KV = k_cache.shape[2]
    G = H // KV
    scale = Dh**-0.5
    qh = q.transpose(0, 2, 1, 3).reshape(B, KV, G, S, Dh) * scale
    kh = k_cache.transpose(0, 2, 1, 3)  # (B, KV, Smax, Dh)
    vh = v_cache.transpose(0, 2, 1, 3)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qh, kh.astype(qh.dtype))
    kpos = jnp.arange(k_cache.shape[1])
    valid = kpos[None, :] <= cache_len  # queries sit at cache_len
    if window is not None:
        valid = valid & (kpos[None, :] > cache_len - window)
    s = jnp.where(valid[None, None, None], s, -1e30)
    prob = jax.nn.softmax(s.astype(jnp.float32), axis=-1).astype(qh.dtype)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", prob, vh.astype(qh.dtype))
    return out.reshape(B, H, S, Dh).transpose(0, 2, 1, 3)


def make_kv_cache(cfg: ModelConfig, batch: int, max_len: int, layers: int):
    """(L, B, Smax, KV, Dh) stacked cache + logical specs."""
    KV, Dh = cfg.n_kv_heads, cfg.d_head
    shape = (layers, batch, max_len, KV, Dh)
    spec = ("layers", "batch", "seq_kv", "kv", None)
    return (
        jnp.zeros(shape, _dtype(cfg)),
        jnp.zeros(shape, _dtype(cfg)),
        spec,
    )


# -- MLPs -------------------------------------------------------------------------


def mlp_init(key, cfg: ModelConfig, d_ff: Optional[int] = None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    dt = _dtype(cfg)
    ks = jax.random.split(key, 3)
    p, s = {}, {}
    p["w1"], s["w1"] = dense_init(ks[0], d, f, "embed", "mlp", dt)  # gate
    p["w3"], s["w3"] = dense_init(ks[1], d, f, "embed", "mlp", dt)  # up
    p["w2"], s["w2"] = dense_init(ks[2], f, d, "mlp", "embed", dt)  # down
    return p, s


def mlp_apply(p, x):
    """SwiGLU."""
    return (jax.nn.silu(x @ p["w1"]) * (x @ p["w3"])) @ p["w2"]


def gelu_mlp_init(key, cfg: ModelConfig, d_ff: Optional[int] = None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    dt = _dtype(cfg)
    ks = jax.random.split(key, 2)
    p, s = {}, {}
    p["wi"], s["wi"] = dense_init(ks[0], d, f, "embed", "mlp", dt)
    p["wo"], s["wo"] = dense_init(ks[1], f, d, "mlp", "embed", dt)
    return p, s


def gelu_mlp_apply(p, x):
    return jax.nn.gelu(x @ p["wi"]) @ p["wo"]
