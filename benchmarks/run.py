"""Benchmark harness: one module per paper table/figure (+ beyond-paper).

  speedup           Fig. 4 / Table 1 (semi vs central x encodings)
  encoding_bytes    §4.3 serialization sizes
  protocol_stats    §3 message accounting (failed requests == 0)
  engine_throughput TPU-adapted engine rounds/transfers budget
  balancer_bench    beyond-paper serving balancer
  kernel_bench      kernel arithmetic-intensity table

Usage:  PYTHONPATH=src python -m benchmarks.run [name ...]
"""

import sys
import time

from benchmarks import (
    balancer_bench,
    encoding_bytes,
    engine_throughput,
    kernel_bench,
    protocol_stats,
    speedup,
)

ALL = {
    "encoding_bytes": encoding_bytes,
    "protocol_stats": protocol_stats,
    "engine_throughput": engine_throughput,
    "balancer_bench": balancer_bench,
    "kernel_bench": kernel_bench,
    "speedup": speedup,
}


def main() -> None:
    names = sys.argv[1:] or list(ALL)
    for name in names:
        mod = ALL[name]
        print(f"== {name} ==")
        t0 = time.perf_counter()
        mod.run()
        print(f"-- {name} done in {time.perf_counter() - t0:.1f}s\n", flush=True)


if __name__ == "__main__":
    main()
