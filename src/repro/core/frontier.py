"""Fixed-capacity per-worker task frontier (device arrays).

The paper's per-thread task tree (§3.4) is a caterpillar: internal nodes are
the DFS path, leaves are donatable pending tasks.  On a fixed-shape SPMD
device the same object is a flat pool of (mask, sol, depth) slots with an
``active`` flag:

* **explore** pops the *deepest* active task (DFS order — the caterpillar
  spine), so the pool size stays O(depth) like the paper's tree;
* **donate** pops the *shallowest* active task (the paper's highest-priority
  leaf, Alg. 6) — quasi-horizontal exploration.

Two deepest-first selection paths serve the explore phase:

* :func:`pop_deepest` — the reference full-capacity ``lax.top_k`` (a sort
  over all CAP slots every round);
* :func:`pop_deepest_cheap` — the fused plane's depth-major selection: per
  lane, one max-reduce finds the deepest pending depth (the bucket) and one
  ``argmax`` over the reversed slot index picks the lowest slot inside it.
  Per round this is a few elementwise reduces per lane instead of sorting
  the whole pool, so selection cost scales with the ``lanes`` actually
  popped, not with capacity — and the lexicographic (depth desc, slot asc)
  order reproduces ``top_k`` exactly, keeping the two paths bit-identical.

Capacity is sized by the engine to ``4·n`` (depth ≤ n and each expansion is
net +lanes); saturated pushes are dropped, with an ``overflow`` flag AND a
cumulative ``dropped`` counter recording exactly how many tasks were lost —
the engine surfaces the count as ``overflow_count`` so saturation is never
silent (tests assert it stays 0 under engine-sized capacity).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

BIG_DEPTH = jnp.int32(1 << 30)


class Frontier(NamedTuple):
    masks: jnp.ndarray  # (CAP, W) uint32
    sols: jnp.ndarray  # (CAP, W) uint32
    depths: jnp.ndarray  # (CAP,) int32
    active: jnp.ndarray  # (CAP,) bool
    overflow: jnp.ndarray  # () bool -- a push was ever dropped
    dropped: jnp.ndarray  # () int32 -- cumulative count of dropped pushes

    @property
    def capacity(self) -> int:
        return self.depths.shape[0]

    def pending(self) -> jnp.ndarray:
        return self.active.sum().astype(jnp.int32)

    def top_priority_depth(self) -> jnp.ndarray:
        """Depth of the shallowest pending task; BIG_DEPTH if empty."""
        return jnp.where(self.active, self.depths, BIG_DEPTH).min()


def make_frontier(capacity: int, W: int) -> Frontier:
    return Frontier(
        masks=jnp.zeros((capacity, W), jnp.uint32),
        sols=jnp.zeros((capacity, W), jnp.uint32),
        depths=jnp.zeros((capacity,), jnp.int32),
        active=jnp.zeros((capacity,), bool),
        overflow=jnp.bool_(False),
        dropped=jnp.int32(0),
    )


def pop_deepest(f: Frontier, count: int):
    """Pop up to ``count`` deepest tasks (DFS lanes).

    Returns (frontier, masks (count, W), sols (count, W), depths (count,),
    valid (count,) bool)."""
    key = jnp.where(f.active, f.depths, jnp.int32(-1))
    _, slots = jax.lax.top_k(key, count)  # deepest first
    valid = f.active[slots]
    # top_k slot indices are unique, so a plain scatter-False is safe (slots
    # that were already inactive just stay inactive).
    return (
        f._replace(active=f.active.at[slots].set(False)),
        f.masks[slots],
        f.sols[slots],
        f.depths[slots],
        valid,
    )


def pop_deepest_cheap(f: Frontier, count: int):
    """Pop up to ``count`` deepest tasks WITHOUT the full-capacity sort.

    The fused exploration plane's selection path: per lane, one max-reduce
    finds the deepest pending depth (the bucket) and one argmax over the
    reversed slot index picks the lowest slot inside that bucket — a
    lexicographic (depth desc, slot asc) selection from two O(CAP)
    elementwise reduces, unrolled ``count`` times.  With the engine's small
    static ``lanes`` this replaces the per-round ``top_k`` sort with a
    handful of reductions, and the two-phase form needs no depth·capacity
    composite key, so it cannot overflow for ANY capacity/depth a caller
    pins.

    Same contract as :func:`pop_deepest` (including its precondition that
    active depths are non-negative — the engine only pushes depths ≥ 0):
    the post-pop ``active`` set and the valid lanes (tasks, order, flags)
    are bit-identical to the top_k path (property-tested), so
    ``explore_impl="fused"`` and ``"reference"`` traces stay
    interchangeable.
    """
    cap = f.capacity
    rev = jnp.arange(cap - 1, -1, -1, dtype=jnp.int32)
    act = f.active
    slots_l, valids_l = [], []
    for _ in range(count):
        d = jnp.max(jnp.where(act, f.depths, jnp.int32(-1)))
        s = jnp.argmax(
            jnp.where(act & (f.depths == d), rev, jnp.int32(-1))
        ).astype(jnp.int32)
        slots_l.append(s)
        valids_l.append(d >= 0)
        if count > 1:
            act = act.at[s].set(False)
    if count == 1:
        # the engine's default single-lane pop: no stacking round-trip
        slots = slots_l[0][None]
        valid = valids_l[0][None]
    else:
        slots = jnp.stack(slots_l)
        valid = jnp.stack(valids_l)
    return (
        f._replace(active=f.active.at[slots].set(False)),
        f.masks[slots],
        f.sols[slots],
        f.depths[slots],
        valid,
    )


def pop_shallowest(f: Frontier):
    """Pop the single shallowest task (the donation, Alg. 6).

    Returns (frontier, mask, sol, depth, valid)."""
    key = jnp.where(f.active, f.depths, BIG_DEPTH)
    slot = jnp.argmin(key)
    valid = f.active[slot]
    return (
        f._replace(active=f.active.at[slot].set(False)),
        f.masks[slot],
        f.sols[slot],
        f.depths[slot],
        valid,
    )


def pop_k_shallowest(f: Frontier, count: int, limit=None):
    """Pop up to ``count`` shallowest tasks (multi-task donation, the batched
    Alg. 6): a donor with a deep frontier fills a starved worker with several
    quasi-horizontal tasks in ONE rebalance round.

    ``limit`` (dynamic, () int32) caps how many of the ``count`` candidates
    are actually removed — the engine passes ``min(k, pending - 1)`` so a
    donor always keeps at least one task (the paper's failure-free rule).

    Returns (frontier, masks (count, W), sols (count, W), depths (count,),
    valid (count,) bool) with tasks ordered shallowest-first; ``valid`` marks
    the entries that were really popped.
    """
    key = jnp.where(f.active, f.depths, BIG_DEPTH)
    # top_k of the negated key = the ``count`` smallest depths, in order.
    _, slots = jax.lax.top_k(-key, count)
    valid = f.active[slots]
    if limit is not None:
        valid = valid & (jnp.arange(count) < limit)
    # slots from top_k are unique; keep rows beyond ``limit`` active.
    new_active = f.active.at[slots].set(
        jnp.where(valid, False, f.active[slots])
    )
    return (
        f._replace(active=new_active),
        f.masks[slots],
        f.sols[slots],
        f.depths[slots],
        valid,
    )


def push_many(f: Frontier, masks, sols, depths, valid):
    """Push up to K tasks (valid flags mark real ones).

    Free slots are assigned in order; pushes beyond capacity are dropped,
    setting ``overflow`` and adding the exact number of lost tasks to the
    cumulative ``dropped`` counter (engine sizes capacity so neither ever
    moves; the counter makes saturation loud when a caller undersizes)."""
    K = valid.shape[0]
    free = ~f.active  # (CAP,)
    # rank of each free slot among free slots (0-based)
    free_rank = jnp.cumsum(free.astype(jnp.int32)) - 1
    # for each incoming task i (0-based among valid), target free rank
    task_rank = jnp.cumsum(valid.astype(jnp.int32)) - 1  # (K,)
    n_free = free.sum()
    placeable = valid & (task_rank < n_free)
    n_dropped = (valid & ~placeable).sum().astype(jnp.int32)
    overflow = f.overflow | (n_dropped > 0)
    # slot index for each placeable task: the free slot with matching rank.
    # Build map rank -> slot; non-free slots scatter out-of-range (dropped).
    cap = f.capacity
    slot_of_rank = jnp.zeros((cap,), jnp.int32)
    slot_of_rank = slot_of_rank.at[jnp.where(free, free_rank, cap)].set(
        jnp.arange(cap, dtype=jnp.int32), mode="drop"
    )
    # non-placeable tasks scatter out-of-range (dropped) — avoids duplicate
    # in-range indices, which XLA scatters nondeterministically.
    tgt = jnp.where(
        placeable, slot_of_rank[jnp.clip(task_rank, 0, cap - 1)], cap
    )  # (K,)

    def place(arr, vals):
        return arr.at[tgt].set(vals, mode="drop")

    return f._replace(
        masks=place(f.masks, masks),
        sols=place(f.sols, sols),
        depths=place(f.depths, depths.astype(jnp.int32)),
        active=f.active.at[tgt].set(True, mode="drop"),
        overflow=overflow,
        dropped=f.dropped + n_dropped,
    )


def push_one(f: Frontier, mask, sol, depth, valid):
    return push_many(
        f, mask[None], sol[None], depth[None].astype(jnp.int32), valid[None]
    )


# -- batched (instance-axis) views ---------------------------------------------
#
# The multi-instance solve plane (`engine.solve_many`) stacks B independent
# instances in front of the (P, CAP, ...) worker axes.  The per-slot ops above
# are shape-polymorphic pure functions, so the batched forms are plain vmaps —
# kept here (rather than inlined at call sites) so every layer talks about the
# same instance axis and tests can exercise it directly.  Each wrapper maps
# over ONE leading axis; compose them (worker axis inside, instance axis
# outside) for (B, P, ...) pools.

pop_deepest_b = jax.vmap(pop_deepest, in_axes=(0, None))
pop_k_shallowest_b = jax.vmap(pop_k_shallowest, in_axes=(0, None, 0))
push_many_b = jax.vmap(push_many)


def pending_per_worker(f: Frontier) -> jnp.ndarray:
    """Pending counts for a stacked frontier, summed over the slot axis only.

    Works for any leading stack: (P, CAP) active -> (P,); (B, P, CAP) ->
    (B, P).  ``Frontier.pending`` sums over EVERYTHING, which is the right
    scalar inside a per-worker superstep but useless for the host-side
    per-instance quiescence/compaction checks."""
    return f.active.sum(axis=-1).astype(jnp.int32)


# -- the spill boundary --------------------------------------------------------
#
# The hierarchical frontier memory (repro.core.spill) moves task records
# across the host/device boundary between chunks: the pump fetches a pool,
# mutates it with numpy, and writes it back.  The write-backs are jitted so
# a pump costs one fused executable instead of a scatter dispatch per leaf
# (and, for the live plane, the lane index is a traced scalar so every lane
# shares the executable).  ``overflow``/``dropped`` are deliberately left
# untouched: with spill enabled they must stay zero (the no-drop guarantee),
# and a nonzero value surviving the pump is a bug the tests would catch.


@jax.jit
def _set_pool(f, masks, sols, depths, active):
    return f._replace(masks=masks, sols=sols, depths=depths, active=active)


def write_pool(f: Frontier, masks, sols, depths, active) -> Frontier:
    """Replace the task-pool leaves of a (stacked) frontier wholesale —
    the solo spill pump's write-back."""
    return _set_pool(
        f,
        jnp.asarray(masks, jnp.uint32),
        jnp.asarray(sols, jnp.uint32),
        jnp.asarray(depths, jnp.int32),
        jnp.asarray(active, bool),
    )


@jax.jit
def _get_lane_pool(f, lane):
    return f.masks[lane], f.sols[lane], f.depths[lane], f.active[lane]


def read_lane_pool(f: Frontier, lane: int):
    """One lane's (P, CAP, ...) pool leaves of a (B, P, CAP, ...) stacked
    frontier — the live plane's spill-pump fetch."""
    return _get_lane_pool(f, jnp.int32(lane))


@jax.jit
def _set_lane_pool(f, lane, masks, sols, depths, active):
    return f._replace(
        masks=f.masks.at[lane].set(masks),
        sols=f.sols.at[lane].set(sols),
        depths=f.depths.at[lane].set(depths),
        active=f.active.at[lane].set(active),
    )


def write_lane_pool(f: Frontier, lane: int, masks, sols, depths, active):
    """Write one lane's pool back into a (B, P, CAP, ...) stacked frontier."""
    return _set_lane_pool(
        f,
        jnp.int32(lane),
        jnp.asarray(masks, jnp.uint32),
        jnp.asarray(sols, jnp.uint32),
        jnp.asarray(depths, jnp.int32),
        jnp.asarray(active, bool),
    )


def pending_per_instance(f: Frontier) -> jnp.ndarray:
    """Pending counts per INSTANCE lane of a (B, P, CAP) stacked frontier:
    the slot and worker axes are reduced, the lane axis survives — the
    live-service occupancy/residency view (a lane with 0 pending and no
    in-flight transfer is quiescent and about to free up)."""
    return f.active.sum(axis=(-1, -2)).astype(jnp.int32)
