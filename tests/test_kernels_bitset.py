"""Pallas bitset kernels (degrees + fused expand stats) vs the jnp oracle,
plus the backend-aware kernel-mode selection."""

import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.graphs.generators import erdos_renyi
from repro.kernels.bitset_ops import (
    batched_degrees_ref,
    default_interpret,
    degrees_op,
    expand_stats_op,
    expand_stats_ref,
    kernels_native,
    max_degree_vertex,
    max_degree_vertex_ref,
)


def _random_masks(n, W, T, seed):
    rng = np.random.default_rng(seed)
    masks = rng.integers(0, 2**32, size=(T, W), dtype=np.uint32)
    rem = n % 32
    if rem:
        masks[:, -1] &= np.uint32((1 << rem) - 1)
    return masks


@pytest.mark.parametrize(
    "n,T,block",
    [(32, 4, 2), (64, 16, 8), (100, 7, 4), (128, 32, 8), (257, 9, 8), (512, 24, 16)],
)
def test_kernel_matches_ref(n, T, block):
    g = erdos_renyi(n, 0.08, n * 31 + T)
    masks = jnp.asarray(_random_masks(n, g.W, T, T))
    adj = jnp.asarray(g.adj)
    got = degrees_op(adj, masks, block_tasks=block)
    want = batched_degrees_ref(adj, masks)
    assert (got == want).all()


def test_argmax_composition():
    g = erdos_renyi(96, 0.15, 5)
    masks = jnp.asarray(_random_masks(96, g.W, 10, 3))
    adj = jnp.asarray(g.adj)
    u1, d1 = max_degree_vertex(adj, masks)
    u2, d2 = max_degree_vertex_ref(adj, masks)
    assert (d1 == d2).all()
    # argmax ties may differ only if degrees tie; verify via degree equality
    deg = batched_degrees_ref(adj, masks)
    assert (jnp.take_along_axis(deg, u1[:, None], 1)[:, 0] == d2).all()


@pytest.mark.parametrize(
    "n,T,block", [(32, 4, 2), (64, 16, 8), (100, 7, 4), (257, 9, 8)]
)
def test_fused_expand_stats_matches_ref(n, T, block):
    """The fused kernel's degrees panel AND both popcounts equal the oracle
    (which itself equals what the per-task callables compute)."""
    g = erdos_renyi(n, 0.08, n * 17 + T)
    masks = jnp.asarray(_random_masks(n, g.W, T, T))
    sols = jnp.asarray(_random_masks(n, g.W, T, T + 1)) & ~masks
    adj = jnp.asarray(g.adj)
    deg, pcm, pcs = expand_stats_op(adj, masks, sols, block_tasks=block)
    rdeg, rpcm, rpcs = expand_stats_ref(adj, masks, sols)
    assert (deg == rdeg).all()
    assert (pcm == rpcm).all() and (pcs == rpcs).all()
    # and the oracle's popcounts really are popcounts
    want = [
        sum(bin(int(w)).count("1") for w in row) for row in np.asarray(masks)
    ]
    assert np.asarray(rpcm).tolist() == want


def test_kernel_mode_auto_detection(monkeypatch):
    """interpret-mode resolution: native only on TPU, env override wins."""
    import jax

    import repro.kernels.bitset_ops.ops as ops

    monkeypatch.delenv("REPRO_PALLAS_INTERPRET", raising=False)
    on_tpu = jax.default_backend() == "tpu"
    assert default_interpret() == (not on_tpu)
    assert kernels_native() == on_tpu
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "0")
    assert not ops.default_interpret() and ops.kernels_native()
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")
    assert ops.default_interpret() and not ops.kernels_native()
    # empty value == unset (leftover `VAR=` in a shell) -> backend detection;
    # alternate falsy spellings are normalized, not misread as "force on"
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "")
    assert ops.default_interpret() == (not on_tpu)
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "FALSE")
    assert ops.kernels_native()
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "off")
    assert ops.kernels_native()


def test_degrees_op_interpret_default_follows_backend(monkeypatch):
    """degrees_op with interpret unset resolves via default_interpret (and
    still matches the oracle when forced through the kernel)."""
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")
    g = erdos_renyi(48, 0.1, 9)
    masks = jnp.asarray(_random_masks(48, g.W, 5, 3))
    got = degrees_op(jnp.asarray(g.adj), masks)  # interpret resolved = True
    assert (got == batched_degrees_ref(jnp.asarray(g.adj), masks)).all()


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10_000))
def test_property_random(seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(8, 200))
    T = int(rng.integers(2, 20))
    g = erdos_renyi(n, float(rng.uniform(0.02, 0.3)), seed)
    masks = jnp.asarray(_random_masks(n, g.W, T, seed + 1))
    got = degrees_op(jnp.asarray(g.adj), masks)
    want = batched_degrees_ref(jnp.asarray(g.adj), masks)
    assert (got == want).all()
