"""Batched LM serving with the semi-centralized request balancer (beyond-
paper integration): greedy decode on a smoke model + the balancer keeping 8
simulated replicas busy under a hot-shard arrival pattern.

(This demo used to live behind ``repro.launch.serve``; that CLI now fronts
the continuous-batching SOLVER service — see ``examples/serve_solver.py`` —
so the LM decode path moved here whole.)

  PYTHONPATH=src python examples/serve_lm.py
"""

import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_smoke_config
from repro.models.registry import get_model
from repro.serving.balancer import simulate


def greedy_decode(cfg, model, params, prompts, gen: int):
    """prompts (B, P) -> generated (B, gen) using the decode cache path."""
    B, P = prompts.shape
    cache, _ = model.init_decode_cache(B, P + gen + 1)
    if cfg.family == "encdec":
        from repro.models import encdec

        frames = jnp.zeros((B, cfg.enc_seq, cfg.d_model), jnp.dtype(cfg.dtype))
        cache = encdec.prime_cross_cache(params, cfg, cache, frames)

    decode = jax.jit(model.decode_fn)
    # prefill token-by-token through the decode path (smoke-scale; a real
    # deployment prefills with the chunked forward then transplants the cache)
    for t in range(P):
        logits, cache = decode(params, cache, prompts[:, t : t + 1])
    out = []
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    for _ in range(gen):
        out.append(tok)
        logits, cache = decode(params, cache, tok)
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    return jnp.concatenate(out, axis=1)


def main(batch=4, prompt_len=12, gen=24, replicas=8, seed=0):
    cfg = get_smoke_config("qwen1.5-0.5b")
    model = get_model(cfg)
    params, _ = model.init(jax.random.key(seed))
    rng = np.random.default_rng(seed)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab, (batch, prompt_len)), jnp.int32
    )
    t0 = time.perf_counter()
    toks = greedy_decode(cfg, model, params, prompts, gen)
    dt = time.perf_counter() - t0
    print(f"[serve_lm] generated {toks.shape} in {dt:.1f}s "
          f"({batch * gen / dt:.1f} tok/s)")
    print("[serve_lm] sample:", np.asarray(toks[0, :16]))

    # balancer demonstration: hot-shard arrival pattern, with/without
    works = list(rng.integers(8, 256, 64))
    on = simulate(replicas, 8, works, balance=True, seed=seed)
    off = simulate(replicas, 8, works, balance=False, seed=seed)
    print(
        f"[balancer] makespan {off['rounds']} -> {on['rounds']} rounds "
        f"({off['rounds']/on['rounds']:.1f}x), idle-slot-steps "
        f"{off['idle_slot_steps']} -> {on['idle_slot_steps']}, "
        f"{on['transfers']} transfers, "
        f"{on['control_ints_per_round']} control ints/round"
    )


if __name__ == "__main__":
    main()
