"""Data pipeline determinism: the fault-tolerance contract."""

import numpy as np

from repro.data.pipeline import SyntheticTokens


def test_restart_determinism():
    a = SyntheticTokens(vocab=1000, seq_len=33, global_batch=8, seed=5)
    b1 = a.next_batch()
    b2 = a.next_batch()
    b = SyntheticTokens(vocab=1000, seq_len=33, global_batch=8, seed=5)
    b.restore({"step": 1})  # resume after the first step
    r2 = b.next_batch()
    assert (np.asarray(b2["tokens"]) == np.asarray(r2["tokens"])).all()


def test_shards_partition_global_batch():
    """num_shards=4 shards concatenate... each shard is its own slice and
    different shards differ (counter-mode keyed by shard)."""
    p0 = SyntheticTokens(vocab=512, seq_len=17, global_batch=8, seed=1)
    s0 = p0._batch_np(0, shard=0, num_shards=4)
    s1 = p0._batch_np(0, shard=1, num_shards=4)
    assert s0.shape == (2, 17)
    assert not (s0 == s1).all()
    # re-generating the same (step, shard) is identical
    again = p0._batch_np(0, shard=1, num_shards=4)
    assert (s1 == again).all()


def test_labels_are_shifted_inputs():
    p = SyntheticTokens(vocab=512, seq_len=33, global_batch=2, seed=0)
    b = p.next_batch()
    assert b["tokens"].shape == (2, 32)
    assert b["labels"].shape == (2, 32)


def test_learnable_pattern_exists():
    """The bigram pattern: token[t+1] - token[t] is constant (mod veff) for
    most positions of a sequence — a model CAN reduce loss below unigram."""
    p = SyntheticTokens(vocab=4096, seq_len=256, global_batch=4, seed=2)
    toks = np.asarray(p.next_batch()["tokens"])
    for row in toks:
        diffs = (row[1:].astype(int) - row[:-1].astype(int)) % min(4096, 32768)
        vals, counts = np.unique(diffs, return_counts=True)
        assert counts.max() > len(diffs) * 0.4  # dominant delta exists
