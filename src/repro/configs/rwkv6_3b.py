"""rwkv6-3b "Finch" [ssm] — attention-free, data-dependent decay.

32L d=2560 (40 heads of 64) d_ff=8960 vocab=65536.  [arXiv:2404.05892]
O(1) decode state => runs long_500k.
"""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-3b",
        family="ssm",
        n_layers=32,
        d_model=2560,
        n_heads=40,
        n_kv_heads=40,
        d_head=64,
        d_ff=8960,
        vocab=65_536,
        subquadratic=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-smoke",
        family="ssm",
        n_layers=2,
        d_model=128,
        n_heads=2,
        n_kv_heads=2,
        d_head=64,
        d_ff=256,
        vocab=512,
        decay_lora=16,
        subquadratic=True,
        dtype="float32",
    )
