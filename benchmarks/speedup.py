"""Paper Fig. 4 / Table 1: speedup vs worker count, semi-centralized vs
fully-centralized, for both task encodings.

Hardware-neutral reproduction: the discrete-event simulators charge ONE tick
per node expansion per worker, so `ticks(sequential) / ticks(P workers)` is
the idealized-compute speedup and the schedulers differ exactly by their
scheduling/communication behaviour (the paper's y-axis, minus machine noise).
Byte counts are the paper's §4.3 communication story.
"""

from __future__ import annotations

from repro.core.centralized import run_centralized_sim
from repro.core.protocol_sim import run_protocol_sim
from repro.graphs.generators import erdos_renyi, p_hat_like
from repro.problems.sequential import solve_sequential


def rows(graph_name, g, workers_list):
    base, _, base_stats = solve_sequential(g)
    seq_ticks = base_stats.nodes  # one expansion per tick
    out = []
    for p in workers_list:
        for codec in ("optimized", "basic"):
            semi = run_protocol_sim(g, num_workers=p, codec_name=codec)
            cent = run_centralized_sim(g, num_workers=p, codec_name=codec)
            assert semi.best_size == cent.best_size == base
            out.append(
                dict(
                    graph=graph_name,
                    workers=p,
                    codec=codec,
                    seq_ticks=seq_ticks,
                    semi_ticks=semi.ticks,
                    central_ticks=cent.ticks,
                    semi_speedup=round(seq_ticks / semi.ticks, 2),
                    central_speedup=round(seq_ticks / cent.ticks, 2),
                    semi_bytes=semi.stats.total_bytes,
                    central_bytes=cent.stats.total_bytes,
                    semi_failed=semi.stats.failed_requests,
                )
            )
    return out


def donation_rows(graph_name, g, workers_list):
    """SPMD engine: multi-task donation (``donate_k``) on a skewed tree —
    a matched donor ships up to k shallowest tasks, so starved workers are
    refilled in fewer rebalance rounds (tasks moved per transfer round)."""
    from repro.api import SolveConfig, SolverSession

    out = []
    for p in workers_list:
        base = None
        for k in (1, 4):
            r = SolverSession(config=SolveConfig(
                num_workers=p, steps_per_round=8, donate_k=k
            )).solve(g)
            if base is None:
                base = r.best_size
            assert r.best_size == base
            transfer_rounds = r.stats.transfer_rounds
            out.append(
                dict(
                    graph=graph_name,
                    workers=p,
                    donate_k=k,
                    rounds=r.rounds,
                    transfer_rounds=transfer_rounds,
                    tasks_moved=r.tasks_transferred,
                    tasks_per_transfer_round=round(
                        r.tasks_transferred / max(transfer_rounds, 1), 2
                    ),
                )
            )
    return out


def run(csv=True):
    results = []
    # hard instance: ~7.5k search nodes sequentially (the p_hat-like regime)
    results += rows("gnp80_p2_hard", erdos_renyi(80, 0.2, 0), [2, 4, 8, 16, 32])
    # easy instance: reductions solve it almost instantly — reproduces the
    # paper's DSJ500.5 finding that massive parallelism wastes work there
    results += rows("phat_48_easy", p_hat_like(48, 0.45, 1), [2, 8])
    donation = donation_rows("gnp64_skewed", erdos_renyi(64, 0.22, 3), [8, 16])
    if csv:
        keys = list(results[0].keys())
        print(",".join(keys))
        for r in results:
            print(",".join(str(r[k]) for k in keys))
        print("# multi-task donation (SPMD engine)")
        keys = list(donation[0].keys())
        print(",".join(keys))
        for r in donation:
            print(",".join(str(r[k]) for k in keys))
    return results + donation


if __name__ == "__main__":
    run()
