"""Center logic (paper §3.1-3.2, Algorithm 3).

The center owns *no tasks*: its entire state is a status array (one enum per
worker), one integer ``best_val_so_far`` (plus which worker holds the best
solution), the optional per-worker metadata integer, and the assignment chain
used for the cycle check described in §3.2.  Every decision consumes and
produces single integers — this is the object that the SPMD engine replicates
on every device (see ``superstep.py``), which is possible precisely because
the paper makes it this small.
"""

from __future__ import annotations

import dataclasses
import enum
import random
from typing import Optional


class Status(enum.IntEnum):
    RUNNING = 0
    AVAILABLE = 1
    ASSIGNED = 2


@dataclasses.dataclass
class CenterState:
    num_workers: int
    policy: str = "random"  # 'random' | 'priority'
    seed: int = 0

    def __post_init__(self):
        self.status = [Status.RUNNING] * (self.num_workers + 1)  # 1-based
        self.best_val: Optional[int] = None
        self.best_holder: Optional[int] = None
        self.metadata = [0] * (self.num_workers + 1)
        # assigned_to[r] = w  <=>  center told w to send work to r
        self.assigned_to: dict[int, int] = {}
        self._rng = random.Random(self.seed)

    # -- bestval_update ----------------------------------------------------
    def offer_best(self, source: int, value: int) -> bool:
        """Returns True iff the value improves the global best (center always
        re-verifies claims, Alg. 3 line 3)."""
        if self.best_val is None or value < self.best_val:
            self.best_val = value
            self.best_holder = source
            return True
        return False

    # -- cycle check (§3.2) --------------------------------------------------
    def _chain_leads_to(self, start: int, target: int) -> bool:
        seen = set()
        cur = start
        while cur in self.assigned_to:
            cur = self.assigned_to[cur]
            if cur == target:
                return True
            if cur in seen:
                return True  # defensive: existing cycle
            seen.add(cur)
        return False

    # -- getNextWorkingNode ---------------------------------------------------
    def get_next_working_node(self, requester: int) -> Optional[int]:
        """Choose a RUNNING donor for ``requester`` (Alg. 3 line 7).

        policy='random'  : uniform among RUNNING workers (paper's default).
        policy='priority': RUNNING worker with the largest metadata value
                           (= size of its most urgent pending instance)."""
        cands = [
            w
            for w in range(1, self.num_workers + 1)
            if self.status[w] == Status.RUNNING
            and w != requester
            and not self._chain_leads_to(w, requester)
        ]
        if not cands:
            return None
        if self.policy == "priority":
            return max(cands, key=lambda w: (self.metadata[w], -w))
        return self._rng.choice(cands)

    # -- message handlers (Alg. 3 body) ---------------------------------------
    def on_available(self, source: int) -> Optional[int]:
        """Worker ``source`` finished its subtree.  Returns the donor w that
        should be told to send work to it (or None -> stays AVAILABLE)."""
        w = self.get_next_working_node(source)
        if w is not None:
            self.status[source] = Status.ASSIGNED
            self.assigned_to[source] = w
            return w
        self.status[source] = Status.AVAILABLE
        return None

    def on_started_running(self, source: int) -> Optional[tuple[int, int]]:
        """Worker ``source`` received work.  Returns (source, r) if some
        yet-unassigned AVAILABLE worker r should now be fed by source."""
        self.status[source] = Status.RUNNING
        self.assigned_to.pop(source, None)
        for r in range(1, self.num_workers + 1):
            if self.status[r] == Status.AVAILABLE:
                self.status[r] = Status.ASSIGNED
                self.assigned_to[r] = source
                return (source, r)
        return None

    def on_metadata(self, source: int, value: int) -> None:
        self.metadata[source] = value

    def all_idle(self) -> bool:
        """Termination pre-condition: nobody RUNNING (Alg. 3 line 20; ASSIGNED
        counts as idle per §3.3)."""
        return all(
            self.status[w] != Status.RUNNING for w in range(1, self.num_workers + 1)
        )
