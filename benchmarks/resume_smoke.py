"""Kill-and-resume smoke: a real SIGKILL mid-solve, then a bit-identical
resume.

The parent solves the instance uninterrupted in-process (the baseline),
then launches a CHILD process running the same checkpointed solve
(``--child``, ``checkpoint_every=1`` so every chunk boundary is durable),
SIGKILLs it as soon as checkpoints appear on disk, and resumes from the
survivors via :meth:`SolverSession.resume` — asserting the final result is
bit-identical to the baseline (modulo wall-clock and the durability
counters, which are outside the contract).

A double-kill cycle then SIGKILLs the RECOVERY itself: a second child
resumes from the survivors while continuing to checkpoint into the same
directory, is killed again once a newer generation is durable, and the
final in-process resume must still be bit-identical — checkpoints written
by a recovering process are as good as any other.

A further kill cycle runs the same contract MID-SPILL: a saturating
``frontier_spill`` solve whose checkpoints carry a non-empty cold tier —
the resumed solve must land bit-identically INCLUDING the spill counters
(``spilled_tasks`` / ``readmitted_tasks``), proving the host cold tier
survives a SIGKILL at any chunk boundary.

Also records the §H durability overheads for EXPERIMENTS.md /
benchmarks/out/RESUME_smoke.json: checkpoint write cost (checkpointed vs
plain solve wall), on-disk checkpoint size, and resume latency.

Usage:
  PYTHONPATH=src python -m benchmarks.resume_smoke           # full
  PYTHONPATH=src python -m benchmarks.resume_smoke --smoke   # CI sizes
"""

from __future__ import annotations

import argparse
import json
import os
import shutil
import signal
import subprocess
import sys
import tempfile
import time

import numpy as np

OUT_DIR = os.path.join(os.path.dirname(__file__), "out")
RESUME_JSON = os.path.join(OUT_DIR, "RESUME_smoke.json")

# the one deterministic workload both processes build (seeded generator);
# the spill variant pins a saturating capacity so checkpoints mid-solve
# carry a non-empty cold tier
def _workload(smoke: bool, spill: bool = False, deep: bool = False):
    from repro.api import SolveConfig
    from repro.graphs.generators import erdos_renyi

    if deep:
        # the double-kill cycle wants many chunks REMAINING after the first
        # kill, so the recovery child demonstrably writes new generations
        # before it too is killed
        g = erdos_renyi(44, 0.25, seed=5)
        cfg = SolveConfig(
            num_workers=4, steps_per_round=2, chunk_rounds=1,
            checkpoint_every=1,
        )
        return g, cfg
    if spill:
        g = erdos_renyi(40, 0.28, seed=0)
        cfg = SolveConfig(
            num_workers=4, steps_per_round=2, chunk_rounds=2, capacity=16,
            frontier_spill=True, checkpoint_every=1,
        )
        return g, cfg
    n = 36 if smoke else 40
    g = erdos_renyi(n, 0.25, seed=3)
    cfg = SolveConfig(
        num_workers=4, steps_per_round=2, chunk_rounds=1, checkpoint_every=1
    )
    return g, cfg


def _child(
    ckpt_dir: str,
    smoke: bool,
    spill: bool = False,
    resume: bool = False,
    deep: bool = False,
) -> None:
    from repro.api import SolverSession

    if resume:
        # recovery child: resume from the survivors AND keep checkpointing
        # into the same directory — so the parent can SIGKILL it again
        # mid-recovery
        SolverSession.resume(ckpt_dir, checkpoint_dir=ckpt_dir)
        return
    g, cfg = _workload(smoke, spill, deep)
    SolverSession(config=cfg).solve(g, checkpoint_dir=ckpt_dir)


def _dir_bytes(d: str) -> int:
    total = 0
    for root, _, files in os.walk(d):
        total += sum(os.path.getsize(os.path.join(root, f)) for f in files)
    return total


def _kill_and_resume(smoke: bool, cache, spill: bool = False):
    """Launch the checkpointing child, SIGKILL it at the first durable
    step, resume from the survivors.  Returns (resumed_result,
    killed_at_step, killed_mid_solve, resume_wall_s)."""
    from repro.api import SolverSession
    from repro.checkpoint.store import latest_step

    d = tempfile.mkdtemp(prefix="resume_smoke_kill_")
    try:
        proc = subprocess.Popen(
            [sys.executable, "-m", "benchmarks.resume_smoke",
             "--child", "--dir", d]
            + (["--smoke"] if smoke else [])
            + (["--spill"] if spill else []),
            env={**os.environ, "PYTHONPATH": "src"},
        )
        deadline = time.time() + 300
        killed_mid_solve = False
        while time.time() < deadline:
            if latest_step(d) is not None:
                proc.send_signal(signal.SIGKILL)
                proc.wait()
                killed_mid_solve = True
                break
            if proc.poll() is not None:
                break  # solved before the first checkpoint landed
            time.sleep(0.05)
        else:
            proc.kill()
            proc.wait()
            raise RuntimeError("child produced no checkpoint within 300s")
        step = latest_step(d)
        assert step is not None, "no checkpoint survived the kill"

        t0 = time.perf_counter()
        resumed = SolverSession.resume(d, cache=cache, checkpoint_dir=None)
        resume_wall = time.perf_counter() - t0
    finally:
        shutil.rmtree(d, ignore_errors=True)
    return resumed, step, killed_mid_solve, resume_wall


def _kill_mid_recovery(smoke: bool, cache):
    """The double-kill cycle: SIGKILL the first child at its first durable
    step, then launch a RECOVERY child (it resumes from the survivors while
    continuing to checkpoint into the same directory) and SIGKILL that one
    too once it has written a newer generation — the final in-process
    resume must still land bit-identically.  Returns (resumed_result,
    first_kill_step, recovery_kill_step, recovery_killed_mid_solve)."""
    from repro.api import SolverSession
    from repro.checkpoint.store import latest_step

    d = tempfile.mkdtemp(prefix="resume_smoke_kill2_")
    try:
        env = {**os.environ, "PYTHONPATH": "src"}
        base_argv = (
            [sys.executable, "-m", "benchmarks.resume_smoke",
             "--child", "--dir", d, "--deep"]
            + (["--smoke"] if smoke else [])
        )
        proc = subprocess.Popen(base_argv, env=env)
        deadline = time.time() + 300
        while time.time() < deadline:
            if latest_step(d) is not None:
                proc.send_signal(signal.SIGKILL)
                proc.wait()
                break
            if proc.poll() is not None:
                break
            time.sleep(0.002)
        else:
            proc.kill()
            proc.wait()
            raise RuntimeError("child produced no checkpoint within 300s")
        step1 = latest_step(d)
        assert step1 is not None, "no checkpoint survived the first kill"

        # recovery child: resumes from step1 and keeps checkpointing; kill
        # it again as soon as a NEWER generation is durable (mid-recovery).
        # If the remaining work finishes before that, the cycle degrades to
        # a plain resume — recorded, not failed.
        proc = subprocess.Popen(base_argv + ["--resume"], env=env)
        deadline = time.time() + 300
        killed_mid_recovery = False
        while time.time() < deadline:
            latest = latest_step(d)
            if latest is not None and latest > step1:
                proc.send_signal(signal.SIGKILL)
                proc.wait()
                killed_mid_recovery = True
                break
            if proc.poll() is not None:
                break  # recovery finished before writing a newer step
            time.sleep(0.002)
        else:
            proc.kill()
            proc.wait()
            raise RuntimeError("recovery child made no progress within 300s")
        step2 = latest_step(d)
        assert step2 is not None and step2 >= step1

        resumed = SolverSession.resume(d, cache=cache, checkpoint_dir=None)
    finally:
        shutil.rmtree(d, ignore_errors=True)
    return resumed, step1, step2, killed_mid_recovery


def run(smoke: bool = False) -> dict:
    from repro.api import PlaneCache, SolverSession
    from repro.checkpoint.store import latest_step

    g, cfg = _workload(smoke)
    cache = PlaneCache()

    # warm the plane cache first so plain-vs-checkpointed walls compare
    # steady-state write cost, not one run's compile against the other's hit
    SolverSession(config=cfg, cache=cache).solve(g)
    t0 = time.perf_counter()
    base = SolverSession(config=cfg, cache=cache).solve(g)
    plain_wall = time.perf_counter() - t0

    # checkpoint write overhead: same solve, every chunk durable, in-process
    d_cost = tempfile.mkdtemp(prefix="resume_smoke_cost_")
    try:
        t0 = time.perf_counter()
        ck_run = SolverSession(config=cfg, cache=cache).solve(
            g, checkpoint_dir=d_cost
        )
        ckpt_wall = time.perf_counter() - t0
        ckpt_bytes = _dir_bytes(os.path.join(d_cost, f"step_{latest_step(d_cost)}"))
        writes = ck_run.stats.checkpoints_written
    finally:
        shutil.rmtree(d_cost, ignore_errors=True)

    resumed, step, killed_mid_solve, resume_wall = _kill_and_resume(
        smoke, cache
    )

    # bit-identity vs the uninterrupted baseline (wall_s and the durability
    # counters are explicitly outside the contract)
    assert resumed.best_size == base.best_size
    assert resumed.rounds == base.rounds
    assert resumed.nodes_expanded == base.nodes_expanded
    assert resumed.tasks_transferred == base.tasks_transferred
    assert resumed.stats.transfer_bytes_total == base.stats.transfer_bytes_total
    assert (np.asarray(resumed.best_sol) == np.asarray(base.best_sol)).all()

    # double-kill cycle: SIGKILL the solve, then SIGKILL the recovery
    # itself mid-checkpoint — the second-generation survivors must still
    # resume bit-identically (checkpoints are valid at EVERY boundary,
    # including ones written by a recovering process)
    g_dp, cfg_dp = _workload(smoke, deep=True)
    base_dp = SolverSession(config=cfg_dp, cache=cache).solve(g_dp)
    res2, kill1_step, kill2_step, killed_mid_recovery = _kill_mid_recovery(
        smoke, cache
    )
    assert res2.best_size == base_dp.best_size
    assert res2.rounds == base_dp.rounds
    assert res2.nodes_expanded == base_dp.nodes_expanded
    assert (np.asarray(res2.best_sol) == np.asarray(base_dp.best_sol)).all()

    # second cycle: SIGKILL with a live cold tier (frontier_spill on a
    # saturating capacity) — resume must replay the spill pump exactly
    g_sp, cfg_sp = _workload(smoke, spill=True)
    base_sp = SolverSession(
        problem="vertex_cover", config=cfg_sp, cache=cache
    ).solve(g_sp)
    assert base_sp.stats.spilled_tasks > 0, (
        "spill workload no longer saturates — retune _workload(spill=True)"
    )
    res_sp, sp_step, sp_killed, _ = _kill_and_resume(smoke, cache, spill=True)
    assert res_sp.best_size == base_sp.best_size
    assert res_sp.rounds == base_sp.rounds
    assert res_sp.nodes_expanded == base_sp.nodes_expanded
    assert (
        np.asarray(res_sp.best_sol) == np.asarray(base_sp.best_sol)
    ).all()
    assert res_sp.stats.spilled_tasks == base_sp.stats.spilled_tasks
    assert res_sp.stats.readmitted_tasks == base_sp.stats.readmitted_tasks
    assert res_sp.stats.overflow_count == 0 and not res_sp.stats.overflow

    out = dict(
        n=g.n,
        rounds=int(base.rounds),
        killed_mid_solve=killed_mid_solve,
        killed_at_step=int(step),
        resumed_best=int(resumed.best_size),
        bit_identical=True,
        plain_wall_s=round(plain_wall, 3),
        checkpointed_wall_s=round(ckpt_wall, 3),
        checkpoint_overhead_pct=round(
            100.0 * (ckpt_wall - plain_wall) / max(plain_wall, 1e-9), 1
        ),
        checkpoints_written=int(writes),
        checkpoint_bytes=int(ckpt_bytes),
        resume_wall_s=round(resume_wall, 3),
        recovery_first_kill_step=int(kill1_step),
        recovery_second_kill_step=int(kill2_step),
        killed_mid_recovery=killed_mid_recovery,
        recovery_bit_identical=True,
        spill_killed_at_step=int(sp_step),
        spill_killed_mid_solve=sp_killed,
        spill_resumed_best=int(res_sp.best_size),
        spill_spilled_tasks=int(res_sp.stats.spilled_tasks),
        spill_readmitted_tasks=int(res_sp.stats.readmitted_tasks),
        spill_bit_identical=True,
    )
    print(
        f"kill-and-resume: SIGKILL at step {step} "
        f"({'mid-solve' if killed_mid_solve else 'after finish'}), resume "
        f"bit-identical (best={out['resumed_best']}, rounds={out['rounds']}); "
        f"checkpoint {out['checkpoint_bytes']}B, write overhead "
        f"{out['checkpoint_overhead_pct']}% at every-chunk cadence, resume "
        f"{out['resume_wall_s']}s"
    )
    second = (
        f"SIGKILL the recovery at step {kill2_step}"
        if killed_mid_recovery
        else "recovery finished before a second kill landed"
    )
    print(
        f"mid-recovery kill: SIGKILL at step {kill1_step}, then {second}; "
        f"final resume bit-identical"
    )
    print(
        f"mid-spill kill: SIGKILL at step {sp_step} with a live cold tier, "
        f"resume bit-identical (best={out['spill_resumed_best']}, "
        f"{out['spill_spilled_tasks']} spilled / "
        f"{out['spill_readmitted_tasks']} readmitted, 0 dropped)"
    )
    os.makedirs(OUT_DIR, exist_ok=True)
    with open(RESUME_JSON, "w") as f:
        json.dump(out, f, indent=2)
        f.write("\n")
    print(f"wrote {RESUME_JSON}")
    return out


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(prog="benchmarks.resume_smoke")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--child", action="store_true", help=argparse.SUPPRESS)
    ap.add_argument("--dir", default=None, help=argparse.SUPPRESS)
    ap.add_argument("--spill", action="store_true", help=argparse.SUPPRESS)
    ap.add_argument("--resume", action="store_true", help=argparse.SUPPRESS)
    ap.add_argument("--deep", action="store_true", help=argparse.SUPPRESS)
    args = ap.parse_args(argv)
    if args.child:
        _child(args.dir, args.smoke, args.spill, args.resume, args.deep)
    else:
        run(args.smoke)


if __name__ == "__main__":
    main()
