"""Durable solve plane: the ``SolveCheckpoint`` schema over the store.

:mod:`repro.checkpoint.store` is the IO layer (atomic tmp-dir swap, npz +
msgpack manifest, async writes).  This module is the SCHEMA layer for the
solve plane: what a checkpoint of a running solve *contains* and when a
resume is *allowed*.

A :class:`SolveCheckpoint` snapshots everything the host loop would need
to reconstruct the exact device state at a chunk boundary:

* ``arrays`` — the device pytree flattened to stable string names:
  the :class:`~repro.core.superstep.WorkerState` (frontier task records in
  the engine's packed-codec layout, best bounds, every carried stat
  counter) or the :class:`~repro.core.superstep.LaneState` of a batched /
  live plane, the batched :class:`~repro.problems.base.ProblemData`, FPT
  bounds, and the instance graphs themselves (so a resume needs nothing
  but the checkpoint);
* ``rounds`` — the host progress counter at the boundary (the engine has
  no host RNG: the round-robin donor salt is ``WorkerState.rounds`` and
  the Algorithm-7 startup permutation is deterministic, so the device
  arrays + this counter ARE the full trajectory state);
* ``fingerprint`` — a digest of every config knob that shapes the solve
  trajectory, plus the problem name and instance graphs.  Resuming under
  a different fingerprint would silently produce a DIFFERENT solve, so it
  refuses loudly (:func:`require_fingerprint`).  Post-trajectory knobs
  (``max_rounds``, the checkpoint knobs themselves, simulator-only knobs)
  are excluded: extending a budget on resume is legitimate.

Corrupt, truncated or half-written checkpoints surface as
:class:`CheckpointError` with the offending path — never a raw
``zipfile``/``msgpack`` traceback, and never a silently wrong resume.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import warnings
from typing import Optional

import jax
import msgpack
import numpy as np

from repro.checkpoint import store

SCHEMA_VERSION = 2

#: SolveConfig fields that determine the solve TRAJECTORY (branching
#: decisions, transfer schedule, stats) — the fingerprint material.  Host
#: budget/durability knobs and simulator-only knobs are deliberately
#: absent: changing them on resume cannot change what the device computes.
TRAJECTORY_FIELDS = (
    "num_workers",
    "steps_per_round",
    "lanes",
    "policy",
    "codec",
    "packed_status",
    "skip_empty_transfer",
    "transfer_impl",
    "explore_impl",
    "donate_k",
    "chunk_rounds",
    "mode",
    "k",
    "capacity",
    "compact_threshold",
    "service_lanes",
    "admission",
    "tenant_max_lanes",
    # the hierarchical frontier memory changes which tasks live on device
    # at any sync point, so its knobs are trajectory material (schema v2)
    "frontier_spill",
    "spill_watermarks",
    "spill_codec",
)


class CheckpointError(RuntimeError):
    """A checkpoint could not be read/validated, or a resume was refused."""


def graph_digest(g) -> str:
    """Content digest of one instance graph (n + packed adjacency)."""
    h = hashlib.sha256()
    h.update(f"n={int(g.n)};".encode())
    h.update(np.ascontiguousarray(np.asarray(g.adj, np.uint32)).tobytes())
    return h.hexdigest()


def config_fingerprint(kind: str, problem: str, cfg, graph_digests) -> str:
    """Digest of (checkpoint kind, problem, trajectory knobs, instances)."""
    knobs = {name: getattr(cfg, name) for name in TRAJECTORY_FIELDS}
    for name, v in knobs.items():
        if isinstance(v, tuple):
            knobs[name] = list(v)
    blob = json.dumps(
        {
            "schema": SCHEMA_VERSION,
            "kind": kind,
            "problem": problem,
            "knobs": knobs,
            "graphs": list(graph_digests),
        },
        sort_keys=True,
    )
    return hashlib.sha256(blob.encode()).hexdigest()


def require_fingerprint(ckpt: "SolveCheckpoint", expected: str, *, what: str) -> None:
    if ckpt.fingerprint != expected:
        raise CheckpointError(
            f"config-fingerprint mismatch resuming {what}: the checkpoint "
            f"was written under a different (problem, trajectory config, "
            f"instances) — resuming would not reproduce the original solve. "
            f"checkpoint fingerprint {ckpt.fingerprint[:12]}..., "
            f"current {expected[:12]}...; align the trajectory knobs "
            f"({', '.join(TRAJECTORY_FIELDS)}) and the instance graphs, or "
            f"start a fresh solve"
        )


# -- the schema ----------------------------------------------------------------


@dataclasses.dataclass
class SolveCheckpoint:
    """One resumable snapshot of a solve plane at a host-sync boundary.

    ``kind`` is ``"solo"`` (one WorkerState), ``"many"`` (the in-flight
    bucket's LaneState + finalized results so far) or ``"service"`` (every
    live plane + the pending queue).  ``arrays`` maps stable names to
    host/device arrays; ``meta`` holds the kind-specific JSON-able rest.
    """

    kind: str
    problem: str
    config: dict
    fingerprint: str
    rounds: int
    arrays: dict
    meta: dict = dataclasses.field(default_factory=dict)

    # -- write -----------------------------------------------------------------

    def save(self, directory: str, step: int, *, blocking: bool = True,
             retry=None, fault_hook=None) -> str:
        """Atomic write through :func:`repro.checkpoint.store.save_checkpoint`
        (unique tmp dir + rename — a kill mid-write never corrupts an
        existing step; overwriting a step keeps the previous generation).
        ``retry`` / ``fault_hook`` thread straight into the store's
        bounded-backoff I/O loop."""
        extra = {
            "schema": SCHEMA_VERSION,
            "kind": self.kind,
            "problem": self.problem,
            "config": self.config,
            "fingerprint": self.fingerprint,
            "rounds": int(self.rounds),
            "arrays": sorted(self.arrays),
            "meta": self.meta,
        }
        return store.save_checkpoint(
            directory, step, dict(self.arrays), extra, blocking=blocking,
            retry=retry, fault_hook=fault_hook,
        )

    # -- read ------------------------------------------------------------------

    @classmethod
    def load(cls, path: str, step: Optional[int] = None, *,
             retry=None, fault_hook=None) -> "SolveCheckpoint":
        """Load from a checkpoint DIRECTORY (latest step, or ``step=``) or
        directly from one ``.../step_<N>`` dir.  Corrupt/truncated data
        raises :class:`CheckpointError` naming the path; transient
        ``OSError`` I/O failures are retried under ``retry``."""
        directory, step = _resolve_step(path, step)
        return cls._load_step_dir(
            os.path.join(directory, f"step_{step}"),
            retry=retry, fault_hook=fault_hook,
        )

    @classmethod
    def _load_step_dir(cls, step_dir: str, *, retry=None,
                       fault_hook=None) -> "SolveCheckpoint":
        """Load one concrete step (or ``step_<N>.prev``) directory."""

        def attempt():
            if fault_hook is not None:
                fault_hook("read")
            with open(os.path.join(step_dir, "manifest.msgpack"), "rb") as f:
                manifest = msgpack.unpackb(f.read(), strict_map_key=False)
            with np.load(os.path.join(step_dir, "arrays.npz")) as z:
                raw = {k: z[k] for k in z.files}
            return manifest, raw

        try:
            manifest, raw = store.call_with_retry(
                attempt, retry, what=f"checkpoint read {step_dir}"
            )
            store.verify_checksums(manifest, raw, where=step_dir)
        except FileNotFoundError as e:
            raise CheckpointError(
                f"incomplete checkpoint at {step_dir}: missing {e.filename}"
            ) from e
        except Exception as e:
            raise CheckpointError(
                f"corrupt or truncated checkpoint at {step_dir}: {e}"
            ) from e
        extra = manifest.get("extra") or {}
        if extra.get("schema") != SCHEMA_VERSION:
            raise CheckpointError(
                f"checkpoint at {step_dir} is not a solve checkpoint "
                f"(schema {extra.get('schema')!r}, want {SCHEMA_VERSION}) — "
                f"was it written by save_checkpoint directly?"
            )
        arrays = {}
        for name in extra["arrays"]:
            key = str(jax.tree_util.DictKey(name))
            if key not in raw:
                raise CheckpointError(
                    f"corrupt checkpoint at {step_dir}: array {name!r} "
                    f"listed in the manifest but absent from arrays.npz"
                )
            arrays[name] = raw[key]
        return cls(
            kind=extra["kind"],
            problem=extra["problem"],
            config=extra["config"],
            fingerprint=extra["fingerprint"],
            rounds=int(extra["rounds"]),
            arrays=arrays,
            meta=extra.get("meta") or {},
        )

    @classmethod
    def load_latest_good(cls, path: str, *, expected_fingerprint=None,
                         what: str = "solve", retry=None,
                         fault_hook=None) -> "SolveCheckpoint":
        """Load the newest checkpoint generation that is intact (and, when
        ``expected_fingerprint`` is given, fingerprint-matching).

        Given a checkpoint DIRECTORY, candidate generations are walked most
        recent first (``step_<N>`` descending, each followed by its
        retained ``step_<N>.prev``); a corrupt/mismatching generation is
        skipped with a LOUD warning and the next one is tried.  Only when
        no good generation remains does the newest generation's error
        propagate — so a single-generation corruption still fails exactly
        like :meth:`load`.  An explicit ``.../step_<N>`` path stays
        strict (no fallback): pointing at one concrete step is a request
        for THAT state."""
        base = os.path.basename(os.path.normpath(path))
        if base.startswith("step_") and not base.endswith(".tmp"):
            ck = cls.load(path, retry=retry, fault_hook=fault_hook)
            if expected_fingerprint is not None:
                require_fingerprint(ck, expected_fingerprint, what=what)
            return ck
        candidates = store.generation_dirs(path)
        if not candidates:
            raise CheckpointError(f"no checkpoint found under {path}")
        errors = []
        for step_dir in candidates:
            try:
                ck = cls._load_step_dir(
                    step_dir, retry=retry, fault_hook=fault_hook
                )
                if expected_fingerprint is not None:
                    require_fingerprint(ck, expected_fingerprint, what=what)
            except CheckpointError as e:
                errors.append((step_dir, e))
                continue
            if errors:
                bad = "; ".join(f"{d}: {e}" for d, e in errors)
                warnings.warn(
                    f"resuming {what} from an OLDER checkpoint generation "
                    f"{step_dir} — newer generation(s) were corrupt or "
                    f"refused ({bad}); recent progress since that "
                    f"generation will be re-executed",
                    RuntimeWarning,
                    stacklevel=2,
                )
            return ck
        raise errors[0][1]

    # -- graph round-trip ------------------------------------------------------

    def pack_graphs(self, tags, graphs) -> None:
        """Store instance graphs under ``graph/<tag>`` (+ per-tag n in meta)
        so a resume is self-contained."""
        ns = {}
        for tag, g in zip(tags, graphs):
            self.arrays[f"graph/{tag}"] = np.asarray(g.adj, np.uint32)
            ns[str(tag)] = int(g.n)
        self.meta["graph_ns"] = ns

    def unpack_graph(self, tag):
        from repro.graphs.bitgraph import BitGraph

        return BitGraph(
            n=self.meta["graph_ns"][str(tag)],
            adj=np.asarray(self.arrays[f"graph/{tag}"], np.uint32),
        )

    def unpack_graphs(self) -> list:
        """All stored graphs in tag order (tags are instance indices)."""
        tags = sorted(int(t) for t in self.meta["graph_ns"])
        return [self.unpack_graph(t) for t in tags]


def _resolve_step(path: str, step: Optional[int]):
    """(directory, step) from a checkpoint dir or a step_<N> subdir."""
    base = os.path.basename(os.path.normpath(path))
    if base.startswith("step_") and not base.endswith(".tmp"):
        if step is not None:
            raise ValueError("pass either a step_<N> path or step=, not both")
        try:
            return os.path.dirname(os.path.normpath(path)), int(base[5:])
        except ValueError:
            raise CheckpointError(f"malformed step directory name: {path}")
    if step is None:
        step = store.latest_step(path)
        if step is None:
            raise CheckpointError(f"no checkpoint found under {path}")
    return path, step


# -- EngineResult round-trip (solve_many finalizes results eagerly; the
# finalized ones ride in the checkpoint meta so a resume never re-extracts
# a lane that was already compacted away) --------------------------------------


def engine_result_to_dict(r) -> dict:
    d = dataclasses.asdict(r)
    if r.best_sol is not None:
        d["best_sol"] = [int(w) for w in np.asarray(r.best_sol, np.uint32)]
    return d


def engine_result_from_dict(d: dict):
    from repro.core.engine import EngineResult

    d = dict(d)
    sol = d.get("best_sol")
    d["best_sol"] = None if sol is None else np.asarray(sol, np.uint32)
    return EngineResult(**d)


# -- ProblemData (de)serialization --------------------------------------------


def data_to_flat(data, prefix: str) -> dict:
    """Batched :class:`~repro.problems.base.ProblemData` -> named arrays."""
    return {
        f"{prefix}.n": np.asarray(jax.device_get(data.n)),
        f"{prefix}.adj": np.asarray(jax.device_get(data.adj)),
        f"{prefix}.word_idx": np.asarray(jax.device_get(data.word_idx)),
        f"{prefix}.bit_idx": np.asarray(jax.device_get(data.bit_idx)),
    }


def data_from_flat(flat: dict, prefix: str):
    import jax.numpy as jnp

    from repro.problems.base import ProblemData

    return ProblemData(
        n=jnp.asarray(flat[f"{prefix}.n"]),
        adj=jnp.asarray(flat[f"{prefix}.adj"]),
        word_idx=jnp.asarray(flat[f"{prefix}.word_idx"]),
        bit_idx=jnp.asarray(flat[f"{prefix}.bit_idx"]),
    )
