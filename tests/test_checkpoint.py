"""Checkpoint/restart fault tolerance: atomicity, resume-exactness, async."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.store import (
    latest_step,
    restore_checkpoint,
    save_checkpoint,
    wait_for_pending,
)
from repro.configs.registry import get_smoke_config
from repro.launch.train import train_loop


def test_roundtrip(tmp_path):
    tree = {"a": jnp.arange(12.0).reshape(3, 4), "b": {"c": jnp.int32(7)}}
    save_checkpoint(str(tmp_path), 5, tree, extra={"x": 1})
    got, step, extra = restore_checkpoint(str(tmp_path), tree)
    assert step == 5 and extra == {"x": 1}
    assert (np.asarray(got["a"]) == np.asarray(tree["a"])).all()
    assert int(got["b"]["c"]) == 7


def test_latest_step_and_atomicity(tmp_path):
    tree = {"a": jnp.zeros(3)}
    save_checkpoint(str(tmp_path), 1, tree)
    save_checkpoint(str(tmp_path), 9, tree)
    # a stale .tmp dir must be ignored
    os.makedirs(tmp_path / "step_50.tmp")
    assert latest_step(str(tmp_path)) == 9


def test_async_write(tmp_path):
    tree = {"a": jnp.ones((64, 64))}
    save_checkpoint(str(tmp_path), 3, tree, blocking=False)
    wait_for_pending()
    got, step, _ = restore_checkpoint(str(tmp_path), tree)
    assert step == 3 and float(got["a"].sum()) == 64 * 64


def test_resume_reproduces_loss_curve(tmp_path):
    """Train 12 steps straight vs 6 + crash + resume 6: identical losses —
    the deterministic pipeline + checkpoint contract."""
    cfg = get_smoke_config("qwen1_5_0_5b")
    ck = str(tmp_path / "ck")
    _, _, full = train_loop(cfg, steps=12, batch=4, seq=32, ckpt_dir=None, seed=3)
    _, _, first = train_loop(
        cfg, steps=6, batch=4, seq=32, ckpt_dir=ck, ckpt_every=3, seed=3
    )
    wait_for_pending()
    _, _, second = train_loop(
        cfg, steps=12, batch=4, seq=32, ckpt_dir=ck, ckpt_every=100,
        resume=True, seed=3,
    )
    resumed = first + second
    assert len(resumed) == len(full)
    np.testing.assert_allclose(resumed, full, rtol=2e-4, atol=2e-4)
