"""minitron-4b [dense] — pruned nemotron.  32L d=3072 24H kv=8 d_ff=9216
vocab=256000.  [arXiv:2407.14679]"""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="minitron-4b",
        family="dense",
        n_layers=32,
        d_model=3072,
        n_heads=24,
        n_kv_heads=8,
        d_ff=9216,
        vocab=256_000,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="minitron-smoke",
        family="dense",
        n_layers=2,
        d_model=48,
        n_heads=4,
        n_kv_heads=2,
        d_ff=96,
        vocab=512,
        dtype="float32",
    )
