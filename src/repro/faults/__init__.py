"""``repro.faults`` — deterministic fault injection for the solve plane.

The center tracks every worker's placement with a few bits (the paper's
semi-centralized bookkeeping); this package turns that into a tested
recovery story.  :class:`FaultPlan` is a seeded schedule of faults keyed
on chunk-boundary indices (never wall clock); :class:`FaultInjector`
fires it against a live solve through host-boundary hooks in
``api/backends.py`` / ``api/service.py`` / ``core/spill.py`` /
``checkpoint/store.py`` and keeps the injected/recovered/retries ledger
surfaced in :class:`repro.api.ServiceStats`.

Quickstart::

    from repro.faults import FaultInjector, FaultPlan

    inj = FaultInjector(FaultPlan.random(seed=0, n_events=6))
    r = session.solve(g, injector=inj)        # same answer, faults healed
    inj.report()   # {'injected': {...}, 'recovered': {...}, 'retries': N}
"""

from repro.faults.injector import FaultInjector
from repro.faults.plan import FAULT_KINDS, FaultEvent, FaultPlan

__all__ = ["FAULT_KINDS", "FaultEvent", "FaultInjector", "FaultPlan"]
