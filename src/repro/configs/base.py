"""Model / run configuration schema (one dataclass covers all 10 families).

Every assigned architecture gets a ``configs/<id>.py`` exporting ``config()``
(the exact published shape) and ``smoke_config()`` (same family, reduced
dims, CPU-runnable).  The launcher resolves ``--arch <id>`` through
``repro.configs.registry``.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int = 0  # 0 -> d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"

    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25

    # hybrid (recurrentgemma / griffin): layer pattern unit, tiled over depth
    pattern: Sequence[str] = ()  # e.g. ("rec", "rec", "attn")
    window: Optional[int] = None  # sliding-window size for local attention
    d_rnn: int = 0  # RG-LRU width (griffin uses ~4/3 d_model)
    conv_width: int = 4

    # rwkv6
    decay_lora: int = 64  # rank of the data-dependent decay LoRA

    # encoder-decoder (whisper): encoder stream
    n_enc_layers: int = 0
    enc_seq: int = 0  # stubbed frontend frames (whisper: 1500)

    # vlm (pixtral): stubbed patch-embedding prefix
    n_patches: int = 0

    # which attention families this config can lower for long_500k
    subquadratic: bool = False

    def __post_init__(self):
        if self.d_head == 0:
            object.__setattr__(self, "d_head", self.d_model // self.n_heads)

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    def layer_kinds(self) -> tuple[str, ...]:
        """Per-layer block kind for the decoder stack."""
        if self.family == "hybrid" and self.pattern:
            reps = -(-self.n_layers // len(self.pattern))
            return tuple((list(self.pattern) * reps)[: self.n_layers])
        if self.family == "ssm":
            return ("rwkv",) * self.n_layers
        return ("attn",) * self.n_layers

    def params_count(self) -> int:
        """Approximate parameter count (embedding + blocks), for 6ND math."""
        d, L = self.d_model, self.n_layers
        emb = self.vocab * d
        kinds = self.layer_kinds()
        total = emb
        dh = self.d_head
        attn = d * (self.n_heads * dh) + 2 * d * (self.n_kv_heads * dh) + (
            self.n_heads * dh
        ) * d
        if self.is_moe:
            mlp = self.n_experts * 3 * d * self.d_ff
        else:
            mlp = 3 * d * self.d_ff
        for kind in kinds:
            if kind == "attn":
                total += attn + mlp
            elif kind == "rec":
                dr = self.d_rnn or d
                total += 2 * d * dr + dr * d + 2 * dr + mlp
            elif kind == "rwkv":
                total += 4 * d * d + d * self.d_ff + self.d_ff * d
        total += d * self.vocab  # unembed
        if self.family == "encdec":
            total += self.n_enc_layers * (attn + mlp)
        return total

    def active_params_count(self) -> int:
        """Active parameters per token (MoE: only routed experts count)."""
        if not self.is_moe:
            return self.params_count()
        d = self.d_model
        dense_like = dataclasses.replace(self, n_experts=0, top_k=0)
        # replace the full expert bank with top_k experts per layer
        full = self.params_count()
        bank = self.n_layers * self.n_experts * 3 * d * self.d_ff
        active = self.n_layers * self.top_k * 3 * d * self.d_ff
        del dense_like
        return full - bank + active


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""

    name: str  # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}
