"""Paper §4.3: bytes-per-task for the two serialization schemes.

basic     = (n+2)·W + 1 words  (adjacency rows travel with the task)
optimized = 2·W + 1 words      (n-bit mask of surviving vertices)

The table shows why the centralized scheduler collapses under the basic
encoding (every task crosses the wire twice) and why the optimized encoding
is what makes the fixed-shape TPU port natural.

The trailing columns extend the story to the SPMD data plane at P=64
(EXPERIMENTS.md §Perf): the gather path all-gathers the full P-row record
table every transfer round, while the sparse masked-psum path pays only for
the records that actually matched (here m=1 match — the common case late in
a run; 0 matches moves 0 bytes).
"""

from __future__ import annotations

from repro.core.encoding import make_codec

P_REF = 64  # reference worker count for the per-round wire columns


def run(csv=True):
    rows = []
    for n in (128, 500, 700, 1000, 4096):
        opt = make_codec("optimized", n)
        bas = make_codec("basic", n)
        rows.append(
            dict(
                n=n,
                optimized_bytes=opt.record_bytes,
                basic_bytes=bas.record_bytes,
                ratio=round(bas.record_bytes / opt.record_bytes, 1),
                gather_round_B_p64=P_REF * opt.record_bytes,
                sparse_round_B_m1=opt.record_bytes,
            )
        )
    if csv:
        keys = list(rows[0].keys())
        print(",".join(keys))
        for r in rows:
            print(",".join(str(r[k]) for k in keys))
    # dict form so benchmarks.run can record it in BENCH_smoke.json
    return {"P_ref": P_REF, "rows": rows}


if __name__ == "__main__":
    run()
