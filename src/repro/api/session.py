"""``SolverSession``: the one public way to solve branching problems.

A session binds (problem, backend, config) once and exposes three verbs:

* ``solve(g)`` — one instance, unified :class:`SolveResult`;
* ``solve_many(graphs)`` — B instances on one batched plane (spmd) or an
  instance loop (simulator backends), unified :class:`BatchSolveResult`;
* ``submit(g) -> ticket`` / ``poll()`` / ``flush()`` — asynchronous
  admission through the serving :class:`~repro.serving.balancer.
  SolveBatcher`: requests queue until a full ``batch_size`` plane is
  admissible (``poll``) or the stream ends (``flush``), and every solved
  ticket's result is retrievable via ``result(ticket)``.

The session owns a :class:`~repro.api.cache.PlaneCache` (or shares one
passed in), so warm repeat solves of the same (problem, codec, shape,
config) reuse compiled executables instead of re-tracing —
``cache_stats()`` exposes the hit/miss/trace accounting.
"""

from __future__ import annotations

from typing import Optional

from repro.api.backends import Backend, SpmdBackend, get_backend
from repro.api.cache import PlaneCache
from repro.api.config import SolveConfig
from repro.api.result import BatchSolveResult, SolveResult
from repro.problems.registry import DEFAULT_PROBLEM, get_problem


class SolverSession:
    """One façade over all backends, with executable reuse across solves.

    >>> session = SolverSession(problem="max_clique", backend="spmd",
    ...                         config=SolveConfig(num_workers=8))
    >>> session.solve(g).best_size
    >>> session.solve_many(graphs).results
    >>> t = session.submit(g); session.flush(); session.result(t)

    ``problem`` is a registry name or spec; ``backend`` one of
    ``spmd | protocol_sim | centralized | sequential`` (see
    :func:`repro.api.backends.known_backends`).  Keyword overrides are
    applied on top of ``config``:  ``SolverSession(num_workers=4)``.
    """

    def __init__(
        self,
        problem=DEFAULT_PROBLEM,
        backend="spmd",
        config: Optional[SolveConfig] = None,
        *,
        cache: Optional[PlaneCache] = None,
        **overrides,
    ):
        self.problem = get_problem(problem)
        self.backend: Backend = get_backend(backend)
        cfg = config if config is not None else SolveConfig()
        if overrides:
            cfg = cfg.replace(**overrides)
        self.config = cfg
        self.cache = cache if cache is not None else PlaneCache()
        self._batcher = None  # lazy serving.SolveBatcher
        self._results: dict = {}  # ticket -> SolveResult

    # -- synchronous solves ----------------------------------------------------

    def solve(
        self,
        g,
        *,
        checkpoint_dir: Optional[str] = None,
        resume_from: Optional[str] = None,
        **backend_kw,
    ) -> SolveResult:
        """Solve one instance; ``backend_kw`` passes backend-specific extras
        (spmd: ``initial_state``, ``mesh``).

        ``checkpoint_dir``/``resume_from`` override the config's durability
        knobs for THIS call (spmd): periodic
        :class:`~repro.checkpoint.solve.SolveCheckpoint` writes every
        ``config.checkpoint_every`` chunks, and fingerprint-checked
        restore-and-continue respectively.
        """
        return self.backend.solve(
            self.problem,
            g,
            self._call_config(checkpoint_dir, resume_from),
            self.cache,
            **backend_kw,
        )

    def solve_many(
        self,
        graphs,
        *,
        checkpoint_dir: Optional[str] = None,
        resume_from: Optional[str] = None,
        **backend_kw,
    ) -> BatchSolveResult:
        """Solve B instances; ``backend_kw`` passes backend-specific extras
        (spmd: ``injector`` for fault injection)."""
        return self.backend.solve_many(
            self.problem,
            list(graphs),
            self._call_config(checkpoint_dir, resume_from),
            self.cache,
            **backend_kw,
        )

    def _call_config(self, checkpoint_dir, resume_from) -> SolveConfig:
        overrides = {
            k: v
            for k, v in (
                ("checkpoint_dir", checkpoint_dir),
                ("resume_from", resume_from),
            )
            if v is not None
        }
        return self.config.replace(**overrides) if overrides else self.config

    @classmethod
    def resume(
        cls,
        path: str,
        *,
        backend="spmd",
        cache: Optional[PlaneCache] = None,
        **config_overrides,
    ) -> "SolveResult | BatchSolveResult":
        """Resume a checkpointed solve to completion and return its result.

        ``path`` is a checkpoint directory (latest step) or one
        ``.../step_<N>`` subdir.  The session is rebuilt FROM the
        checkpoint — problem, config and instance graphs are all stored in
        it — then the solve continues from the snapshotted device state to
        a final result bit-identical to the uninterrupted run (modulo
        wall-clock).  ``config_overrides`` may adjust post-trajectory
        knobs (``max_rounds``, ``checkpoint_dir``, ...); changing a
        trajectory knob is refused by the fingerprint check.

        Service checkpoints restore via
        :meth:`repro.api.SolveService.restore` (they hold live lanes + a
        queue, not one result).
        """
        from repro.checkpoint.solve import CheckpointError, SolveCheckpoint

        ck = SolveCheckpoint.load_latest_good(path, what="session")
        if ck.kind == "service":
            raise CheckpointError(
                f"{path} holds a service checkpoint; use "
                f"SolveService.restore(path)"
            )
        cfg = SolveConfig.from_dict(ck.config).replace(
            resume_from=path, **config_overrides
        )
        session = cls(
            problem=ck.problem, backend=backend, config=cfg, cache=cache
        )
        if ck.kind == "solo":
            return session.solve(ck.unpack_graph(0))
        return session.solve_many(ck.unpack_graphs())

    # -- asynchronous admission (the serving front) ----------------------------

    def submit(self, g) -> int:
        """Queue one instance for batched solving; returns its ticket.

        Tickets solve when a full ``config.batch_size`` plane accumulates
        (``poll``) or on ``flush()``; results are kept until ``result`` is
        called (which pops them).
        """
        if self._batcher is None:
            from repro.serving.balancer import SolveBatcher

            self._batcher = SolveBatcher(self.config.batch_size)
        return self._batcher.submit(g, self.problem.name)

    def poll(self) -> list:
        """Solve every currently FULL batch; returns the tickets solved."""
        if self._batcher is None:
            return []
        return self._run_batches(self._batcher.ready_batches())

    def flush(self) -> list:
        """Solve everything still queued (full and partial batches);
        returns the tickets solved."""
        if self._batcher is None:
            return []
        return self._run_batches(self._batcher.flush())

    def result(self, ticket: int) -> SolveResult:
        """Pop a solved ticket's result (KeyError if unknown or unsolved —
        call ``poll``/``flush`` first)."""
        return self._results.pop(ticket)

    def pending(self) -> int:
        """Tickets submitted but not yet solved."""
        if self._batcher is None:
            return 0
        return len(self._batcher.graphs)

    def _run_batches(self, batches) -> list:
        solved = []
        for tickets in batches:
            gs = self._batcher.take(tickets)
            batch = self.solve_many(gs)
            for t, r in zip(tickets, batch.results):
                self._results[t] = r
            solved.extend(tickets)
        return solved

    # -- the continuous-batching service ---------------------------------------

    def serve(self, *, injector=None, **config_overrides) -> "SolveService":
        """A :class:`~repro.api.service.SolveService` over this session's
        (problem, config, cache): a live compiled plane whose freed lanes
        re-admit queued instances continuously, instead of the fixed
        ``batch_size`` planes behind ``submit``/``poll``/``flush``.

        >>> svc = session.serve(service_lanes=8)
        >>> t = svc.submit(g); svc.drain(); svc.result(t)

        The service shares this session's plane cache, so a session that
        already solved on a shape serves it warm (spmd backend only).
        """
        from repro.api.service import SolveService

        if self.backend.name != "spmd":
            raise ValueError(
                f"serve() needs the spmd backend (live batched plane); "
                f"this session uses {self.backend.name!r}"
            )
        cfg = self.config
        if config_overrides:
            cfg = cfg.replace(**config_overrides)
        return SolveService(
            self.problem, cfg, cache=self.cache, injector=injector
        )

    # -- introspection ---------------------------------------------------------

    def cache_stats(self) -> dict:
        """Warm/cold compiled-plane accounting (see
        :class:`~repro.api.cache.CacheStats`)."""
        return self.cache.stats().to_dict()

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return (
            f"SolverSession(problem={self.problem.name!r}, "
            f"backend={self.backend.name!r})"
        )


def solve_stream_session(
    graphs,
    batch_size: int,
    *,
    problem=DEFAULT_PROBLEM,
    config: Optional[SolveConfig] = None,
    cache: Optional[PlaneCache] = None,
    backend="spmd",
) -> list:
    """Session-backed stream solver: one continuous
    :class:`~repro.api.service.SolveService` per problem in the stream, ALL
    sharing one :class:`PlaneCache` — so a mixed request stream replaying
    the same (problem, W) planes pays each compile once, and a lane freed
    by an easy instance re-admits the next queued one mid-flight instead of
    idling until its whole batch drains.  ``batch_size`` becomes the
    service's lane count.  Returns per-instance :class:`SolveResult` in
    submission order.

    Non-spmd backends have no live batched plane; they fall back to the
    fixed-batch ``submit``/``flush`` path with identical results.

    This is what :func:`repro.serving.balancer.solve_stream` drives when no
    explicit solver is injected.
    """
    graphs = list(graphs)
    probs = [problem] * len(graphs) if isinstance(problem, str) else list(problem)
    if len(probs) != len(graphs):
        raise ValueError("need one problem, or one per instance")
    cache = cache if cache is not None else PlaneCache()
    cfg = config if config is not None else SolveConfig()
    if get_backend(backend).name != "spmd":
        sessions: dict = {}
        tickets = []
        for g, p in zip(graphs, probs):
            name = get_problem(p).name
            if name not in sessions:
                sessions[name] = SolverSession(
                    problem=name,
                    backend=backend,
                    config=cfg.replace(batch_size=batch_size),
                    cache=cache,
                )
            tickets.append((name, sessions[name].submit(g)))
        for s in sessions.values():
            s.flush()
        return [sessions[name].result(t) for name, t in tickets]

    from repro.api.service import SolveService

    services: dict = {}
    tickets = []
    for g, p in zip(graphs, probs):
        name = get_problem(p).name
        if name not in services:
            services[name] = SolveService(
                name, cfg.replace(service_lanes=batch_size), cache=cache
            )
        tickets.append((name, services[name].submit(g)))
    for svc in services.values():
        svc.drain()
    return [services[name].result(t) for name, t in tickets]


# re-exported for the quickstart; the spmd backend is the common default
__all__ = ["SolverSession", "SolveConfig", "SpmdBackend", "solve_stream_session"]
