"""Pallas TPU kernels for the compute hot spots (+ jnp oracles).

* ``bitset_ops``       — batched induced-subgraph degrees (B&B branching);
* ``flash_attention``  — blockwise online-softmax attention (LM layers);
* ``wkv6``             — chunked data-dependent-decay recurrence (RWKV6).

Each subpackage ships ``kernel.py`` (pl.pallas_call + BlockSpec),
``ops.py`` (jit'd dispatch wrapper) and ``ref.py`` (pure-jnp oracle);
kernels are validated with interpret=True on CPU and target TPU natively.
"""
