"""Model dispatch: one uniform interface over the four family implementations.

``get_model(cfg)`` returns a ``Model`` facade with
  init(key) -> (params, specs)
  loss_fn(params, batch, rules) -> scalar
  prefill_fn(params, batch, rules) -> logits
  init_decode_cache(batch, max_len) -> (cache, specs|None)
  decode_fn(params, cache, tokens, rules) -> (logits, cache)
plus ``batch_spec(shape)`` describing the model's inputs for a given assigned
shape (used by input_specs in the launcher and by the data pipeline).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import encdec, griffin, rwkv6, transformer


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    init: Callable
    loss_fn: Callable
    forward: Callable
    init_decode_cache: Callable
    decode_fn: Callable

    def batch_spec(self, shape: ShapeConfig) -> dict[str, Any]:
        """ShapeDtypeStruct-compatible description of one train/prefill batch
        (token dims use the GLOBAL batch; the mesh shards them)."""
        import jax

        B, S = shape.global_batch, shape.seq_len
        cfg = self.cfg
        spec: dict[str, Any] = {}
        if cfg.family == "encdec":
            spec["frames"] = jax.ShapeDtypeStruct(
                (B, cfg.enc_seq, cfg.d_model), jnp.dtype(cfg.dtype)
            )
        if cfg.family == "vlm":
            spec["patch_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.n_patches, cfg.d_model), jnp.dtype(cfg.dtype)
            )
        spec["tokens"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
        if shape.kind == "train":
            spec["labels"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
        return spec


def _transformer_model(cfg: ModelConfig) -> Model:
    def loss(params, batch, rules=None):
        return transformer.loss_fn(params, cfg, batch, rules=rules)

    def fwd(params, batch, rules=None):
        return transformer.forward(
            params, cfg, batch["tokens"], rules=rules,
            extra_embeds=batch.get("patch_embeds"),
        )[0]

    return Model(
        cfg=cfg,
        init=lambda key: transformer.init_lm(key, cfg),
        loss_fn=loss,
        forward=fwd,
        init_decode_cache=lambda b, m: transformer.init_decode_cache(cfg, b, m),
        decode_fn=lambda p, c, t, rules=None: transformer.decode_fn(
            p, cfg, c, t, rules=rules
        ),
    )


def _rwkv_model(cfg: ModelConfig) -> Model:
    return Model(
        cfg=cfg,
        init=lambda key: rwkv6.init_lm(key, cfg),
        loss_fn=lambda p, b, rules=None: rwkv6.loss_fn(p, cfg, b, rules=rules),
        forward=lambda p, b, rules=None: rwkv6.forward(
            p, cfg, b["tokens"], rules=rules
        )[0],
        init_decode_cache=lambda b, m: rwkv6.init_decode_cache(cfg, b, m),
        decode_fn=lambda p, c, t, rules=None: rwkv6.decode_fn(
            p, cfg, c, t, rules=rules
        ),
    )


def _griffin_model(cfg: ModelConfig) -> Model:
    return Model(
        cfg=cfg,
        init=lambda key: griffin.init_lm(key, cfg),
        loss_fn=lambda p, b, rules=None: griffin.loss_fn(p, cfg, b, rules=rules),
        forward=lambda p, b, rules=None: griffin.forward(
            p, cfg, b["tokens"], rules=rules
        )[0],
        init_decode_cache=lambda b, m: griffin.init_decode_cache(cfg, b, m),
        decode_fn=lambda p, c, t, rules=None: griffin.decode_fn(
            p, cfg, c, t, rules=rules
        ),
    )


def _encdec_model(cfg: ModelConfig) -> Model:
    return Model(
        cfg=cfg,
        init=lambda key: encdec.init_lm(key, cfg),
        loss_fn=lambda p, b, rules=None: encdec.loss_fn(p, cfg, b, rules=rules),
        forward=lambda p, b, rules=None: encdec.forward(
            p, cfg, b["tokens"], frames=b["frames"], rules=rules
        )[0],
        init_decode_cache=lambda b, m: encdec.init_decode_cache(cfg, b, m),
        decode_fn=lambda p, c, t, rules=None: encdec.decode_fn(
            p, cfg, c, t, rules=rules
        ),
    )


def get_model(cfg: ModelConfig) -> Model:
    if cfg.family in ("dense", "moe", "vlm"):
        return _transformer_model(cfg)
    if cfg.family == "ssm":
        return _rwkv_model(cfg)
    if cfg.family == "hybrid":
        return _griffin_model(cfg)
    if cfg.family == "encdec":
        return _encdec_model(cfg)
    raise ValueError(f"unknown family {cfg.family!r}")
