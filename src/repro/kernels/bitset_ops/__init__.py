from repro.kernels.bitset_ops.ops import degrees_op, max_degree_vertex
from repro.kernels.bitset_ops.ref import batched_degrees_ref, max_degree_vertex_ref

__all__ = [
    "degrees_op",
    "max_degree_vertex",
    "batched_degrees_ref",
    "max_degree_vertex_ref",
]
