"""The asynchronous protocol simulator vs the sequential ground truth.

Validates the paper's claims: (a) correct optima under any policy/codec/
latency, (b) ZERO failed work requests (§3.1), (c) safe termination even
with in-flight tasks (§3.3).
"""

import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core.centralized import run_centralized_sim
from repro.core.protocol_sim import run_protocol_sim
from repro.graphs.generators import erdos_renyi, p_hat_like
from repro.problems.sequential import solve_sequential, verify_cover


@pytest.mark.parametrize("policy", ["random", "priority"])
@pytest.mark.parametrize("codec", ["optimized", "basic"])
def test_matches_sequential(policy, codec):
    g = erdos_renyi(36, 0.25, 7)
    want, _, _ = solve_sequential(g)
    res = run_protocol_sim(g, num_workers=5, policy=policy, codec_name=codec)
    assert res.best_size == want
    assert verify_cover(g, res.best_sol)
    assert res.stats.failed_requests == 0


@pytest.mark.parametrize("latency", [1, 2, 5])
def test_latency_exposes_termination_race(latency):
    """Higher latency widens the §3.3 in-flight window; the sent/ack safety
    mechanism must still terminate with the right answer."""
    g = erdos_renyi(32, 0.3, 3)
    want, _, _ = solve_sequential(g)
    res = run_protocol_sim(g, num_workers=6, latency=latency)
    assert res.best_size == want
    assert res.stats.failed_requests == 0


def test_metadata_policy():
    g = erdos_renyi(30, 0.3, 11)
    want, _, _ = solve_sequential(g)
    res = run_protocol_sim(
        g, num_workers=4, policy="priority", send_metadata=True
    )
    assert res.best_size == want


def test_fpt_mode_early_stop():
    g = erdos_renyi(30, 0.25, 5)
    opt, _, _ = solve_sequential(g)
    yes = run_protocol_sim(g, num_workers=4, mode="fpt", k=opt)
    assert yes.best_size != -1 and yes.best_size <= opt
    no = run_protocol_sim(g, num_workers=4, mode="fpt", k=opt - 1)
    assert no.best_size == -1


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000), st.integers(2, 8))
def test_random_graphs_property(seed, workers):
    g = erdos_renyi(26, 0.22, seed)
    want, _, _ = solve_sequential(g)
    res = run_protocol_sim(g, num_workers=workers, seed=seed)
    assert res.best_size == want
    assert res.stats.failed_requests == 0
    if res.best_sol is not None:
        assert verify_cover(g, res.best_sol)


def test_control_plane_smaller_than_centralized():
    """§4.2/§4.3: the semi-centralized scheme moves fewer total bytes; its
    center sees only integers while the centralized center sees every task."""
    g = p_hat_like(40, 0.4, 2)
    semi = run_protocol_sim(g, num_workers=5)
    cent = run_centralized_sim(g, num_workers=5)
    assert semi.best_size == cent.best_size
    assert semi.stats.total_bytes < cent.stats.total_bytes
    # every center-bound message in the semi scheme is a single integer
    assert semi.stats.center_bytes < semi.stats.total_bytes
