"""Cold vs warm session solves: the compiled-plane cache payoff.

A ``SolverSession`` keys compiled planes by (problem, codec, shape, config)
and takes the instance tensors as call-time arguments, so the SECOND solve
of a same-shape instance reuses the executable outright — no tracing, no
XLA compile, just the device loop.  This benchmark measures exactly that:

* **cold** — the session's first solve (trace + compile + run);
* **warm** — a same-shape solve of a DIFFERENT graph right after;
* **warm-repeat** — the same graph again, asserted bit-identical to cold.

``run(smoke=True)`` is in the CI bench-smoke set and GATES the speedup:
warm must be at least ``MIN_WARM_SPEEDUP`` x faster than cold, and the
cache/trace accounting must show exactly one trace for the same-shape pair.
This is the per-PR guard on the executable-reuse contract (EXPERIMENTS.md
§E tracks the numbers).
"""

from __future__ import annotations

import time

from repro.api import SolveConfig, SolverSession
from repro.core import superstep
from repro.graphs.generators import erdos_renyi

# acceptance bar (ISSUE 4): warm wall-clock >= 5x faster than cold.
# measured headroom is ~2 orders of magnitude above it on CPU.
MIN_WARM_SPEEDUP = 5.0


def run(smoke: bool = False) -> dict:
    n, p, workers, spr = (24, 0.3, 4, 8) if smoke else (40, 0.28, 6, 8)
    session = SolverSession(
        problem="vertex_cover",
        config=SolveConfig(num_workers=workers, steps_per_round=spr),
    )
    g_cold = erdos_renyi(n, p, 0)
    g_warm = erdos_renyi(n, p, 1)

    traces0 = superstep.PLANE_TRACES
    t0 = time.perf_counter()
    r_cold = session.solve(g_cold)
    cold_s = time.perf_counter() - t0

    t0 = time.perf_counter()
    r_warm = session.solve(g_warm)
    warm_s = time.perf_counter() - t0

    t0 = time.perf_counter()
    r_repeat = session.solve(g_cold)
    repeat_s = time.perf_counter() - t0
    traces = superstep.PLANE_TRACES - traces0

    # correctness invariants of the reuse: one trace for the same-shape trio,
    # and the warm repeat is bit-identical to the cold solve
    stats = session.cache_stats()
    assert traces == 1, f"same-shape solves traced {traces}x, want 1"
    assert stats["misses"] == 1 and stats["hits"] == 2, stats
    assert r_repeat.best_size == r_cold.best_size
    assert (r_repeat.best_sol == r_cold.best_sol).all()
    assert r_repeat.rounds == r_cold.rounds
    assert r_warm.best_size is not None

    speedup = cold_s / max(warm_s, 1e-9)
    if smoke:  # the CI gate; full-size local runs just report
        assert speedup >= MIN_WARM_SPEEDUP, (
            f"warm-plane reuse regressed: warm solve only {speedup:.1f}x "
            f"faster than cold (< {MIN_WARM_SPEEDUP}x; benchmark-gated CI)"
        )

    print(f"G({n}, {p}), {workers} workers, steps_per_round={spr}")
    print(f"cold  (trace+compile+run): {cold_s * 1e3:9.1f} ms")
    print(f"warm  (same-shape reuse) : {warm_s * 1e3:9.1f} ms   "
          f"({speedup:.1f}x)")
    print(f"warm  (repeat, bit-identical): {repeat_s * 1e3:5.1f} ms")
    print(f"cache: {stats}")
    return dict(
        problem="vertex_cover",
        n=n,
        p=p,
        workers=workers,
        steps_per_round=spr,
        cold_s=round(cold_s, 4),
        warm_s=round(warm_s, 4),
        warm_repeat_s=round(repeat_s, 4),
        warm_speedup=round(speedup, 1),
        plane_traces=traces,
        cache=stats,
    )


if __name__ == "__main__":
    run()
