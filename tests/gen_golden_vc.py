"""Regenerate tests/golden_vc.json — the pre-refactor vertex-cover goldens.

The goldens pin `engine.solve` / `engine.solve_many` outputs (best_size,
best_sol and every deterministic stat) for a fixed set of instances and
engine configs.  tests/test_problems_generic.py asserts the generic
problem-plugin plane reproduces them bit-for-bit, so the vertex-cover
behavior of any future solve-plane refactor stays verifiable.

Run from the repo root (NOT via pytest — the filename is deliberately not
test_*):

  PYTHONPATH=src python tests/gen_golden_vc.py
"""

import json
import os

import numpy as np

from repro.core import engine as E
from repro.graphs.generators import erdos_renyi
from repro.problems.sequential import solve_sequential

OUT = os.path.join(os.path.dirname(__file__), "golden_vc.json")

# (label, graph kwargs, solve kwargs) — each exercises a different engine path
SOLO_CASES = [
    ("base", dict(n=30, p=0.22, seed=0), dict(num_workers=5, steps_per_round=8)),
    (
        "multi_lane_donate",
        dict(n=24, p=0.3, seed=1),
        dict(num_workers=4, steps_per_round=4, lanes=2, donate_k=3),
    ),
    (
        "gather_basic_codec",
        dict(n=26, p=0.28, seed=2),
        dict(num_workers=4, steps_per_round=8, transfer_impl="gather", codec="basic"),
    ),
    (
        "random_policy_chunk1",
        dict(n=22, p=0.3, seed=3),
        dict(num_workers=4, steps_per_round=8, policy_priority=False, chunk_rounds=1),
    ),
]

# mixed sizes: W=1 bucket {18, 24, 12} (padding!), W=2 bucket {40, 36};
# chunk_rounds=2 + threshold 0.5 forces the compaction path
MANY_SIZES = [18, 40, 24, 12, 36]
MANY_KW = dict(
    num_workers=4, steps_per_round=4, chunk_rounds=2, compact_threshold=0.5
)


def _rec(r):
    return {
        "best_size": int(r.best_size),
        "best_sol": [int(w) for w in np.asarray(r.best_sol, np.uint32)],
        "rounds": int(r.rounds),
        "nodes_expanded": int(r.nodes_expanded),
        "tasks_transferred": int(r.tasks_transferred),
        "transfer_rounds": int(r.transfer_rounds),
        "transfer_bytes_total": int(r.transfer_bytes_total),
        "overflow": bool(r.overflow),
    }


def main():
    golden = {"solo": {}, "fpt": {}, "many": {}}
    for label, gkw, skw in SOLO_CASES:
        g = erdos_renyi(gkw["n"], gkw["p"], gkw["seed"])
        r = E.solve(g, **skw)
        want, _, _ = solve_sequential(g)
        assert r.best_size == want, (label, r.best_size, want)
        golden["solo"][label] = {"graph": gkw, "solve_kw": skw, "result": _rec(r)}

    g = erdos_renyi(24, 0.3, 5)
    opt, _, _ = solve_sequential(g)
    r = E.solve(g, num_workers=4, mode="fpt", k=opt)
    golden["fpt"] = {
        "graph": dict(n=24, p=0.3, seed=5),
        "k": int(opt),
        "result": _rec(r),
    }

    graphs = [erdos_renyi(n, 0.25, 100 + i) for i, n in enumerate(MANY_SIZES)]
    batch = E.solve_many(graphs, **MANY_KW)
    golden["many"] = {
        "sizes": MANY_SIZES,
        "p": 0.25,
        "seed0": 100,
        "solve_kw": MANY_KW,
        "compactions": int(batch.compactions),
        "buckets": [[int(W), int(n_max), list(map(int, idxs))]
                    for W, n_max, idxs in batch.buckets],
        "results": [_rec(r) for r in batch.results],
    }

    with open(OUT, "w") as f:
        json.dump(golden, f, indent=1)
        f.write("\n")
    print(f"wrote {OUT}")


if __name__ == "__main__":
    main()
