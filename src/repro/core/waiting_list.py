"""Equitable-startup waiting lists (paper §3.5, Algorithm 7).

``build_waiting_lists(max_b, p)`` reproduces Algorithm 7 exactly: process
p_i's waiting list receives process q = j·max_b^d + p_i for depth d from
base_d..max_depth and j = 1..max_b-1, recursing into q at depth d+1.  Process
indices are 1-based as in the paper; max_depth = floor(log_max_b p).

The intent (Fig. 3): during startup, each process sends its first max_b - 1
spawned tasks to its waiting list in order, explores the max_b-th task
sequentially, and repeats one level deeper — approximating the equitable
depth-log_b(p) split while remaining fully dynamic afterwards.
"""

from __future__ import annotations

import math


def max_startup_depth(max_b: int, p: int) -> int:
    if p <= 1:
        return -1
    return int(math.floor(math.log(p) / math.log(max_b)))


def build_waiting_lists(max_b: int, p: int) -> dict[int, list[int]]:
    """Exact Algorithm 7.  Returns {process_index: [assignees in send order]}
    with 1-based indices; every process 1..p appears as a key."""
    if max_b < 2:
        raise ValueError("max_b must be >= 2")
    md = max_startup_depth(max_b, p)
    lists: dict[int, list[int]] = {i: [] for i in range(1, p + 1)}

    def build(p_i: int, base_d: int) -> None:
        for d in range(base_d, md + 1):
            for j in range(1, max_b):
                q = j * (max_b**d) + p_i
                if q <= p:
                    lists[p_i].append(q)
                    build(q, d + 1)

    build(1, 0)
    return lists


def startup_assignment(max_b: int, p: int) -> list[int]:
    """Flatten the waiting lists into the order in which the p processes are
    reached during startup (root-first traversal).  Process 1 holds the seed;
    the rest receive their first task from their assigner.  Used by the
    SPMD engine to order the scatter of the startup frontier so that the
    initial distribution matches the paper's intended topology."""
    lists = build_waiting_lists(max_b, p)
    order: list[int] = []
    seen: set[int] = set()

    def visit(i: int) -> None:
        if i in seen:
            return
        seen.add(i)
        order.append(i)
        for q in lists[i]:
            visit(q)

    visit(1)
    # any process unreachable via waiting lists (p not a clean power) goes last
    for i in range(1, p + 1):
        visit(i)
    return order
