"""Paper case study end-to-end: a DIMACS-style hard instance solved by the
semi-centralized, centralized and SPMD engines; reproduces the §4 comparison
(byte counts, failed requests, encoding effect) at laptop scale — all four
backends driven through the ONE public `repro.api.SolverSession` façade.

  PYTHONPATH=src python examples/solve_dimacs.py [n] [density]

Multi-file mode: pass DIMACS files and they are packed onto ONE batched
solve plane (`session.solve_many` — shared executable, per-instance
results); `--problem max_clique` (or mis / vertex_cover) picks the registry
problem:

  PYTHONPATH=src python examples/solve_dimacs.py --files a.col b.col c.col
  PYTHONPATH=src python examples/solve_dimacs.py --problem mis --files a.col

Memory-tier mode (`--spill`): solve an instance whose peak frontier
exceeds a deliberately tiny hot capacity, once WITHOUT spill (tasks
dropped, loud ``overflow_count``) and once WITH the hierarchical frontier
memory (`frontier_spill=True`) — same optimum as an engine-sized run,
zero drops, and the cold-tier traffic printed:

  PYTHONPATH=src python examples/solve_dimacs.py --spill
"""

import sys

sys.path.insert(0, "src")

from repro.api import SolveConfig, SolverSession
from repro.graphs.generators import p_hat_like, parse_dimacs, to_dimacs
from repro.problems.registry import get_problem


def solve_files(paths, problem="vertex_cover"):
    """Pack several DIMACS instances onto one batched solve plane."""
    spec = get_problem(problem)  # ValueError lists known names on a typo
    graphs = []
    for path in paths:
        with open(path) as f:
            graphs.append(parse_dimacs(f.read()))
    session = SolverSession(
        problem=spec, config=SolveConfig(num_workers=8, steps_per_round=16)
    )
    res = session.solve_many(graphs)
    print(f"{len(graphs)} instances [{spec.name}] on one plane, "
          f"{len(res.buckets)} (n,W) bucket(s), {res.wall_s:.2f}s total "
          f"({len(graphs) / max(res.wall_s, 1e-9):.2f} inst/s)")
    for path, g, r in zip(paths, graphs, res.results):
        ok = spec.verify(g, r.best_sol)
        print(f"  {path}: n={g.n} m={g.num_edges} best={r.best_size} "
              f"rounds={r.rounds} nodes={r.nodes_expanded} verified={ok}")


def solve_with_spill():
    """The hierarchical-frontier-memory worked example (README 'Memory
    tiers'): a saturating solve, dropped-vs-spilled, optimum preserved."""
    from repro.graphs.generators import erdos_renyi

    g = erdos_renyi(48, 0.28, seed=0)
    cap = 12  # hot slots per worker — far below this search's peak frontier
    base = SolveConfig(
        num_workers=4, steps_per_round=2, chunk_rounds=2, capacity=cap
    )
    print(f"instance: n={g.n} m={g.num_edges}, hot capacity {cap} slots/worker")

    full = SolverSession(config=base.replace(capacity=None)).solve(g)
    print(f"engine-sized capacity: mvc={full.best_size} ({full.rounds} rounds)")

    starved = SolverSession(config=base).solve(g)
    print(f"capacity={cap}, no spill:  mvc={starved.best_size}  "
          f"DROPPED {starved.stats.overflow_count} tasks "
          f"(overflow={starved.stats.overflow}) — completeness lost")

    spilled = SolverSession(config=base.replace(frontier_spill=True)).solve(g)
    s = spilled.stats
    assert spilled.best_size == full.best_size and s.overflow_count == 0
    print(f"capacity={cap}, --spill:   mvc={spilled.best_size}  dropped 0, "
          f"spilled {s.spilled_tasks} / readmitted {s.readmitted_tasks} "
          f"tasks through a cold tier peaking at {s.cold_bytes_peak}B "
          f"({spilled.rounds} rounds) — optimum preserved")


def main():
    argv = list(sys.argv[1:])
    if argv and argv[0] == "--spill":
        solve_with_spill()
        return
    problem = "vertex_cover"
    if "--problem" in argv:
        i = argv.index("--problem")
        if i + 1 >= len(argv):
            raise SystemExit("error: --problem needs a name (e.g. max_clique)")
        problem = argv[i + 1]
        del argv[i : i + 2]
        try:
            get_problem(problem)
        except ValueError as e:
            raise SystemExit(f"error: {e}")
    if argv and argv[0] == "--files":
        solve_files(argv[1:], problem)
        return
    if problem != "vertex_cover":
        raise SystemExit(
            "the single-instance §4 comparison is vertex-cover only; "
            "use --problem with --files (the batched generic plane)"
        )
    sys.argv = [sys.argv[0]] + argv
    n = int(sys.argv[1]) if len(sys.argv) > 1 else 60
    density = float(sys.argv[2]) if len(sys.argv) > 2 else 0.4
    g = p_hat_like(n, density, seed=0)
    print(f"p_hat-style instance: n={g.n} m={g.num_edges}")
    print(to_dimacs(g).splitlines()[0])

    best = SolverSession(backend="sequential").solve(g)
    print(f"\nsequential: mvc={best.best_size}, {best.nodes_expanded} nodes")

    print(f"\n{'engine':<22}{'codec':<12}{'ticks/rounds':<14}{'bytes':<12}"
          f"{'center B':<10}{'failed':<7}")
    for codec in ("optimized", "basic"):
        cfg = SolveConfig(num_workers=8, codec=codec)
        semi = SolverSession(backend="protocol_sim", config=cfg).solve(g)
        cent = SolverSession(backend="centralized", config=cfg).solve(g)
        assert semi.best_size == cent.best_size == best.best_size
        print(f"{'semi-centralized':<22}{codec:<12}{semi.rounds:<14}"
              f"{semi.stats.total_bytes:<12}{semi.stats.center_bytes:<10}"
              f"{semi.stats.failed_requests:<7}")
        print(f"{'centralized':<22}{codec:<12}{cent.rounds:<14}"
              f"{cent.stats.total_bytes:<12}{'-':<10}{'-':<7}")

    # SPMD engine: both data-plane paths must agree bit-for-bit (the sparse
    # masked-psum path moves only matched records; gather moves the full
    # P-row table — see EXPERIMENTS.md §Perf)
    spmd = {}
    for impl in ("sparse", "gather"):
        session = SolverSession(config=SolveConfig(
            num_workers=8, steps_per_round=16, transfer_impl=impl))
        r = session.solve(g)
        assert r.best_size == best.best_size
        spmd[impl] = r
        print(f"\nSPMD engine [{impl:>6}]: mvc={r.best_size}, "
              f"{r.rounds} supersteps, {r.tasks_transferred} transfers, "
              f"{r.stats.control_bytes_per_round} control B/round, "
              f"{r.stats.transfer_bytes_per_round:.1f} payload B/round")
    a, b = spmd["sparse"], spmd["gather"]
    assert a.best_size == b.best_size and (a.best_sol == b.best_sol).all()
    print("transfer paths bit-identical; sparse payload "
          f"{a.stats.transfer_bytes_total}B vs gather "
          f"{b.stats.transfer_bytes_total}B")

    # batched solve plane: mixed-size instances packed onto one executable,
    # per-instance results bit-identical to solo solves — and the session's
    # compiled-plane cache makes the solo cross-checks warm after the first
    sizes = [n, max(n - 7, 8), max(n - 13, 6), n]
    graphs = [p_hat_like(m, density, seed=s) for s, m in enumerate(sizes)]
    session = SolverSession(config=SolveConfig(num_workers=8, steps_per_round=16))
    batch = session.solve_many(graphs)
    print(f"\nsolve_many over {len(graphs)} mixed-size instances "
          f"(n={sizes}, {len(batch.buckets)} bucket(s)):")
    for g, r in zip(graphs, batch.results):
        solo = session.solve(g)
        assert (r.best_size, r.rounds) == (solo.best_size, solo.rounds)
        assert (r.best_sol == solo.best_sol).all()
        print(f"  n={g.n}: mvc={r.best_size} rounds={r.rounds} "
              f"(== solo solve, bit-identical)")
    print(f"session cache after the cross-checks: {session.cache_stats()}")


if __name__ == "__main__":
    main()
