"""Architecture guard: the core solve plane must stay problem-generic.

The PR-3 refactor extracted the :class:`BranchingProblem` plugin protocol so
no module under ``src/repro/core/`` depends on a concrete problem's device
ops.  This test pins that invariant: the refactor cannot silently regress by
someone re-importing ``repro.problems.vertex_cover`` (or any other concrete
plugin's device module) from core.  Core may import the protocol
(``repro.problems.base``) and the name registry
(``repro.problems.registry``); the host sims (protocol_sim / centralized)
may keep using the sequential REFERENCE module, which predates and is
independent of the device plane.
"""

import ast
import pathlib

CORE = pathlib.Path(__file__).resolve().parent.parent / "src" / "repro" / "core"

# concrete problem plugins core must never import
FORBIDDEN = {
    "repro.problems.vertex_cover",
    "repro.problems.max_clique",
    "repro.problems.mis",
}


def _imports_of(path: pathlib.Path):
    tree = ast.parse(path.read_text())
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for alias in node.names:
                yield alias.name
        elif isinstance(node, ast.ImportFrom) and node.module:
            yield node.module


def test_core_never_imports_a_concrete_problem():
    assert CORE.is_dir(), CORE
    offenders = {}
    for path in sorted(CORE.glob("*.py")):
        bad = [
            mod
            for mod in _imports_of(path)
            if mod in FORBIDDEN
            or any(mod.startswith(f + ".") for f in FORBIDDEN)
        ]
        if bad:
            offenders[path.name] = bad
    assert not offenders, (
        f"core modules import concrete problem plugins: {offenders} — "
        f"route through repro.problems.registry / repro.problems.base instead"
    )


def test_core_resolves_problems_through_the_registry():
    """The engine's defaults come from the registry, not a hardcoded plugin:
    the default-problem constant lives in problems/, and core references it
    by import."""
    from repro.core import engine
    from repro.problems.registry import DEFAULT_PROBLEM, get_problem

    assert engine.DEFAULT_PROBLEM == DEFAULT_PROBLEM
    # and the registry resolves it to a real spec
    assert get_problem(DEFAULT_PROBLEM).name == DEFAULT_PROBLEM


# -- the public API surface ----------------------------------------------------

# The PR-4 redesign made `repro.api` THE public surface.  This snapshot pins
# it: adding or removing a name is a deliberate, reviewed change (update the
# list here AND the README quickstart), never an accidental side effect of a
# refactor.
PUBLIC_API = [
    "BACKENDS",
    "Backend",
    "BatchSolveResult",
    "CacheStats",
    "PlaneCache",
    "SolveConfig",
    "SolveResult",
    "SolverSession",
    "get_backend",
    "known_backends",
    "solve_stream_session",
]


def test_public_api_snapshot():
    import repro.api as api

    assert sorted(api.__all__) == PUBLIC_API, (
        "repro.api.__all__ drifted from the pinned public-API snapshot — "
        "if intentional, update tests/test_arch_guard.py and the README"
    )
    # every advertised name must actually resolve
    for name in api.__all__:
        assert hasattr(api, name), f"repro.api.__all__ lists missing {name!r}"


def test_backend_registry_covers_the_advertised_backends():
    from repro.api import known_backends

    assert known_backends() == [
        "centralized", "protocol_sim", "sequential", "spmd"
    ]
