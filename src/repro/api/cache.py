"""The compiled-plane cache: warm repeat solves reuse executables.

The legacy engine rebuilt (and re-jitted) a chunk executable per ``solve``
call because the builders closed over the instance's ``ProblemData``.  The
parametric builders (:func:`repro.core.superstep.build_plane_fn` /
``build_batch_plane_fn``) take the instance tensors as call-time arguments,
so one jitted function serves every same-shape instance: a serving balancer
replaying the same (problem, W, B) plane all day compiles once.

:class:`PlaneCache` holds those parametric functions keyed by
``(kind, problem, config, pad_words, use_fpt)`` and accounts warm/cold at
SHAPE granularity: a cache *miss* is the first time a shape signature
``(n, W, capacity[, B])`` hits a plane (jax traces + compiles), a *hit* is
every subsequent same-shape call (executable reuse, no tracing).  The
ground-truth compile counter is ``repro.core.superstep.PLANE_TRACES``,
bumped by a host side effect that only runs while jax traces — tests assert
hits never trace.
"""

from __future__ import annotations

import dataclasses

from repro.core import superstep


@dataclasses.dataclass
class CacheStats:
    """Warm/cold accounting for one :class:`PlaneCache`.

    ``misses``/``hits`` count shape-level cold/warm calls; ``planes`` is the
    number of distinct parametric functions built; ``shapes`` the distinct
    shape signatures seen; ``bypasses`` counts solves that skipped the cache
    (currently: mesh-sharded solves, which close over their mesh);
    ``plane_traces`` snapshots the global jax trace counter.
    """

    hits: int = 0
    misses: int = 0
    planes: int = 0
    shapes: int = 0
    bypasses: int = 0
    plane_traces: int = 0

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


class PlaneCache:
    """Parametric compiled planes, keyed by configuration; shared freely.

    A session owns one by default, but a cache may be passed to many
    sessions (and is what the legacy ``engine.solve`` shims share), so
    equal-config callers pool their executables.
    """

    def __init__(self):
        self._planes: dict = {}
        self._shapes: set = set()
        self.hits = 0
        self.misses = 0
        self.bypasses = 0

    # -- plane lookup ----------------------------------------------------------

    @staticmethod
    def _plane_key(kind: str, spec, cfg, pad: int, use_fpt: bool) -> tuple:
        # key on the knobs the executable actually depends on, so configs
        # differing only in host-side knobs (max_rounds, sim latency, ...)
        # share planes
        knobs = (
            cfg.steps_per_round, cfg.lanes, cfg.policy, cfg.packed_status,
            cfg.skip_empty_transfer, cfg.transfer_impl, cfg.explore_impl,
            cfg.donate_k, cfg.chunk_rounds,
        )
        return (kind, spec, knobs, pad, use_fpt)

    def _get(self, kind: str, spec, cfg, pad: int, use_fpt: bool):
        key = self._plane_key(kind, spec, cfg, pad, use_fpt)
        plane = self._planes.get(key)
        if plane is None:
            build = (
                superstep.build_plane_fn
                if kind == "solo"
                else superstep.build_batch_plane_fn
            )
            plane = build(
                spec,
                steps_per_round=cfg.steps_per_round,
                lanes=cfg.lanes,
                policy_priority=cfg.policy_priority,
                transfer_pad_words=pad,
                packed_status=cfg.packed_status,
                skip_empty_transfer=cfg.skip_empty_transfer,
                transfer_impl=cfg.transfer_impl,
                explore_impl=cfg.explore_impl,
                donate_k=cfg.donate_k,
                chunk_rounds=cfg.chunk_rounds,
                use_fpt=use_fpt,
            )
            self._planes[key] = plane
        return plane

    def solo_plane(self, spec, cfg, pad: int, use_fpt: bool):
        """The parametric ``(data, state[, fpt_bound])`` solo runner."""
        return self._get("solo", spec, cfg, pad, use_fpt)

    def batch_plane(self, spec, cfg, pad: int, use_fpt: bool):
        """The parametric ``(datas, state, done[, fpt_bounds])`` runner."""
        return self._get("batch", spec, cfg, pad, use_fpt)

    # -- warm/cold accounting --------------------------------------------------

    def note(
        self, kind: str, spec, cfg, pad: int, use_fpt: bool, shape: tuple
    ) -> bool:
        """Record one plane invocation's full signature (plane key + the
        shape tuple jax specializes on); True if it was warm."""
        key = (self._plane_key(kind, spec, cfg, pad, use_fpt), shape)
        warm = key in self._shapes
        if warm:
            self.hits += 1
        else:
            self.misses += 1
            self._shapes.add(key)
        return warm

    def note_bypass(self) -> None:
        self.bypasses += 1

    def stats(self) -> CacheStats:
        return CacheStats(
            hits=self.hits,
            misses=self.misses,
            planes=len(self._planes),
            shapes=len(self._shapes),
            bypasses=self.bypasses,
            plane_traces=superstep.PLANE_TRACES,
        )
