from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ops import attention_op, blockwise_attention
from repro.kernels.flash_attention.ref import attention_ref

__all__ = [
    "flash_attention",
    "attention_op",
    "blockwise_attention",
    "attention_ref",
]
