"""llama4-scout-17b-a16e [moe] — MoE 16 experts top-1, early fusion.

48L d=5120 40H kv=8 d_ff=8192(expert) vocab=202048.
[hf:meta-llama/Llama-4-Scout-17B-16E]
"""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llama4-scout-17b-a16e",
        family="moe",
        n_layers=48,
        d_model=5120,
        n_heads=40,
        n_kv_heads=8,
        d_head=128,
        d_ff=8192,
        vocab=202_048,
        n_experts=16,
        top_k=1,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="llama4-smoke",
        family="moe",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=96,
        vocab=512,
        n_experts=4,
        top_k=1,
        dtype="float32",
    )
