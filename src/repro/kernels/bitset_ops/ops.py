"""Jit'd public wrappers for the bitset kernels, with backend-aware dispatch.

``degrees_op`` / ``expand_stats_op`` dispatch to the Pallas kernels and fall
back to the jnp oracle for shapes the kernel does not tile well (tiny T).

Kernel mode is resolved ONCE per process by :func:`default_interpret`:
**native** Mosaic lowering on a TPU runtime, **interpret** everywhere else —
the Pallas interpreter is a correctness harness, not a fast path, so it is
never chosen implicitly off-TPU for hot-path work (``degrees_auto`` /
``expand_stats_auto`` below go straight to the jnp oracle there, which XLA
fuses well on CPU/GPU).  The environment variable ``REPRO_PALLAS_INTERPRET``
(``0``/``1``) overrides the detection for debugging either direction.
"""

from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.kernels.bitset_ops.kernel import (
    batched_degrees,
    batched_expand_stats,
    default_interpret,
    kernels_native,
)
from repro.kernels.bitset_ops.ref import batched_degrees_ref, expand_stats_ref


def degrees_op(
    adj: jnp.ndarray,
    masks: jnp.ndarray,
    *,
    use_kernel: bool = True,
    block_tasks: int = 8,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """(n, W) adj × (T, W) masks -> (T, n) induced-subgraph degrees.

    ``interpret=None`` resolves via :func:`default_interpret` (native on
    TPU, interpret elsewhere); pass an explicit bool to pin a mode.
    """
    if not use_kernel or masks.shape[0] < 2:
        return batched_degrees_ref(adj, masks)
    if interpret is None:
        interpret = default_interpret()
    return batched_degrees(
        adj, masks, block_tasks=block_tasks, interpret=interpret
    )


def expand_stats_op(
    adj: jnp.ndarray,
    masks: jnp.ndarray,
    sols: jnp.ndarray,
    *,
    use_kernel: bool = True,
    block_tasks: int = 8,
    interpret: Optional[bool] = None,
):
    """Fused expand panel: -> (deg (T, n) int32, pc_mask (T,), pc_sol (T,)).

    One pass over the packed words yields the degrees panel plus both
    per-task popcounts — everything a fused ``expand_tasks`` needs for
    bound / pivot / child-prune.  Kernel-backed when worthwhile, jnp oracle
    otherwise; results are bit-identical either way (tests assert it).
    """
    if not use_kernel or masks.shape[0] < 2:
        return expand_stats_ref(adj, masks, sols)
    if interpret is None:
        interpret = default_interpret()
    deg, pc = batched_expand_stats(
        adj, masks, sols, block_tasks=block_tasks, interpret=interpret
    )
    return deg, pc[:, 0], pc[:, 1]


# -- hot-path auto dispatch ----------------------------------------------------
#
# The fused exploration plane calls these from inside jitted supersteps; the
# kernel is only a win when it lowers natively, so off-TPU they go straight
# to the jnp oracle (bit-identical values, XLA-fused) instead of paying the
# Pallas interpreter.


def degrees_auto(adj: jnp.ndarray, masks: jnp.ndarray) -> jnp.ndarray:
    """Batched degrees for the fused plane: native kernel on TPU, jnp
    oracle elsewhere — same values bit-for-bit."""
    if kernels_native() and masks.shape[0] >= 2:
        return batched_degrees(adj, masks, interpret=False)
    return batched_degrees_ref(adj, masks)


def expand_stats_auto(adj: jnp.ndarray, masks: jnp.ndarray, sols: jnp.ndarray):
    """Fused expand panel for the fused plane: native kernel on TPU, jnp
    oracle elsewhere — same values bit-for-bit."""
    if kernels_native() and masks.shape[0] >= 2:
        deg, pc = batched_expand_stats(adj, masks, sols, interpret=False)
        return deg, pc[:, 0], pc[:, 1]
    return expand_stats_ref(adj, masks, sols)


def max_degree_vertex(adj, masks, **kw):
    deg = degrees_op(adj, masks, **kw)
    return jnp.argmax(deg, axis=1).astype(jnp.int32), deg.max(axis=1)
