"""The SPMD superstep engine vs the sequential ground truth (+ elasticity)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core import engine as E
from repro.core.superstep import build_superstep_fn, make_worker_state
from repro.graphs.bitgraph import n_words
from repro.graphs.generators import erdos_renyi
from repro.problems.base import make_data
from repro.problems.registry import get_problem
from repro.problems.sequential import solve_sequential, verify_cover

VC = get_problem("vertex_cover")


@pytest.mark.parametrize("policy", [True, False])
@pytest.mark.parametrize("codec", ["optimized", "basic"])
def test_matches_sequential(policy, codec):
    g = erdos_renyi(40, 0.28, 0)
    want, _, _ = solve_sequential(g)
    r = E.solve(
        g, num_workers=6, steps_per_round=8,
        policy_priority=policy, codec=codec,
    )
    assert r.best_size == want
    assert verify_cover(g, r.best_sol)
    assert not r.overflow


def test_lanes():
    g = erdos_renyi(44, 0.25, 4)
    want, _, _ = solve_sequential(g)
    r = E.solve(g, num_workers=4, steps_per_round=4, lanes=4)
    assert r.best_size == want
    assert not r.overflow


def test_fpt_mode():
    g = erdos_renyi(34, 0.3, 9)
    opt, _, _ = solve_sequential(g)
    r = E.solve(g, num_workers=4, mode="fpt", k=opt)
    assert r.best_size != -1 and r.best_size <= opt


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 10_000))
def test_random_graphs_property(seed):
    g = erdos_renyi(30, 0.22, seed)
    want, _, _ = solve_sequential(g)
    r = E.solve(g, num_workers=5, steps_per_round=8)
    assert r.best_size == want
    assert not r.overflow


def test_snapshot_restore_resize():
    """Fault tolerance: checkpoint mid-run, restart on a DIFFERENT worker
    count, still optimal (elastic re-meshing of the frontier)."""
    g = erdos_renyi(46, 0.25, 2)
    want, _, _ = solve_sequential(g)
    W = n_words(g.n)
    cap = 4 * g.n + 8
    state = jax.vmap(lambda _: make_worker_state(cap, W, g.n + 1))(jnp.arange(8))
    state = E._scatter_startup(state, VC, g, 8)
    data = make_data(VC, g)
    fn = build_superstep_fn(VC, data, num_workers=8, steps_per_round=4, lanes=1)
    for _ in range(3):
        state, done = fn(state)
    snap = E.snapshot(state)  # "node failure" here
    resized = E.resize(E.restore(snap), 5)
    r = E.solve(g, num_workers=5, steps_per_round=8, initial_state=resized)
    assert r.best_size == want


def test_transfer_accounting():
    g = erdos_renyi(40, 0.28, 0)
    W = n_words(g.n)
    rec_opt = 2 * W + 1
    rec_bas = (g.n + 2) * W + 1
    # gather: every transfer round moves the full P-row record table
    r_opt = E.solve(g, num_workers=4, codec="optimized", transfer_impl="gather")
    r_bas = E.solve(g, num_workers=4, codec="basic", transfer_impl="gather")
    assert r_opt.transfer_bytes_total == 4 * rec_opt * 4 * r_opt.transfer_rounds
    assert r_bas.transfer_bytes_total == 4 * rec_bas * 4 * r_bas.transfer_rounds
    # sparse: payload == exactly the records that matched (paper: the donated
    # task is the sole payload), regardless of P
    r_sp = E.solve(g, num_workers=4, codec="optimized", transfer_impl="sparse")
    assert r_sp.transfer_bytes_total == 4 * rec_opt * r_sp.tasks_transferred
    assert r_sp.transfer_bytes_total < r_opt.transfer_bytes_total
    # rounds that ran no transfer move zero payload on either path
    assert r_sp.transfer_rounds <= r_sp.rounds
    # the paper's point: control plane is O(P) integers regardless of codec —
    # ONE packed i32 per worker by default, three with packed_status=False
    assert r_opt.control_bytes_per_round == r_bas.control_bytes_per_round == 16
    r_unpacked = E.solve(g, num_workers=4, packed_status=False)
    assert r_unpacked.control_bytes_per_round == 48


def test_chunked_loop_matches_per_round():
    """K supersteps per host sync must be bit-identical to per-round syncs."""
    g = erdos_renyi(40, 0.28, 0)
    want, _, _ = solve_sequential(g)
    r1 = E.solve(g, num_workers=6, steps_per_round=8, chunk_rounds=1)
    rk = E.solve(g, num_workers=6, steps_per_round=8, chunk_rounds=32)
    assert r1.best_size == rk.best_size == want
    assert (r1.best_sol == rk.best_sol).all()
    assert r1.rounds == rk.rounds
    assert r1.nodes_expanded == rk.nodes_expanded


def test_multi_task_donation():
    g = erdos_renyi(44, 0.25, 4)
    want, _, _ = solve_sequential(g)
    r1 = E.solve(g, num_workers=8, steps_per_round=4, donate_k=1)
    r4 = E.solve(g, num_workers=8, steps_per_round=4, donate_k=4)
    assert r1.best_size == r4.best_size == want
    assert not r4.overflow
    # single-task donation ships exactly one record per match...
    assert r1.tasks_transferred >= r1.transfer_rounds
    # ...while k=4 actually exploits the batch (deep donors ship > 1/match)
    assert r4.tasks_transferred > r4.transfer_rounds
    assert (
        r4.tasks_transferred / max(r4.transfer_rounds, 1)
        > r1.tasks_transferred / max(r1.transfer_rounds, 1)
    )


def test_scatter_startup_overflow_uses_waiting_list_order():
    """Regression: overflow tasks (i >= P when BFS over-expands) must follow
    the same Algorithm-7 permutation as the first P, not raw i mod P."""
    from repro.core.waiting_list import startup_assignment
    from repro.problems.sequential import expand_frontier

    g = erdos_renyi(40, 0.28, 0)
    P = 6
    W = n_words(g.n)
    tasks = expand_frontier(g, num_tasks=2 * P + 3)  # BFS over-expansion
    assert len(tasks) > P
    state = jax.vmap(lambda _: make_worker_state(40, W, g.n + 1))(jnp.arange(P))
    placed = E._scatter_startup(state, VC, g, P, tasks=tasks)
    order = startup_assignment(max_b=2, p=P)
    want_counts = np.zeros(P, np.int64)
    for i in range(len(tasks)):
        want_counts[order[i % P] - 1] += 1
    active = np.asarray(placed.frontier.active)
    got_counts = active.sum(axis=1)
    assert (got_counts == want_counts).all()
    # every BFS task landed somewhere, none lost or duplicated
    placed_recs = sorted(
        np.asarray(placed.frontier.masks)[w, s].tobytes()
        + np.asarray(placed.frontier.sols)[w, s].tobytes()
        for w in range(P)
        for s in range(active.shape[1])
        if active[w, s]
    )
    want_recs = sorted(m.tobytes() + s.tobytes() for m, s, _ in tasks)
    assert placed_recs == want_recs
