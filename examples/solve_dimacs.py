"""Paper case study end-to-end: a DIMACS-style hard instance solved by the
semi-centralized, centralized and SPMD engines; reproduces the §4 comparison
(byte counts, failed requests, encoding effect) at laptop scale.

  PYTHONPATH=src python examples/solve_dimacs.py [n] [density]
"""

import sys

sys.path.insert(0, "src")

from repro.core.centralized import run_centralized_sim
from repro.core.engine import solve
from repro.core.protocol_sim import run_protocol_sim
from repro.graphs.generators import p_hat_like, to_dimacs
from repro.problems.sequential import solve_sequential


def main():
    n = int(sys.argv[1]) if len(sys.argv) > 1 else 60
    density = float(sys.argv[2]) if len(sys.argv) > 2 else 0.4
    g = p_hat_like(n, density, seed=0)
    print(f"p_hat-style instance: n={g.n} m={g.num_edges}")
    print(to_dimacs(g).splitlines()[0])

    best, _, st = solve_sequential(g)
    print(f"\nsequential: mvc={best}, {st.nodes} nodes")

    print(f"\n{'engine':<22}{'codec':<12}{'ticks/rounds':<14}{'bytes':<12}"
          f"{'center B':<10}{'failed':<7}")
    for codec in ("optimized", "basic"):
        semi = run_protocol_sim(g, num_workers=8, codec_name=codec)
        cent = run_centralized_sim(g, num_workers=8, codec_name=codec)
        assert semi.best_size == cent.best_size == best
        print(f"{'semi-centralized':<22}{codec:<12}{semi.ticks:<14}"
              f"{semi.stats.total_bytes:<12}{semi.stats.center_bytes:<10}"
              f"{semi.stats.failed_requests:<7}")
        print(f"{'centralized':<22}{codec:<12}{cent.ticks:<14}"
              f"{cent.stats.total_bytes:<12}{'-':<10}{'-':<7}")

    # SPMD engine: both data-plane paths must agree bit-for-bit (the sparse
    # masked-psum path moves only matched records; gather moves the full
    # P-row table — see EXPERIMENTS.md §Perf)
    spmd = {}
    for impl in ("sparse", "gather"):
        r = solve(g, num_workers=8, steps_per_round=16, transfer_impl=impl)
        assert r.best_size == best
        spmd[impl] = r
        print(f"\nSPMD engine [{impl:>6}]: mvc={r.best_size}, "
              f"{r.rounds} supersteps, {r.tasks_transferred} transfers, "
              f"{r.control_bytes_per_round} control B/round, "
              f"{r.transfer_bytes_per_round:.1f} payload B/round")
    a, b = spmd["sparse"], spmd["gather"]
    assert a.best_size == b.best_size and (a.best_sol == b.best_sol).all()
    print("transfer paths bit-identical; sparse payload "
          f"{a.transfer_bytes_total}B vs gather {b.transfer_bytes_total}B")


if __name__ == "__main__":
    main()
