"""Fully-centralized scheduler baseline (paper §4.2, after Abu-Khzam 2006).

The paper implements this strategy itself to compare against; we do the same.
A central process RECEIVES tasks from workers and REDISTRIBUTES them — every
task crosses the wire twice, which is why the basic (adjacency) encoding
collapses in Table 1.  Mechanics reproduced from §4.2:

* center holds a size-priority queue of tasks, capped at ``queue_cap_per_p·p``
  tasks (paper: 1000·p) or a byte budget (paper: 10 GB);
* workers push their highest-priority pending task to center whenever center
  is `not full` (workers track center fullness via broadcast flags);
* center sends the largest-instance task to each AVAILABLE worker;
* `full` is broadcast when the cap is hit, `not full` when it drains below
  90% (hysteresis — prevents flag thrash);
* termination: all workers AVAILABLE and queue empty.

The same discrete-event network as :mod:`repro.core.protocol_sim` is used so
byte/message statistics are directly comparable.
"""

from __future__ import annotations

import dataclasses
import heapq
from typing import Optional

import numpy as np

from repro.core.encoding import Task, make_codec
from repro.core.protocol_sim import SimResult, SimStats, _Network
from repro.core.task_tree import TaskTree
from repro.graphs.bitgraph import BitGraph, mask_full, popcount_rows
from repro.problems import base as problems_base
from repro.problems.registry import DEFAULT_PROBLEM, get_problem

CENTER = 0


class _CWorker:
    """Worker under the centralized scheme: explores, ships tasks to center.

    Like :class:`repro.core.protocol_sim._Worker`, branching/bounding go
    through the problem's host callables (internal minimization sense), so
    the baseline runs any registry problem with host plumbing."""

    def __init__(
        self, wid: int, g: BitGraph, net: _Network, stats: SimStats,
        problem: problems_base.BranchingProblem, initial_best: int,
    ):
        self.wid = wid
        self.g = g
        self.net = net
        self.stats = stats
        self.problem = problem
        self.tree = TaskTree()
        self.stack: list[list] = []
        self.local_best = initial_best
        self.local_best_sol: Optional[np.ndarray] = None
        self.global_best_seen = initial_best
        self.center_full = False
        self.announced_available = False

    def is_idle(self) -> bool:
        return not self.stack and self.tree.is_empty()

    def bound(self) -> int:
        return min(self.local_best, self.global_best_seen)

    def update_ipc(self, now: int) -> None:
        for m in self.net.deliver(self.wid, now):
            if m.tag == "bestval_update":
                if m.data < self.global_best_seen:
                    self.global_best_seen = m.data
            elif m.tag == "full":
                self.center_full = True
            elif m.tag == "not_full":
                self.center_full = False
            elif m.tag == "work":
                task: Task = m.data
                self._start_task(task)
                self.announced_available = False

    def _start_task(self, task: Task) -> None:
        assert self.is_idle()
        self.tree = TaskTree()
        self.tree.set_root(task, depth=task.depth)
        self.stack = [[task, None, 0]]

    def explore_step(self, now: int) -> None:
        if not self.stack:
            return
        frame = self.stack[-1]
        task, children, idx = frame
        if children is None:
            self.stats.nodes_expanded += 1
            spec = self.problem
            if spec.host_task_bound(self.g, task.mask, task.sol_mask) >= self.bound():
                self._finish(task)
                return
            kids, terminal = spec.branch_once_host(self.g, task.mask, task.sol_mask)
            if terminal is not None:
                tval = int(spec.host_terminal_value(self.g, terminal[0], terminal[1]))
                if tval < self.bound():
                    self.local_best = tval
                    self.local_best_sol = terminal[1]
                    self.net.send(self.wid, CENTER, "bestval_update", tval, now)
                self._finish(task)
                return
            child_tasks = [
                Task(mask=c[0], sol_mask=c[1], depth=task.depth + 1) for c in kids
            ]
            self.tree.register_child_instances(child_tasks, task)
            frame[1], frame[2] = child_tasks, 0
            return
        if idx < len(children):
            frame[2] += 1
            child = children[idx]
            if self.tree.try_claim(child):
                self.stack.append([child, None, 0])
            return
        self._finish(task)

    def _finish(self, task: Task) -> None:
        self.tree.finish(task)
        self.stack.pop()

    def offload_to_center(self, now: int) -> None:
        """§4.2: each time a child is registered and center is not full, the
        worker ships its highest-priority pending task to center."""
        if self.center_full:
            return
        payload = self.tree.pop_highest_priority()
        if payload is not None:
            self.net.send(self.wid, CENTER, "task_upload", payload, now)
            # every task crosses the wire AT FULL RECORD SIZE (tag 'work…'
            # so stats count codec bytes — this is the 2x cost of the design)
            self.stats.msg_bytes["task_upload"] += self.net.codec.record_bytes - 4
            self.stats.tasks_transferred += 1

    def maybe_announce(self, now: int) -> None:
        if self.is_idle() and not self.announced_available:
            self.net.send(self.wid, CENTER, "available", self.wid, now)
            self.announced_available = True


def run_centralized_sim(
    g: BitGraph,
    num_workers: int,
    latency: int = 1,
    codec_name: str = "optimized",
    queue_cap_per_p: int = 1000,
    use_priority_queue: bool = True,
    max_ticks: int = 2_000_000,
    mode: str = "bnb",
    k: Optional[int] = None,
    problem=DEFAULT_PROBLEM,
) -> SimResult:
    spec = problems_base.require_host_bounds(get_problem(problem))
    view = spec.host_view(g)
    initial = problems_base.initial_bound(spec, view, mode, k)
    stats = SimStats()
    codec = make_codec(codec_name, view.n, problem=spec)
    net = _Network(latency=latency, stats=stats, codec=codec)
    workers = {
        i: _CWorker(i, view, net, stats, spec, initial)
        for i in range(1, num_workers + 1)
    }

    # center state
    queue: list = []  # heap of (-instance_size, seq, Task) | FIFO list
    seq = 0
    best_val = initial
    status_available: set[int] = set()
    full = False
    cap = queue_cap_per_p * num_workers

    # startup: original instance to worker 1 (§4.2)
    seed = Task(
        mask=mask_full(view.n), sol_mask=np.zeros(view.W, np.uint32), depth=0
    )
    workers[1]._start_task(seed)

    now = 0
    while now < max_ticks:
        now += 1
        # ---- center loop ----
        for m in net.deliver(CENTER, now):
            if m.tag == "bestval_update":
                if m.data < best_val:
                    best_val = m.data
                    for wid in workers:
                        net.send(CENTER, wid, "bestval_update", best_val, now)
            elif m.tag == "available":
                status_available.add(m.src)
            elif m.tag == "task_upload":
                task: Task = m.data
                # prune on arrival against the current bound (cheap birth
                # bound — the problem's host_child_bound)
                if spec.host_child_bound(view, task.mask, task.sol_mask) < best_val:
                    seq += 1
                    size = int(popcount_rows(task.mask))
                    if use_priority_queue:
                        heapq.heappush(queue, (-size, seq, task))
                    else:
                        queue.append((0, seq, task))
        # dispatch: largest-instance task to each AVAILABLE worker
        while queue and status_available:
            wid = min(status_available)
            status_available.discard(wid)
            if use_priority_queue:
                _, _, task = heapq.heappop(queue)
            else:
                _, _, task = queue.pop(0)
            net.send(CENTER, wid, "work", task, now)
        # fullness hysteresis (90% threshold, §4.2)
        if not full and len(queue) >= cap:
            full = True
            for wid in workers:
                net.send(CENTER, wid, "full", None, now)
        elif full and len(queue) <= 0.9 * cap:
            full = False
            for wid in workers:
                net.send(CENTER, wid, "not_full", None, now)

        # ---- termination: all available + queue empty + nothing in flight ----
        if (
            len(status_available) == num_workers
            and not queue
            and net.in_flight() == 0
        ):
            break

        # ---- fpt early stop: the internal decision target was reached ----
        if mode == "fpt" and best_val <= spec.fpt_target(k):
            break

        # ---- workers ----
        for wid, wk in workers.items():
            wk.update_ipc(now)
            wk.explore_step(now)
            wk.offload_to_center(now)
            wk.maybe_announce(now)

    stats.ticks = now
    internal_best = initial
    best_sol = None
    for wk in workers.values():
        if wk.local_best < internal_best:
            internal_best = wk.local_best
            best_sol = wk.local_best_sol
    found = internal_best < initial
    best_size = int(spec.external_value(internal_best))
    if not found:
        best_sol = None
        if mode == "fpt":
            best_size = -1
    return SimResult(best_size, best_sol, stats, now)
