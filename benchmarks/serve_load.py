"""Continuous-admission serving vs fixed batching under a sustained stream.

Two measurements over the same heterogeneous request trace (alternating
easy/hard instances, one (problem, W) plane):

* **throughput** — requests stream through (a) the continuous
  :class:`~repro.api.SolveService` (freed lanes re-admit immediately) and
  (b) the fixed-batch ``SolverSession.submit``/``poll`` baseline (a plane
  launches only when ``batch_size`` requests queue, and every lane waits
  for the batch's straggler).  Both paths run WARM (planes pre-compiled on
  the same shapes) so the ratio is pure admission efficiency — steady-state
  instances/sec, not compile time.
* **latency** — a Poisson arrival stream at ~70% of the measured
  continuous throughput through :class:`~repro.api.AsyncSolveService`;
  reports end-to-end p50/p99 (submit -> result), the EXPERIMENTS.md §G
  numbers.

``run(smoke=True)`` is in the CI bench-smoke set and GATES the ratio:
continuous admission must clear ``MIN_CONTINUOUS_SPEEDUP`` x the
fixed-batch throughput (measured headroom ~1.4-1.5x on CPU CI sizes).
"""

from __future__ import annotations

import asyncio
import time

import numpy as np

from repro.api import AsyncSolveService, SolveConfig, SolverSession, SolveService
from repro.graphs.generators import erdos_renyi

# acceptance bar (ISSUE 6): continuous admission >= 1.2x fixed-batch
# steady-state throughput on the mixed easy/hard stream.
MIN_CONTINUOUS_SPEEDUP = 1.2

PROBLEM = "max_clique"


def _trace(requests: int, n_easy: int, n_hard: int, seed: int) -> list:
    """Alternating easy/hard instances: the workload where lanes freed by
    easy instances idle under fixed batching until the batch's hard
    straggler finishes."""
    return [
        erdos_renyi(n_easy if i % 2 == 0 else n_hard, 0.5, seed=seed + i)
        for i in range(requests)
    ]


def _throughput(gs, cfg) -> dict:
    # continuous: submit-as-they-arrive, lanes re-admit as they free
    svc = SolveService(PROBLEM, cfg)
    for g in gs[: cfg.service_lanes * 2]:  # warm the plane (compile off-clock)
        svc.submit(g)
    svc.drain()
    t0 = time.perf_counter()
    tickets = []
    for g in gs:
        tickets.append(svc.submit(g))
        svc.step()
    svc.drain()
    cont_s = time.perf_counter() - t0
    results = [svc.result(t) for t in tickets]

    # fixed-batch baseline: arrival-order batches via submit/poll, the
    # pre-continuous solve_stream admission
    sess = SolverSession(problem=PROBLEM, config=cfg)
    for g in gs[: cfg.batch_size * 2]:
        sess.submit(g)
    sess.flush()
    t0 = time.perf_counter()
    fixed_tickets = []
    for g in gs:
        fixed_tickets.append(sess.submit(g))
        sess.poll()
    sess.flush()
    fixed_s = time.perf_counter() - t0
    fixed_results = [sess.result(t) for t in fixed_tickets]

    # both paths are the same compiled superstep math: identical answers
    for a, b in zip(results, fixed_results):
        assert a.best_size == b.best_size, (a.best_size, b.best_size)

    return {
        "continuous_inst_per_s": len(gs) / cont_s,
        "fixed_inst_per_s": len(gs) / fixed_s,
        "continuous_speedup": fixed_s / cont_s,
        "occupancy": svc.stats()["occupancy"],
        "overflow_counts": [r.stats.overflow_count for r in results],
    }


async def _latency_run(gs, cfg, rate: float) -> list:
    service = SolveService(PROBLEM, cfg)
    rng = np.random.default_rng(7)
    gaps = rng.exponential(1.0 / rate, len(gs))
    latencies = []

    async def one(delay_s, g):
        await asyncio.sleep(delay_s)
        t0 = time.perf_counter()
        await svc.solve(g)
        latencies.append(time.perf_counter() - t0)

    arrivals = np.cumsum(gaps)
    async with AsyncSolveService(service) as svc:
        await asyncio.gather(*(one(a, g) for a, g in zip(arrivals, gs)))
    return latencies


def run(smoke: bool = False) -> dict:
    requests, n_easy, n_hard = (24, 12, 30) if smoke else (48, 14, 34)
    cfg = SolveConfig(
        num_workers=4,
        steps_per_round=8,
        chunk_rounds=2,
        batch_size=4,
        service_lanes=4,
    )
    gs = _trace(requests, n_easy, n_hard, seed=100)

    tp = _throughput(gs, cfg)
    # Poisson arrivals at ~70% of measured continuous capacity: a loaded
    # but not saturated service — the latency regime EXPERIMENTS.md §G pins
    rate = 0.7 * tp["continuous_inst_per_s"]
    lat = np.array(asyncio.run(_latency_run(gs, cfg, rate)))
    p50, p99 = float(np.percentile(lat, 50)), float(np.percentile(lat, 99))

    print(
        f"{requests} requests (n {n_easy}/{n_hard} alternating), "
        f"{cfg.service_lanes} lanes:"
    )
    print(
        f"continuous {tp['continuous_inst_per_s']:8.1f} inst/s "
        f"(occupancy {tp['occupancy']:.2f})"
    )
    print(
        f"fixed      {tp['fixed_inst_per_s']:8.1f} inst/s   "
        f"-> {tp['continuous_speedup']:.2f}x continuous"
    )
    print(
        f"latency @ {rate:.1f} req/s Poisson: "
        f"p50 {p50*1e3:.0f}ms  p99 {p99*1e3:.0f}ms"
    )

    if smoke:  # the CI gate; full-size local runs just report
        assert tp["continuous_speedup"] >= MIN_CONTINUOUS_SPEEDUP, (
            f"continuous admission regressed: only "
            f"{tp['continuous_speedup']:.2f}x the fixed-batch throughput "
            f"(< {MIN_CONTINUOUS_SPEEDUP}x; benchmark-gated CI)"
        )
    assert all(c == 0 for c in tp["overflow_counts"]), tp["overflow_counts"]

    return {
        "problem": PROBLEM,
        "requests": requests,
        "n_easy": n_easy,
        "n_hard": n_hard,
        "service_lanes": cfg.service_lanes,
        "continuous_inst_per_s": round(tp["continuous_inst_per_s"], 1),
        "fixed_inst_per_s": round(tp["fixed_inst_per_s"], 1),
        "continuous_speedup": round(tp["continuous_speedup"], 2),
        "occupancy": round(tp["occupancy"], 3),
        "poisson_rate_per_s": round(rate, 1),
        "latency_p50_ms": round(p50 * 1e3, 1),
        "latency_p99_ms": round(p99 * 1e3, 1),
    }


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()
    run(smoke=args.smoke)
