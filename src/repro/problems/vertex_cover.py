"""Device-side (jnp) vertex-cover branching ops on packed bitsets.

This is the jit/vmap-compatible twin of :mod:`repro.problems.sequential`.
Every function operates on tasks in the paper's *optimized encoding* (§4.3):
packed ``uint32[W]`` masks over the ORIGINAL vertex set; the adjacency bitset
``adj (n, W)`` is loaded once per worker and never re-serialized.

All control flow is `jax.lax` (while_loop / select) so the ops compose into
the SPMD superstep engine (`repro.core.superstep`) and into the Pallas
bitset kernels (`repro.kernels.bitset_ops`, which accelerates `degrees`).
Semantics match the host reference exactly (tests assert equality), with one
deliberate exception: rule application order inside `reduce_instance` may pick
a different (equally valid) vertex — both preserve at least one optimal
cover, so terminal best values are identical.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

WORD_BITS = 32


class VCProblem(NamedTuple):
    """Static per-instance device data (replicated on every worker)."""

    n: jnp.ndarray  # () int32 -- number of vertices
    adj: jnp.ndarray  # (n, W) uint32 packed adjacency
    word_idx: jnp.ndarray  # (n,) int32 -- v // 32
    bit_idx: jnp.ndarray  # (n,) uint32 -- v % 32


def make_problem(adj, n: int) -> VCProblem:
    v = jnp.arange(adj.shape[0], dtype=jnp.int32)
    return VCProblem(
        n=jnp.int32(n),
        adj=jnp.asarray(adj, dtype=jnp.uint32),
        word_idx=v // WORD_BITS,
        bit_idx=(v % WORD_BITS).astype(jnp.uint32),
    )


# -- packed-bitset primitives -------------------------------------------------


def popcount(words: jnp.ndarray) -> jnp.ndarray:
    """Popcount summed over the trailing word axis -> int32."""
    return jax.lax.population_count(words).astype(jnp.int32).sum(axis=-1)


def unpack_bits(words: jnp.ndarray, n: int) -> jnp.ndarray:
    """(..., W) uint32 -> (..., n) bool."""
    shifts = jnp.arange(WORD_BITS, dtype=jnp.uint32)
    bits = (words[..., :, None] >> shifts) & jnp.uint32(1)
    return bits.reshape(*words.shape[:-1], -1)[..., :n].astype(bool)


def pack_bits(bits: jnp.ndarray, W: int) -> jnp.ndarray:
    """(..., n) bool -> (..., W) uint32 (LSB-first)."""
    n = bits.shape[-1]
    pad = W * WORD_BITS - n
    if pad:
        bits = jnp.concatenate(
            [bits, jnp.zeros((*bits.shape[:-1], pad), dtype=bool)], axis=-1
        )
    b = bits.reshape(*bits.shape[:-1], W, WORD_BITS).astype(jnp.uint32)
    weights = jnp.uint32(1) << jnp.arange(WORD_BITS, dtype=jnp.uint32)
    return (b * weights).sum(axis=-1).astype(jnp.uint32)


def single_bit(v: jnp.ndarray, W: int) -> jnp.ndarray:
    """Packed mask with only bit ``v`` set (v: () int32)."""
    word = v // WORD_BITS
    bit = (v % WORD_BITS).astype(jnp.uint32)
    return jnp.where(
        jnp.arange(W) == word, jnp.uint32(1) << bit, jnp.uint32(0)
    ).astype(jnp.uint32)


def in_mask(problem: VCProblem, mask: jnp.ndarray) -> jnp.ndarray:
    """(n,) bool: vertex v inside the packed mask."""
    return ((mask[problem.word_idx] >> problem.bit_idx) & 1).astype(bool)


def degrees(problem: VCProblem, mask: jnp.ndarray) -> jnp.ndarray:
    """Induced-subgraph degrees; -1 outside the mask.  (n,) int32.

    This is the B&B hot spot the Pallas kernel accelerates (one AND + popcount
    per adjacency row per task).
    """
    deg = popcount(problem.adj & mask[None, :])
    return jnp.where(in_mask(problem, mask), deg, jnp.int32(-1))


def edge_count(deg: jnp.ndarray) -> jnp.ndarray:
    return jnp.maximum(deg, 0).sum() // 2


def lower_bound(deg: jnp.ndarray) -> jnp.ndarray:
    """ceil(E / maxdeg): each cover vertex covers at most maxdeg edges."""
    maxdeg = jnp.maximum(deg.max(), 0)
    E = edge_count(deg)
    return jnp.where(maxdeg > 0, -(-E // jnp.maximum(maxdeg, 1)), 0).astype(jnp.int32)


# -- reduction rules (paper §4.1, Chen-Kanj-Jia) -------------------------------


def _first_vertex(cond: jnp.ndarray, n_total: int) -> jnp.ndarray:
    """Lowest vertex index satisfying ``cond``; n_total if none."""
    idx = jnp.where(cond, jnp.arange(n_total, dtype=jnp.int32), jnp.int32(n_total))
    return idx.min()


def _reduce_step(problem: VCProblem, mask, sol_mask):
    """One reduction sweep.  Returns (mask, sol_mask, changed)."""
    n_total, W = problem.adj.shape
    deg = degrees(problem, mask)
    inside = deg >= 0

    # Rule 1: drop all isolated vertices at once (removals never conflict).
    iso = inside & (deg == 0)
    any_iso = iso.any()
    mask_r1 = mask & ~pack_bits(iso, W)

    # Rule 2: one degree-1 vertex per sweep (batching could over-add on
    # isolated edges where both endpoints have degree 1).
    u2 = _first_vertex(inside & (deg == 1), n_total)
    has_u2 = u2 < n_total
    u2c = jnp.minimum(u2, n_total - 1)
    nb2 = problem.adj[u2c] & mask
    sol_r2 = sol_mask | nb2
    mask_r2 = mask & ~(nb2 | single_bit(u2c, W))

    # Rule 3: first degree-2 vertex whose two neighbours are adjacent.
    nb_all = problem.adj & mask[None, :]  # (n, W)
    bits = unpack_bits(nb_all, n_total)  # (n, n) neighbour booleans
    vidx = jnp.arange(n_total, dtype=jnp.int32)
    first_nb = jnp.where(bits, vidx[None, :], n_total).min(axis=1)
    last_nb = jnp.where(bits, vidx[None, :], -1).max(axis=1)
    fc = jnp.clip(first_nb, 0, n_total - 1)
    lc = jnp.clip(last_nb, 0, n_total - 1)
    vw_edge = bits[fc, lc]  # adj is symmetric: v's row has bit w
    cand3 = inside & (deg == 2) & vw_edge
    u3 = _first_vertex(cand3, n_total)
    has_u3 = u3 < n_total
    u3c = jnp.minimum(u3, n_total - 1)
    nb3 = problem.adj[u3c] & mask
    sol_r3 = sol_mask | nb3
    mask_r3 = mask & ~(nb3 | single_bit(u3c, W))

    # Priority: rule 1 > rule 2 > rule 3 (mirrors the host reference).
    new_mask = jnp.where(any_iso, mask_r1, jnp.where(has_u2, mask_r2, jnp.where(has_u3, mask_r3, mask)))
    new_sol = jnp.where(any_iso, sol_mask, jnp.where(has_u2, sol_r2, jnp.where(has_u3, sol_r3, sol_mask)))
    changed = any_iso | has_u2 | has_u3
    return new_mask, new_sol, changed


def reduce_instance(problem: VCProblem, mask, sol_mask):
    """Apply rules 1-3 to fixpoint (bounded while_loop)."""

    def cond(state):
        _, _, changed, it = state
        return changed & (it < problem.adj.shape[0] + 1)

    def body(state):
        m, s, _, it = state
        m2, s2, ch = _reduce_step(problem, m, s)
        return (m2, s2, ch, it + 1)

    # initial `changed` is derived from mask (always True) so its varying-
    # manual-axes match the body output under shard_map (see JAX scan-vma).
    changed0 = popcount(mask) >= 0
    mask, sol_mask, _, _ = jax.lax.while_loop(
        cond, body, (mask, sol_mask, changed0, jnp.int32(0))
    )
    return mask, sol_mask


# -- branching (paper Algorithm 8 lines 7-11) ----------------------------------


class BranchResult(NamedTuple):
    left_mask: jnp.ndarray
    left_sol: jnp.ndarray
    right_mask: jnp.ndarray
    right_sol: jnp.ndarray
    is_terminal: jnp.ndarray  # () bool -- reduced instance has no edges
    terminal_sol: jnp.ndarray  # (W,) uint32 -- full cover if is_terminal
    terminal_size: jnp.ndarray  # () int32


def branch_once(problem: VCProblem, mask, sol_mask) -> BranchResult:
    """Reduce, then branch on a maximum-degree vertex u:
    left = (G-u, S+{u}), right = (G-N[u], S+N(u)).  Matches Alg. 8/9."""
    W = problem.adj.shape[1]
    mask, sol_mask = reduce_instance(problem, mask, sol_mask)
    deg = degrees(problem, mask)
    maxdeg = deg.max()
    is_terminal = maxdeg <= 0
    u = jnp.argmax(deg).astype(jnp.int32)
    u_bit = single_bit(u, W)
    nb = problem.adj[u] & mask
    return BranchResult(
        left_mask=mask & ~u_bit,
        left_sol=sol_mask | u_bit,
        right_mask=mask & ~(nb | u_bit),
        right_sol=sol_mask | nb,
        is_terminal=is_terminal,
        terminal_sol=sol_mask,
        terminal_size=popcount(sol_mask),
    )


@functools.partial(jax.jit, static_argnames=("n",))
def verify_cover(adj, sol_mask, n: int) -> jnp.ndarray:
    """True iff sol_mask covers every edge (device-side checker)."""
    problem = make_problem(adj, n)
    inc = in_mask(problem, sol_mask)  # (n,)
    # edges with neither endpoint in the cover
    uncovered_rows = adj & ~sol_mask[None, :]
    cnt = popcount(uncovered_rows)
    return (jnp.where(inc, 0, cnt).sum() == 0)
