"""Hierarchical frontier memory gate: spill completes what overflow drops.

Three solves of the same instance, same compiled-plane cache:

* **unsaturated** — engine-sized capacity, the ground-truth optimum and the
  wall-clock baseline;
* **starved**    — a pinned hot capacity the search's peak frontier
  exceeds, WITHOUT spill: tasks are dropped (``overflow_count > 0``) —
  the failure mode the cold tier exists to remove;
* **spilled**    — the SAME pinned capacity with ``frontier_spill=True``:
  must report zero drops, land on the unsaturated optimum, and stay
  within ``MAX_WALL_RATIO`` of the unsaturated wall (the pump is host
  numpy at chunk boundaries — cheap, and CI-gated to stay cheap).

The gate assertions run in-process (a failed claim fails the benchmark,
not just a number in a JSON); ``check_regression`` additionally pins the
recorded numbers against ``benchmarks/baseline.json``.
"""

from __future__ import annotations

import time

import numpy as np

MAX_WALL_RATIO = 1.5


def _median_wall(fn, reps=3):
    walls = []
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn()
        walls.append(time.perf_counter() - t0)
    return out, sorted(walls)[len(walls) // 2]


def run(smoke: bool = False) -> dict:
    from repro.api import PlaneCache, SolveConfig, SolverSession
    from repro.graphs.generators import erdos_renyi

    n, p, seed = (40, 0.28, 0) if smoke else (48, 0.28, 0)
    cap = 12
    g = erdos_renyi(n, p, seed)
    base = SolveConfig(
        num_workers=4, steps_per_round=2, chunk_rounds=2, capacity=cap
    )
    cache = PlaneCache()

    def solve(cfg):
        return SolverSession("vertex_cover", config=cfg, cache=cache).solve(g)

    # warm each plane shape once so the walls compare steady-state solves
    unsat_cfg = base.replace(capacity=None)
    spill_cfg = base.replace(frontier_spill=True)
    solve(unsat_cfg), solve(base), solve(spill_cfg)

    unsat, unsat_wall = _median_wall(lambda: solve(unsat_cfg))
    starved, _ = _median_wall(lambda: solve(base))
    spilled, spill_wall = _median_wall(lambda: solve(spill_cfg))

    # the three claims, asserted (this benchmark IS the gate)
    assert starved.stats.overflow and starved.stats.overflow_count > 0, (
        "starved baseline did not overflow — shrink `cap` so the gate "
        "actually exercises saturation"
    )
    assert spilled.stats.spilled_tasks > 0
    assert not spilled.stats.overflow and spilled.stats.overflow_count == 0
    assert spilled.best_size == unsat.best_size, (
        f"spilled optimum {spilled.best_size} != unsaturated "
        f"{unsat.best_size}"
    )
    wall_ratio = spill_wall / max(unsat_wall, 1e-9)
    assert wall_ratio <= MAX_WALL_RATIO, (
        f"spilled solve took {wall_ratio:.2f}x the unsaturated wall "
        f"(budget {MAX_WALL_RATIO}x) — the pump is no longer cheap"
    )

    out = dict(
        n=n,
        p=p,
        capacity=cap,
        best=int(unsat.best_size),
        starved_overflow_count=int(starved.stats.overflow_count),
        starved_best=int(starved.best_size),
        spilled_tasks=int(spilled.stats.spilled_tasks),
        readmitted_tasks=int(spilled.stats.readmitted_tasks),
        cold_bytes_peak=int(spilled.stats.cold_bytes_peak),
        no_drop=bool(
            not spilled.stats.overflow and spilled.stats.overflow_count == 0
        ),
        optimum_matches=bool(spilled.best_size == unsat.best_size),
        unsat_wall_s=round(unsat_wall, 3),
        spill_wall_s=round(spill_wall, 3),
        wall_ratio=round(wall_ratio, 2),
    )
    print(
        f"spill gate: cap={cap} drops {out['starved_overflow_count']} tasks "
        f"without spill; with spill {out['spilled_tasks']} spilled / "
        f"{out['readmitted_tasks']} readmitted, 0 dropped, optimum "
        f"{out['best']} preserved at {out['wall_ratio']}x unsaturated wall"
    )
    return out


if __name__ == "__main__":
    run()
