"""qwen1.5-0.5b [dense] — QKV bias.  24L d=1024 16H kv=16 d_ff=2816
vocab=151936.  [hf:Qwen/Qwen1.5-0.5B]"""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-0.5b",
        family="dense",
        n_layers=24,
        d_model=1024,
        n_heads=16,
        n_kv_heads=16,
        d_ff=2816,
        vocab=151_936,
        qkv_bias=True,
        rope_theta=1_000_000.0,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-smoke",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=128,
        vocab=512,
        qkv_bias=True,
        dtype="float32",
    )
