"""End-to-end behaviour: all three schedulers agree with ground truth on the
paper's workload, and the engine survives a mid-run elastic resize.  (The
per-component suites live in the sibling test modules.)"""

from repro.api import SolveConfig, SolverSession
from repro.core.centralized import run_centralized_sim
from repro.core.protocol_sim import run_protocol_sim
from repro.graphs.generators import p_hat_like
from repro.problems.sequential import solve_sequential, verify_cover


def test_three_schedulers_agree():
    g = p_hat_like(36, 0.45, 1)
    want, _, _ = solve_sequential(g)
    semi = run_protocol_sim(g, num_workers=4)
    cent = run_centralized_sim(g, num_workers=4)
    cfg = SolveConfig(num_workers=4, steps_per_round=8)
    spmd = SolverSession(config=cfg).solve(g)
    assert semi.best_size == cent.best_size == spmd.best_size == want
    assert verify_cover(g, spmd.best_sol)
    # the paper's headline guarantee
    assert semi.stats.failed_requests == 0
