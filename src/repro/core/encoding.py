"""Task serialization codecs (paper §4.3).

*Basic encoding*: serialize the induced subgraph's full adjacency structure —
O(n·W) words per task.  This is what made the fully-centralized strategy
collapse in the paper's experiments (tasks cross the wire twice).

*Optimized encoding*: each worker loads the ORIGINAL graph at startup; a task
is only the packed bitset of surviving vertices plus the partial-solution
bitset — O(W) words.  The receiver reconstructs the induced subgraph locally.

Both are implemented so the paper's comparison (Fig. 4 / Table 1) can be
reproduced; the SPMD engine transfers fixed-shape records, so the codecs below
also define the exact on-the-wire byte counts used by the communication
accounting in benchmarks and in the roofline collective term.
"""

from __future__ import annotations

import dataclasses
import zlib

import numpy as np

from repro.graphs.bitgraph import BitGraph, n_words
from repro.problems.base import RECORD_FIELDS


@dataclasses.dataclass(frozen=True)
class Task:
    """A search-tree node: induced-subgraph mask + partial solution + depth."""

    mask: np.ndarray  # (W,) uint32 -- vertices still in the instance
    sol_mask: np.ndarray  # (W,) uint32 -- vertices already in the solution
    depth: int

    def key(self) -> tuple:
        return (self.mask.tobytes(), self.sol_mask.tobytes(), self.depth)


# the frontier's native task record — single-sourced from the plugin
# protocol so the schema cannot drift between problems/ and the codecs
DEFAULT_RECORD_FIELDS = RECORD_FIELDS


def resolve_record_words(fields, n: int, W: int) -> int:
    """Total u32 words of a record schema.  Widths are symbolic: "W" (one
    packed bitset), "n*W" (an adjacency payload) or a literal int."""
    total = 0
    for _, width in fields:
        if width == "W":
            total += W
        elif width == "n*W":
            total += n * W
        elif isinstance(width, int):
            total += width
        else:
            raise ValueError(f"unknown record-field width {width!r}")
    return total


class OptimizedCodec:
    """n-bit-mask encoding: the problem's record schema verbatim (for the
    native (mask, sol, depth) layout: 2W + 1 words per task).

    A schema must START with the native triple — the frontier owns those
    fields; anything after rides as zero-filled extra payload words that
    both ``encode`` and the SPMD data plane (via ``pad_words``) actually
    carry, so byte accounting always matches the wire.
    """

    name = "optimized"

    def __init__(self, n: int, fields=DEFAULT_RECORD_FIELDS):
        if tuple(fields[:3]) != tuple(DEFAULT_RECORD_FIELDS):
            raise ValueError(
                f"record schema must start with the native "
                f"{DEFAULT_RECORD_FIELDS} triple, got {tuple(fields[:3])}"
            )
        self.n = n
        self.W = n_words(n)
        self.fields = tuple(fields)

    @property
    def record_words(self) -> int:
        return resolve_record_words(self.fields, self.n, self.W)

    @property
    def record_bytes(self) -> int:
        return 4 * self.record_words

    @property
    def native_words(self) -> int:
        return resolve_record_words(DEFAULT_RECORD_FIELDS, self.n, self.W)

    @property
    def pad_words(self) -> int:
        """Payload words over the frontier's native record — what the SPMD
        engine appends (zero-filled) per task so the collective moves this
        codec's exact wire size (schema extras plus any codec payload)."""
        return self.record_words - self.native_words

    def _extra_zeros(self) -> np.ndarray:
        extra = resolve_record_words(self.fields[3:], self.n, self.W)
        return np.zeros(extra, dtype=np.uint32)

    def encode(self, task: Task) -> np.ndarray:
        return np.concatenate(
            [
                task.mask,
                task.sol_mask,
                np.array([task.depth], dtype=np.uint32),
                self._extra_zeros(),
            ]
        ).astype(np.uint32)

    def decode(self, rec: np.ndarray, graph: BitGraph | None = None) -> Task:
        W = self.W
        return Task(
            mask=rec[:W].astype(np.uint32),
            sol_mask=rec[W : 2 * W].astype(np.uint32),
            depth=int(rec[2 * W]),
        )


class BasicCodec(OptimizedCodec):
    """Adjacency-list encoding: the induced subgraph's rows travel with the
    task -- n·W words on top of the problem's record schema ((n+2)·W + 1 for
    the default layout).  The decode does NOT need the original graph (that
    is its only advantage)."""

    name = "basic"

    @property
    def record_words(self) -> int:
        return self.n * self.W + super().record_words

    def encode(self, task: Task, graph: BitGraph) -> np.ndarray:
        sub_adj = (graph.adj & task.mask[None, :]).astype(np.uint32)
        # zero out rows outside the mask
        from repro.graphs.bitgraph import unpack_mask

        inside = unpack_mask(task.mask, self.n)
        sub_adj = np.where(inside[:, None], sub_adj, 0).astype(np.uint32)
        return np.concatenate(
            [
                sub_adj.reshape(-1),
                task.mask,
                task.sol_mask,
                np.array([task.depth], dtype=np.uint32),
                self._extra_zeros(),
            ]
        ).astype(np.uint32)

    def decode(self, rec: np.ndarray, graph: BitGraph | None = None) -> Task:
        n, W = self.n, self.W
        off = n * W
        return Task(
            mask=rec[off : off + W].astype(np.uint32),
            sol_mask=rec[off + W : off + 2 * W].astype(np.uint32),
            depth=int(rec[off + 2 * W]),
        )


CODECS = {"optimized": OptimizedCodec, "basic": BasicCodec}


def known_codecs() -> list:
    return sorted(CODECS)


def make_codec(name: str, n: int, problem=None):
    """Build a codec, parameterized by the problem's record schema.

    ``problem`` is an optional :class:`~repro.problems.base.BranchingProblem`
    (its ``record_fields`` define the task-record layout); omitted, the
    default (mask, sol, depth) layout applies.  Unknown names raise a
    ``ValueError`` listing what IS available (the CLIs surface it verbatim).
    """
    if name not in CODECS:
        raise ValueError(
            f"unknown codec {name!r}; known codecs: {', '.join(known_codecs())}"
        )
    fields = (
        problem.record_fields if problem is not None else DEFAULT_RECORD_FIELDS
    )
    return CODECS[name](n, fields)


# -- payload integrity --------------------------------------------------------
#
# The cold tier and checkpoint store carry codec records through host memory
# and disk, where corruption must be DETECTED, never propagated into the
# search (a flipped mask bit silently changes the answer).  A record is
# "checked" by appending one CRC32 word over its payload; CRC32 is linear,
# so any single-bit flip — including one in the checksum word itself — is
# always caught.  The checksum word is integrity metadata, not wire payload:
# codec ``record_words`` / ``record_bytes`` (the paper's §4.3 byte
# accounting) are unchanged.


class PayloadCorruptionError(RuntimeError):
    """A checked task record failed checksum verification."""


def payload_checksum(words) -> int:
    """CRC32 (as uint32) over a u32 word array's raw bytes."""
    a = np.ascontiguousarray(np.asarray(words, dtype=np.uint32))
    return zlib.crc32(a.tobytes()) & 0xFFFFFFFF


def checked_record(rec: np.ndarray) -> np.ndarray:
    """``rec`` (record_words,) -> (record_words + 1,) with a trailing
    CRC32 word."""
    rec = np.asarray(rec, dtype=np.uint32)
    return np.concatenate(
        [rec, np.array([payload_checksum(rec)], dtype=np.uint32)]
    )


def verify_record(rec: np.ndarray) -> bool:
    """Does a checked record's trailing CRC32 word match its payload?"""
    rec = np.asarray(rec, dtype=np.uint32)
    return rec.size >= 1 and payload_checksum(rec[:-1]) == int(rec[-1])


def strip_record(rec: np.ndarray) -> np.ndarray:
    """Verify a checked record and return the bare payload words; raises
    :class:`PayloadCorruptionError` on mismatch."""
    rec = np.asarray(rec, dtype=np.uint32)
    if not verify_record(rec):
        raise PayloadCorruptionError(
            f"task record failed checksum verification "
            f"(got {int(rec[-1]) if rec.size else '<empty>'}, "
            f"expected {payload_checksum(rec[:-1]) if rec.size else '?'})"
        )
    return rec[:-1]
