"""The continuous-batching ``SolveService``: live lane lifecycle guarantees.

Four claims from the continuous-admission refactor:

1. **Bit-identity through the live plane** — a request admitted into a
   service lane (even mid-stream, into a lane freed by another instance)
   produces the SAME result — branching decisions AND counters — as its
   solo ``SolverSession.solve``, pinned against ``tests/golden_vc.json``
   (including the basic codec's byte accounting and fpt mode).
2. **Zero-retrace admission** — admitting into a freed lane is pure data
   movement: ``superstep.PLANE_TRACES`` does not move after the first
   drain, no matter how many instances churn through.
3. **Streaming lifecycle** — easy instances complete and stream out while
   hard lanemates keep solving (out-of-order completion); ``result()`` on
   a not-ready ticket raises; overflow/deadline/occupancy accounting
   propagates into the streamed results.
4. **Deterministic scheduling** — priority/deadline admission order and
   per-tenant lane caps are pure functions of the submit sequence.
"""

import json
import pathlib

import numpy as np
import pytest

from repro.api import (
    PlaneCache,
    SolveConfig,
    SolveService,
    SolverSession,
    solve_stream_session,
)
from repro.api.backends import config_from_legacy
from repro.api.service import LaneScheduler, SolveRequest
from repro.core import superstep
from repro.graphs.generators import erdos_renyi
from repro.problems.sequential import (
    solve_sequential,
    solve_sequential_max_clique,
)

GOLDEN = json.loads(
    (pathlib.Path(__file__).parent / "golden_vc.json").read_text()
)


def _check_golden(r, want: dict):
    got = {
        "best_size": int(r.best_size),
        "best_sol": [int(w) for w in np.asarray(r.best_sol, np.uint32)],
        "rounds": int(r.rounds),
        "nodes_expanded": int(r.nodes_expanded),
        "tasks_transferred": int(r.tasks_transferred),
        "transfer_rounds": int(r.stats.transfer_rounds),
        "transfer_bytes_total": int(r.stats.transfer_bytes_total),
        "overflow": bool(r.stats.overflow),
    }
    assert got == want


# -- 1. bit-identity: the live plane vs the solo goldens -----------------------


@pytest.mark.parametrize("label", sorted(GOLDEN["solo"]))
def test_service_result_bit_identical_to_solo_golden(label):
    """The golden instance solves in a lane NEXT TO another live instance
    and still reproduces its solo trajectory exactly — the frozen-lane
    select means lanemates can never perturb each other."""
    case = GOLDEN["solo"][label]
    gkw = case["graph"]
    g = erdos_renyi(gkw["n"], gkw["p"], gkw["seed"])
    cfg = config_from_legacy(**case["solve_kw"]).replace(service_lanes=2)
    svc = SolveService("vertex_cover", cfg)
    g_mate = erdos_renyi(gkw["n"], gkw["p"], gkw["seed"] + 77)
    ticket = svc.submit(g)
    lanemate = svc.submit(g_mate)
    svc.drain()
    r = svc.result(ticket)
    assert (r.problem, r.backend, r.found) == ("vertex_cover", "spmd", True)
    _check_golden(r, case["result"])
    # the lanemate is a real solve too, not a casualty of the golden's lane
    assert svc.result(lanemate).best_size == solve_sequential(g_mate)[0]
    assert svc.idle() and not svc.ready(ticket)  # result() pops


def test_service_fpt_bit_identical_to_golden():
    case = GOLDEN["fpt"]
    gkw = case["graph"]
    g = erdos_renyi(gkw["n"], gkw["p"], gkw["seed"])
    cfg = SolveConfig(
        num_workers=4, mode="fpt", k=case["k"], service_lanes=2
    )
    svc = SolveService("vertex_cover", cfg)
    t = svc.submit(g)  # k defaults from the config in fpt mode
    svc.drain()
    _check_golden(svc.result(t), case["result"])


def test_service_churn_matches_solo_across_sizes():
    """A stream wider than the lane count: every instance that churns
    through a reused lane matches its solo solve bit-for-bit (best, rounds,
    counters), across mixed sizes within the W bucket."""
    cfg = SolveConfig(num_workers=4, steps_per_round=8, service_lanes=2)
    sizes = [18, 26, 22, 30, 20, 24]
    gs = [erdos_renyi(n, 0.3, 200 + i) for i, n in enumerate(sizes)]
    svc = SolveService("vertex_cover", cfg)
    tickets = [svc.submit(g) for g in gs]
    svc.drain()
    sess = SolverSession(problem="vertex_cover", config=cfg)
    for t, g in zip(tickets, gs):
        r, solo = svc.result(t), sess.solve(g)
        assert r.best_size == solo.best_size
        assert r.rounds == solo.rounds
        assert r.nodes_expanded == solo.nodes_expanded
        assert r.tasks_transferred == solo.tasks_transferred
        assert r.stats.transfer_bytes_total == solo.stats.transfer_bytes_total
        assert (np.asarray(r.best_sol) == np.asarray(solo.best_sol)).all()


# -- 2. zero-retrace admission into freed lanes --------------------------------


def test_admission_into_freed_lanes_traces_nothing():
    cfg = SolveConfig(num_workers=4, steps_per_round=8, service_lanes=2)
    svc = SolveService("vertex_cover", cfg)
    wave1 = [svc.submit(erdos_renyi(20, 0.3, s)) for s in range(2)]
    svc.drain()  # compiles the plane (first wave)
    traces0 = superstep.PLANE_TRACES
    wave2 = [svc.submit(erdos_renyi(24, 0.3, 10 + s)) for s in range(4)]
    svc.drain()
    assert superstep.PLANE_TRACES == traces0, (
        "admitting into freed lanes must be pure data movement — a plane "
        "re-trace means the live-plane shape contract broke"
    )
    for t in wave1 + wave2:
        assert svc.ready(t)
    stats = svc.stats()
    assert stats["completed"] == 6 and stats["planes"] == 1
    assert 0.0 < stats["occupancy"] <= 1.0


# -- 3. streaming lifecycle ----------------------------------------------------


def test_out_of_order_completion_streams_early_finishers():
    """An easy instance submitted AFTER a hard one completes first and its
    result is poppable while the hard lane keeps solving."""
    cfg = SolveConfig(
        num_workers=2, steps_per_round=2, chunk_rounds=1, service_lanes=2,
        admission="fifo",
    )
    svc = SolveService("vertex_cover", cfg)
    hard = svc.submit(erdos_renyi(30, 0.5, 3))
    easy = svc.submit(erdos_renyi(8, 0.3, 4))
    completed, steps = [], 0
    while not svc.ready(easy):
        completed.extend(svc.step())
        steps += 1
        assert steps < 200
    assert completed[0] == easy
    if not svc.ready(hard):  # the point: easy streamed out mid-solve
        assert svc.status()["planes"]["(1, None)"]["tickets"] == [hard]
    r_easy = svc.result(easy)
    assert r_easy.best_size == solve_sequential(erdos_renyi(8, 0.3, 4))[0]
    svc.drain()
    assert svc.result(hard).best_size == solve_sequential(
        erdos_renyi(30, 0.5, 3)
    )[0]


def test_result_before_completion_raises_keyerror():
    svc = SolveService(
        "vertex_cover", SolveConfig(num_workers=2, service_lanes=2)
    )
    t = svc.submit(erdos_renyi(12, 0.3, 0))
    assert not svc.ready(t)
    with pytest.raises(KeyError):
        svc.result(t)  # still queued — step()/drain() first
    with pytest.raises(KeyError):
        svc.result(999)  # unknown ticket
    svc.drain()
    assert svc.ready(t) and svc.result(t).found


def test_overflow_count_propagates_into_streamed_results():
    """A capacity-starved config overflows identically through the service
    and the solo path — the live plane does not hide dropped work."""
    cfg = SolveConfig(
        num_workers=2, steps_per_round=4, capacity=6, service_lanes=2
    )
    g = erdos_renyi(26, 0.3, 0)
    solo = SolverSession(problem="vertex_cover", config=cfg).solve(g)
    assert solo.stats.overflow_count > 0  # the config really starves
    svc = SolveService("vertex_cover", cfg)
    t = svc.submit(g)
    svc.drain()
    r = svc.result(t)
    assert r.stats.overflow_count == solo.stats.overflow_count
    assert r.stats.overflow and r.best_size == solo.best_size


def test_deadline_evicts_with_anytime_result():
    cfg = SolveConfig(
        num_workers=2, steps_per_round=2, chunk_rounds=1, service_lanes=2
    )
    g = erdos_renyi(32, 0.5, 7)
    svc = SolveService("vertex_cover", cfg)
    t = svc.submit(g, deadline=1)
    svc.drain()
    r = svc.result(t)
    assert r.stats.service.deadline_hit is True
    assert r.rounds == 1  # stopped at the budget, not at optimality
    assert svc.stats()["evicted"] == 1
    # the anytime answer is a valid-but-possibly-loose bound vs full solve
    full = SolverSession(problem="vertex_cover", config=cfg).solve(g)
    assert r.best_size >= full.best_size
    # a finished (non-evicted) lane never reports a deadline hit
    svc2 = SolveService("vertex_cover", cfg)
    t2 = svc2.submit(erdos_renyi(12, 0.3, 1), deadline=500)
    svc2.drain()
    assert svc2.result(t2).stats.service.deadline_hit is False


def test_wall_deadline_evicts_on_injected_clock():
    """``deadline_s`` is a WALL budget on the service's injectable clock:
    blowing it between steps evicts with an anytime result flagged
    ``wall_deadline_hit`` (and NOT ``deadline_hit`` — that stays the
    superstep-budget flag).  No ``time.time()`` in traced code: advancing
    the fake clock is the only stimulus."""

    class FakeClock:
        def __init__(self):
            self.t = 0.0

        def __call__(self):
            return self.t

    clk = FakeClock()
    cfg = SolveConfig(
        num_workers=4, steps_per_round=2, chunk_rounds=2, service_lanes=2
    )
    svc = SolveService("vertex_cover", cfg, clock=clk)
    g = erdos_renyi(40, 0.28, 0)
    t = svc.submit(g, deadline_s=5.0)
    svc.step()  # still within budget: not evicted
    assert t not in [*svc._results]
    clk.t = 10.0  # budget blown between steps
    assert svc.step() == [t]
    r = svc.result(t)
    assert r.stats.service.wall_deadline_hit is True
    assert r.stats.service.deadline_hit is False
    assert r.found  # anytime incumbent, valid but possibly loose
    full = SolverSession(problem="vertex_cover", config=cfg).solve(g)
    assert r.best_size >= full.best_size
    assert svc.stats()["evicted"] == 1

    # a solve finishing before its wall budget never reports the hit
    svc2 = SolveService("vertex_cover", cfg, clock=FakeClock())
    t2 = svc2.submit(erdos_renyi(12, 0.3, 1), deadline_s=100.0)
    svc2.drain()
    s2 = svc2.result(t2).stats.service
    assert s2.wall_deadline_hit is False and s2.deadline_hit is False


def test_wall_deadline_survives_checkpoint_restore(tmp_path):
    """``deadline_s`` rides the request metadata through checkpoint():
    a restored service still enforces the original wall budget."""

    class FakeClock:
        def __init__(self):
            self.t = 0.0

        def __call__(self):
            return self.t

    cfg = SolveConfig(
        num_workers=4, steps_per_round=2, chunk_rounds=1, service_lanes=2
    )
    svc = SolveService("vertex_cover", cfg, clock=FakeClock())
    t = svc.submit(erdos_renyi(40, 0.28, 0), deadline_s=5.0)
    svc.step()
    svc.checkpoint(str(tmp_path / "ck"))
    back = SolveService.restore(str(tmp_path / "ck"))
    req = next(
        r
        for p in back._planes.values()
        for r in p.requests
        if r is not None
    )
    assert req.deadline_s == 5.0


def test_submit_validation():
    svc = SolveService(
        "vertex_cover", SolveConfig(num_workers=2, service_lanes=2)
    )
    with pytest.raises(ValueError, match="fpt"):
        svc.submit(erdos_renyi(10, 0.3, 0), k=3)  # k needs mode='fpt'
    with pytest.raises(ValueError, match="deadline"):
        svc.submit(erdos_renyi(10, 0.3, 0), deadline=0)
    with pytest.raises(ValueError, match="servable"):
        SolveService(
            "vertex_cover", SolveConfig(num_workers=2, use_mesh=True)
        )


# -- 4. deterministic scheduling -----------------------------------------------


def test_priority_admission_order_is_deterministic():
    sched = LaneScheduler("priority")
    reqs = [
        SolveRequest(ticket=0, g=None, priority=0),
        SolveRequest(ticket=1, g=None, priority=5, deadline=9),
        SolveRequest(ticket=2, g=None, priority=5, deadline=3),
        SolveRequest(ticket=3, g=None, priority=5),  # no deadline: last of the 5s
        SolveRequest(ticket=4, g=None, priority=1),
    ]
    for r in reqs:
        sched.push(r)
    assert [r.ticket for r in sched.ordered()] == [2, 1, 3, 4, 0]
    # fifo ignores all of that
    fifo = LaneScheduler("fifo")
    for r in reversed(reqs):
        fifo.push(r)
    assert [r.ticket for r in fifo.ordered()] == [0, 1, 2, 3, 4]


def test_tenant_cap_skips_without_starving():
    """tenant_max_lanes=1: tenant a's second request waits even though a
    lane is free, tenant b overtakes into it, and a2 still completes."""
    cfg = SolveConfig(
        num_workers=2, steps_per_round=2, chunk_rounds=1, service_lanes=2,
        admission="fifo", tenant_max_lanes=1,
    )
    # hard enough to outlive the first chunk, so occupancy is observable
    svc = SolveService("vertex_cover", cfg)
    a1 = svc.submit(erdos_renyi(30, 0.5, 0), tenant="a")
    a2 = svc.submit(erdos_renyi(30, 0.5, 1), tenant="a")
    b1 = svc.submit(erdos_renyi(30, 0.5, 2), tenant="b")
    svc.step()
    st = svc.status()
    lanes = st["planes"]["(1, None)"]
    assert lanes["occupied"] == 2
    assert lanes["tickets"] == sorted([a1, b1])  # a2 skipped, b1 overtook
    assert st["queued"] == 1
    svc.drain()
    for t in (a1, a2, b1):
        assert svc.ready(t)


def test_fpt_per_request_k_overrides_config():
    g = erdos_renyi(20, 0.3, 2)
    want, _, _ = solve_sequential(g)
    cfg = SolveConfig(num_workers=4, mode="fpt", k=want, service_lanes=2)
    svc = SolveService("vertex_cover", cfg)
    t_yes = svc.submit(g)  # config k == optimum: found
    t_no = svc.submit(g, k=want - 1)  # per-request tighter k: infeasible
    svc.drain()
    assert svc.result(t_yes).found is True
    assert svc.result(t_no).found is False


# -- 5. the continuous path under solve_stream_session -------------------------


def test_solve_stream_session_mixed_problem_churn():
    """A mixed-problem stream wider than the lane count routes through one
    continuous service per problem (shared cache), preserves submission
    order, matches the sequential references and keeps one plane per
    problem (no per-wave re-compiles)."""
    sizes = [16, 18, 14, 20, 16, 18, 14, 20]
    probs = ["vertex_cover", "max_clique"] * 4
    gs = [erdos_renyi(n, 0.35, 40 + i) for i, n in enumerate(sizes)]
    cache = PlaneCache()
    out = solve_stream_session(
        gs, batch_size=2, problem=probs, cache=cache,
        config=SolveConfig(num_workers=4, steps_per_round=8),
    )
    assert [r.problem for r in out] == probs
    for g, r in zip(gs, out):
        ref = (
            solve_sequential if r.problem == "vertex_cover"
            else solve_sequential_max_clique
        )
        assert r.best_size == ref(g)[0]
    assert cache.stats().planes == 2  # one live plane per problem, reused
