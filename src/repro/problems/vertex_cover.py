"""Vertex-cover plugin: the paper's own workload on the generic solve plane.

This is the jit/vmap-compatible twin of the host reference in
:mod:`repro.problems.sequential`.  Every function operates on tasks in the
paper's *optimized encoding* (§4.3): packed ``uint32[W]`` masks over the
ORIGINAL vertex set; the adjacency bitset ``adj (n, W)`` is loaded once per
worker and never re-serialized.  The packed-bitset primitives themselves are
problem-agnostic and live in :mod:`repro.problems.base` (re-exported here
for compatibility).

All control flow is `jax.lax` (while_loop / select) so the ops compose into
the SPMD superstep engine (`repro.core.superstep`) and into the Pallas
bitset kernels (`repro.kernels.bitset_ops`, which accelerates `degrees`).
Semantics match the host reference exactly (tests assert equality), with one
deliberate exception: rule application order inside `reduce_instance` may pick
a different (equally valid) vertex — both preserve at least one optimal
cover, so terminal best values are identical.

``SPEC`` at the bottom is the :class:`~repro.problems.base.BranchingProblem`
plugin registered as ``"vertex_cover"``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.problems import sequential
from repro.problems.base import (  # noqa: F401  (re-exported public API)
    WORD_BITS,
    BranchingProblem,
    BranchStep,
    ExpandResult,
    ProblemData,
    degrees,
    degrees_batch,
    edge_count,
    in_mask,
    pack_bits,
    popcount,
    single_bit,
    unpack_bits,
)

# the pre-plugin names, kept for callers and tests
VCProblem = ProblemData
BranchResult = BranchStep


def make_problem(adj, n: int) -> ProblemData:
    v = jnp.arange(adj.shape[0], dtype=jnp.int32)
    return ProblemData(
        n=jnp.int32(n),
        adj=jnp.asarray(adj, dtype=jnp.uint32),
        word_idx=v // WORD_BITS,
        bit_idx=(v % WORD_BITS).astype(jnp.uint32),
    )


def lower_bound(deg: jnp.ndarray) -> jnp.ndarray:
    """ceil(E / maxdeg): each cover vertex covers at most maxdeg edges."""
    maxdeg = jnp.maximum(deg.max(), 0)
    E = edge_count(deg)
    return jnp.where(maxdeg > 0, -(-E // jnp.maximum(maxdeg, 1)), 0).astype(jnp.int32)


# -- reduction rules (paper §4.1, Chen-Kanj-Jia) -------------------------------


def _first_vertex(cond: jnp.ndarray, n_total: int) -> jnp.ndarray:
    """Lowest vertex index satisfying ``cond``; n_total if none."""
    idx = jnp.where(cond, jnp.arange(n_total, dtype=jnp.int32), jnp.int32(n_total))
    return idx.min()


def _reduce_step(problem: ProblemData, mask, sol_mask):
    """One reduction sweep.  Returns (mask, sol_mask, changed)."""
    n_total, W = problem.adj.shape
    deg = degrees(problem, mask)
    inside = deg >= 0

    # Rule 1: drop all isolated vertices at once (removals never conflict).
    iso = inside & (deg == 0)
    any_iso = iso.any()
    mask_r1 = mask & ~pack_bits(iso, W)

    # Rule 2: one degree-1 vertex per sweep (batching could over-add on
    # isolated edges where both endpoints have degree 1).
    u2 = _first_vertex(inside & (deg == 1), n_total)
    has_u2 = u2 < n_total
    u2c = jnp.minimum(u2, n_total - 1)
    nb2 = problem.adj[u2c] & mask
    sol_r2 = sol_mask | nb2
    mask_r2 = mask & ~(nb2 | single_bit(u2c, W))

    # Rule 3: first degree-2 vertex whose two neighbours are adjacent.
    nb_all = problem.adj & mask[None, :]  # (n, W)
    bits = unpack_bits(nb_all, n_total)  # (n, n) neighbour booleans
    vidx = jnp.arange(n_total, dtype=jnp.int32)
    first_nb = jnp.where(bits, vidx[None, :], n_total).min(axis=1)
    last_nb = jnp.where(bits, vidx[None, :], -1).max(axis=1)
    fc = jnp.clip(first_nb, 0, n_total - 1)
    lc = jnp.clip(last_nb, 0, n_total - 1)
    vw_edge = bits[fc, lc]  # adj is symmetric: v's row has bit w
    cand3 = inside & (deg == 2) & vw_edge
    u3 = _first_vertex(cand3, n_total)
    has_u3 = u3 < n_total
    u3c = jnp.minimum(u3, n_total - 1)
    nb3 = problem.adj[u3c] & mask
    sol_r3 = sol_mask | nb3
    mask_r3 = mask & ~(nb3 | single_bit(u3c, W))

    # Priority: rule 1 > rule 2 > rule 3 (mirrors the host reference).
    new_mask = jnp.where(any_iso, mask_r1, jnp.where(has_u2, mask_r2, jnp.where(has_u3, mask_r3, mask)))
    new_sol = jnp.where(any_iso, sol_mask, jnp.where(has_u2, sol_r2, jnp.where(has_u3, sol_r3, sol_mask)))
    changed = any_iso | has_u2 | has_u3
    return new_mask, new_sol, changed


def reduce_instance(problem: ProblemData, mask, sol_mask):
    """Apply rules 1-3 to fixpoint (bounded while_loop)."""

    def cond(state):
        _, _, changed, it = state
        return changed & (it < problem.adj.shape[0] + 1)

    def body(state):
        m, s, _, it = state
        m2, s2, ch = _reduce_step(problem, m, s)
        return (m2, s2, ch, it + 1)

    # initial `changed` is derived from mask (always True) so its varying-
    # manual-axes match the body output under shard_map (see JAX scan-vma).
    changed0 = popcount(mask) >= 0
    mask, sol_mask, _, _ = jax.lax.while_loop(
        cond, body, (mask, sol_mask, changed0, jnp.int32(0))
    )
    return mask, sol_mask


# -- branching (paper Algorithm 8 lines 7-11) ----------------------------------


def branch_once(problem: ProblemData, mask, sol_mask) -> BranchStep:
    """Reduce, then branch on a maximum-degree vertex u:
    left = (G-u, S+{u}), right = (G-N[u], S+N(u)).  Matches Alg. 8/9."""
    W = problem.adj.shape[1]
    mask, sol_mask = reduce_instance(problem, mask, sol_mask)
    deg = degrees(problem, mask)
    maxdeg = deg.max()
    is_terminal = maxdeg <= 0
    u = jnp.argmax(deg).astype(jnp.int32)
    u_bit = single_bit(u, W)
    nb = problem.adj[u] & mask
    return BranchStep(
        left_mask=mask & ~u_bit,
        left_sol=sol_mask | u_bit,
        right_mask=mask & ~(nb | u_bit),
        right_sol=sol_mask | nb,
        is_terminal=is_terminal,
        terminal_sol=sol_mask,
        terminal_value=popcount(sol_mask),
    )


def task_bound(problem: ProblemData, mask, sol_mask) -> jnp.ndarray:
    """|S| + ceil(E/maxdeg): admissible lower bound on the final cover."""
    return popcount(sol_mask) + lower_bound(degrees(problem, mask))


def expand_tasks(problem: ProblemData, masks, sols) -> ExpandResult:
    """One-pass fused expansion of an (L, W) lane batch (Alg. 8 hot path).

    The per-task path computes two full degree panels per lane (task_bound
    on the raw mask, branch_once on the reduced mask) through separate
    vmapped calls, then popcounts both children's covers from scratch.
    Here each panel is ONE batched ``degrees_batch`` over all lanes (the
    Pallas kernel on TPU), the pivot and bound read the same panel, and the
    child bounds are arithmetic on it — ``|S|+1`` for the take-u child and
    ``|S| + deg[u]`` for the take-N(u) child (u and its neighbours live in
    the reduced mask, disjoint from the cover, so the popcounts are exact).
    Terminal lanes carry placeholder child bounds (never consumed — see
    :class:`ExpandResult`); all consumed values are bit-identical to the
    composed per-task callables (property-tested).
    """
    W = problem.adj.shape[1]
    deg0 = degrees_batch(problem, masks)  # (L, n)
    bound = popcount(sols) + jax.vmap(lower_bound)(deg0)  # (L,)
    rmasks, rsols = jax.vmap(
        lambda m, s: reduce_instance(problem, m, s)
    )(masks, sols)
    deg = degrees_batch(problem, rmasks)  # (L, n)
    maxdeg = deg.max(axis=1)  # also == deg[u], so it feeds the right bound
    u = jnp.argmax(deg, axis=1).astype(jnp.int32)
    u_bit = jax.vmap(lambda v: single_bit(v, W))(u)
    nb = problem.adj[u] & rmasks
    pc_rsol = popcount(rsols)  # (L,)
    step = BranchStep(
        left_mask=rmasks & ~u_bit,
        left_sol=rsols | u_bit,
        right_mask=rmasks & ~(nb | u_bit),
        right_sol=rsols | nb,
        is_terminal=maxdeg <= 0,
        terminal_sol=rsols,
        terminal_value=pc_rsol,
    )
    return ExpandResult(
        bound=bound,
        step=step,
        left_bound=pc_rsol + 1,
        right_bound=pc_rsol + maxdeg,
    )


def child_bound(problem: ProblemData, mask, sol_mask) -> jnp.ndarray:
    """Cheap birth-time bound: the partial cover can only grow."""
    return popcount(sol_mask)


@functools.partial(jax.jit, static_argnames=("n",))
def verify_cover(adj, sol_mask, n: int) -> jnp.ndarray:
    """True iff sol_mask covers every edge (device-side checker)."""
    problem = make_problem(adj, n)
    inc = in_mask(problem, sol_mask)  # (n,)
    # edges with neither endpoint in the cover
    uncovered_rows = adj & ~sol_mask[None, :]
    cnt = popcount(uncovered_rows)
    return (jnp.where(inc, 0, cnt).sum() == 0)


def _host_task_bound(g, mask, sol_mask) -> int:
    """|S| + ceil(E/maxdeg) — the host twin of :func:`task_bound`."""
    from repro.graphs.bitgraph import popcount_rows

    return int(popcount_rows(sol_mask)) + sequential.lower_bound(g, mask)


def _host_child_bound(g, mask, sol_mask) -> int:
    from repro.graphs.bitgraph import popcount_rows

    return int(popcount_rows(sol_mask))


SPEC = BranchingProblem(
    name="vertex_cover",
    objective="minimize |cover|",
    branch_once=branch_once,
    task_bound=task_bound,
    child_bound=child_bound,
    expand_tasks=expand_tasks,
    bnb_bound=lambda g: g.n + 1,
    branch_once_host=sequential.branch_once,
    sequential=sequential.solve_sequential,
    verify=sequential.verify_cover,
    host_task_bound=_host_task_bound,
    host_child_bound=_host_child_bound,
    host_terminal_value=_host_child_bound,  # a leaf's cover size is |S|
)
