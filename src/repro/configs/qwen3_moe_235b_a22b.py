"""qwen3-moe-235b-a22b [moe] — 128 experts top-8, fine-grained (d_ff=1536).

94L d=4096 64H kv=4 d_ff=1536(expert) vocab=151936.  [hf:Qwen/Qwen3-30B-A3B
scaled family; assigned shape]
"""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-235b-a22b",
        family="moe",
        n_layers=94,
        d_model=4096,
        n_heads=64,
        n_kv_heads=4,
        d_head=128,
        d_ff=1536,
        vocab=151_936,
        n_experts=128,
        top_k=8,
        rope_theta=1_000_000.0,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-smoke",
        family="moe",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=48,
        vocab=512,
        n_experts=8,
        top_k=2,
        dtype="float32",
    )
