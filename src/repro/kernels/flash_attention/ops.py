"""Jit'd public wrapper: picks the flash kernel or the jnp blockwise path.

Three tiers, all with identical semantics (tests sweep all of them):

* ``attention_ref``      — (S, S) materialized; test sizes only.
* ``blockwise_attention``— jnp online-softmax lax.scan over KV blocks; the
  XLA-compiled path used by models for dry-run/roofline (no S² buffer, which
  keeps the compiled memory term honest — this IS flash, expressed in jnp).
* ``flash_attention``    — the Pallas kernel (interpret on CPU, native TPU).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ref import attention_ref

NEG_INF = -1e30


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "scale", "block_k")
)
def blockwise_attention(
    q: jnp.ndarray,  # (B, Sq, Hq, D)
    k: jnp.ndarray,  # (B, Sk, Hkv, D)
    v: jnp.ndarray,  # (B, Sk, Hkv, D)
    *,
    causal: bool = True,
    window: int | None = None,
    scale: float | None = None,
    block_k: int = 512,
) -> jnp.ndarray:
    """Online-softmax attention as a lax.scan over KV blocks (pure jnp)."""
    B, Sq, Hq, D = q.shape
    _, Sk, Hkv, _ = k.shape
    G = Hq // Hkv
    scale = scale if scale is not None else 1.0 / (D**0.5)
    Bk = min(block_k, Sk)
    nblk = -(-Sk // Bk)
    pad = nblk * Bk - Sk

    # (B, Hkv, G, Sq, D) query layout so GQA needs no KV repeat
    qh = q.transpose(0, 2, 1, 3).reshape(B, Hkv, G, Sq, D) * scale
    kh = k.transpose(0, 2, 1, 3)  # (B, Hkv, Sk, D)
    vh = v.transpose(0, 2, 1, 3)
    if pad:
        kh = jnp.pad(kh, ((0, 0), (0, 0), (0, pad), (0, 0)))
        vh = jnp.pad(vh, ((0, 0), (0, 0), (0, pad), (0, 0)))
    kb = kh.reshape(B, Hkv, nblk, Bk, D).transpose(2, 0, 1, 3, 4)
    vb = vh.reshape(B, Hkv, nblk, Bk, D).transpose(2, 0, 1, 3, 4)

    qpos = jnp.arange(Sq) + (Sk - Sq)

    def step(carry, blk):
        acc, m, l = carry
        kj, vj, j = blk
        s = jnp.einsum("bhgqd,bhkd->bhgqk", qh, kj)  # (B,Hkv,G,Sq,Bk)
        kpos = j * Bk + jnp.arange(Bk)
        mask = kpos[None, :] < Sk
        if causal:
            mask = mask & (kpos[None, :] <= qpos[:, None])
        if window is not None:
            mask = mask & (kpos[None, :] > qpos[:, None] - window)
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m - m_new)
        l_new = alpha * l + p.sum(-1, keepdims=True)
        acc_new = acc * alpha + jnp.einsum("bhgqk,bhkd->bhgqd", p, vj)
        return (acc_new, m_new, l_new), None

    acc0 = jnp.zeros((B, Hkv, G, Sq, D), jnp.float32)
    m0 = jnp.full((B, Hkv, G, Sq, 1), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Hkv, G, Sq, 1), jnp.float32)
    (acc, m, l), _ = jax.lax.scan(
        step, (acc0, m0, l0), (kb, vb, jnp.arange(nblk))
    )
    out = acc / jnp.maximum(l, 1e-30)
    out = out.reshape(B, Hq, Sq, D).transpose(0, 2, 1, 3)
    return out.astype(q.dtype)


def attention_op(
    q, k, v, *, causal=True, window=None, scale=None, impl: str = "blockwise", **kw
):
    """Dispatch: impl in {'ref', 'blockwise', 'pallas'}."""
    if impl == "ref":
        return attention_ref(q, k, v, causal=causal, window=window, scale=scale)
    if impl == "pallas":
        return flash_attention(
            q, k, v, causal=causal, window=window, scale=scale, **kw
        )
    return blockwise_attention(
        q, k, v, causal=causal, window=window, scale=scale, **kw
    )
