"""Serving driver: batched greedy decode with the semi-centralized balancer.

Runs a smoke-scale model end to end: prefill the prompt batch, then decode
tokens with the KV-cache ``decode_fn``, while the request balancer keeps the
replica batches full (simulated replicas on CPU; on a pod each replica is a
data-parallel model copy and the balancer state table is the all-gathered
O(R)-integer vector — see serving/balancer.py).

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b --smoke \
      --batch 4 --prompt-len 16 --gen 32
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config, get_smoke_config
from repro.models.registry import get_model
from repro.serving.balancer import simulate


def greedy_decode(cfg, model, params, prompts, gen: int):
    """prompts (B, P) -> generated (B, gen) using the decode cache path."""
    B, P = prompts.shape
    cache, _ = model.init_decode_cache(B, P + gen + 1)
    if cfg.family == "encdec":
        from repro.models import encdec

        frames = jnp.zeros((B, cfg.enc_seq, cfg.d_model), jnp.dtype(cfg.dtype))
        cache = encdec.prime_cross_cache(params, cfg, cache, frames)

    decode = jax.jit(model.decode_fn)
    # prefill token-by-token through the decode path (smoke-scale; a real
    # deployment prefills with the chunked forward then transplants the cache)
    tok = prompts[:, :1]
    for t in range(P):
        logits, cache = decode(params, cache, prompts[:, t : t + 1])
    out = []
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    for _ in range(gen):
        out.append(tok)
        logits, cache = decode(params, cache, tok)
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    return jnp.concatenate(out, axis=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--replicas", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = get_model(cfg)
    params, _ = model.init(jax.random.key(args.seed))
    rng = np.random.default_rng(args.seed)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)), jnp.int32
    )
    t0 = time.perf_counter()
    toks = greedy_decode(cfg, model, params, prompts, args.gen)
    dt = time.perf_counter() - t0
    print(f"[serve] generated {toks.shape} in {dt:.1f}s "
          f"({args.batch * args.gen / dt:.1f} tok/s)")
    print("[serve] sample:", np.asarray(toks[0, :16]))

    # balancer demonstration: hot-shard arrival pattern, with/without
    works = list(rng.integers(8, 256, 64))
    on = simulate(args.replicas, 8, works, balance=True, seed=args.seed)
    off = simulate(args.replicas, 8, works, balance=False, seed=args.seed)
    print(
        f"[balancer] makespan {off['rounds']} -> {on['rounds']} rounds "
        f"({off['rounds']/on['rounds']:.1f}x), idle-slot-steps "
        f"{off['idle_slot_steps']} -> {on['idle_slot_steps']}, "
        f"{on['transfers']} transfers, "
        f"{on['control_ints_per_round']} control ints/round"
    )


if __name__ == "__main__":
    main()
