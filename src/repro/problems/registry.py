"""Problem registry: name -> :class:`~repro.problems.base.BranchingProblem`.

The engine, CLIs and serving plane resolve problems exclusively through
:func:`get_problem`, so adding a workload is: write a plugin module, add one
line here (or call :func:`register` at import time).
"""

from __future__ import annotations

from repro.problems import max_clique, mis, vertex_cover
from repro.problems.base import BranchingProblem

# the paper's own workload; core modules take this as their default
DEFAULT_PROBLEM = "vertex_cover"

REGISTRY: dict = {
    spec.name: spec
    for spec in (vertex_cover.SPEC, max_clique.SPEC, mis.SPEC)
}

ALIASES = {
    "vc": "vertex_cover",
    "min_vertex_cover": "vertex_cover",
    "clique": "max_clique",
    "maximum_independent_set": "mis",
    "independent_set": "mis",
}


def register(spec: BranchingProblem) -> BranchingProblem:
    """Add a plugin to the registry (idempotent for the same object)."""
    have = REGISTRY.get(spec.name)
    if have is not None and have is not spec:
        raise ValueError(f"problem {spec.name!r} already registered")
    REGISTRY[spec.name] = spec
    return spec


def known_problems() -> list:
    return sorted(REGISTRY)


def get_problem(name) -> BranchingProblem:
    """Resolve a problem by name (or pass a spec through unchanged).

    Raises a ``ValueError`` that lists the known names — the CLIs surface it
    verbatim, so a typo'd ``--problem`` tells you what IS available.
    """
    if isinstance(name, BranchingProblem):
        return name
    key = ALIASES.get(name, name)
    if key not in REGISTRY:
        raise ValueError(
            f"unknown problem {name!r}; known problems: "
            f"{', '.join(known_problems())} "
            f"(aliases: {', '.join(sorted(ALIASES))})"
        )
    return REGISTRY[key]
