"""Pure-jnp oracle for the RWKV6 (Finch) WKV recurrence.

Per head with key dim K and value dim V, state S ∈ R^{K×V}:

    o_t = r_t · (S_{t-1} + (u ⊙ k_t) ⊗ v_t)          (the u-bonus "current
    S_t = diag(d_t) S_{t-1} + k_t ⊗ v_t               token counts extra")

with d_t ∈ (0, 1]^K the *data-dependent* per-channel decay (RWKV6's novelty
over RWKV5: d_t = exp(-exp(w_t)) is a function of the token).  The oracle is
a lax.scan over time — O(T) sequential, exact.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def wkv6_ref(
    r: jnp.ndarray,  # (B, T, H, K) receptance ("query")
    k: jnp.ndarray,  # (B, T, H, K)
    v: jnp.ndarray,  # (B, T, H, V)
    decay: jnp.ndarray,  # (B, T, H, K) in (0, 1] -- d_t
    u: jnp.ndarray,  # (H, K) current-token bonus
    initial_state: jnp.ndarray | None = None,  # (B, H, K, V)
):
    """Returns (out (B, T, H, V), final_state (B, H, K, V))."""
    B, T, H, K = r.shape
    V = v.shape[-1]
    S0 = (
        initial_state
        if initial_state is not None
        else jnp.zeros((B, H, K, V), jnp.float32)
    )

    def step(S, inp):
        r_t, k_t, v_t, d_t = inp  # (B,H,K),(B,H,K),(B,H,V),(B,H,K)
        kv = k_t[..., :, None] * v_t[..., None, :]  # (B,H,K,V)
        o = jnp.einsum(
            "bhk,bhkv->bhv", r_t, S + u[None, :, :, None] * kv
        )
        S_new = d_t[..., :, None] * S + kv
        return S_new, o

    xs = (
        r.transpose(1, 0, 2, 3).astype(jnp.float32),
        k.transpose(1, 0, 2, 3).astype(jnp.float32),
        v.transpose(1, 0, 2, 3).astype(jnp.float32),
        decay.transpose(1, 0, 2, 3).astype(jnp.float32),
    )
    S, outs = jax.lax.scan(step, S0, xs)
    return outs.transpose(1, 0, 2, 3).astype(r.dtype), S
