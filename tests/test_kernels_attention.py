"""Flash-attention kernel + blockwise jnp path: sweep vs the exact oracle."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import (
    attention_ref,
    blockwise_attention,
    flash_attention,
)

RNG = np.random.default_rng(0)


def mk(B, Sq, Sk, Hq, Hkv, D, dtype):
    f = lambda *s: jnp.asarray(RNG.standard_normal(s), dtype)
    return f(B, Sq, Hq, D), f(B, Sk, Hkv, D), f(B, Sk, Hkv, D)


CASES = [
    dict(B=2, Sq=64, Sk=64, Hq=4, Hkv=2, D=32, causal=True, window=None),
    dict(B=1, Sq=128, Sk=128, Hq=4, Hkv=1, D=64, causal=True, window=32),
    dict(B=2, Sq=1, Sk=96, Hq=8, Hkv=4, D=32, causal=True, window=None),
    dict(B=1, Sq=50, Sk=50, Hq=2, Hkv=2, D=16, causal=False, window=None),
    dict(B=1, Sq=70, Sk=70, Hq=2, Hkv=1, D=32, causal=True, window=None),
    dict(B=1, Sq=1, Sk=77, Hq=4, Hkv=2, D=64, causal=True, window=24),
    dict(B=3, Sq=33, Sk=33, Hq=6, Hkv=3, D=8, causal=True, window=16),
]


@pytest.mark.parametrize("case", CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pallas_kernel(case, dtype):
    q, k, v = mk(case["B"], case["Sq"], case["Sk"], case["Hq"], case["Hkv"],
                 case["D"], dtype)
    ref = attention_ref(q.astype(jnp.float32), k.astype(jnp.float32),
                        v.astype(jnp.float32), causal=case["causal"],
                        window=case["window"])
    got = flash_attention(q, k, v, causal=case["causal"], window=case["window"],
                          block_q=32, block_k=32)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    assert float(jnp.abs(ref - got.astype(jnp.float32)).max()) < tol


@pytest.mark.parametrize("case", CASES)
def test_blockwise_path(case):
    q, k, v = mk(case["B"], case["Sq"], case["Sk"], case["Hq"], case["Hkv"],
                 case["D"], jnp.float32)
    ref = attention_ref(q, k, v, causal=case["causal"], window=case["window"])
    got = blockwise_attention(q, k, v, causal=case["causal"],
                              window=case["window"], block_k=16)
    assert float(jnp.abs(ref - got).max()) < 1e-5


def test_block_size_invariance():
    q, k, v = mk(1, 96, 96, 2, 2, 32, jnp.float32)
    ref = attention_ref(q, k, v, causal=True, window=None)
    for bq, bk in [(16, 16), (32, 64), (96, 96), (128, 128)]:
        got = flash_attention(q, k, v, causal=True, block_q=bq, block_k=bk)
        assert float(jnp.abs(ref - got).max()) < 1e-5, (bq, bk)
