"""SPMD superstep engine: the TPU adaptation of the semi-centralized strategy.

One superstep =

  1. **explore** — each worker expands up to ``lanes`` of its deepest tasks
     for ``steps_per_round`` rounds (the paper's exploration threads);
  2. **control plane** — each worker contributes THREE integers
     (pending count, shallowest pending depth, local best value) to an
     all-gather: this is the paper's "every message is a single integer"
     budget, and the gathered (P, 3) table is the entire center state;
  3. **replicated center** — every worker deterministically computes the same
     idle→donor matching from the table (`getNextWorkingNode` over RUNNING
     workers; priority = shallowest pending task, or round-robin "random");
  4. **data plane** — matched donors pop up to ``donate_k`` of their
     *shallowest* tasks (Alg. 6, batched) and the fixed-size records move to
     the idle worker.  Two implementations (§Perf in EXPERIMENTS.md):

       ``transfer_impl="sparse"`` (default) — each donor scatters its record
       block into a zero (P, k, REC) buffer addressed by ``send_to`` and ONE
       ``psum`` delivers it; rows for unmatched workers are zero, so the
       payload actually carrying tasks scales with ``n_match`` (and the
       whole collective is skipped on match-free rounds — zero bytes);

       ``transfer_impl="gather"`` — the all-gather + select reference path
       kept for A/B benchmarking: every transfer round moves the full
       (P, k, REC) table regardless of how few records matched;
  5. **best-value broadcast** — global best = min over workers (the paper's
     ``bestval_update`` verify-then-broadcast collapses to one pmin).

Failure-free guarantee (paper §3.1): the matcher only pairs an idle worker
with a donor whose ``pending >= 2``, donors keep at least one task
(``donated = min(k, pending - 1)``), and in BSP the transfer completes inside
the same superstep — a matched idle worker ALWAYS receives a task, no retries.

Termination (paper §3.3): transfers cannot straddle a superstep boundary, so
``psum(pending) == 0`` after the transfer phase is exact quiescence — the
sent/ack counting and timeout safety mechanisms of the MPI implementation are
subsumed by the BSP barrier.

The same function runs under ``jax.vmap(axis_name=...)`` (P virtual workers
on one device — used by tests) and shard_map (one worker per mesh device —
used by the launcher and the multi-pod dry-run).  ``build_chunk_fn`` wraps
either path in a device-resident ``lax.while_loop`` that runs up to K
supersteps per host sync, checking quiescence (and the FPT bound) on device —
the host only syncs once per chunk, so round latency is hardware-bound, not
host-dispatch-bound.
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.frontier import (
    Frontier,
    make_frontier,
    pending_per_worker,
    pop_deepest,
    pop_deepest_cheap,
    pop_k_shallowest,
    push_many,
)
from repro.problems.base import (
    DATA_IN_AXES,
    BranchingProblem,
    ProblemData,
    compose_expand_tasks,
    resolve_expand,
)

# explore-phase implementations (§Perf, EXPERIMENTS.md §F):
#   "reference" — per-task callables (task_bound / branch_once / child_bound
#                 as three separate vmapped calls) + full-capacity top_k pop;
#                 no repro.kernels dependency (arch-guarded), the bit-exact
#                 baseline kept for A/B and goldens;
#   "fused"     — the problem's one-pass batched expand_tasks (hand-fused
#                 impls share degrees/popcounts and ride the Pallas bitset
#                 kernel on TPU; other plugins get the composed default) +
#                 the cheap depth-major frontier pop.  Bit-identical to the
#                 reference by contract (golden- and property-tested).
# These tuples are THE registries for the two hot-path knobs —
# SolveConfig._validate imports them, so the engine and the config can never
# disagree about what is valid.
EXPLORE_IMPLS = ("fused", "reference")
TRANSFER_IMPLS = ("sparse", "gather")


def _shard_map(body, *, mesh, in_specs, out_specs):
    """shard_map across jax versions (top-level on newer, experimental on
    0.4.x).  The 0.4.x replication checker has no rule for ``while`` — the
    chunked runner's device-resident loop — so replication checking is
    disabled where the kwarg exists.  Kept local so :mod:`repro.core` stays
    launch-independent."""
    fn = getattr(jax, "shard_map", None)
    if fn is None:
        from jax.experimental.shard_map import shard_map as fn
    try:
        return fn(
            body, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_rep=False,
        )
    except TypeError:  # newer jax renamed/removed check_rep
        return fn(body, mesh=mesh, in_specs=in_specs, out_specs=out_specs)


class WorkerState(NamedTuple):
    frontier: Frontier
    best_val: jnp.ndarray  # () int32 -- global best seen (paper: global_bestval)
    local_best_val: jnp.ndarray  # () int32 -- best found by THIS worker
    best_sol: jnp.ndarray  # (W,) uint32 -- the cover achieving local_best_val
    nodes_expanded: jnp.ndarray  # () int32
    tasks_sent: jnp.ndarray  # () int32
    tasks_recv: jnp.ndarray  # () int32
    rounds: jnp.ndarray  # () int32
    # collective-traffic accounting, carried ON DEVICE so the chunked runner
    # never has to sync for stats (replicated: same value on every worker)
    transfer_rounds: jnp.ndarray  # () int32 -- rounds that ran the data plane
    payload_words: jnp.ndarray  # () int32 -- u32 words moved by the data plane

    @property
    def overflow_count(self) -> jnp.ndarray:
        """Tasks this worker lost to frontier saturation (cumulative () int32
        stat, owned by ``frontier.dropped`` — push_many maintains it).  0
        under engine-sized capacity; surfaced per instance as
        ``SolveResult.stats["overflow_count"]``."""
        return self.frontier.dropped


def make_worker_state(capacity: int, W: int, initial_best: int) -> WorkerState:
    z = jnp.int32(0)
    return WorkerState(
        frontier=make_frontier(capacity, W),
        best_val=jnp.int32(initial_best),
        local_best_val=jnp.int32(initial_best),
        best_sol=jnp.zeros((W,), jnp.uint32),
        nodes_expanded=z,
        tasks_sent=z,
        tasks_recv=z,
        rounds=z,
        transfer_rounds=z,
        payload_words=z,
    )


# -- phase 1: exploration ------------------------------------------------------


def _explore_one_round(
    problem: BranchingProblem,
    data: ProblemData,
    state: WorkerState,
    lanes: int,
    explore_impl: str = "reference",
):
    """Pop up to ``lanes`` deepest tasks, expand each, push children.

    Problem-generic: the plugin supplies ``task_bound`` (admissible bound on
    the internal objective, gates expansion), ``branch_once`` (one node
    expansion -> :class:`BranchStep`) and ``child_bound`` (cheap birth-time
    prune).  The engine always minimizes internal values.

    ``explore_impl`` picks the hot-path implementation (:data:`EXPLORE_IMPLS`):
    the reference path sweeps the lane batch once per callable (plus a
    full-capacity top_k pop); the fused path pops via the cheap depth-major
    selection and expands through the plugin's one-pass ``expand_tasks``.
    Both produce bit-identical states.
    """
    if explore_impl == "fused":
        f, masks, sols, depths, valid = pop_deepest_cheap(state.frontier, lanes)
        expand = resolve_expand(problem)
    else:
        f, masks, sols, depths, valid = pop_deepest(state.frontier, lanes)
        # ALWAYS the composed per-task callables — one source of truth with
        # the fused path's default, so the two can never desynchronize
        expand = compose_expand_tasks(problem)
    ex = expand(data, masks, sols)
    bounds, res = ex.bound, ex.step
    left_bound, right_bound = ex.left_bound, ex.right_bound

    not_pruned = valid & (bounds < state.best_val)

    # terminal candidates -> best update (paper: handleSolution + bestval)
    term = not_pruned & res.is_terminal & (res.terminal_value < state.best_val)
    term_val = jnp.where(term, res.terminal_value, jnp.int32(1 << 30))
    li = jnp.argmin(term_val)
    found_val = term_val[li]  # 1<<30 when no lane found a terminal
    # local best only improves with terminals THIS worker found (its stored
    # solution must actually achieve local_best_val); the global view may also
    # shrink via the pmin in the communication phase.
    new_sol = jnp.where(
        found_val < state.local_best_val, res.terminal_sol[li], state.best_sol
    )
    new_local = jnp.minimum(state.local_best_val, found_val)
    new_best = jnp.minimum(state.best_val, found_val)

    # children push: [left_0..left_L, right_0..right_L], pruned-at-birth when
    # the cheap bound says they cannot beat best (host reference does the same).
    expandable = not_pruned & ~res.is_terminal
    cdepth = depths + 1
    lvalid = expandable & (left_bound < new_best)
    rvalid = expandable & (right_bound < new_best)
    all_masks = jnp.concatenate([res.left_mask, res.right_mask], axis=0)
    all_sols = jnp.concatenate([res.left_sol, res.right_sol], axis=0)
    all_depths = jnp.concatenate([cdepth, cdepth], axis=0)
    all_valid = jnp.concatenate([lvalid, rvalid], axis=0)
    f = push_many(f, all_masks, all_sols, all_depths, all_valid)

    return state._replace(
        frontier=f,
        best_val=new_best,
        local_best_val=new_local,
        best_sol=new_sol,
        nodes_expanded=state.nodes_expanded + valid.sum().astype(jnp.int32),
    )


def explore_phase(
    problem: BranchingProblem,
    data: ProblemData,
    state: WorkerState,
    steps: int,
    lanes: int,
    explore_impl: str = "reference",
) -> WorkerState:
    def body(_, s):
        return _explore_one_round(problem, data, s, lanes, explore_impl)

    return jax.lax.fori_loop(0, steps, body, state)


# -- phase 3: the replicated center -------------------------------------------


def match_idle_to_donors(
    pending: jnp.ndarray,  # (P,) int32
    top_depth: jnp.ndarray,  # (P,) int32 (BIG_DEPTH when empty)
    policy_priority: bool,
    round_idx: jnp.ndarray,  # () int32 -- salt for the round-robin policy
):
    """The center's `getNextWorkingNode`, replicated: every worker computes
    the same matching from the same (P,) status vectors.

    Returns (send_to, recv_from): per-worker partner index or -1.
    Donors need pending >= 2 (donate one, keep one — failure-free).
    'priority' ranks donors by shallowest pending depth (heaviest task,
    paper §3.2 metadata policy); 'random' becomes a round-salted round-robin
    (deterministic — required for SPMD replication — but unbiased over time).
    """
    P = pending.shape[0]
    idx = jnp.arange(P, dtype=jnp.int32)
    idle = pending == 0
    donor = pending >= 2

    # rank idle workers 0..n_idle-1 in index order
    idle_rank = jnp.where(idle, jnp.cumsum(idle.astype(jnp.int32)) - 1, -1)

    # order donors: priority -> by (top_depth, idx); round-robin -> by
    # ((idx + salt) mod P, idx) which rotates who donates first each round.
    if policy_priority:
        donor_key = top_depth * P + idx
    else:
        donor_key = (idx + round_idx) % P
    donor_key = jnp.where(donor, donor_key, jnp.int32(1 << 30))
    donor_order = jnp.argsort(donor_key)  # donors first, in key order
    donor_rank = jnp.zeros((P,), jnp.int32).at[donor_order].set(idx)
    donor_rank = jnp.where(donor, donor_rank, -1)

    # donor with rank k serves idle with rank k
    n_idle = idle.sum()
    n_donor = donor.sum()
    n_match = jnp.minimum(n_idle, n_donor)

    # send_to[w] = idle worker with rank donor_rank[w] (if matched)
    idle_by_rank = jnp.zeros((P,), jnp.int32).at[
        jnp.where(idle, idle_rank, P)
    ].set(idx, mode="drop")
    send_to = jnp.where(
        donor & (donor_rank < n_match), idle_by_rank[jnp.clip(donor_rank, 0, P - 1)], -1
    )
    donor_by_rank = jnp.zeros((P,), jnp.int32).at[
        jnp.where(donor, donor_rank, P)
    ].set(idx, mode="drop")
    recv_from = jnp.where(
        idle & (idle_rank < n_match), donor_by_rank[jnp.clip(idle_rank, 0, P - 1)], -1
    )
    return send_to, recv_from


# -- the full superstep ---------------------------------------------------------


def superstep(
    problem: BranchingProblem,
    data: ProblemData,
    state: WorkerState,
    *,
    axis_name: str,
    steps_per_round: int,
    lanes: int,
    policy_priority: bool = True,
    transfer_pad_words: int = 0,
    packed_status: bool = True,
    skip_empty_transfer: bool = True,
    transfer_impl: str = "sparse",
    donate_k: int = 1,
    explore_impl: str = "reference",
):
    """One BSP round for a single worker (replicated via vmap/shard_map).

    ``transfer_pad_words`` emulates the paper's *basic* encoding (§4.3): the
    task record is padded by n·W words of (redundant) adjacency payload so the
    collective moves the same bytes the MPI version would — used by the
    encoding benchmark; 0 = optimized encoding.

    §Perf knobs (EXPERIMENTS.md):
      packed_status       — (pending, top_depth) bit-packed into ONE i32 per
                            worker (+ a scalar pmin for the bound) instead of
                            a 3-int row: the control-plane gather shrinks 3x.
      skip_empty_transfer — the data-plane collective runs under a cond that
                            every worker evaluates identically from the
                            replicated table; rounds with no match move ZERO
                            payload.
      transfer_impl       — "sparse": donors scatter their record block into a
                            zero (P, k, REC) buffer by ``send_to`` and one
                            psum delivers it (payload records == matches);
                            "gather": all-gather + select reference path
                            (payload == the full P·k record table).
      donate_k            — a matched donor sends up to ``donate_k`` of its
                            shallowest tasks (always keeping one), filling a
                            starved worker in one rebalance round.
      explore_impl        — "fused": one-pass batched expansion + cheap
                            depth-major frontier pop; "reference": per-task
                            callables + full-capacity top_k.  Bit-identical
                            traces (see :data:`EXPLORE_IMPLS`).

    Returns (state, done) where done is the exact global quiescence flag.
    """
    if transfer_impl not in TRANSFER_IMPLS:
        raise ValueError(
            f"unknown transfer_impl: {transfer_impl!r}; "
            f"valid: {', '.join(TRANSFER_IMPLS)}"
        )
    if explore_impl not in EXPLORE_IMPLS:
        raise ValueError(
            f"unknown explore_impl: {explore_impl!r}; "
            f"valid: {', '.join(EXPLORE_IMPLS)}"
        )
    if donate_k < 1:
        # a matched donor must ship at least one task, or the failure-free
        # guarantee (a matched idle worker ALWAYS receives work) breaks
        raise ValueError(f"donate_k must be >= 1, got {donate_k}")
    W = state.best_sol.shape[0]
    # the frontier's native task record: (mask, sol, depth) — problem-
    # independent by construction (every plugin uses the packed-state layout)
    rec_words = 2 * W + 1 + transfer_pad_words

    # 1. explore
    state = explore_phase(
        problem, data, state, steps_per_round, lanes, explore_impl
    )

    # 2. control plane through the "center" + 5. best-value broadcast
    pending = state.frontier.pending()
    top_depth = state.frontier.top_priority_depth()
    if packed_status:
        # one i32 per worker: pending (15b) | clamped depth (16b)
        word = (jnp.clip(pending, 0, 0x7FFF) << 16) | jnp.clip(
            top_depth, 0, 0xFFFF
        )
        table_w = jax.lax.all_gather(word, axis_name)  # (P,)
        pend_t = table_w >> 16
        depth_t = table_w & 0xFFFF
        global_best = jax.lax.pmin(
            jnp.minimum(state.local_best_val, state.best_val), axis_name
        )
    else:
        my_status = jnp.stack([pending, top_depth, state.local_best_val])
        table = jax.lax.all_gather(my_status, axis_name)  # (P, 3)
        pend_t, depth_t = table[:, 0], table[:, 1]
        global_best = jnp.minimum(table[:, 2].min(), state.best_val)
    state = state._replace(best_val=global_best)

    # 3. replicated center matching
    P = pend_t.shape[0]
    me = jax.lax.axis_index(axis_name).astype(jnp.int32)
    send_to, recv_from = match_idle_to_donors(
        pend_t, depth_t, policy_priority, state.rounds
    )
    n_match = (send_to >= 0).sum()
    # records each donor actually ships (>=1 when matched: pending >= 2);
    # replicated, so donor AND receiver count the block identically.
    n_don = jnp.where(
        send_to >= 0,
        jnp.minimum(jnp.int32(donate_k), pend_t - 1),
        jnp.int32(0),
    )  # (P,)

    # 4. data plane: donor pops its shallowest block; record row =
    #    (mask, sol, depth[, pad])
    def do_transfer(state):
        f2, d_masks, d_sols, d_depths, d_valid = pop_k_shallowest(
            state.frontier, donate_k, limit=n_don[me]
        )
        record = jnp.concatenate(
            [d_masks, d_sols, d_depths[:, None].astype(jnp.uint32)], axis=1
        )
        if transfer_pad_words:
            record = jnp.concatenate(
                [record, jnp.zeros((donate_k, transfer_pad_words), jnp.uint32)],
                axis=1,
            )
        record = jnp.where(d_valid[:, None], record, jnp.uint32(0))

        my_src = recv_from[me]
        i_recv = my_src >= 0
        if transfer_impl == "gather":
            # reference path: all-gather the full record table (indexed by
            # DONOR), select my donor's block
            all_records = jax.lax.all_gather(record, axis_name)  # (P, k, REC)
            got = all_records[jnp.clip(my_src, 0, P - 1)]  # (k, REC)
            moved_words = jnp.int32(P * donate_k * rec_words)
        else:
            # sparse path: scatter my block into the row my RECEIVER owns;
            # one psum delivers every matched block at once (unmatched rows
            # stay zero — the payload is exactly the matched records), and
            # each receiver reads its own row.
            buf = jnp.zeros((P, donate_k, rec_words), jnp.uint32)
            tgt = jnp.where(send_to[me] >= 0, send_to[me], jnp.int32(P))
            buf = buf.at[tgt].set(record, mode="drop")
            delivered = jax.lax.psum(buf, axis_name)  # (P, k, REC)
            got = delivered[me]  # (k, REC)
            moved_words = n_don.sum() * rec_words
        recv_valid = i_recv & (
            jnp.arange(donate_k) < n_don[jnp.clip(my_src, 0, P - 1)]
        )
        new_frontier = push_many(
            f2,
            got[:, :W],
            got[:, W : 2 * W],
            got[:, 2 * W].astype(jnp.int32),
            recv_valid,
        )
        return state._replace(
            frontier=new_frontier,
            tasks_sent=state.tasks_sent + n_don[me],
            tasks_recv=state.tasks_recv + recv_valid.sum().astype(jnp.int32),
            transfer_rounds=state.transfer_rounds + 1,
            payload_words=state.payload_words + moved_words,
        )

    if skip_empty_transfer:
        # n_match derives from the replicated table: every worker takes the
        # same branch, so the collective inside the cond is safe.
        state = jax.lax.cond(n_match > 0, do_transfer, lambda s: s, state)
    else:
        state = do_transfer(state)
    state = state._replace(rounds=state.rounds + 1)

    # exact termination: nothing pending anywhere after the transfer phase
    total_pending = jax.lax.psum(state.frontier.pending(), axis_name)
    done = total_pending == 0
    return state, done


def build_superstep_fn(
    problem: BranchingProblem,
    data: ProblemData,
    *,
    num_workers: int,
    steps_per_round: int,
    lanes: int,
    policy_priority: bool = True,
    transfer_pad_words: int = 0,
    packed_status: bool = True,
    skip_empty_transfer: bool = True,
    transfer_impl: str = "sparse",
    donate_k: int = 1,
    explore_impl: str = "reference",
    mesh=None,
    axis_name: str = "workers",
):
    """Return a jitted ``state -> (state, done)`` over stacked (P, ...) state.

    mesh=None  -> vmap over the leading axis (P virtual workers, one device).
    mesh given -> shard_map over the mesh axis ``axis_name`` (one worker per
                  device; state leading axis must equal mesh size).

    One host sync per superstep — prefer :func:`build_chunk_fn` for solve
    loops; this remains the single-round entry point for tests/benchmarks.
    """
    step = functools.partial(
        superstep,
        problem,
        data,
        axis_name=axis_name,
        steps_per_round=steps_per_round,
        lanes=lanes,
        policy_priority=policy_priority,
        transfer_pad_words=transfer_pad_words,
        packed_status=packed_status,
        skip_empty_transfer=skip_empty_transfer,
        transfer_impl=transfer_impl,
        donate_k=donate_k,
        explore_impl=explore_impl,
    )
    if mesh is None:
        vstep = jax.vmap(step, axis_name=axis_name)

        def run(state):
            state, done = vstep(state)
            return state, done.all()

        return jax.jit(run)

    from jax.sharding import PartitionSpec as P

    spec = P(axis_name)

    def body(state_block):
        # each shard sees a (1, ...) block: strip, step, restore
        state = jax.tree.map(lambda x: x[0], state_block)
        state, done = step(state)
        return jax.tree.map(lambda x: x[None], state), done

    return jax.jit(
        _shard_map(body, mesh=mesh, in_specs=(spec,), out_specs=(spec, P()))
    )


# -- parametric compiled planes ------------------------------------------------
#
# The builders below close over NOTHING instance-specific: `ProblemData` (and
# the FPT bound) are call-time arguments of the returned jitted function, so
# ONE executable serves every same-shape instance — the session-level
# compiled-plane cache (repro.api) keys these functions by configuration and
# lets jax's own trace cache specialize per (n, W, capacity) shape.  A warm
# repeat solve therefore re-traces nothing.
#
# `PLANE_TRACES` counts actual traces: it is bumped by a host side effect
# inside the traced body, which only runs when jax (re)traces — tests and the
# session's cache_stats() use it as the ground-truth compile counter.

PLANE_TRACES = 0


def _count_plane_trace() -> None:
    global PLANE_TRACES
    PLANE_TRACES += 1


def build_plane_fn(
    problem: BranchingProblem,
    *,
    steps_per_round: int,
    lanes: int,
    policy_priority: bool = True,
    transfer_pad_words: int = 0,
    packed_status: bool = True,
    skip_empty_transfer: bool = True,
    transfer_impl: str = "sparse",
    donate_k: int = 1,
    explore_impl: str = "reference",
    chunk_rounds: int = 16,
    use_fpt: bool = False,
    axis_name: str = "workers",
):
    """Parametric solo chunk runner (vmap virtual workers).

    Returns a jitted ``(data, state) -> (state, done, ran, hot)`` — or, with
    ``use_fpt``, ``(data, state, fpt_bound) -> ...`` where ``fpt_bound`` is
    the () int32 INTERNAL decision target.  ``hot`` is the (P,) int32
    per-worker pending count after the chunk — the spill pump's eviction
    trigger, computed on device so the host decides whether to pump from
    scalars it already fetched.  Semantics are otherwise identical to
    :func:`build_chunk_fn` (mesh=None); the difference is purely that the
    instance tensors are arguments, so the function is reusable across
    same-shape instances without re-tracing.
    """
    if chunk_rounds < 1:
        raise ValueError(f"chunk_rounds must be >= 1, got {chunk_rounds}")
    step = functools.partial(
        superstep,
        problem,
        axis_name=axis_name,
        steps_per_round=steps_per_round,
        lanes=lanes,
        policy_priority=policy_priority,
        transfer_pad_words=transfer_pad_words,
        packed_status=packed_status,
        skip_empty_transfer=skip_empty_transfer,
        transfer_impl=transfer_impl,
        donate_k=donate_k,
        explore_impl=explore_impl,
    )

    def cond(carry):
        _, done, i = carry
        return jnp.logical_not(done) & (i < chunk_rounds)

    def _run(data, state, fpt_bound):
        _count_plane_trace()
        vstep = jax.vmap(lambda s: step(data, s), axis_name=axis_name)

        def body(carry):
            state, _, i = carry
            state, done = vstep(state)
            done = done.all()
            if use_fpt:
                done = done | (state.best_val.min() <= fpt_bound)
            return state, done, i + 1

        state, done, i = jax.lax.while_loop(
            cond, body, (state, jnp.bool_(False), jnp.int32(0))
        )
        return state, done, i, pending_per_worker(state.frontier)

    if use_fpt:
        return jax.jit(_run)
    return jax.jit(lambda data, state: _run(data, state, None))


def build_batch_plane_fn(
    problem: BranchingProblem,
    *,
    steps_per_round: int,
    lanes: int,
    policy_priority: bool = True,
    transfer_pad_words: int = 0,
    packed_status: bool = True,
    skip_empty_transfer: bool = True,
    transfer_impl: str = "sparse",
    donate_k: int = 1,
    explore_impl: str = "reference",
    chunk_rounds: int = 16,
    use_fpt: bool = False,
    axis_name: str = "workers",
):
    """Parametric batch chunk runner over (B, P, ...) stacked state.

    Returns a jitted ``(datas, state, done) -> (state, done, rounds_delta,
    ran, hot)`` — with ``use_fpt``, an extra trailing ``fpt_bounds`` (B,)
    int32 argument.  ``hot`` is the (B, P) int32 per-lane, per-worker
    pending count after the chunk (the spill pump's trigger, see
    :func:`build_plane_fn`).  Same contract as
    :func:`build_batch_chunk_fn`, but the batched
    instance tensors are call-time arguments: host-side compaction can
    reslice and keep calling the SAME function, and a later batch with
    previously-seen shapes reuses the executable outright.
    """
    if chunk_rounds < 1:
        raise ValueError(f"chunk_rounds must be >= 1, got {chunk_rounds}")
    step = functools.partial(
        superstep,
        problem,
        axis_name=axis_name,
        steps_per_round=steps_per_round,
        lanes=lanes,
        policy_priority=policy_priority,
        transfer_pad_words=transfer_pad_words,
        packed_status=packed_status,
        skip_empty_transfer=skip_empty_transfer,
        transfer_impl=transfer_impl,
        donate_k=donate_k,
        explore_impl=explore_impl,
    )

    def one_instance(data, state):
        state, done = jax.vmap(
            lambda s: step(data, s), axis_name=axis_name
        )(state)
        return state, done.all()

    bstep = jax.vmap(one_instance, in_axes=(DATA_IN_AXES, 0))

    def cond(carry):
        _, done, _, i = carry
        return jnp.logical_not(done.all()) & (i < chunk_rounds)

    def _run(datas, state, done, fpt_bounds):
        _count_plane_trace()

        def body(carry):
            state, done, rounds_delta, i = carry
            new_state, step_done = bstep(datas, state)
            # freeze finished lanes (see build_batch_chunk_fn)
            state = jax.tree.map(
                lambda old, new: jnp.where(_expand_like(done, new), old, new),
                state,
                new_state,
            )
            new_done = done | step_done
            if use_fpt:
                new_done = new_done | (state.best_val[:, 0] <= fpt_bounds)
            rounds_delta = rounds_delta + jnp.where(done, 0, 1).astype(jnp.int32)
            return state, new_done, rounds_delta, i + 1

        B = done.shape[0]
        state, done, rounds_delta, i = jax.lax.while_loop(
            cond, body, (state, done, jnp.zeros((B,), jnp.int32), jnp.int32(0))
        )
        return state, done, rounds_delta, i, pending_per_worker(state.frontier)

    if use_fpt:
        return jax.jit(_run)
    return jax.jit(lambda datas, state, done: _run(datas, state, done, None))


# -- the instance axis ---------------------------------------------------------
#
# `solve_many` stacks B independent instances in front of the worker axis:
# state leaves become (B, P, ...) and the problem data gains per-instance
# leaves (adj (B, n, W), n (B,)) while word_idx/bit_idx stay shared
# (`problems.base.DATA_IN_AXES`).  The collectives inside `superstep` are
# bound to the WORKER axis name, so vmapping the whole worker-mapped step
# over an unnamed instance axis keeps every all-gather / psum / pmin confined
# to one instance: donation cannot cross the instance axis by construction
# (tested in tests/test_solve_many.py).


def _expand_like(flags: jnp.ndarray, leaf: jnp.ndarray) -> jnp.ndarray:
    """Broadcast a (B,) flag vector against a (B, ...) state leaf."""
    return flags.reshape(flags.shape + (1,) * (leaf.ndim - 1))


# -- the lane lifecycle --------------------------------------------------------
#
# A *lane* is one instance slot of the batched plane: worker-state leaves
# (B, P, ...) plus the per-lane control scalars.  `LaneState` makes the
# lifecycle explicit so a batch is no longer an all-or-nothing unit of work:
# the host can step the plane one chunk at a time (`step_lanes`), slice a
# finished lane's state out (`lane_slice`), retire it (`lane_retire`) and
# swap a NEW instance into the freed slot (`lane_swap_in`) — all data-only
# writes against the parametric batch plane, so a long-lived "live" plane
# admits work forever without re-tracing.  Both the run-to-completion
# `solve_many` driver and the continuous solve service (repro.api.service)
# are built from these four verbs.


class LaneState(NamedTuple):
    """Per-lane lifecycle state of a live batched plane.

    ``worker``  — (B, P, ...) stacked :class:`WorkerState` (the plane state);
    ``done``    — (B,) bool: quiescent/FPT-finished OR vacant (frozen no-op);
    ``tag``     — (B,) host int32: the occupant's instance tag, -1 = vacant.
                  Kept as a numpy array: tags are pure host bookkeeping (the
                  plane never reads them) and the scheduler consults them
                  every chunk, so a device round-trip per lookup would be
                  wasted;
    ``rounds``  — (B,) int32: supersteps run by the CURRENT occupant (reset
                  on swap-in).
    """

    worker: WorkerState
    done: jnp.ndarray
    tag: object  # (B,) np.int32 — host-side, see class docstring
    rounds: jnp.ndarray

    @property
    def num_lanes(self) -> int:
        return self.done.shape[0]

    def occupied(self):
        """(B,) host bool — lanes holding a (possibly finished) instance."""
        return np.asarray(self.tag) >= 0


def make_vacant_lanes(
    num_lanes: int, num_workers: int, capacity: int, W: int
) -> LaneState:
    """An all-vacant live plane: every lane is a frozen no-op (``done``)
    until an instance is swapped in."""
    one = jax.vmap(lambda _: make_worker_state(capacity, W, 0))(
        jnp.arange(num_workers)
    )
    worker = jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (num_lanes,) + x.shape), one
    )
    return LaneState(
        worker=worker,
        done=jnp.ones((num_lanes,), bool),
        tag=np.full((num_lanes,), -1, np.int32),
        rounds=jnp.zeros((num_lanes,), jnp.int32),
    )


def lane_slice(lanes: LaneState, lane: int) -> WorkerState:
    """One lane's (P, ...) worker state, sliced out for result extraction."""
    return jax.tree.map(lambda x: x[lane], lanes.worker)


# the admission write, jitted: one fused executable per lane-state shape
# instead of ~15 eager scatter dispatches per swap-in (`lane` is a traced
# scalar, so every lane index shares the executable)
@jax.jit
def _swap_in_dev(worker_full, worker_one, done, rounds, lane):
    return (
        jax.tree.map(
            lambda full, one: full.at[lane].set(one), worker_full, worker_one
        ),
        done.at[lane].set(False),
        rounds.at[lane].set(0),
    )


def lane_swap_in(
    lanes: LaneState, lane: int, worker: WorkerState, tag: int
) -> LaneState:
    """Admit a freshly startup-scattered instance into ``lane``.

    ``worker`` is a solo (P, ...) state (same shapes as one lane).  The lane
    un-freezes (``done`` False), its round counter resets, and its tag
    records the occupant.  Pure data writes — the compiled plane is reused
    as-is, no re-trace (asserted via ``PLANE_TRACES`` in tests).
    """
    new_tag = np.asarray(lanes.tag).copy()
    new_tag[lane] = tag
    new_worker, new_done, new_rounds = _swap_in_dev(
        lanes.worker, worker, lanes.done, lanes.rounds, jnp.int32(lane)
    )
    return LaneState(
        worker=new_worker, done=new_done, tag=new_tag, rounds=new_rounds
    )


# the stall write-back, jitted like _swap_in_dev: restore one lane's worker
# state AND its done/rounds flags exactly as sliced (no swap-in resets).
# Used to freeze a stalled lane across a chunk — the plane steps it, then
# the snapshot is written back so the lane observably made no progress —
# without touching the compiled plane (traced lane index, shared executable).
@jax.jit
def _write_back_dev(worker_full, worker_one, done_full, done_one,
                    rounds_full, rounds_one, lane):
    return (
        jax.tree.map(
            lambda full, one: full.at[lane].set(one), worker_full, worker_one
        ),
        done_full.at[lane].set(done_one),
        rounds_full.at[lane].set(rounds_one),
    )


def lane_write_back(
    lanes: LaneState, lane: int, worker: WorkerState, done, rounds
) -> LaneState:
    """Overwrite one lane with a previously sliced snapshot: the (P, ...)
    ``worker`` state plus the exact ``done`` flag and ``rounds`` counter
    (contrast :func:`lane_swap_in`, which resets both).  The tag is
    untouched — the occupant never changed."""
    new_worker, new_done, new_rounds = _write_back_dev(
        lanes.worker, worker, lanes.done, jnp.asarray(done, bool),
        lanes.rounds, jnp.asarray(rounds, jnp.int32), jnp.int32(lane)
    )
    return lanes._replace(
        worker=new_worker, done=new_done, rounds=new_rounds
    )


_retire_dev = jax.jit(lambda done, lane: done.at[lane].set(True))
_resume_dev = jax.jit(lambda done, lane: done.at[lane].set(False))


def lane_resume(lanes: LaneState, lane: int) -> LaneState:
    """Un-freeze a quiescent lane WITHOUT touching its occupant: the spill
    pump re-admitted cold tasks into its frontier, so the "done" verdict the
    plane reached no longer holds and the lane must keep stepping."""
    return lanes._replace(done=_resume_dev(lanes.done, jnp.int32(lane)))


def lane_retire(lanes: LaneState, lane: int) -> LaneState:
    """Mark a lane vacant (after collecting its result, or on deadline
    eviction): frozen no-op until the next swap-in.  The stale worker state
    is inert — admission overwrites every leaf."""
    new_tag = np.asarray(lanes.tag).copy()
    new_tag[lane] = -1
    return lanes._replace(
        done=_retire_dev(lanes.done, jnp.int32(lane)), tag=new_tag
    )


def slice_lanes(lanes: LaneState, sel) -> LaneState:
    """Select/reorder lanes (host-side batch compaction): every leaf —
    device and host alike — is indexed by ``sel`` along the lane axis."""
    return jax.tree.map(lambda x: x[sel], lanes)


def step_lanes(plane, datas, lanes: LaneState, fpt_bounds=None):
    """One resumable plane step: run up to ``chunk_rounds`` supersteps of a
    :func:`build_batch_plane_fn` executable over the live lanes.

    Finished and vacant lanes are frozen inside the plane (their state and
    per-occupant stats stay bit-identical to a solo run); ``rounds``
    accumulates each occupant's actual supersteps.  Returns ``(lanes, ran,
    hot)`` where ``ran`` is the chunk's superstep count (0 when every lane
    was already done — the plane's while_loop exits immediately) and ``hot``
    is the (B, P) per-worker pending count (the spill-pump trigger).
    """
    if fpt_bounds is not None:
        worker, done, delta, ran, hot = plane(
            datas, lanes.worker, lanes.done, fpt_bounds
        )
    else:
        worker, done, delta, ran, hot = plane(datas, lanes.worker, lanes.done)
    return (
        lanes._replace(worker=worker, done=done, rounds=lanes.rounds + delta),
        ran,
        hot,
    )


# -- checkpoint (de)serialization ----------------------------------------------
#
# The engine carries its ENTIRE trajectory state on device (frontier task
# records, bounds, stats counters, the round-robin donor salt in `rounds`),
# so a checkpoint is exactly these named arrays — flat stable names, one per
# leaf, consumed by repro.checkpoint.solve.  Explicit field-by-field code
# (not a generic tree flatten) so a schema change here is a visible,
# reviewed change to the checkpoint format.


def worker_state_to_flat(state: WorkerState, prefix: str = "worker") -> dict:
    """A (possibly batched) :class:`WorkerState` as named host arrays."""
    host = jax.device_get(state)
    flat = {
        f"{prefix}.frontier.{name}": np.asarray(leaf)
        for name, leaf in host.frontier._asdict().items()
    }
    for name, leaf in host._asdict().items():
        if name != "frontier":
            flat[f"{prefix}.{name}"] = np.asarray(leaf)
    return flat


def worker_state_from_flat(flat: dict, prefix: str = "worker") -> WorkerState:
    frontier = Frontier(
        **{
            name: jnp.asarray(flat[f"{prefix}.frontier.{name}"])
            for name in Frontier._fields
        }
    )
    rest = {
        name: jnp.asarray(flat[f"{prefix}.{name}"])
        for name in WorkerState._fields
        if name != "frontier"
    }
    return WorkerState(frontier=frontier, **rest)


def lane_state_to_flat(lanes: LaneState, prefix: str = "lanes") -> dict:
    flat = worker_state_to_flat(lanes.worker, f"{prefix}.worker")
    flat[f"{prefix}.done"] = np.asarray(jax.device_get(lanes.done))
    flat[f"{prefix}.tag"] = np.asarray(lanes.tag, np.int32)
    flat[f"{prefix}.rounds"] = np.asarray(jax.device_get(lanes.rounds))
    return flat


def lane_state_from_flat(flat: dict, prefix: str = "lanes") -> LaneState:
    return LaneState(
        worker=worker_state_from_flat(flat, f"{prefix}.worker"),
        done=jnp.asarray(flat[f"{prefix}.done"]),
        tag=np.asarray(flat[f"{prefix}.tag"], np.int32),
        rounds=jnp.asarray(flat[f"{prefix}.rounds"]),
    )


def build_batch_superstep_fn(
    problem: BranchingProblem,
    datas: ProblemData,
    *,
    steps_per_round: int,
    lanes: int,
    policy_priority: bool = True,
    transfer_pad_words: int = 0,
    packed_status: bool = True,
    skip_empty_transfer: bool = True,
    transfer_impl: str = "sparse",
    donate_k: int = 1,
    explore_impl: str = "reference",
    axis_name: str = "workers",
):
    """Jitted ``state -> (state, done)`` over (B, P, ...) stacked state.

    ``datas`` is a batched :class:`ProblemData` (leading instance axis on
    ``n``/``adj``; ``word_idx``/``bit_idx`` shared).  ``done`` is (B,) bool —
    exact PER-INSTANCE quiescence.  One superstep always runs for every
    instance (no freezing); use :func:`build_batch_chunk_fn` for solve loops,
    which masks finished instances into no-op lanes.
    """
    step = functools.partial(
        superstep,
        problem,
        axis_name=axis_name,
        steps_per_round=steps_per_round,
        lanes=lanes,
        policy_priority=policy_priority,
        transfer_pad_words=transfer_pad_words,
        packed_status=packed_status,
        skip_empty_transfer=skip_empty_transfer,
        transfer_impl=transfer_impl,
        donate_k=donate_k,
        explore_impl=explore_impl,
    )

    def one_instance(data, state):
        state, done = jax.vmap(
            lambda s: step(data, s), axis_name=axis_name
        )(state)
        return state, done.all()

    bstep = jax.vmap(one_instance, in_axes=(DATA_IN_AXES, 0))

    def run(state):
        return bstep(datas, state)

    return jax.jit(run)


def build_batch_chunk_fn(
    problem: BranchingProblem,
    datas: ProblemData,
    *,
    steps_per_round: int,
    lanes: int,
    policy_priority: bool = True,
    transfer_pad_words: int = 0,
    packed_status: bool = True,
    skip_empty_transfer: bool = True,
    transfer_impl: str = "sparse",
    donate_k: int = 1,
    explore_impl: str = "reference",
    chunk_rounds: int = 16,
    fpt_bounds: Optional[jnp.ndarray] = None,
    axis_name: str = "workers",
):
    """Device-resident multi-round runner over a batch of instances.

    Returns a jitted ``(state, done) -> (state, done, rounds_delta, ran)``:

    * ``state``        (B, P, ...) stacked worker state;
    * ``done``         (B,) bool carried ACROSS chunks — instances that
      finished (quiescent, or FPT bound hit when ``fpt_bounds`` (B,) int32 is
      given; bounds are INTERNAL targets, ``problem.fpt_target(k)``) become
      no-op lanes: their state is frozen by a select, so stats
      stay bit-identical to a solo run while live instances keep stepping;
    * ``rounds_delta`` (B,) int32 supersteps each instance actually ran this
      chunk (0 for already-finished lanes);
    * ``ran``          () int32 supersteps the chunk executed (max over
      instances) — the host's ``max_rounds`` progress counter;
    * ``hot``          (B, P) int32 per-worker pending counts (the spill
      pump's trigger, see :func:`build_plane_fn`).

    The while_loop exits when EVERY instance is done or after
    ``chunk_rounds`` supersteps, so one straggler instance never forces the
    finished majority through extra host syncs — and the host can compact
    the batch between chunks (see ``engine.solve_many``).
    """
    plane = build_batch_plane_fn(
        problem,
        steps_per_round=steps_per_round,
        lanes=lanes,
        policy_priority=policy_priority,
        transfer_pad_words=transfer_pad_words,
        packed_status=packed_status,
        skip_empty_transfer=skip_empty_transfer,
        transfer_impl=transfer_impl,
        donate_k=donate_k,
        explore_impl=explore_impl,
        chunk_rounds=chunk_rounds,
        use_fpt=(fpt_bounds is not None),
        axis_name=axis_name,
    )
    if fpt_bounds is not None:
        bounds = jnp.asarray(fpt_bounds, jnp.int32)
        return lambda state, done: plane(datas, state, done, bounds)
    return lambda state, done: plane(datas, state, done)


def build_chunk_fn(
    problem: BranchingProblem,
    data: ProblemData,
    *,
    num_workers: int,
    steps_per_round: int,
    lanes: int,
    policy_priority: bool = True,
    transfer_pad_words: int = 0,
    packed_status: bool = True,
    skip_empty_transfer: bool = True,
    transfer_impl: str = "sparse",
    donate_k: int = 1,
    explore_impl: str = "reference",
    chunk_rounds: int = 16,
    fpt_bound: Optional[int] = None,
    mesh=None,
    axis_name: str = "workers",
):
    """Device-resident multi-round runner: ``state -> (state, done, ran,
    hot)`` with ``hot`` the (P,) per-worker pending counts after the chunk.

    Runs up to ``chunk_rounds`` supersteps inside ONE ``lax.while_loop`` on
    device, exiting early on exact global quiescence or (FPT mode) when the
    global best reaches ``fpt_bound``.  The host syncs once per call instead
    of once per round — the BSP cadence is set by the hardware, not by host
    dispatch latency.  ``ran`` is the number of supersteps executed (< K only
    when the run finished mid-chunk).

    vmap path: the while_loop wraps the vmapped superstep, predicate =
    all-workers quiescence.  shard_map path: the while_loop runs INSIDE the
    per-device body — the quiescence flag is already replicated by the psum
    in the superstep, so every device takes the same branch.
    """
    if chunk_rounds < 1:
        # 0 would return (state, done=False, ran=0) forever: the caller's
        # progress counter never advances and its solve loop cannot exit
        raise ValueError(f"chunk_rounds must be >= 1, got {chunk_rounds}")
    if mesh is None:
        plane = build_plane_fn(
            problem,
            steps_per_round=steps_per_round,
            lanes=lanes,
            policy_priority=policy_priority,
            transfer_pad_words=transfer_pad_words,
            packed_status=packed_status,
            skip_empty_transfer=skip_empty_transfer,
            transfer_impl=transfer_impl,
            donate_k=donate_k,
            explore_impl=explore_impl,
            chunk_rounds=chunk_rounds,
            use_fpt=(fpt_bound is not None),
            axis_name=axis_name,
        )
        if fpt_bound is not None:
            bound = jnp.int32(fpt_bound)
            return lambda state: plane(data, state, bound)
        return lambda state: plane(data, state)

    step = functools.partial(
        superstep,
        problem,
        data,
        axis_name=axis_name,
        steps_per_round=steps_per_round,
        lanes=lanes,
        policy_priority=policy_priority,
        transfer_pad_words=transfer_pad_words,
        packed_status=packed_status,
        skip_empty_transfer=skip_empty_transfer,
        transfer_impl=transfer_impl,
        donate_k=donate_k,
        explore_impl=explore_impl,
    )

    def cond(carry):
        _, done, i = carry
        return jnp.logical_not(done) & (i < chunk_rounds)

    from jax.sharding import PartitionSpec as P

    spec = P(axis_name)

    def block(state_block):
        state0 = jax.tree.map(lambda x: x[0], state_block)

        def body(carry):
            state, _, i = carry
            state, done = step(state)
            if fpt_bound is not None:
                # best_val is the global min after the pmin phase: replicated
                done = done | (state.best_val <= fpt_bound)
            return state, done, i + 1

        state, done, i = jax.lax.while_loop(
            cond, body, (state0, jnp.bool_(False), jnp.int32(0))
        )
        hot = state.frontier.active.sum().astype(jnp.int32)
        return jax.tree.map(lambda x: x[None], state), done, i, hot[None]

    return jax.jit(
        _shard_map(
            block, mesh=mesh, in_specs=(spec,),
            out_specs=(spec, P(), P(), spec),
        )
    )
