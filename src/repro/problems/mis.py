"""Maximum-independent-set plugin: the complement-graph reduction, end to end.

An independent set of G is a clique of the complement graph, so the whole
plugin is "branch like max-clique, but on complement adjacency": ``host_adj``
/ ``host_view`` swap in the complement for both the device tensors and the
host startup split, and every other callable is reused from
:mod:`repro.problems.max_clique` verbatim.  The solution mask the engine
returns is the independent set in the ORIGINAL graph (clique vertices of the
complement), which is what ``verify`` checks.

This file is the README's "adding a new problem in ~50 lines" walkthrough:
a complete NP-hard workload on the unchanged coordination machinery.
"""

from __future__ import annotations

from repro.graphs.bitgraph import complement
from repro.problems import max_clique, sequential
from repro.problems.base import BranchingProblem

SPEC = BranchingProblem(
    name="mis",
    objective="maximize |independent set|",
    branch_once=max_clique.branch_once,
    task_bound=max_clique.bound,
    child_bound=max_clique.bound,
    expand_tasks=max_clique.expand_tasks,  # fused hot path rides along too
    bnb_bound=lambda g: 1,  # just worse than the empty set (value 0)
    external_value=lambda v: -v,
    fpt_target=lambda k: -k,
    host_adj=lambda g: complement(g).adj,
    host_view=complement,
    branch_once_host=sequential.branch_once_clique,
    sequential=sequential.solve_sequential_mis,
    verify=sequential.verify_independent_set,
    host_task_bound=max_clique.host_bound,
    host_child_bound=max_clique.host_bound,
    host_terminal_value=max_clique.host_terminal_value,
)
